//! END-TO-END serving driver: boots the srds JSON-line server (PJRT
//! artifacts when built, native otherwise), replays a Poisson request
//! trace against it over TCP, and reports latency percentiles,
//! throughput, convergence statistics, and sample quality (CondScore) —
//! the full L3→L2→L1 stack under a realistic small-batch serving load
//! (the paper's motivating use case, §1 / §6).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_demo
//! ```
//!
//! Results for this run are recorded in EXPERIMENTS.md §End-to-end.

use srds::data::make_gmm;
use srds::exec::NativeFactory;
use srds::json;
use srds::metrics::cond_score;
use srds::model::{EpsModel, GmmEps};
use srds::runtime::PjrtFactory;
use srds::server::{serve, ServeConfig};
use srds::solvers::{BackendFactory, Solver};
use srds::workload::{generate_trace, percentile, TraceConfig};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;
use std::time::Instant;

fn main() -> srds::Result<()> {
    let model = "gmm_latent_cond";
    let workers = 4;
    let (factory, backend_kind): (Arc<dyn BackendFactory>, &str) =
        match PjrtFactory::new(srds::artifacts_dir(), model, Solver::Ddim) {
            Ok(f) => (Arc::new(f), "pjrt"),
            Err(_) => {
                let m: Arc<dyn EpsModel> = Arc::new(GmmEps::new(make_gmm("latent_cond")));
                (Arc::new(NativeFactory::new(m, Solver::Ddim)), "native")
            }
        };

    // Boot the server on an ephemeral port.
    let probe = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = probe.local_addr()?.to_string();
    drop(probe);
    {
        let addr = addr.clone();
        let model = model.to_string();
        std::thread::spawn(move || {
            let _ = serve(ServeConfig {
                addr,
                shards: srds::exec::default_shards(workers),
                workers,
                model_name: model,
                factory,
                batch: srds::batching::BatchPolicy::default(),
                max_inflight: srds::server::DEFAULT_MAX_INFLIGHT,
                default_deadline: None,
            });
        });
    }
    let mut stream = None;
    for _ in 0..100 {
        if let Ok(s) = std::net::TcpStream::connect(&addr) {
            stream = Some(s);
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    let stream = stream.expect("server did not come up");
    println!("server up on {addr} (backend={backend_kind}, workers={workers})");

    // Workload: Poisson arrivals of class-conditioned 25-step requests.
    let trace_cfg = TraceConfig { rate_hz: 4.0, num_requests: 48, n_steps: 25, num_classes: 4, seed: 99 };
    let trace = generate_trace(&trace_cfg);
    println!(
        "replaying {} requests, Poisson {} req/s, N = {} steps, guidance 7.5\n",
        trace.len(),
        trace_cfg.rate_hz,
        trace_cfg.n_steps
    );

    // Writer: paced submission; reader: collect completions.
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let t0 = Instant::now();
    let send_times: Arc<std::sync::Mutex<HashMap<u64, f64>>> =
        Arc::new(std::sync::Mutex::new(HashMap::new()));
    let st2 = send_times.clone();
    let trace2 = trace.clone();
    let sender = std::thread::spawn(move || {
        for req in &trace2 {
            let target = std::time::Duration::from_millis(req.arrival_ms);
            if let Some(wait) = target.checked_sub(t0.elapsed()) {
                std::thread::sleep(wait);
            }
            st2.lock().unwrap().insert(req.id, t0.elapsed().as_secs_f64() * 1e3);
            let line = format!(
                r#"{{"id":{},"sampler":"srds","n":{},"class":{},"guidance":7.5,"seed":{},"tol":0.0025}}"#,
                req.id,
                req.n,
                req.class.unwrap_or(0),
                req.seed
            );
            writeln!(writer, "{line}").unwrap();
        }
        writer.flush().unwrap();
        // Half-close so the server knows no more requests are coming.
        let _ = writer.shutdown(std::net::Shutdown::Write);
    });

    let gmm = make_gmm("latent_cond");
    let mut latencies = Vec::new();
    let mut iters_sum = 0.0;
    let mut eff_sum = 0.0;
    let mut scores = Vec::new();
    let mut done = 0usize;
    let expect = trace.len();
    let class_of: HashMap<u64, u32> =
        trace.iter().map(|r| (r.id, r.class.unwrap_or(0))).collect();
    for line in reader.lines() {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        let now_ms = t0.elapsed().as_secs_f64() * 1e3;
        let v = json::parse(&line)?;
        assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true), "{line}");
        let id = v.req("id")?.as_f64().unwrap() as u64;
        let sent = send_times.lock().unwrap()[&id];
        latencies.push(now_ms - sent);
        iters_sum += v.req("iters")?.as_f64().unwrap();
        eff_sum += v.req("eff_serial_evals_pipelined")?.as_f64().unwrap();
        let sample = v.req("sample")?.as_f32_vec().unwrap();
        scores.push(cond_score(&sample, 1, &gmm, Some(class_of[&id])));
        done += 1;
        if done == expect {
            break;
        }
    }
    sender.join().unwrap();
    let wall_s = t0.elapsed().as_secs_f64();

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean_lat = latencies.iter().sum::<f64>() / latencies.len() as f64;
    let mean_score = scores.iter().sum::<f64>() / scores.len() as f64;
    let mut t = srds::report::Table::new(
        "End-to-end serving (SRDS over the full rust+JAX+Pallas stack)",
        &["metric", "value"],
    );
    t.row(vec!["backend".into(), backend_kind.into()]);
    t.row(vec!["requests".into(), format!("{done}")]);
    t.row(vec!["throughput (req/s)".into(), format!("{:.1}", done as f64 / wall_s)]);
    t.row(vec!["mean latency (ms)".into(), format!("{mean_lat:.1}")]);
    t.row(vec!["p50 latency (ms)".into(), format!("{:.1}", percentile(&latencies, 0.5))]);
    t.row(vec!["p95 latency (ms)".into(), format!("{:.1}", percentile(&latencies, 0.95))]);
    t.row(vec!["p99 latency (ms)".into(), format!("{:.1}", percentile(&latencies, 0.99))]);
    t.row(vec!["mean SRDS iters".into(), format!("{:.2}", iters_sum / done as f64)]);
    t.row(vec![
        "mean eff serial evals (of 25 serial)".into(),
        format!("{:.1}", eff_sum / done as f64),
    ]);
    t.row(vec!["mean CondScore (sample quality)".into(), format!("{mean_score:.3}")]);
    t.print();
    println!("\nall {done} requests served; python was never on the request path.");
    Ok(())
}
