//! Figure 2: Parareal iterations on a toy ODE — the coarse init, the
//! parallel fine solves, and the predictor-corrector update, rendered as
//! an ASCII plot of the running trajectory against the fine reference.
//!
//! ```bash
//! cargo run --release --example figure2_parareal_toy
//! ```

use srds::coordinator::{sequential_trajectory, prior_sample, Conditioning};
use srds::model::AffineModel;
use srds::schedule::Partition;
use srds::solvers::{NativeBackend, Solver, StepBackend, StepRequest};
use std::sync::Arc;

fn main() {
    // 1-d affine model → a nontrivial linear probability-flow ODE.
    let model = Arc::new(AffineModel::new(1, 0.9, 0.4));
    let be = NativeBackend::new(model, Solver::Euler);
    let n = 64;
    let seed = 12;
    let x0 = prior_sample(1, seed);

    // Fine reference trajectory (the black curve of Fig. 2).
    let fine = sequential_trajectory(&be, &x0, n, &Conditioning::none(), seed);
    let fine_curve: Vec<f64> = fine.iter().map(|x| x[0] as f64).collect();

    let part = Partition::sqrt_n(n);
    println!(
        "toy ODE: N = {n} fine steps, {} blocks of {} (Fig. 2 reproduction)\n",
        part.num_blocks(),
        part.block()
    );
    let mut curves: Vec<(String, Vec<f64>)> = vec![("fine".into(), fine_curve)];
    for iters in [0usize, 1, 2] {
        let label = if iters == 0 { "coarse".to_string() } else { format!("iter{iters}") };
        curves.push((label, boundary_states(&be, &x0, n, iters, seed)));
    }
    let refs: Vec<(&str, &[f64])> =
        curves.iter().map(|(l, v)| (l.as_str(), v.as_slice())).collect();
    println!("{}", srds::viz::ascii_plot(&refs, 64, 18));
    println!("x-axis: denoising progress s ∈ [0,1]; y-axis: state x(s).");
    println!("Each refinement pulls the block boundaries onto the fine solution;");
    println!("after p iterations the first p boundaries match it exactly (Prop. 1).");
}

/// Block-boundary states of the SRDS iterate after `iters` refinements,
/// densified to the fine grid (piecewise-linear) for plotting.
fn boundary_states(
    be: &NativeBackend,
    x0: &[f32],
    n: usize,
    iters: usize,
    seed: u64,
) -> Vec<f64> {
    let part = Partition::sqrt_n(n);
    let m = part.num_blocks();
    let coarse = |x: &[f32], a: f32, b: f32| -> Vec<f32> {
        be.step(&StepRequest { x, s_from: &[a], s_to: &[b], mask: None, guidance: 0.0, seeds: &[seed] })
    };
    let fine = |x: &[f32], j: usize| -> Vec<f32> {
        let pts = part.block_points(j);
        let mut cur = x.to_vec();
        for w in pts.windows(2) {
            cur = be.step(&StepRequest {
                x: &cur,
                s_from: &[w[0]],
                s_to: &[w[1]],
                mask: None,
                guidance: 0.0,
                seeds: &[seed],
            });
        }
        cur
    };
    // Parareal on the block boundaries (Alg. 1, transcribed for clarity).
    let mut x: Vec<Vec<f32>> = vec![x0.to_vec()];
    let mut prev: Vec<Vec<f32>> = vec![vec![]];
    for i in 1..=m {
        let g = coarse(&x[i - 1], part.s_bound(i - 1), part.s_bound(i));
        x.push(g.clone());
        prev.push(g);
    }
    for _p in 0..iters {
        let y: Vec<Vec<f32>> = (0..m).map(|j| fine(&x[j], j)).collect();
        for i in 1..=m {
            let cur = coarse(&x[i - 1], part.s_bound(i - 1), part.s_bound(i));
            for t in 0..x[i].len() {
                x[i][t] = y[i - 1][t] + (cur[t] - prev[i][t]);
            }
            prev[i] = cur;
        }
    }
    // Densify boundaries to the fine grid.
    let mut out = Vec::with_capacity(n + 1);
    for j in 0..m {
        let (a, b) = (x[j][0] as f64, x[j + 1][0] as f64);
        let len = part.block_len(j);
        for t in 0..len {
            out.push(a + (b - a) * t as f64 / len as f64);
        }
    }
    out.push(x[m][0] as f64);
    out
}
