//! Figures 6 & 8: prompt-conditioned generations, SRDS (top row) vs the
//! serial trajectory (bottom row) — "essentially indistinguishable,
//! highlighting the approximation-free nature of SRDS".
//!
//! The "prompts" are the four classes of the conditional latent GMM
//! (guidance w = 7.5, as in the paper's Table 2 setup).
//!
//! ```bash
//! cargo run --release --example figure6_samples [--pjrt]
//! ```

use srds::coordinator::{prior_sample, sequential, Conditioning, ConvNorm, SamplerSpec};
use srds::data::make_gmm;
use srds::metrics::cond_score;
use srds::model::GmmEps;
use srds::runtime::{PjrtBackend, PjrtRuntime};
use srds::solvers::{NativeBackend, Solver, StepBackend};
use std::sync::Arc;

const PROMPTS: [&str; 4] = [
    "a black colored dog",
    "a kitten licking a baby duck",
    "a blue cup and a green cell phone",
    "a beautiful castle, matte painting",
];

fn main() -> srds::Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let backend: Box<dyn StepBackend> = if use_pjrt {
        let rt = Box::leak(Box::new(PjrtRuntime::open_default()?));
        Box::new(PjrtBackend::new(rt, "gmm_latent_cond", Solver::Ddim)?)
    } else {
        Box::new(NativeBackend::new(
            Arc::new(GmmEps::new(make_gmm("latent_cond"))),
            Solver::Ddim,
        ))
    };
    let gmm = make_gmm("latent_cond");
    let n = 100;
    let w = 7.5;

    println!("Figure 6/8 — class-conditioned 16×16 samples, SRDS vs serial (N = {n}, w = {w})\n");
    for (cls, prompt) in PROMPTS.iter().enumerate() {
        let cond = Conditioning::class(gmm.class_mask(cls as u32), w);
        let seed = 100 + cls as u64;
        let x0 = prior_sample(256, seed);
        let cfg = SamplerSpec::srds(n).with_tol(2.5e-3).with_cond(cond.clone()).with_seed(seed);
        let res = srds::coordinator::srds(backend.as_ref(), &x0, &cfg);
        let (seq, _) = sequential(backend.as_ref(), &x0, n, &cond, seed);
        let diff = ConvNorm::L1Mean.dist(&res.sample, &seq);
        let score_srds = cond_score(&res.sample, 1, &gmm, Some(cls as u32));
        let score_seq = cond_score(&seq, 1, &gmm, Some(cls as u32));
        println!(
            "\"{}\" (class {cls}): {} SRDS iters, |Δ|₁ = {diff:.1e}, CondScore srds {score_srds:.3} vs serial {score_seq:.3}",
            prompt, res.stats.iters
        );
        let srds_img = srds::viz::ascii_image(&res.sample, 16, 16);
        let seq_img = srds::viz::ascii_image(&seq, 16, 16);
        for (a, b) in srds_img.lines().zip(seq_img.lines()) {
            println!("  {a}    {b}");
        }
        println!("  {:^32}    {:^32}", "SRDS", "serial");
        srds::viz::write_pgm(
            std::path::Path::new(&format!("figure6_class{cls}_srds.pgm")),
            &res.sample,
            16,
            16,
        )?;
        srds::viz::write_pgm(
            std::path::Path::new(&format!("figure6_class{cls}_serial.pgm")),
            &seq,
            16,
            16,
        )?;
        println!();
    }
    println!("wrote figure6_class*_{{srds,serial}}.pgm");
    Ok(())
}
