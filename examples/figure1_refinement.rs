//! Figure 1: iterative refinement of one sample — the coarse solve is a
//! rough estimate that each SRDS iteration sharpens toward the exact
//! sequential generation ("a beautiful castle, matte painting" in the
//! paper; an 8×8 church-GMM sample here).
//!
//! ```bash
//! cargo run --release --example figure1_refinement [--pjrt]
//! ```
//!
//! Writes `figure1_iter<k>.pgm` next to an ASCII rendering of every
//! iterate and its ℓ1 distance to the sequential solution.

use srds::coordinator::{prior_sample, sequential, Conditioning, ConvNorm, SamplerSpec};
use srds::data::make_gmm;
use srds::model::GmmEps;
use srds::runtime::{PjrtBackend, PjrtRuntime};
use srds::solvers::{NativeBackend, Solver, StepBackend};
use std::sync::Arc;

fn main() -> srds::Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let backend: Box<dyn StepBackend> = if use_pjrt {
        let rt = Box::leak(Box::new(PjrtRuntime::open_default()?));
        Box::new(PjrtBackend::new(rt, "gmm_church", Solver::Ddim)?)
    } else {
        Box::new(NativeBackend::new(Arc::new(GmmEps::new(make_gmm("church"))), Solver::Ddim))
    };

    let n = 1024; // the paper's pixel-model trajectory length
    let seed = 1234;
    let x0 = prior_sample(64, seed);
    let (seq, _) = sequential(backend.as_ref(), &x0, n, &Conditioning::none(), seed);

    let cfg = SamplerSpec::srds(n)
        .with_tol(0.0)
        .with_max_iters(6)
        .with_iterates()
        .with_seed(seed);
    let res = srds::coordinator::srds(backend.as_ref(), &x0, &cfg);

    println!("Figure 1 — SRDS iterative refinement (N = {n}, church GMM)\n");
    for (k, iterate) in res.iterates.iter().enumerate() {
        let err = ConvNorm::L1Mean.dist(iterate, &seq);
        let label = if k == 0 { "coarse solve".to_string() } else { format!("after iteration {k}") };
        println!("--- {label}: |x − sequential|₁ = {err:.5}");
        println!("{}", srds::viz::ascii_image(iterate, 8, 8));
        let path = format!("figure1_iter{k}.pgm");
        srds::viz::write_pgm(std::path::Path::new(&path), iterate, 8, 8)?;
    }
    println!("--- sequential reference:");
    println!("{}", srds::viz::ascii_image(&seq, 8, 8));
    println!("wrote figure1_iter*.pgm (early convergence: the 3rd iterate already matches)");
    Ok(())
}
