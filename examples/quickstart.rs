//! Quickstart: generate one sample with SRDS and compare against the
//! sequential baseline — the 60-second tour of the public API.
//!
//! ```bash
//! make artifacts            # once; builds the AOT HLO artifacts
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the PJRT backend when artifacts are present, otherwise falls
//! back to the pure-rust native model (identical semantics).

use srds::coordinator::{prior_sample, registry, sequential, Conditioning, SamplerSpec};
use srds::data::make_gmm;
use srds::model::GmmEps;
use srds::runtime::{PjrtBackend, PjrtRuntime};
use srds::solvers::{NativeBackend, Solver, StepBackend};
use std::sync::Arc;

fn main() -> srds::Result<()> {
    let n = 256; // denoising steps
    let seed = 7;

    // 1. Pick a backend: AOT-compiled PJRT artifacts, or native rust.
    let rt = PjrtRuntime::open_default().ok();
    let backend: Box<dyn StepBackend> = match &rt {
        Some(rt) => {
            println!("backend: PJRT ({})", rt.platform());
            Box::new(PjrtBackend::new(rt, "gmm_church", Solver::Ddim)?)
        }
        None => {
            println!("backend: native (run `make artifacts` for the PJRT path)");
            Box::new(NativeBackend::new(Arc::new(GmmEps::new(make_gmm("church"))), Solver::Ddim))
        }
    };

    // 2. Draw the prior and run SRDS (Algorithm 1) through the sampler
    //    registry — the same dispatch the server and CLI use.
    println!("registered samplers: {}", registry().list().join(", "));
    let x0 = prior_sample(backend.dim(), seed);
    let cfg = SamplerSpec::srds(n).with_tol(2.5e-3).with_seed(seed);
    let t = std::time::Instant::now();
    let res = cfg.run(backend.as_ref(), &x0);
    let srds_ms = t.elapsed().as_secs_f64() * 1e3;

    // 3. Sequential baseline from the same prior.
    let t = std::time::Instant::now();
    let (seq, seq_stats) = sequential(backend.as_ref(), &x0, n, &Conditioning::none(), seed);
    let seq_ms = t.elapsed().as_secs_f64() * 1e3;

    let diff = cfg.norm.dist(&res.sample, &seq);
    println!("\nN = {n} steps, block = ⌈√N⌉ = {}", cfg.partition().block());
    println!(
        "SRDS:       {} iterations, eff serial evals {} (pipelined {}), total {}, {srds_ms:.1} ms",
        res.stats.iters,
        res.stats.eff_serial_evals,
        res.stats.eff_serial_evals_pipelined,
        res.stats.total_evals
    );
    println!("sequential: {} evals, {seq_ms:.1} ms", seq_stats.total_evals);
    println!(
        "latency speedup (eff serial evals): {:.1}x   |sample − sequential|₁ = {diff:.2e}",
        n as f64 / res.stats.eff_serial_evals_pipelined as f64
    );

    println!("\nthe generated 8×8 'image':");
    println!("{}", srds::viz::ascii_image(&res.sample, 8, 8));
    Ok(())
}
