//! Figures 3 & 4: the SRDS dependency graph scheduled as a pipeline —
//! prints the device-by-device gantt chart of the pipelined algorithm on
//! N = 16 denoising steps (the paper's illustration) and compares the
//! makespan against vanilla (barrier-per-iteration) execution.
//!
//! ```bash
//! cargo run --release --example figure4_pipeline_trace
//! ```

use srds::exec::{simulate_srds, SimReport};
use srds::schedule::Partition;

fn show(report: &SimReport, title: &str) {
    println!("--- {title}: makespan {} eval-units, peak concurrency {}, utilization {:.0}%",
        report.makespan, report.peak_concurrency, report.utilization * 100.0);
    let spans: Vec<(String, usize, u64, u64)> = report
        .spans
        .iter()
        .map(|&(task, dev, s, e)| (format!("{task}"), dev, s, e))
        .collect();
    // Label lanes with F/G by duration (fine solves are longer).
    let labeled: Vec<(String, usize, u64, u64)> = spans
        .iter()
        .map(|(_, dev, s, e)| {
            let kind = if e - s > 1 { "F" } else { "g" };
            (kind.to_string(), *dev, *s, *e)
        })
        .collect();
    println!("{}", srds::viz::ascii_gantt(&labeled, 72));
}

fn main() {
    let n = 16;
    let part = Partition::sqrt_n(n); // 4 blocks of 4
    let m = part.num_blocks();
    let iters = m; // worst case: full convergence
    println!(
        "SRDS pipeline on N = {n} (blocks = {m}, fine steps/block = {}), {iters} refinements\n",
        part.block()
    );
    println!("F = fine-solve step span, g = coarse step\n");

    let devices = m + 1;
    let pipelined = simulate_srds(&part, iters, 1, devices, true);
    let vanilla = simulate_srds(&part, iters, 1, devices, false);
    show(&pipelined, &format!("pipelined, {devices} devices (Fig. 4)"));
    show(&vanilla, &format!("vanilla (iteration barrier), {devices} devices"));
    println!(
        "pipelining speedup at equal devices: {:.2}x (paper: ~2x)",
        vanilla.makespan as f64 / pipelined.makespan as f64
    );
    println!(
        "worst-case pipelined makespan == N = {} (Prop. 2 ✓)",
        pipelined.makespan
    );
}
