//! Fixture tests: every rule must fire on its known-bad snippet and be
//! suppressed by exactly its own waiver.

use srds_lint::{analyze_file, check_wire_schema, cycle_findings, FileReport, Rule};

fn load(name: &str) -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/fixtures");
    std::fs::read_to_string(format!("{path}/{name}")).unwrap_or_else(|e| panic!("fixture {name}: {e}"))
}

fn analyze(name: &str) -> FileReport {
    analyze_file(name, &load(name), &Rule::ALL)
}

fn unwaived(rep: &FileReport) -> Vec<(Rule, usize)> {
    rep.findings
        .iter()
        .filter(|f| f.waived.is_none())
        .map(|f| (f.rule, f.line))
        .collect()
}

#[test]
fn hot_path_alloc_fires_only_in_marked_fn() {
    let rep = analyze("hot_alloc_bad.rs");
    let v = unwaived(&rep);
    assert_eq!(v.len(), 4, "Vec::new, to_vec, Box::new, collect: {v:?}");
    assert!(v.iter().all(|(r, _)| *r == Rule::HotPathAlloc));
    // The vec! in the unmarked `cold` fn (line 18) must not fire.
    assert!(v.iter().all(|(_, line)| *line < 15), "{v:?}");
}

#[test]
fn hot_path_alloc_waivers_suppress() {
    let rep = analyze("hot_alloc_waived.rs");
    assert!(unwaived(&rep).is_empty(), "{:?}", unwaived(&rep));
    assert_eq!(rep.findings.iter().filter(|f| f.waived.is_some()).count(), 3);
    assert!(rep.unused_waivers.is_empty(), "{:?}", rep.unused_waivers);
}

#[test]
fn step_convenience_fires_outside_tests_only() {
    let rep = analyze("step_bad.rs");
    let v = unwaived(&rep);
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].0, Rule::NoStepConvenience);
    // The #[cfg(test)] call sits past line 12 and must be exempt.
    assert!(v[0].1 < 12, "{v:?}");
}

#[test]
fn step_convenience_waiver_suppresses() {
    let rep = analyze("step_waived.rs");
    assert!(unwaived(&rep).is_empty(), "{:?}", unwaived(&rep));
    assert_eq!(rep.findings.len(), 1);
}

#[test]
fn lock_cycle_across_fns_is_reported_once() {
    let rep = analyze("lock_cycle_bad.rs");
    assert!(unwaived(&rep).is_empty(), "per-fn sequences are clean: {:?}", unwaived(&rep));
    assert_eq!(rep.edges.len(), 2, "{:?}", rep.edges);
    let cycles = cycle_findings(&rep.edges);
    assert_eq!(cycles.len(), 1, "{cycles:?}");
    assert!(cycles[0].msg.contains("cycle"));
}

#[test]
fn lock_held_across_step_fires_and_scopes_release() {
    let rep = analyze("lock_held_bad.rs");
    let v = unwaived(&rep);
    assert_eq!(v.len(), 1, "only `held` should fire: {v:?}");
    assert_eq!(v[0].0, Rule::LockOrder);
    assert!(rep.findings[0].msg.contains("held across solver step"));
}

#[test]
fn lock_waivers_suppress_and_drop_edges() {
    let rep = analyze("lock_waived.rs");
    assert!(unwaived(&rep).is_empty(), "{:?}", unwaived(&rep));
    assert_eq!(rep.findings.iter().filter(|f| f.waived.is_some()).count(), 2);
    assert!(rep.edges.is_empty(), "waived edge must leave the graph: {:?}", rep.edges);
}

#[test]
fn panic_policy_fires_only_in_marked_fn() {
    let rep = analyze("panic_bad.rs");
    let v = unwaived(&rep);
    assert_eq!(v.len(), 3, "unwrap, expect, panic!: {v:?}");
    assert!(v.iter().all(|(r, _)| *r == Rule::PanicPolicy));
    // `tolerant` (unwrap_or) and `unmarked` must both stay clean.
    assert!(v.iter().all(|(_, line)| *line < 14), "{v:?}");
}

#[test]
fn panic_policy_waiver_suppresses() {
    let rep = analyze("panic_waived.rs");
    assert!(unwaived(&rep).is_empty(), "{:?}", unwaived(&rep));
    assert_eq!(rep.findings.len(), 1);
}

#[test]
fn waiver_suppresses_exactly_its_rule() {
    let rep = analyze("cross_rule.rs");
    let v = unwaived(&rep);
    assert_eq!(v.len(), 1, "panic-policy must survive the alloc waiver: {v:?}");
    assert_eq!(v[0].0, Rule::PanicPolicy);
    let waived: Vec<_> = rep.findings.iter().filter(|f| f.waived.is_some()).collect();
    assert_eq!(waived.len(), 1);
    assert_eq!(waived[0].rule, Rule::HotPathAlloc);
}

#[test]
fn disabled_rules_do_not_run() {
    let rep = analyze_file("hot_alloc_bad.rs", &load("hot_alloc_bad.rs"), &[Rule::PanicPolicy]);
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn wire_schema_in_sync_is_clean() {
    let f = check_wire_schema(&load("wire_good.md"), "wire_good.md", &load("wire_server.rs"), "wire_server.rs");
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn wire_schema_drift_fires_both_directions() {
    let f = check_wire_schema(&load("wire_bad.md"), "wire_bad.md", &load("wire_server.rs"), "wire_server.rs");
    assert_eq!(f.len(), 2, "{f:?}");
    assert!(f.iter().any(|x| x.file == "wire_server.rs" && x.msg.contains("`n`")), "{f:?}");
    assert!(f.iter().any(|x| x.file == "wire_bad.md" && x.msg.contains("`bogus`")), "{f:?}");
}

#[test]
fn wire_schema_missing_anchor_fires() {
    // The fixture server implements only from_json + success_response,
    // so exactly those two pairs are active and demand their anchors;
    // the framed-dialect pairs stay silent with their fns absent.
    let f = check_wire_schema("# no anchors here\n", "empty.md", &load("wire_server.rs"), "wire_server.rs");
    assert_eq!(f.len(), 2, "one per missing anchor of an active pair: {f:?}");
    assert!(f.iter().all(|x| x.msg.contains("lint-anchor")));
}

#[test]
fn wire_frame_pairs_activate_only_when_their_fns_exist() {
    // Error serializer (pair heads), envelope (pair heads) and the
    // error-kind registry (match-arm values) in sync — and no findings
    // for the request/response pairs, whose fns this fixture lacks.
    let f = check_wire_schema(
        &load("wire_frames_good.md"),
        "wire_frames_good.md",
        &load("wire_frames_server.rs"),
        "wire_frames_server.rs",
    );
    assert!(f.is_empty(), "{f:?}");
    // Dropping a kind row fires the match-arm direction (code → docs).
    let doc = load("wire_frames_good.md").replace("| `overloaded` | admission cap |\n", "");
    let f = check_wire_schema(&doc, "doc.md", &load("wire_frames_server.rs"), "s.rs");
    assert_eq!(f.len(), 1, "{f:?}");
    assert!(
        f[0].msg.contains("error kind") && f[0].msg.contains("`overloaded`"),
        "{f:?}"
    );
    // A documented kind the registry never returns fires the other way.
    let doc = load("wire_frames_good.md")
        .replace("| `parse` | malformed line |", "| `parse` | malformed line |\n| `ghost` | nothing |");
    let f = check_wire_schema(&doc, "doc.md", &load("wire_frames_server.rs"), "s.rs");
    assert_eq!(f.len(), 1, "{f:?}");
    assert!(f[0].msg.contains("`ghost`") && f[0].msg.contains("documented"), "{f:?}");
}

#[test]
fn unknown_rule_in_waiver_is_a_finding() {
    let rep = analyze_file("inline", "// lint-allow(no-such-rule): oops\nfn f() {}\n", &Rule::ALL);
    assert_eq!(rep.findings.len(), 1);
    assert!(rep.findings[0].msg.contains("unknown rule"));
}
