//! CLI for `srds-lint`. Exit status 1 iff any unwaived violation exists.
//!
//! ```text
//! srds-lint [--root PATH] [--rule NAME]... [--list-rules]
//! ```
//!
//! With no `--rule` flags all five rules run. Waived findings and unused
//! waivers are printed (but do not fail the run) so suppressions stay
//! visible in CI logs.

use srds_lint::{run, Rule};
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut rules: Vec<Rule> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => return usage("--root needs a path"),
            },
            "--rule" => match args.next().as_deref().and_then(Rule::parse) {
                Some(r) => rules.push(r),
                None => return usage("--rule needs one of the names from --list-rules"),
            },
            "--list-rules" => {
                for r in Rule::ALL {
                    println!("{r}");
                }
                return ExitCode::SUCCESS;
            }
            other => return usage(&format!("unknown argument `{other}`")),
        }
    }
    if rules.is_empty() {
        rules = Rule::ALL.to_vec();
    }

    let report = match run(&root, &rules) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("srds-lint: failed to read sources under {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    };

    let mut violations = 0usize;
    for f in report.violations() {
        println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg);
        violations += 1;
    }
    let waived: Vec<_> = report.waived().collect();
    if !waived.is_empty() {
        println!("-- {} waiver(s) in effect:", waived.len());
        for f in &waived {
            println!("   {}:{}: [{}] waived: {}", f.file, f.line, f.rule, f.waived.as_deref().unwrap_or(""));
        }
    }
    for (file, line, rule, reason) in &report.unused_waivers {
        println!("-- warning: unused lint-allow({rule}) at {file}:{line} ({reason})");
    }
    println!(
        "srds-lint: {} file(s) scanned, {} violation(s), {} waiver(s)",
        report.files_scanned,
        violations,
        waived.len()
    );
    if violations == 0 {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn usage(err: &str) -> ExitCode {
    eprintln!("srds-lint: {err}");
    eprintln!("usage: srds-lint [--root PATH] [--rule NAME]... [--list-rules]");
    ExitCode::FAILURE
}
