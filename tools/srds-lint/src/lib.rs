//! `srds-lint` — in-repo invariant analyzer for the SRDS serving crate.
//!
//! The serving spine rests on contracts that `clippy` cannot know and that
//! external lint frameworks cannot be vendored into a hermetic build:
//!
//! * **hot-path-alloc** — regions marked `// lint: hot-path` (the
//!   `step_into` implementations, `BatchStage::execute`, the dispatcher
//!   poll/flush loop, the batcher drain) must not allocate.
//! * **no-step-convenience** — the allocating `StepBackend::step` wrapper
//!   is banned outside `#[cfg(test)]` code.
//! * **lock-order** — per-function `Mutex` acquisition sequences must form
//!   an acyclic graph, and no lock may be held across a solver step.
//! * **panic-policy** — functions marked `// lint: request-path` (the
//!   request-controlled parse/dispatch paths) must not `unwrap`/`expect`/
//!   `panic!`.
//! * **wire-schema-sync** — the DESIGN.md wire tables (marked by
//!   `<!-- lint-anchor: ... -->` comments) must match the fields the
//!   server actually parses and serializes, in both directions. Since
//!   the v1 framed dialect this is a table of fn↔anchor pairs: the
//!   request reader, every frame serializer (`success_response`,
//!   `error_frame`, `stats_response`, the envelope and the streaming
//!   `ack`/`iterate` frames), and the `kind_name` error-kind registry.
//!
//! Any finding can be waived in place with
//! `// lint-allow(<rule>): <reason>` on (or directly above) the offending
//! line; waivers are counted and printed so they stay visible.
//!
//! The analysis is a deliberate *lexical* approximation: a byte-level
//! lexer blanks comments and string/char literals, then token scans run
//! over function spans. No `syn`, no dependencies — the tool builds
//! hermetically, like the crate it checks.

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::path::{Path, PathBuf};

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

/// The five checked invariants. Each is independently toggleable from the
/// CLI and independently waivable in source.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rule {
    HotPathAlloc,
    NoStepConvenience,
    LockOrder,
    PanicPolicy,
    WireSchemaSync,
}

impl Rule {
    pub const ALL: [Rule; 5] = [
        Rule::HotPathAlloc,
        Rule::NoStepConvenience,
        Rule::LockOrder,
        Rule::PanicPolicy,
        Rule::WireSchemaSync,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Rule::HotPathAlloc => "hot-path-alloc",
            Rule::NoStepConvenience => "no-step-convenience",
            Rule::LockOrder => "lock-order",
            Rule::PanicPolicy => "panic-policy",
            Rule::WireSchemaSync => "wire-schema-sync",
        }
    }

    pub fn parse(s: &str) -> Option<Rule> {
        Rule::ALL.into_iter().find(|r| r.name() == s)
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One violation (or waived would-be violation) at a source location.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: Rule,
    pub file: String,
    pub line: usize,
    pub msg: String,
    /// `Some(reason)` when a `lint-allow` waiver suppressed this finding.
    pub waived: Option<String>,
}

/// A directed "held `from`, then acquired `to`" edge for the global lock
/// graph. Edges survive per-file analysis so cycles across files are seen.
#[derive(Debug, Clone)]
pub struct LockEdge {
    pub from: String,
    pub to: String,
    pub file: String,
    pub line: usize,
}

// ---------------------------------------------------------------------------
// Lexer
// ---------------------------------------------------------------------------

/// Lexed view of one source file: the `code` buffer is byte-for-byte the
/// input with comments and string/char-literal bytes blanked to spaces
/// (newlines preserved), so token scans never match inside either.
pub struct Lexed {
    pub code: Vec<u8>,
    /// Raw (unblanked) source, used for marker/waiver comment scans.
    pub raw: String,
    /// String literals as (start byte incl. quote, end byte excl., contents).
    pub strings: Vec<(usize, usize, String)>,
    /// Byte offset of each line start; index = line number - 1.
    pub line_starts: Vec<usize>,
}

fn is_ident(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

impl Lexed {
    /// 1-based line number of a byte offset.
    pub fn line_of(&self, pos: usize) -> usize {
        match self.line_starts.binary_search(&pos) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }
}

/// Blank comments and literals out of `src`. The lexer understands line
/// comments, nested block comments, plain/raw/byte strings and char
/// literals (distinguishing lifetimes), which is all the surface the
/// checked crate uses.
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let n = b.len();
    let mut code = b.to_vec();
    let mut strings = Vec::new();
    let mut line_starts = vec![0usize];
    for (i, &c) in b.iter().enumerate() {
        if c == b'\n' && i + 1 < n {
            line_starts.push(i + 1);
        }
    }

    fn blank(code: &mut [u8], from: usize, to: usize) {
        for x in code[from..to].iter_mut() {
            if *x != b'\n' {
                *x = b' ';
            }
        }
    }

    /// Scan a plain string body starting at the opening quote; returns one
    /// past the closing quote.
    fn scan_string(b: &[u8], open: usize) -> usize {
        let mut j = open + 1;
        while j < b.len() {
            match b[j] {
                b'\\' => j += 2,
                b'"' => return j + 1,
                _ => j += 1,
            }
        }
        b.len()
    }

    /// Raw string `r##"..."##` starting at `open` (the `r`); returns
    /// (content_start, end) or None if this is not a raw-string head.
    fn scan_raw(b: &[u8], open: usize) -> Option<(usize, usize)> {
        let mut k = open + 1;
        let mut hashes = 0usize;
        while k < b.len() && b[k] == b'#' {
            hashes += 1;
            k += 1;
        }
        if k >= b.len() || b[k] != b'"' {
            return None;
        }
        let content = k + 1;
        let mut e = content;
        while e < b.len() {
            if b[e] == b'"' {
                let mut h = 0;
                while h < hashes && e + 1 + h < b.len() && b[e + 1 + h] == b'#' {
                    h += 1;
                }
                if h == hashes {
                    return Some((content, e + 1 + hashes));
                }
            }
            e += 1;
        }
        Some((content, b.len()))
    }

    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            blank(&mut code, i, j);
            i = j;
        } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut code, i, j);
            i = j;
        } else if c == b'"' {
            let end = scan_string(b, i);
            strings.push((i, end, String::from_utf8_lossy(&b[i + 1..end.saturating_sub(1)]).into_owned()));
            blank(&mut code, i, end);
            i = end;
        } else if (c == b'r' || c == b'b') && (i == 0 || !is_ident(b[i - 1])) {
            // r"..." / r#"..."# / b"..." / br"..." / b'x'
            let raw_at = if c == b'r' {
                Some(i)
            } else if i + 1 < n && b[i + 1] == b'r' {
                Some(i + 1)
            } else {
                None
            };
            if let Some((content, end)) = raw_at.and_then(|p| scan_raw(b, p)) {
                strings.push((i, end, String::from_utf8_lossy(&b[content..end.saturating_sub(1)]).into_owned()));
                blank(&mut code, i, end);
                i = end;
            } else if c == b'b' && i + 1 < n && b[i + 1] == b'"' {
                let end = scan_string(b, i + 1);
                strings.push((i, end, String::from_utf8_lossy(&b[i + 2..end.saturating_sub(1)]).into_owned()));
                blank(&mut code, i, end);
                i = end;
            } else if c == b'b' && i + 1 < n && b[i + 1] == b'\'' {
                let mut j = i + 2;
                while j < n && b[j] != b'\'' {
                    j += if b[j] == b'\\' { 2 } else { 1 };
                }
                blank(&mut code, i, (j + 1).min(n));
                i = (j + 1).min(n);
            } else {
                i += 1;
            }
        } else if c == b'\'' {
            // Char literal vs. lifetime: a literal is '\...' or 'x'.
            if i + 1 < n && b[i + 1] == b'\\' {
                let mut j = i + 2;
                while j < n && b[j] != b'\'' {
                    j += if b[j] == b'\\' { 2 } else { 1 };
                }
                blank(&mut code, i, (j + 1).min(n));
                i = (j + 1).min(n);
            } else if i + 2 < n && b[i + 2] == b'\'' && b[i + 1] != b'\'' {
                blank(&mut code, i, i + 3);
                i += 3;
            } else {
                i += 1; // lifetime
            }
        } else {
            i += 1;
        }
    }

    Lexed { code, raw: src.to_string(), strings, line_starts }
}

/// Byte index one past the `}` matching the `{` at `open` (in blanked code).
fn match_brace(code: &[u8], open: usize) -> usize {
    let mut depth = 0usize;
    let mut i = open;
    while i < code.len() {
        match code[i] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return i + 1;
                }
            }
            _ => {}
        }
        i += 1;
    }
    code.len()
}

/// Find `pat` in `code` at or after `from`; ident-boundary-checked on the
/// left when the pattern starts with an identifier character.
fn find_token(code: &[u8], from: usize, pat: &[u8]) -> Option<usize> {
    let mut i = from;
    while i + pat.len() <= code.len() {
        if &code[i..i + pat.len()] == pat {
            let ok_left = !is_ident(pat[0]) || i == 0 || !is_ident(code[i - 1]);
            if ok_left {
                return Some(i);
            }
        }
        i += 1;
    }
    None
}

/// `#[cfg(test)]`-gated byte spans (test modules and test fns).
pub fn test_spans(lx: &Lexed) -> Vec<(usize, usize)> {
    let code = &lx.code;
    let pat = b"#[cfg(test)]";
    let mut spans = Vec::new();
    let mut i = 0usize;
    while let Some(p) = find_token(code, i, pat) {
        // Skip further attributes / the item header to the body brace.
        let mut j = p + pat.len();
        while j < code.len() && code[j] != b'{' && code[j] != b';' {
            j += 1;
        }
        if j < code.len() && code[j] == b'{' {
            let end = match_brace(code, j);
            spans.push((p, end));
            i = end;
        } else {
            i = j + 1;
        }
    }
    spans
}

fn in_spans(spans: &[(usize, usize)], pos: usize) -> bool {
    spans.iter().any(|&(s, e)| pos >= s && pos < e)
}

/// One `fn` item with a body.
pub struct FnSpan {
    pub name: String,
    /// Byte offset of the `fn` keyword.
    pub decl: usize,
    /// Body span: `{` offset .. one past `}`.
    pub body: (usize, usize),
}

/// Extract every `fn name(..) { .. }` span (trait-method declarations
/// without bodies and `fn(..)` pointer types are skipped).
pub fn fn_spans(lx: &Lexed) -> Vec<FnSpan> {
    let code = &lx.code;
    let n = code.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 2 <= n {
        let kw = code[i] == b'f'
            && code[i + 1] == b'n'
            && (i == 0 || !is_ident(code[i - 1]))
            && (i + 2 >= n || !is_ident(code[i + 2]));
        if !kw {
            i += 1;
            continue;
        }
        let mut j = i + 2;
        while j < n && code[j].is_ascii_whitespace() {
            j += 1;
        }
        let name_start = j;
        while j < n && is_ident(code[j]) {
            j += 1;
        }
        if j == name_start {
            i += 2; // `fn(` pointer type — no name follows
            continue;
        }
        let name = String::from_utf8_lossy(&code[name_start..j]).into_owned();
        // Body starts at the first `{` outside the generics/args/return
        // type; a `;` first means a bodiless trait declaration.
        let mut depth = 0i32;
        let mut k = j;
        let mut body_open = None;
        while k < n {
            match code[k] {
                b'(' | b'[' => depth += 1,
                b')' | b']' => depth -= 1,
                b'{' if depth == 0 => {
                    body_open = Some(k);
                    break;
                }
                b';' if depth == 0 => break,
                _ => {}
            }
            k += 1;
        }
        if let Some(open) = body_open {
            let close = match_brace(code, open);
            out.push(FnSpan { name, decl: i, body: (open, close) });
        }
        i = j;
    }
    out
}

// ---------------------------------------------------------------------------
// Markers and waivers
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Marker {
    HotPath,
    RequestPath,
}

/// `// lint: hot-path` / `// lint: request-path` comment lines; each
/// attaches to the first `fn` declared after it.
pub fn markers(lx: &Lexed) -> Vec<(usize, Marker)> {
    let mut out = Vec::new();
    for (idx, line) in lx.raw.lines().enumerate() {
        let t = line.trim();
        let m = if t == "// lint: hot-path" {
            Some(Marker::HotPath)
        } else if t == "// lint: request-path" {
            Some(Marker::RequestPath)
        } else {
            None
        };
        if let Some(m) = m {
            out.push((idx + 1, m));
        }
    }
    out
}

#[derive(Debug, Clone)]
pub struct Waiver {
    pub line: usize,
    pub rule: Rule,
    pub reason: String,
}

/// Parse every `lint-allow(<rule>): <reason>` comment. Malformed rule
/// names come back as findings so typos don't silently disable checks.
pub fn waivers(lx: &Lexed, file: &str) -> (Vec<Waiver>, Vec<Finding>) {
    let mut out = Vec::new();
    let mut bad = Vec::new();
    for (idx, line) in lx.raw.lines().enumerate() {
        let Some(p) = line.find("lint-allow(") else { continue };
        let rest = &line[p + "lint-allow(".len()..];
        let Some(close) = rest.find(')') else {
            bad.push(Finding {
                rule: Rule::WireSchemaSync,
                file: file.to_string(),
                line: idx + 1,
                msg: "malformed lint-allow: missing `)`".into(),
                waived: None,
            });
            continue;
        };
        let rule_name = rest[..close].trim();
        let reason = rest[close + 1..].trim_start_matches(':').trim().to_string();
        match Rule::parse(rule_name) {
            Some(rule) => out.push(Waiver { line: idx + 1, rule, reason }),
            None => bad.push(Finding {
                rule: Rule::WireSchemaSync,
                file: file.to_string(),
                line: idx + 1,
                msg: format!("lint-allow names unknown rule `{rule_name}`"),
                waived: None,
            }),
        }
    }
    (out, bad)
}

/// Resolve the waiver covering a finding at `line`, if any: a waiver of
/// the same rule on the same line, or directly above it (only blank lines,
/// comments and attributes may intervene).
fn find_waiver(waivers: &[Waiver], raw_lines: &[&str], line: usize, rule: Rule) -> Option<usize> {
    if let Some(i) = waivers.iter().position(|w| w.line == line && w.rule == rule) {
        return Some(i);
    }
    let mut l = line.saturating_sub(1);
    while l >= 1 {
        if let Some(i) = waivers.iter().position(|w| w.line == l && w.rule == rule) {
            return Some(i);
        }
        let t = raw_lines.get(l - 1).map(|s| s.trim()).unwrap_or("");
        if t.is_empty() || t.starts_with("//") || t.starts_with("#[") {
            l -= 1;
        } else {
            break;
        }
    }
    None
}

// ---------------------------------------------------------------------------
// Per-file analysis
// ---------------------------------------------------------------------------

/// Allocation tokens banned inside `// lint: hot-path` regions. Lexical on
/// purpose: `.cloned()` iterator adapters and `unwrap_or(..)` do not match.
const ALLOC_TOKENS: &[&str] = &[
    "vec!",
    "Vec::new",
    "Vec::with_capacity",
    ".to_vec(",
    ".collect(",
    ".collect::<",
    "Box::new",
    ".clone(",
    ".to_string(",
    ".to_owned(",
    "format!",
    "String::new",
    "String::with_capacity",
];

/// Panic tokens banned inside `// lint: request-path` regions.
/// `.unwrap()` is matched exactly so `unwrap_or(..)` stays legal.
const PANIC_TOKENS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
];

/// Result of analyzing one file. `findings` includes waived entries (with
/// `waived: Some(..)`); `edges` feed the global lock graph.
pub struct FileReport {
    pub findings: Vec<Finding>,
    pub edges: Vec<LockEdge>,
    pub unused_waivers: Vec<(usize, Rule, String)>,
}

pub fn analyze_file(file: &str, src: &str, rules: &[Rule]) -> FileReport {
    let lx = lex(src);
    let raw_lines: Vec<&str> = lx.raw.lines().collect();
    let tests = test_spans(&lx);
    let fns = fn_spans(&lx);
    let (wv, mut findings) = waivers(&lx, file);
    let mut used = vec![false; wv.len()];
    let mut edges = Vec::new();

    let enabled = |r: Rule| rules.contains(&r);

    // Attach each marker to the first fn declared after it.
    let mut hot_fns: Vec<usize> = Vec::new();
    let mut req_fns: Vec<usize> = Vec::new();
    for &(mline, m) in &markers(&lx) {
        let target = fns
            .iter()
            .enumerate()
            .filter(|(_, f)| lx.line_of(f.decl) > mline)
            .min_by_key(|(_, f)| f.decl)
            .map(|(i, _)| i);
        match target {
            Some(i) => match m {
                Marker::HotPath => hot_fns.push(i),
                Marker::RequestPath => req_fns.push(i),
            },
            None => findings.push(Finding {
                rule: if m == Marker::HotPath { Rule::HotPathAlloc } else { Rule::PanicPolicy },
                file: file.to_string(),
                line: mline,
                msg: "lint marker is not followed by any fn".into(),
                waived: None,
            }),
        }
    }

    let mut emit = |rule: Rule, line: usize, msg: String, findings: &mut Vec<Finding>| {
        let waived = find_waiver(&wv, &raw_lines, line, rule).map(|i| {
            used[i] = true;
            wv[i].reason.clone()
        });
        findings.push(Finding { rule, file: file.to_string(), line, msg, waived });
    };

    // --- hot-path-alloc -----------------------------------------------------
    if enabled(Rule::HotPathAlloc) {
        for &fi in &hot_fns {
            let f = &fns[fi];
            for tok in ALLOC_TOKENS {
                let mut at = f.body.0;
                while let Some(p) = find_token(&lx.code, at, tok.as_bytes()) {
                    if p >= f.body.1 {
                        break;
                    }
                    emit(
                        Rule::HotPathAlloc,
                        lx.line_of(p),
                        format!("allocation in hot-path fn `{}`: `{}`", f.name, tok.trim_end_matches('(')),
                        &mut findings,
                    );
                    at = p + tok.len();
                }
            }
        }
    }

    // --- no-step-convenience ------------------------------------------------
    if enabled(Rule::NoStepConvenience) {
        let mut at = 0usize;
        while let Some(p) = find_token(&lx.code, at, b".step(") {
            if !in_spans(&tests, p) {
                emit(
                    Rule::NoStepConvenience,
                    lx.line_of(p),
                    "allocating `StepBackend::step` call outside tests (use `step_into` with a pooled buffer)".into(),
                    &mut findings,
                );
            }
            at = p + ".step(".len();
        }
    }

    // --- panic-policy -------------------------------------------------------
    if enabled(Rule::PanicPolicy) {
        for &fi in &req_fns {
            let f = &fns[fi];
            for tok in PANIC_TOKENS {
                let mut at = f.body.0;
                while let Some(p) = find_token(&lx.code, at, tok.as_bytes()) {
                    if p >= f.body.1 {
                        break;
                    }
                    emit(
                        Rule::PanicPolicy,
                        lx.line_of(p),
                        format!(
                            "`{}` in request-path fn `{}`",
                            tok.trim_end_matches('('),
                            f.name
                        ),
                        &mut findings,
                    );
                    at = p + tok.len();
                }
            }
        }
    }

    // --- lock-order ---------------------------------------------------------
    if enabled(Rule::LockOrder) {
        for f in &fns {
            if in_spans(&tests, f.decl) {
                continue;
            }
            lock_scan(&lx, f, file, &wv, &raw_lines, &mut used, &mut findings, &mut edges);
        }
    }

    let unused_waivers = wv
        .iter()
        .zip(&used)
        .filter(|(_, &u)| !u)
        .map(|(w, _)| (w.line, w.rule, w.reason.clone()))
        .collect();

    FileReport { findings, edges, unused_waivers }
}

/// Scan one fn body for `.lock(` acquisitions; within each guard's
/// estimated scope, record held→acquired edges and flag solver steps.
#[allow(clippy::too_many_arguments)]
fn lock_scan(
    lx: &Lexed,
    f: &FnSpan,
    file: &str,
    wv: &[Waiver],
    raw_lines: &[&str],
    used: &mut [bool],
    findings: &mut Vec<Finding>,
    edges: &mut Vec<LockEdge>,
) {
    let code = &lx.code;
    let (body_start, body_end) = f.body;
    let mut at = body_start;
    while let Some(p) = find_token(code, at, b".lock(") {
        if p >= body_end {
            break;
        }
        at = p + ".lock(".len();
        let name = receiver_name(code, p);

        // Statement start: walk back to the previous `;`/`{`/`}`.
        let mut s = p;
        while s > body_start && !matches!(code[s - 1], b';' | b'{' | b'}') {
            s -= 1;
        }
        while s < p && code[s].is_ascii_whitespace() {
            s += 1;
        }
        let let_bound = code[s..].starts_with(b"let") && !is_ident(*code.get(s + 3).unwrap_or(&b' '));

        // Guard scope: a let-bound guard lives to the end of its enclosing
        // block; a temporary dies at the statement's `;`. Both are scanned
        // with brace-depth tracking relative to the acquisition point.
        let scope_end = {
            let mut depth = 0i32;
            let mut k = p;
            let mut end = body_end;
            while k < body_end {
                match code[k] {
                    b'{' => depth += 1,
                    b'}' => {
                        if depth == 0 {
                            end = k;
                            break;
                        }
                        depth -= 1;
                    }
                    b';' if !let_bound && depth == 0 => {
                        end = k;
                        break;
                    }
                    _ => {}
                }
                k += 1;
            }
            end
        };

        let mut emit = |rule: Rule, line: usize, msg: String, findings: &mut Vec<Finding>| -> bool {
            if let Some(i) = find_waiver(wv, raw_lines, line, rule) {
                used[i] = true;
                findings.push(Finding {
                    rule,
                    file: file.to_string(),
                    line,
                    msg,
                    waived: Some(wv[i].reason.clone()),
                });
                true
            } else {
                findings.push(Finding { rule, file: file.to_string(), line, msg, waived: None });
                false
            }
        };

        // Within the guard's live scope: further acquisitions become graph
        // edges, solver steps become findings.
        let mut k = at;
        while k < scope_end {
            if let Some(q) = find_token(code, k, b".lock(") {
                if q < scope_end {
                    let other = receiver_name(code, q);
                    let line = lx.line_of(q);
                    if other == name {
                        emit(
                            Rule::LockOrder,
                            line,
                            format!("lock `{name}` re-acquired while already held in `{}`", f.name),
                            findings,
                        );
                    } else if find_waiver(wv, raw_lines, line, Rule::LockOrder).is_some() {
                        // A waived edge is excluded from the global graph
                        // (recorded as a waived finding for visibility).
                        emit(
                            Rule::LockOrder,
                            line,
                            format!("lock edge `{name}` -> `{other}` in `{}`", f.name),
                            findings,
                        );
                    } else {
                        edges.push(LockEdge {
                            from: name.clone(),
                            to: other.clone(),
                            file: file.to_string(),
                            line,
                        });
                    }
                    k = q + ".lock(".len();
                    continue;
                }
            }
            break;
        }
        for step_tok in [".step_into(", ".execute(", ".step("] {
            let mut k2 = at;
            while let Some(q) = find_token(code, k2, step_tok.as_bytes()) {
                if q >= scope_end {
                    break;
                }
                emit(
                    Rule::LockOrder,
                    lx.line_of(q),
                    format!(
                        "lock `{name}` held across solver step `{}` in `{}`",
                        step_tok.trim_end_matches('('),
                        f.name
                    ),
                    findings,
                );
                k2 = q + step_tok.len();
            }
        }
    }
}

/// Last path segment of the dotted receiver ending at the `.` of `.lock(`.
fn receiver_name(code: &[u8], dot: usize) -> String {
    let mut k = dot;
    while k > 0 && (is_ident(code[k - 1]) || code[k - 1] == b'.' || code[k - 1] == b':') {
        k -= 1;
    }
    let path = String::from_utf8_lossy(&code[k..dot]).into_owned();
    path.rsplit(|c| c == '.' || c == ':')
        .find(|s| !s.is_empty())
        .unwrap_or("<unknown>")
        .to_string()
}

/// Detect cycles in the global lock graph (edges pre-deduped by name pair).
pub fn cycle_findings(edges: &[LockEdge]) -> Vec<Finding> {
    let mut adj: HashMap<&str, Vec<&LockEdge>> = HashMap::new();
    let mut seen_pairs = HashSet::new();
    for e in edges {
        if seen_pairs.insert((e.from.as_str(), e.to.as_str())) {
            adj.entry(e.from.as_str()).or_default().push(e);
        }
    }
    let mut findings = Vec::new();
    let mut reported: HashSet<Vec<String>> = HashSet::new();
    for start in adj.keys().copied().collect::<Vec<_>>() {
        let mut stack = vec![start];
        dfs(start, &adj, &mut stack, &mut findings, &mut reported);
    }
    findings
}

fn dfs<'a>(
    node: &'a str,
    adj: &HashMap<&'a str, Vec<&'a LockEdge>>,
    stack: &mut Vec<&'a str>,
    findings: &mut Vec<Finding>,
    reported: &mut HashSet<Vec<String>>,
) {
    let Some(nexts) = adj.get(node) else { return };
    for e in nexts {
        if let Some(pos) = stack.iter().position(|&n| n == e.to.as_str()) {
            let mut cycle: Vec<String> = stack[pos..].iter().map(|s| s.to_string()).collect();
            // Canonicalize on the cycle's node set so each cycle is
            // reported once regardless of which node the DFS entered at.
            let mut canon = cycle.clone();
            canon.sort();
            cycle.push(e.to.clone());
            if reported.insert(canon) {
                findings.push(Finding {
                    rule: Rule::LockOrder,
                    file: e.file.clone(),
                    line: e.line,
                    msg: format!("lock-order cycle: {}", cycle.join(" -> ")),
                    waived: None,
                });
            }
            continue;
        }
        stack.push(e.to.as_str());
        dfs(e.to.as_str(), adj, stack, findings, reported);
        stack.pop();
    }
}

// ---------------------------------------------------------------------------
// wire-schema-sync
// ---------------------------------------------------------------------------

/// Fields of the markdown table(s) following every
/// `<!-- lint-anchor: <anchor> -->` comment in `design`, with 1-based
/// line numbers. Multiple anchors with the same name union their tables.
pub fn anchored_fields(design: &str, anchor: &str) -> (Vec<(String, usize)>, usize) {
    let tag = format!("<!-- lint-anchor: {anchor} -->");
    let lines: Vec<&str> = design.lines().collect();
    let mut fields = Vec::new();
    let mut anchors = 0usize;
    let mut i = 0usize;
    while i < lines.len() {
        if lines[i].trim() != tag {
            i += 1;
            continue;
        }
        anchors += 1;
        i += 1;
        // Skip blanks to the table head, then consume `|`-rows.
        while i < lines.len() && lines[i].trim().is_empty() {
            i += 1;
        }
        while i < lines.len() && lines[i].trim_start().starts_with('|') {
            let cell = lines[i]
                .trim()
                .trim_matches('|')
                .split('|')
                .next()
                .unwrap_or("")
                .trim()
                .trim_matches('`')
                .to_string();
            let header = cell.eq_ignore_ascii_case("field");
            let separator = !cell.is_empty() && cell.chars().all(|c| c == '-' || c == ':');
            if !cell.is_empty() && !header && !separator {
                fields.push((cell, i + 1));
            }
            i += 1;
        }
    }
    (fields, anchors)
}

/// String literals inside the body of the first fn named `fname` whose
/// immediate non-whitespace left context satisfies `ctx_ok` and (optional)
/// right context satisfies `after_ok`.
fn fn_literals(
    lx: &Lexed,
    fname: &str,
    ctx_ok: impl Fn(&[u8]) -> bool,
    after_ok: impl Fn(&[u8]) -> bool,
) -> Vec<(String, usize)> {
    let Some(f) = fn_spans(lx).into_iter().find(|f| f.name == fname) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for (start, end, content) in &lx.strings {
        if *start < f.body.0 || *start >= f.body.1 {
            continue;
        }
        let mut l = *start;
        while l > 0 && lx.code[l - 1].is_ascii_whitespace() {
            l -= 1;
        }
        let mut r = *end;
        while r < lx.code.len() && lx.code[r].is_ascii_whitespace() {
            r += 1;
        }
        if ctx_ok(&lx.code[..l]) && after_ok(&lx.code[r..]) {
            out.push((content.clone(), lx.line_of(*start)));
        }
    }
    out
}

/// How a wire fn's field literals are recognized lexically.
#[derive(Clone, Copy)]
enum WireLits {
    /// `o.get("k")` / `o.num("k")` / `num("k", default)` accessor keys —
    /// the request-reader shape.
    RequestKeys,
    /// `("key", value)` serializer pair heads — every frame-building fn.
    PairHeads,
    /// `Variant => "name"` match-arm values — the error-kind registry.
    ArmValues,
}

/// The fn↔anchor contract table. A pair is *active* when the fn exists
/// in the server source (so fixture/partial servers only activate the
/// pairs they implement); an active pair requires its DESIGN.md anchor,
/// and an anchored table is cross-checked even if its fn has since been
/// deleted — stale docs fire as "documented but not handled".
const WIRE_PAIRS: [(&str, &str, &str, WireLits); 8] = [
    ("from_json", "wire-request-fields", "request", WireLits::RequestKeys),
    ("success_response", "wire-response-fields", "response", WireLits::PairHeads),
    ("error_frame", "wire-error-fields", "error frame", WireLits::PairHeads),
    ("stats_response", "wire-stats-fields", "stats", WireLits::PairHeads),
    ("frame_head", "wire-frame-envelope", "frame envelope", WireLits::PairHeads),
    ("ack_frame", "wire-ack-fields", "ack frame", WireLits::PairHeads),
    ("iterate_frame", "wire-iterate-fields", "iterate frame", WireLits::PairHeads),
    ("kind_name", "wire-error-kinds", "error kind", WireLits::ArmValues),
];

/// Cross-check DESIGN.md's anchored wire tables against what the server
/// code actually parses and serializes: the request reader, each frame
/// serializer, and the error-kind name registry (see [`WIRE_PAIRS`]).
pub fn check_wire_schema(
    design: &str,
    design_file: &str,
    server: &str,
    server_file: &str,
) -> Vec<Finding> {
    let mut findings = Vec::new();
    let lx = lex(server);
    let fn_names: HashSet<String> = fn_spans(&lx).into_iter().map(|f| f.name).collect();

    for (fname, anchor, what, mode) in WIRE_PAIRS {
        let (docs, anchors) = anchored_fields(design, anchor);
        let active = fn_names.contains(fname);
        if active && anchors == 0 {
            findings.push(Finding {
                rule: Rule::WireSchemaSync,
                file: design_file.to_string(),
                line: 1,
                msg: format!("DESIGN.md has no `<!-- lint-anchor: {anchor} -->` table"),
                waived: None,
            });
        }
        if anchors == 0 {
            continue;
        }
        let code = match mode {
            WireLits::RequestKeys => fn_literals(
                &lx,
                fname,
                |pre| pre.ends_with(b"get(") || pre.ends_with(b"num("),
                |_| true,
            ),
            WireLits::PairHeads => fn_literals(
                &lx,
                fname,
                |pre| pre.ends_with(b"("),
                |post| post.starts_with(b","),
            ),
            WireLits::ArmValues => fn_literals(&lx, fname, |pre| pre.ends_with(b"=>"), |_| true),
        };
        let doc_names: HashSet<&str> = docs.iter().map(|(n, _)| n.as_str()).collect();
        let code_names: HashSet<&str> = code.iter().map(|(n, _)| n.as_str()).collect();
        for (name, line) in &code {
            if !doc_names.contains(name.as_str()) {
                findings.push(Finding {
                    rule: Rule::WireSchemaSync,
                    file: server_file.to_string(),
                    line: *line,
                    msg: format!(
                        "{what} field `{name}` is handled by the server but missing from DESIGN.md"
                    ),
                    waived: None,
                });
            }
        }
        for (name, line) in &docs {
            if !code_names.contains(name.as_str()) {
                findings.push(Finding {
                    rule: Rule::WireSchemaSync,
                    file: design_file.to_string(),
                    line: *line,
                    msg: format!("{what} field `{name}` is documented but not handled by the server"),
                    waived: None,
                });
            }
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// Repo runner
// ---------------------------------------------------------------------------

pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    pub unused_waivers: Vec<(String, usize, Rule, String)>,
}

impl Report {
    pub fn violations(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.waived.is_none())
    }

    pub fn waived(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.waived.is_some())
    }
}

fn rust_sources(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            rust_sources(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Run every enabled rule over `<root>/rust/src` (+ `<root>/DESIGN.md` for
/// wire-schema-sync). Findings come back waiver-resolved and sorted.
pub fn run(root: &Path, rules: &[Rule]) -> std::io::Result<Report> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    rust_sources(&src_root, &mut files)?;

    let mut findings = Vec::new();
    let mut edges = Vec::new();
    let mut unused = Vec::new();
    for path in &files {
        let src = std::fs::read_to_string(path)?;
        let label = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .into_owned();
        let rep = analyze_file(&label, &src, rules);
        findings.extend(rep.findings);
        edges.extend(rep.edges);
        unused.extend(rep.unused_waivers.into_iter().map(|(l, r, why)| (label.clone(), l, r, why)));
    }
    if rules.contains(&Rule::LockOrder) {
        findings.extend(cycle_findings(&edges));
    }
    if rules.contains(&Rule::WireSchemaSync) {
        let design = std::fs::read_to_string(root.join("DESIGN.md"))?;
        let server = std::fs::read_to_string(src_root.join("server").join("mod.rs"))?;
        findings.extend(check_wire_schema(&design, "DESIGN.md", &server, "rust/src/server/mod.rs"));
    }
    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(Report { findings, files_scanned: files.len(), unused_waivers: unused })
}
