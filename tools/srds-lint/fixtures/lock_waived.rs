// Fixture: a waived held-across-step finding and a waived edge (which is
// then excluded from the global lock graph).

fn held(m: &std::sync::Mutex<u32>, be: &dyn StepBackend, req: &StepRequest, out: &mut [f32]) {
    let g = m.lock().unwrap();
    // lint-allow(lock-order): fixture exercises the waiver path
    be.step_into(req, out);
    drop(g);
}

fn ab(a: &std::sync::Mutex<u32>, b: &std::sync::Mutex<u32>) {
    let ga = a.lock().unwrap();
    // lint-allow(lock-order): fixture edge waiver keeps this out of the graph
    let gb = b.lock().unwrap();
    drop((ga, gb));
}
