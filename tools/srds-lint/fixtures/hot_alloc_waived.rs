// Fixture: every allocation carries a waiver, so hot-path-alloc reports
// zero unwaived findings (and three waived ones).

// lint: hot-path
fn hot(xs: &[f32]) -> Vec<f32> {
    // lint-allow(hot-path-alloc): fixture exercises the waiver path
    let mut out = Vec::new();
    // lint-allow(hot-path-alloc): fixture exercises the waiver path
    let copy = xs.to_vec();
    out.extend(copy);
    let n = out.len().to_string(); // lint-allow(hot-path-alloc): trailing waiver form
    out.push(n.len() as f32);
    out
}
