// Fixture: unwrap/expect/panic! in a `// lint: request-path` fn must fire
// panic-policy; the same tokens in an unmarked fn must not, and
// `unwrap_or(..)` never matches.

// lint: request-path
fn parse(v: &str) -> u32 {
    let x: u32 = v.parse().unwrap();
    let y: u32 = v.parse().expect("request field");
    if x > 10 {
        panic!("too big");
    }
    x + y
}

// lint: request-path
fn tolerant(v: &str) -> u32 {
    v.parse().unwrap_or(0)
}

fn unmarked(v: &str) -> u32 {
    v.parse().unwrap()
}
