// Fixture: fn `ab` acquires a then b; fn `ba` acquires b then a. The
// per-file edges are acyclic within each fn but the global graph has the
// a -> b -> a cycle, which cycle_findings must report exactly once.

fn ab(a: &std::sync::Mutex<u32>, b: &std::sync::Mutex<u32>) {
    let ga = a.lock().unwrap();
    let gb = b.lock().unwrap();
    drop((ga, gb));
}

fn ba(a: &std::sync::Mutex<u32>, b: &std::sync::Mutex<u32>) {
    let gb = b.lock().unwrap();
    let ga = a.lock().unwrap();
    drop((ga, gb));
}
