// Fixture: waived panic-policy findings do not fail the run.

// lint: request-path
fn parse(v: &str) -> u32 {
    // lint-allow(panic-policy): fixture exercises the waiver path
    let x: u32 = v.parse().unwrap();
    x
}
