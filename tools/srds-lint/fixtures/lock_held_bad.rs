// Fixture: a solver step while a Mutex guard is live must fire
// lock-order; the same step after the guard's block has closed must not.

fn held(m: &std::sync::Mutex<u32>, be: &dyn StepBackend, req: &StepRequest, out: &mut [f32]) {
    let g = m.lock().unwrap();
    be.step_into(req, out);
    drop(g);
}

fn released(m: &std::sync::Mutex<u32>, be: &dyn StepBackend, req: &StepRequest, out: &mut [f32]) {
    {
        let g = m.lock().unwrap();
        drop(g);
    }
    be.step_into(req, out);
}

fn temporary(m: &std::sync::Mutex<u32>, be: &dyn StepBackend, req: &StepRequest, out: &mut [f32]) {
    *m.lock().unwrap() += 1;
    be.step_into(req, out);
}
