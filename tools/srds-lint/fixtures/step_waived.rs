// Fixture: a waived step-convenience call does not fail the run.

fn runner(be: &dyn StepBackend, req: &StepRequest) -> Vec<f32> {
    // lint-allow(no-step-convenience): fixture exercises the waiver path
    be.step(req)
}
