// Fixture: allocations inside a `// lint: hot-path` fn must fire
// hot-path-alloc; the same tokens in an unmarked fn must not.

// lint: hot-path
fn hot(xs: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    let copy = xs.to_vec();
    let boxed = Box::new(copy.len());
    out.push(*boxed as f32);
    let doubled: Vec<f32> = xs.iter().map(|v| v * 2.0).collect();
    out.extend(doubled);
    out
}

fn cold(xs: &[f32]) -> Vec<f32> {
    // Unmarked fn: vec! here is legal.
    let mut out = vec![0.0f32; xs.len()];
    out.copy_from_slice(xs);
    out
}
