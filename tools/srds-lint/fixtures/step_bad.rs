// Fixture: the allocating StepBackend::step convenience is banned in
// production code but legal inside #[cfg(test)] items.

fn runner(be: &dyn StepBackend, req: &StepRequest) -> Vec<f32> {
    be.step(req)
}

fn fine(be: &dyn StepBackend, req: &StepRequest, out: &mut [f32]) {
    be.step_into(req, out);
}

#[cfg(test)]
mod tests {
    fn exempt(be: &dyn StepBackend, req: &StepRequest) -> Vec<f32> {
        be.step(req)
    }
}
