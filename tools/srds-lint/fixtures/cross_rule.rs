// Fixture: a waiver suppresses exactly its own rule. The line below
// violates both hot-path-alloc (.clone) and panic-policy (.unwrap), but
// only hot-path-alloc is waived — panic-policy must still fire.

// lint: hot-path
// lint: request-path
fn both(v: &Option<Vec<f32>>) -> Vec<f32> {
    // lint-allow(hot-path-alloc): fixture waives only the allocation
    v.clone().unwrap()
}
