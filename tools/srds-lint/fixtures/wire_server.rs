// Fixture server: parses two request fields and serializes three
// response fields, mirroring the shape of rust/src/server/mod.rs.

impl SampleRequest {
    fn from_json(v: &Value) -> Result<Self> {
        let num = |k: &str, default: f64| v.get(k).and_then(|x| x.as_f64()).unwrap_or(default);
        Ok(SampleRequest {
            id: num("id", 0.0) as u64,
            n: v.get("n").and_then(|x| x.as_usize()).unwrap_or(8),
        })
    }
}

fn success_response(r: &SampleRequest, ok: bool) -> Value {
    json::obj(vec![
        ("id", Value::Num(r.id as f64)),
        ("ok", Value::Bool(ok)),
        (
            "wall_ms",
            Value::Num(0.0),
        ),
    ])
}
