// Fixture server for the framed-dialect wire pairs: an error
// serializer, the frame envelope, and the error-kind match registry.
// Deliberately has no `from_json`/`success_response` — those pairs
// must stay inactive when their fns don't exist.

fn error_frame(e: &WireError, v: u64) -> Value {
    let mut pairs = vec![
        ("ok", Value::Bool(false)),
        ("error", Value::Str(e.detail.clone())),
    ];
    pairs.push(("kind", Value::Str(kind_name(e.kind).into())));
    json::obj(pairs)
}

fn frame_head(v: u64, frame: &str) -> Vec<(&'static str, Value)> {
    vec![("v", Value::Num(v as f64)), ("frame", Value::Str(frame.to_string()))]
}

fn kind_name(k: ErrKind) -> &'static str {
    match k {
        ErrKind::Parse => "parse",
        ErrKind::Overloaded => "overloaded",
    }
}
