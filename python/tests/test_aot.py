"""AOT path tests: manifest combos, golden-input generation, and the HLO
text round-trip (lower → print → parse → compile → execute) for a
representative artifact."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot


def test_combo_inventory():
    combos = aot.combos()
    names = [aot.artifact_name(*c) for c in combos]
    assert len(names) == len(set(names))
    assert "step_gmm_church_ddim_b1" in names
    assert "step_gmm_latent_cond_dpm2_b32" in names
    assert "step_small_denoiser_heun_b8" in names
    # pixel datasets ship ddim only (DESIGN.md artifact inventory)
    assert "step_gmm_church_heun_b1" not in names


def test_input_specs_order():
    specs = aot.input_specs("gmm_latent_cond", "ddpm", 8, 256, 16)
    assert [n for n, _ in specs] == ["x", "s_from", "s_to", "mask", "w", "noise"]
    specs = aot.input_specs("gmm_church", "ddim", 1, 64, 8)
    assert [n for n, _ in specs] == ["x", "s_from", "s_to"]


def test_golden_inputs_deterministic():
    specs = aot.input_specs("gmm_church", "ddim", 1, 64, 8)
    a = aot.golden_inputs("x", specs, 64, 8)
    b = aot.golden_inputs("x", specs, 64, 8)
    np.testing.assert_array_equal(a["x"], b["x"])
    assert a["x"].shape == (1, 64)


def test_hlo_text_roundtrip_parses():
    """Lower a small artifact to HLO text and parse it back — the exact
    interchange the rust runtime relies on. Execution-level agreement is
    pinned by `rust/tests/golden.rs` (PJRT vs golden vectors); here we
    check the two print pitfalls that silently corrupt artifacts:
    elided large constants and unparseable metadata attributes."""
    fn, abstract, specs, dim, k = aot.lower_one("gmm_toy2d", "ddim", 1)
    text = aot.to_hlo_text(fn.lower(*abstract))
    assert "constant({...})" not in text, "large constants must not be elided"
    assert "source_end_line" not in text, "metadata must be stripped"
    mod = xc._xla.hlo_module_from_text(text)  # raises on parse failure
    assert mod.name
    # Proto round-trip stays stable.
    proto = mod.as_serialized_hlo_module_proto()
    assert len(proto) > 100
    # Entry signature survived: all inputs + 1-tuple output present.
    assert f"f32[1,{dim}]" in text
    del specs, k


def test_manifest_on_disk_if_built():
    out = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(out):
        pytest.skip("artifacts not built")
    m = json.load(open(out))
    assert m["schedule"]["beta_max"] == 20.0
    names = {a["name"] for a in m["artifacts"]}
    for model, solver, batch in aot.combos():
        assert aot.artifact_name(model, solver, batch) in names
    for a in m["artifacts"]:
        f = os.path.join(os.path.dirname(out), a["file"])
        assert os.path.exists(f), a["file"]
