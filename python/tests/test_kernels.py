"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, with
hypothesis sweeping shapes and values — the CORE correctness signal."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_mlp, gmm_score, ref, solver_step
from compile.datasets import make_gmm

SET = dict(max_examples=25, deadline=None)


def arr(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)


@settings(**SET)
@given(
    b=st.integers(1, 48),
    d=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_axpbypcz_matches_ref(b, d, seed):
    rng = np.random.default_rng(seed)
    c1, c2, c3 = (arr(rng, b) for _ in range(3))
    x, y, z = (arr(rng, b, d) for _ in range(3))
    got = solver_step.axpbypcz(c1, c2, c3, x, y, z)
    want = ref.axpbypcz_ref(c1, c2, c3, x, y, z)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(**SET)
@given(
    b=st.integers(1, 40),
    h=st.sampled_from([8, 32, 64]),
    f=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_mlp_matches_ref(b, h, f, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, b, h)
    w1, b1 = arr(rng, h, f, scale=0.3), arr(rng, f, scale=0.1)
    w2, b2 = arr(rng, f, h, scale=0.3), arr(rng, h, scale=0.1)
    got = fused_mlp.fused_mlp(x, w1, b1, w2, b2)
    want = ref.fused_mlp_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(**SET)
@given(
    b=st.integers(1, 24),
    name=st.sampled_from(["church", "cifar", "latent_cond", "toy2d"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gmm_eps_matches_ref(b, name, seed):
    g = make_gmm(name)
    rng = np.random.default_rng(seed)
    x = arr(rng, b, g.dim)
    s = jnp.asarray(rng.uniform(0.0, 0.999, b).astype(np.float32))
    means = jnp.asarray(g.means)
    sig = jnp.asarray(g.sigmas)
    w = jnp.asarray(g.weights)
    mask = jnp.ones((b, g.k), dtype=jnp.float32)
    got = gmm_score.gmm_eps(x, s, means, sig, w, mask)
    want = ref.gmm_eps_ref(x, s, means, sig, w, mask)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_gmm_eps_masked_matches_ref():
    g = make_gmm("latent_cond")
    rng = np.random.default_rng(0)
    b = 6
    x = arr(rng, b, g.dim)
    s = jnp.full((b,), 0.4)
    mask = jnp.asarray((g.comp_class[None, :] == 1).astype(np.float32).repeat(b, 0))
    args = (x, s, jnp.asarray(g.means), jnp.asarray(g.sigmas), jnp.asarray(g.weights), mask)
    np.testing.assert_allclose(
        gmm_score.gmm_eps(*args), ref.gmm_eps_ref(*args), rtol=2e-4, atol=2e-5
    )


def test_gelu_known_values():
    xs = jnp.asarray([0.0, 1.0, -1.0], dtype=jnp.float32)
    out = np.asarray(ref.gelu_ref(xs))
    np.testing.assert_allclose(out, [0.0, 0.841192, -0.158808], atol=1e-4)


def test_single_gaussian_closed_form():
    """eps of a 1-component mixture has a closed form (rust test mirror)."""
    from compile import schedule

    g = make_gmm("church")
    means = jnp.asarray(g.means[:1])
    sig = jnp.asarray(g.sigmas[:1])
    w = jnp.asarray([1.0], dtype=jnp.float32)
    rng = np.random.default_rng(3)
    x = arr(rng, 2, g.dim)
    s = jnp.asarray([0.35, 0.6], dtype=jnp.float32)
    mask = jnp.ones((2, 1), dtype=jnp.float32)
    got = np.asarray(ref.gmm_eps_ref(x, s, means, sig, w, mask))
    ab = np.asarray(schedule.alpha_bar(s))[:, None]
    v = ab * float(sig[0]) ** 2 + (1 - ab)
    want = np.sqrt(1 - ab) * (np.asarray(x) - np.sqrt(ab) * np.asarray(means)) / v
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
