"""L2 model/solver tests: pallas == ref paths, solver semantics, guidance
identities, batching invariance."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import schedule
from compile.datasets import make_gmm
from compile.model import (
    EVALS_PER_STEP,
    SOLVERS,
    CondGmmModel,
    GmmModel,
    SmallDenoiser,
    build_model,
    ddim_step,
    make_step_fn,
)


@pytest.fixture(scope="module")
def church():
    return GmmModel(make_gmm("church"))


def randx(b, d, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal((b, d)).astype(np.float32))


def test_pallas_and_ref_model_paths_agree(church):
    m_ref = GmmModel(make_gmm("church"), use_pallas=False)
    x = randx(4, 64)
    s = jnp.full((4,), 0.3)
    np.testing.assert_allclose(church.eps(x, s), m_ref.eps(x, s), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("solver", SOLVERS)
def test_solver_steps_all_models(solver):
    for model_name in ["gmm_church", "gmm_latent_cond", "small_denoiser"]:
        model, guided, dim = build_model(model_name)
        step = make_step_fn(model, solver, guided)
        b = 3
        x = randx(b, dim, seed=1)
        s_from = jnp.asarray([0.1, 0.3, 0.6], dtype=jnp.float32)
        s_to = s_from + 0.1
        args = [x, s_from, s_to]
        if guided:
            mask = jnp.zeros((b, model.k)).at[:, 1::4].set(1.0)
            args += [mask, jnp.asarray(7.5, dtype=jnp.float32)]
        if solver == "ddpm":
            args += [jnp.zeros_like(x)]
        out = step(*args)
        assert out.shape == (b, dim)
        assert bool(jnp.isfinite(out).all()), f"{model_name}/{solver}"


def test_ddim_identity_at_equal_times(church):
    x = randx(2, 64, seed=2)
    s = jnp.asarray([0.3, 0.5])
    out = ddim_step(lambda xx, ss: church.eps(xx, ss), x, s, s)
    np.testing.assert_allclose(out, x, rtol=1e-5, atol=1e-6)


def test_guidance_identities():
    m = CondGmmModel(make_gmm("latent_cond"))
    x = randx(2, 256, seed=3)
    s = jnp.full((2,), 0.4)
    mask = jnp.zeros((2, m.k)).at[:, 0::4].set(1.0)
    full = jnp.ones((2, m.k))
    e_u = m.eps(x, s, full)
    e_c = m.eps(x, s, mask)
    np.testing.assert_allclose(m.eps_guided(x, s, mask, 0.0), e_u, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(m.eps_guided(x, s, mask, 1.0), e_c, rtol=1e-5, atol=1e-6)


def test_batched_equals_rowwise(church):
    x = randx(5, 64, seed=4)
    s = jnp.asarray([0.1, 0.2, 0.5, 0.7, 0.9])
    full = church.eps(x, s)
    for i in range(5):
        row = church.eps(x[i : i + 1], s[i : i + 1])
        np.testing.assert_allclose(full[i], row[0], rtol=1e-5, atol=1e-6)


def test_denoiser_deterministic_weights():
    a = SmallDenoiser(64)
    b = SmallDenoiser(64)
    x = randx(2, 64, seed=5)
    s = jnp.asarray([0.2, 0.8])
    np.testing.assert_array_equal(np.asarray(a.eps(x, s)), np.asarray(b.eps(x, s)))


def test_solvers_converge_to_same_solution():
    """All deterministic solvers approach the same x(1) as steps increase."""
    m = GmmModel(make_gmm("cifar"))
    x0 = randx(1, 64, seed=6)
    n = 200
    grid = schedule.grid(n)

    def solve(solver):
        step = make_step_fn(m, solver, False)
        x = x0
        for i in range(n):
            x = step(x, grid[i : i + 1], grid[i + 1 : i + 2])
        return np.asarray(x)

    base = solve("ddim")
    for solver in ["euler", "heun", "dpm2"]:
        diff = np.abs(solve(solver) - base).mean()
        assert diff < 0.08, f"{solver}: {diff}"


def test_evals_per_step_registry():
    assert EVALS_PER_STEP["ddim"] == 1
    assert EVALS_PER_STEP["heun"] == 2
    assert EVALS_PER_STEP["dpm2"] == 2
    assert set(EVALS_PER_STEP) == set(SOLVERS)
