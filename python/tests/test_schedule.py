"""VP schedule invariants (mirrors rust/src/schedule tests)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import schedule


def test_endpoints():
    assert float(schedule.alpha_bar(1.0)) == pytest.approx(1.0, abs=1e-7)
    ab0 = float(schedule.alpha_bar(0.0))
    assert 0.0 < ab0 < 1e-4


def test_monotone_in_s():
    s = jnp.linspace(0, 1, 101)
    ab = np.asarray(schedule.alpha_bar(s))
    assert (np.diff(ab) > 0).all()


def test_sigma_floor_at_data():
    assert float(schedule.sigma(jnp.asarray(1.0))) == pytest.approx(
        schedule.SIGMA_FLOOR
    )


def test_lambda_inverse_roundtrip():
    s = jnp.linspace(0.01, 0.99, 50)
    back = np.asarray(schedule.s_of_lam(schedule.lam(s)))
    np.testing.assert_allclose(back, np.asarray(s), atol=2e-3)


def test_grid_shape():
    g = schedule.grid(25)
    assert g.shape == (26,)
    assert float(g[0]) == 0.0
    assert float(g[-1]) == 1.0


def test_beta_positive():
    for tau in np.linspace(0, 1, 11):
        assert schedule.beta(float(tau)) > 0
