"""Cross-language RNG contract tests (mirrors rust/src/data/rng.rs)."""

import math

from compile.rng import SplitMix64, seed_for


def test_splitmix_reference_values():
    # Same constants asserted in the rust test suite.
    r = SplitMix64(0)
    assert r.next_u64() == 0xE220A8397B1DCDAF
    assert r.next_u64() == 0x6E789E6AA1B965F4
    assert r.next_u64() == 0x06C45D188009454F


def test_wrapping_behaviour():
    r = SplitMix64(2**64 - 1)
    v = r.next_u64()
    assert 0 <= v < 2**64


def test_f64_unit_interval():
    r = SplitMix64(42)
    for _ in range(1000):
        u = r.next_f64()
        assert 0.0 <= u < 1.0


def test_normals_moments():
    r = SplitMix64(7)
    xs = [r.next_normal() for _ in range(20000)]
    mean = sum(xs) / len(xs)
    var = sum((x - mean) ** 2 for x in xs) / len(xs)
    assert abs(mean) < 0.03
    assert abs(var - 1.0) < 0.05


def test_normal_draw_uses_exactly_two_uniforms():
    # The rust impl relies on this draw-count contract.
    a = SplitMix64(9)
    b = SplitMix64(9)
    a.next_normal()
    b.next_u64()
    b.next_u64()
    assert a.next_u64() == b.next_u64()


def test_seed_for_fnv1a():
    assert seed_for("") == 0xCBF29CE484222325
    assert seed_for("church") != seed_for("bedroom")
    assert seed_for("church") == seed_for("church")


def test_normal_is_finite():
    r = SplitMix64(123)
    assert all(math.isfinite(r.next_normal()) for _ in range(100))
