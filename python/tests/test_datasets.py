"""Dataset zoo tests (mirrors rust/src/data/gmm.rs tests)."""

import numpy as np
import pytest

from compile.datasets import PIXEL_DATASETS, SPECS, make_gmm


def test_zoo_complete():
    for name in SPECS:
        g = make_gmm(name)
        assert g.means.shape == (g.k, g.dim)
        assert g.sigmas.shape == (g.k,)


def test_weights_normalized_positive():
    for name in SPECS:
        g = make_gmm(name)
        assert g.weights.sum() == pytest.approx(1.0, abs=1e-6)
        assert (g.weights > 0).all()


def test_deterministic_and_distinct():
    a, b = make_gmm("church"), make_gmm("church")
    np.testing.assert_array_equal(a.means, b.means)
    assert not np.array_equal(make_gmm("church").means, make_gmm("bedroom").means)


def test_pixel_datasets_are_64d():
    for name in PIXEL_DATASETS:
        assert make_gmm(name).dim == 64


def test_class_mask_partitions():
    g = make_gmm("latent_cond")
    total = np.zeros(g.k)
    for c in range(g.spec.n_classes):
        total += g.class_mask(c)
    np.testing.assert_array_equal(total, np.ones(g.k))


def test_sampling_moments():
    g = make_gmm("cifar")
    xs = g.sample(4000, 123)
    np.testing.assert_allclose(xs.mean(0), g.mean(), atol=0.12)


def test_conditional_sampling_stays_in_class():
    g = make_gmm("latent_cond")
    xs = g.sample(64, 5, cls=2)
    for x in xs:
        dists = np.linalg.norm(g.means - x, axis=1)
        assert g.comp_class[np.argmin(dists)] == 2


def test_analytic_cov_psd():
    g = make_gmm("bedroom")
    w = np.linalg.eigvalsh(g.cov())
    assert (w > 0).all()
