"""Synthetic Gaussian-mixture dataset zoo.

Substitutes for the paper's pretrained-checkpoint datasets (LSUN Church /
Bedroom, ImageNet-64, CIFAR, StableDiffusion latents) — see DESIGN.md
§Substitutions.  Each dataset is a K-component isotropic GMM whose diffused
score is available in closed form, so the "pretrained model" is exact and
sample-quality metrics (FD / KID / CondScore) have analytic references.

Parameters are generated from the shared splitmix64 stream (rng.py) so the
rust side (rust/src/data/) reproduces them bit-for-bit without files.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from .rng import SplitMix64, seed_for


@dataclass(frozen=True)
class GmmSpec:
    """Static description of one dataset (mirrors rust data::GmmSpec)."""

    name: str
    dim: int
    n_components: int
    n_classes: int = 1  # >1 => conditional; components are split by class
    mean_scale: float = 1.0
    sigma_lo: float = 0.15
    sigma_hi: float = 0.6


# The zoo.  Pixel datasets stand in for Table 1's four image sets (d = 64
# "8x8 pixels"); `latent_cond` stands in for StableDiffusion-v2 latents
# (d = 256, 4 "prompt" classes).  `toy2d` is for visualisation examples.
SPECS = {
    "church": GmmSpec("church", 64, 8),
    "bedroom": GmmSpec("bedroom", 64, 8),
    "imagenet64": GmmSpec("imagenet64", 64, 10),
    "cifar": GmmSpec("cifar", 64, 8, mean_scale=0.8),
    "latent_cond": GmmSpec("latent_cond", 256, 16, n_classes=4),
    "toy2d": GmmSpec("toy2d", 2, 6, mean_scale=1.5),
}

PIXEL_DATASETS = ("church", "bedroom", "imagenet64", "cifar")


@dataclass
class Gmm:
    """Concrete mixture parameters, all float32.

    means:   (K, d)
    sigmas:  (K,)    isotropic per-component std
    weights: (K,)    sums to 1
    comp_class: (K,) int, class id of each component (0 if unconditional)
    """

    spec: GmmSpec
    means: np.ndarray
    sigmas: np.ndarray
    weights: np.ndarray
    comp_class: np.ndarray = field(default=None)

    @property
    def dim(self) -> int:
        return self.spec.dim

    @property
    def k(self) -> int:
        return self.spec.n_components

    def class_mask(self, cls: int) -> np.ndarray:
        """Component mask selecting one class (all-ones if unconditional)."""
        if self.spec.n_classes <= 1:
            return np.ones(self.k, dtype=np.float32)
        return (self.comp_class == cls).astype(np.float32)

    # ---- analytic reference moments (used by FD metric) ----
    def mean(self) -> np.ndarray:
        return (self.weights[:, None] * self.means).sum(0)

    def cov(self) -> np.ndarray:
        mu = self.mean()
        d = self.dim
        c = np.zeros((d, d), dtype=np.float64)
        for k in range(self.k):
            dm = (self.means[k] - mu).astype(np.float64)
            c += self.weights[k] * (np.outer(dm, dm) + self.sigmas[k] ** 2 * np.eye(d))
        return c

    def sample(self, n: int, seed: int, cls: int | None = None) -> np.ndarray:
        """Draw exact samples (reference distribution for metrics)."""
        rng = SplitMix64(seed)
        w = self.weights * (self.class_mask(cls) if cls is not None else 1.0)
        w = w / w.sum()
        cdf = np.cumsum(w)
        out = np.empty((n, self.dim), dtype=np.float32)
        for i in range(n):
            u = rng.next_f64()
            k = int(np.searchsorted(cdf, u))
            k = min(k, self.k - 1)
            z = np.array(rng.normals(self.dim), dtype=np.float64)
            out[i] = self.means[k] + self.sigmas[k] * z
        return out


def make_gmm(name: str) -> Gmm:
    """Deterministically generate the mixture for a dataset name.

    Draw order matters: means (K*d normals), sigmas (K uniforms), weights
    (K uniforms), all from one splitmix64 stream seeded by FNV-1a(name).
    rust/src/data/gmm.rs replays exactly this order.
    """
    spec = SPECS[name]
    rng = SplitMix64(seed_for(name))
    k, d = spec.n_components, spec.dim
    means = np.array(rng.normals(k * d), dtype=np.float64).reshape(k, d)
    means = (means * spec.mean_scale / math.sqrt(d) * 4.0).astype(np.float32)
    sigmas = np.array(
        [spec.sigma_lo + (spec.sigma_hi - spec.sigma_lo) * rng.next_f64() for _ in range(k)],
        dtype=np.float32,
    )
    raw_w = np.array([0.5 + rng.next_f64() for _ in range(k)], dtype=np.float64)
    weights = (raw_w / raw_w.sum()).astype(np.float32)
    comp_class = np.arange(k, dtype=np.int32) % max(spec.n_classes, 1)
    return Gmm(spec, means, sigmas, weights, comp_class)
