"""Deterministic cross-language RNG (splitmix64).

Both the python compile path and the rust coordinator generate dataset
parameters and model weights from the *same* splitmix64 stream so the two
sides agree bit-for-bit without shipping parameter files.  Mirrors
``rust/src/data/rng.rs``.
"""

from __future__ import annotations

import math

MASK64 = (1 << 64) - 1


class SplitMix64:
    """splitmix64 PRNG (Steele et al.) on arbitrary-precision ints.

    Python ints are masked to 64 bits each step, which makes the stream
    identical to the wrapping-u64 rust implementation.
    """

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return z ^ (z >> 31)

    def next_f64(self) -> float:
        """Uniform in [0, 1) with 53 bits of precision."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_f32(self) -> float:
        """Uniform in [0, 1) rounded the way rust's `as f32` would."""
        import struct

        return struct.unpack("f", struct.pack("f", self.next_f64()))[0]

    def next_normal(self) -> float:
        """Standard normal via Box-Muller (f64 math, one draw per call).

        We deliberately burn two uniforms per normal (no caching of the
        second Box-Muller output) so the call sequence is trivially
        reproducible across languages.
        """
        # Guard u1 > 0 so log() is finite; splitmix64 emits 0 with
        # probability 2^-53 per draw, loop keeps the stream aligned by
        # construction (rust does the same).
        while True:
            u1 = self.next_f64()
            u2 = self.next_f64()
            if u1 > 0.0:
                break
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)

    def normals(self, n: int) -> list:
        return [self.next_normal() for _ in range(n)]


def seed_for(name: str) -> int:
    """Stable 64-bit seed from a short ascii name (FNV-1a)."""
    h = 0xCBF29CE484222325
    for b in name.encode("ascii"):
        h = ((h ^ b) * 0x100000001B3) & MASK64
    return h
