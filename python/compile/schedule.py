"""Continuous VP diffusion schedule shared by every solver.

Conventions (paper §2, reversed index): denoising progress ``s`` runs over
[0, 1] with ``s = 0`` pure noise and ``s = 1`` data.  Internally the VP SDE
uses diffusion time ``tau = 1 - s``.  Mirrors ``rust/src/schedule/`` — the
f32 values must agree to ~1 ulp so native-rust solves match HLO solves.

    beta(tau)      = BETA_MIN + tau * (BETA_MAX - BETA_MIN)
    log alpha_bar  = -(BETA_MIN * tau + 0.5 * (BETA_MAX - BETA_MIN) * tau^2)

At tau = 1 this gives alpha_bar ~= 4.3e-5, i.e. x(s=0) ~ N(0, I) for
unit-variance data.
"""

from __future__ import annotations

import jax.numpy as jnp

BETA_MIN = 0.1
BETA_MAX = 20.0
DBETA = BETA_MAX - BETA_MIN
# Floor on sqrt(1 - alpha_bar); guards the score -> eps conversion at s = 1
# where 1 - alpha_bar(tau=0) = 0 (Euler / Heun / DPM evaluate there).
SIGMA_FLOOR = 1e-4


def beta(tau):
    return BETA_MIN + tau * DBETA


def log_alpha_bar(tau):
    return -(BETA_MIN * tau + 0.5 * DBETA * tau * tau)


def alpha_bar(s):
    """alpha_bar as a function of denoising progress s in [0, 1]."""
    tau = 1.0 - s
    return jnp.exp(log_alpha_bar(tau))


def sqrt_ab(s):
    return jnp.sqrt(alpha_bar(s))


def sigma(s):
    """sqrt(1 - alpha_bar), floored away from 0 (see SIGMA_FLOOR)."""
    return jnp.maximum(jnp.sqrt(jnp.maximum(1.0 - alpha_bar(s), 0.0)), SIGMA_FLOOR)


def lam(s):
    """Half log-SNR lambda(s) = log(sqrt_ab / sigma) used by DPM-Solver."""
    return jnp.log(sqrt_ab(s) / sigma(s))


def s_of_lam(l):
    """Invert lambda -> s in closed form (used by DPM-Solver-2 midpoints).

    alpha_bar = sigmoid(2 lambda); then solve the quadratic
    log alpha_bar = -(BETA_MIN tau + DBETA/2 tau^2) for tau >= 0.
    """
    log_ab = -jnp.logaddexp(0.0, -2.0 * l)  # log sigmoid(2l)
    disc = BETA_MIN * BETA_MIN - 2.0 * DBETA * log_ab
    tau = (-BETA_MIN + jnp.sqrt(disc)) / DBETA
    return 1.0 - jnp.clip(tau, 0.0, 1.0)


def grid(n: int):
    """The (n+1)-point uniform denoising grid s_0 = 0 .. s_n = 1."""
    return jnp.linspace(0.0, 1.0, n + 1)
