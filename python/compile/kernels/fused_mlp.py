"""Pallas kernel: fused residual MLP block (the denoiser's GEMM hot spot).

    out = h + gelu(h @ w1 + b1) @ w2 + b2

This is the MXU-targeted analogue of the paper's UNet conv/attention GEMMs
(DESIGN.md §Hardware-Adaptation): the batch dimension is tiled via
BlockSpec; both weight matrices live whole in VMEM (H=256, F=512 f32 =>
0.5 MiB + 0.5 MiB), and the intermediate activation tile never touches HBM
— one fused kernel instead of matmul/bias/gelu/matmul/bias/add.

interpret=True: CPU PJRT cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import gelu_ref

BLOCK_ROWS = 32


def _kernel(h_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    h = h_ref[...]
    a = jnp.dot(h, w1_ref[...]) + b1_ref[...][None, :]
    a = gelu_ref(a)
    o_ref[...] = h + jnp.dot(a, w2_ref[...]) + b2_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def fused_mlp(h, w1, b1, w2, b2, *, block_rows: int = BLOCK_ROWS):
    """Residual MLP block h + gelu(h@w1+b1)@w2 + b2 (pallas)."""
    b, hd = h.shape
    f = w1.shape[1]
    rows = min(block_rows, b)
    if b % rows != 0:
        rows = 1
    grid = (b // rows,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, hd), lambda i: (i, 0)),
            pl.BlockSpec((hd, f), lambda i: (0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, hd), lambda i: (0, 0)),
            pl.BlockSpec((hd,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hd), h.dtype),
        interpret=True,
    )(h, w1, b1, w2, b2)
