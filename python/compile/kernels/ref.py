"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: pytest asserts each kernel in this
package matches its oracle here (hypothesis sweeps shapes/dtypes/values).
They are also what the L2 model uses under ``use_pallas=False``.
"""

from __future__ import annotations

import jax.numpy as jnp


def axpbypcz_ref(c1, c2, c3, x, y, z):
    """Fused solver update: out = c1*x + c2*y + c3*z with per-row coeffs.

    c1, c2, c3: (B,) float32; x, y, z: (B, d) float32.
    Every solver's final state update is expressed through this form
    (DDIM: y=eps, z=0; DDPM: z=noise; Euler/Heun/DPM: y/z = slope terms).
    """
    return c1[:, None] * x + c2[:, None] * y + c3[:, None] * z


def gelu_ref(x):
    """tanh-approximation GELU (matches the pallas kernel and rust)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def fused_mlp_ref(h, w1, b1, w2, b2):
    """Residual MLP block: h + gelu(h @ w1 + b1) @ w2 + b2.

    h: (B, H); w1: (H, F); b1: (F,); w2: (F, H); b2: (H,).
    """
    return h + gelu_ref(h @ w1 + b1) @ w2 + b2


def gmm_eps_ref(x, s, means, sigmas, weights, mask, sigma_floor=1e-4):
    """Analytic eps-prediction of a diffused Gaussian mixture.

    x: (B, d) state at denoising progress s (B,); means (K, d);
    sigmas/weights (K,); mask (B, K) component mask (conditioning).

    Diffused marginal: p_s = sum_k w_k N(sqrt_ab * mu_k, v_k I) with
    v_k = ab * sigma_k^2 + (1 - ab).  Then
        score = sum_k r_k(x) * (sqrt_ab mu_k - x) / v_k
        eps   = -sigma(s) * score
    with responsibilities r_k softmaxed over components.
    """
    from .. import schedule

    ab = schedule.alpha_bar(s)[:, None]  # (B, 1)
    sab = jnp.sqrt(ab)
    sig = jnp.maximum(jnp.sqrt(jnp.maximum(1.0 - ab, 0.0)), sigma_floor)
    v = ab * (sigmas[None, :] ** 2) + (1.0 - ab)  # (B, K)
    diff = x[:, None, :] - sab[:, :, None] * means[None, :, :]  # (B, K, d)
    sq = jnp.sum(diff * diff, axis=-1)  # (B, K)
    d = x.shape[-1]
    logw = jnp.log(weights[None, :]) + jnp.log(mask + 1e-30)
    logits = logw - 0.5 * d * jnp.log(v) - 0.5 * sq / v
    r = jnp.exp(logits - jnp.max(logits, axis=1, keepdims=True))
    r = r / jnp.sum(r, axis=1, keepdims=True)  # (B, K)
    # eps = sigma * sum_k r_k (x - sab mu_k) / v_k
    contrib = jnp.einsum("bk,bkd->bd", r / v, diff)
    return sig * contrib
