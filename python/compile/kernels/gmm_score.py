"""Pallas kernel: analytic Gaussian-mixture eps-prediction.

The "pretrained model" substitute (DESIGN.md §Substitutions): the diffused
score of a K-component isotropic GMM in closed form, computed per batch
tile.  The (rows, K) responsibility logits, the (K, d) means, and the state
tile all stay in VMEM; softmax + weighted contraction never round-trip
to HBM.  Oracle: kernels/ref.py:gmm_eps_ref.

interpret=True: CPU PJRT cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import schedule

BLOCK_ROWS = 32


def _kernel(x_ref, s_ref, means_ref, sig2_ref, logw_ref, mask_ref, o_ref):
    x = x_ref[...]  # (rows, d)
    s = s_ref[...]  # (rows,)
    means = means_ref[...]  # (K, d)
    sig2 = sig2_ref[...]  # (K,)
    logw = logw_ref[...]  # (K,)
    mask = mask_ref[...]  # (rows, K)
    d = x.shape[-1]

    tau = 1.0 - s
    ab = jnp.exp(-(schedule.BETA_MIN * tau + 0.5 * schedule.DBETA * tau * tau))
    ab = ab[:, None]  # (rows, 1)
    sab = jnp.sqrt(ab)
    sig = jnp.maximum(jnp.sqrt(jnp.maximum(1.0 - ab, 0.0)), schedule.SIGMA_FLOOR)

    v = ab * sig2[None, :] + (1.0 - ab)  # (rows, K)
    diff = x[:, None, :] - sab[:, :, None] * means[None, :, :]  # (rows, K, d)
    sq = jnp.sum(diff * diff, axis=-1)  # (rows, K)
    logits = (logw[None, :] + jnp.log(mask + 1e-30)) - 0.5 * d * jnp.log(v) - 0.5 * sq / v
    r = jnp.exp(logits - jnp.max(logits, axis=1, keepdims=True))
    r = r / jnp.sum(r, axis=1, keepdims=True)
    o_ref[...] = sig * jnp.einsum("bk,bkd->bd", r / v, diff)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def gmm_eps(x, s, means, sigmas, weights, mask, *, block_rows: int = BLOCK_ROWS):
    """Analytic GMM eps-model (pallas).  See gmm_eps_ref for semantics."""
    b, d = x.shape
    k = means.shape[0]
    rows = min(block_rows, b)
    if b % rows != 0:
        rows = 1
    grid = (b // rows,)
    sig2 = sigmas * sigmas
    logw = jnp.log(weights)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((rows, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        interpret=True,
    )(x, s, means, sig2, logw, mask)
