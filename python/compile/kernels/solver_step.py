"""Pallas kernel: fused solver state update (VPU-style elementwise).

    out[b, :] = c1[b] * x[b, :] + c2[b] * y[b, :] + c3[b] * z[b, :]

One HBM->VMEM round trip instead of five separate elementwise HLO ops.
Every solver (DDIM / DDPM / Euler / Heun / DPM-Solver-2) expresses its
final update through this form; see kernels/ref.py:axpbypcz_ref for the
oracle and DESIGN.md §Hardware-Adaptation for the TPU mapping (rows are
the BlockSpec-tiled dimension; coefficient scalars ride along in SMEM-like
(block, 1) refs).

interpret=True everywhere: the CPU PJRT client cannot run Mosaic
custom-calls, so the kernel lowers to plain HLO for this testbed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step.  d (the feature dim) stays whole in VMEM: for this
# repo d <= 256 floats = 1 KiB/row, so a 64-row tile is 64 KiB x 4 operands
# well under a ~16 MiB VMEM budget (see EXPERIMENTS.md §Perf L1).
BLOCK_ROWS = 64


def _kernel(c1_ref, c2_ref, c3_ref, x_ref, y_ref, z_ref, o_ref):
    c1 = c1_ref[...][:, None]
    c2 = c2_ref[...][:, None]
    c3 = c3_ref[...][:, None]
    o_ref[...] = c1 * x_ref[...] + c2 * y_ref[...] + c3 * z_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def axpbypcz(c1, c2, c3, x, y, z, *, block_rows: int = BLOCK_ROWS):
    """Fused c1*x + c2*y + c3*z with per-row coefficients (pallas)."""
    b, d = x.shape
    rows = min(block_rows, b)
    if b % rows != 0:  # keep the grid exact; callers use bucketed batches
        rows = 1
    grid = (b // rows,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows,), lambda i: (i,)),
            pl.BlockSpec((rows,), lambda i: (i,)),
            pl.BlockSpec((rows,), lambda i: (i,)),
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        interpret=True,
    )(c1, c2, c3, x, y, z)
