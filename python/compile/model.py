"""L2: the paper's compute graph in JAX, calling the L1 Pallas kernels.

Two eps-models (DESIGN.md §Substitutions):

* ``GmmModel`` — exact analytic score of a Gaussian-mixture dataset; the
  stand-in for the paper's pretrained checkpoints (sample quality is
  measurable against the known mixture).
* ``SmallDenoiser`` — a seeded residual-MLP eps-net (~0.5M params) giving
  realistic per-eval compute through the fused_mlp Pallas kernel.

On top of each model, one *solver step* per solver family (paper §2.1 and
App. C): DDIM, DDPM(eta), probability-flow Euler, Heun, DPM-Solver-2.
Each step is ``(x[B,d], s_from[B], s_to[B], ...) -> x'[B,d]`` with the
schedule coefficients computed inline from the scalar times — no host
round-trip per step.  These are exactly the functions aot.py lowers to
HLO text for the rust coordinator, and the functions the rust-native
solvers in rust/src/solvers/ must match to fp tolerance.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import schedule
from .datasets import Gmm, make_gmm
from .kernels import fused_mlp as fused_mlp_k
from .kernels import gmm_score as gmm_score_k
from .kernels import solver_step as solver_step_k
from .kernels import ref
from .rng import SplitMix64, seed_for

SOLVERS = ("ddim", "ddpm", "euler", "heun", "dpm2")
# Model evaluations per solver step (the unit every latency table counts).
EVALS_PER_STEP = {"ddim": 1, "ddpm": 1, "euler": 1, "heun": 2, "dpm2": 2}


# --------------------------------------------------------------------------
# eps-models
# --------------------------------------------------------------------------


class GmmModel:
    """Analytic GMM eps-model.  eps(x, s[, mask]) -> (B, d)."""

    def __init__(self, gmm: Gmm, use_pallas: bool = True):
        self.gmm = gmm
        self.use_pallas = use_pallas
        self.dim = gmm.dim
        self.k = gmm.k
        self.means = jnp.asarray(gmm.means)
        self.sigmas = jnp.asarray(gmm.sigmas)
        self.weights = jnp.asarray(gmm.weights)

    def eps(self, x, s, mask=None):
        if mask is None:
            mask = jnp.ones((x.shape[0], self.k), dtype=x.dtype)
        if self.use_pallas:
            return gmm_score_k.gmm_eps(x, s, self.means, self.sigmas, self.weights, mask)
        return ref.gmm_eps_ref(x, s, self.means, self.sigmas, self.weights, mask)


class CondGmmModel(GmmModel):
    """Classifier-free-guided conditional GMM model.

    eps(x, s, mask, w) = eps_u + w * (eps_c - eps_u)  (diffusers convention;
    the paper's Table 2 uses guidance w = 7.5).  ``mask`` selects the class'
    mixture components; the unconditional branch uses the full mixture.
    """

    def eps_guided(self, x, s, mask, w):
        full = jnp.ones_like(mask)
        e_u = self.eps(x, s, full)
        e_c = self.eps(x, s, mask)
        return e_u + w * (e_c - e_u)


@dataclass
class DenoiserWeights:
    """Seeded residual-MLP weights (generated identically in rust)."""

    w_in: np.ndarray  # (d + 2*NFREQ, H)
    b_in: np.ndarray  # (H,)
    blocks: list  # [(w1 (H,F), b1 (F,), w2 (F,H), b2 (H,))] * NBLOCK
    w_out: np.ndarray  # (H, d)
    b_out: np.ndarray  # (d,)


NFREQ = 16  # Fourier time-feature frequencies
HIDDEN = 256
FF = 512
NBLOCK = 2


def make_denoiser_weights(dim: int, name: str = "small_denoiser") -> DenoiserWeights:
    """Variance-scaled weights from the shared splitmix64 stream.

    Draw order (mirrored in rust/src/model/denoiser.rs): w_in row-major,
    b_in, then per block w1, b1, w2, b2, then w_out, b_out.  Scales are
    1/sqrt(fan_in); the residual branch w2 gets an extra 0.5 so the network
    is ~1-Lipschitz and the probability-flow ODE stays well-conditioned.
    """
    rng = SplitMix64(seed_for(f"{name}:{dim}"))
    din = dim + 2 * NFREQ

    def mat(r, c, scale):
        a = np.array(rng.normals(r * c), dtype=np.float64).reshape(r, c)
        return (a * scale).astype(np.float32)

    w_in = mat(din, HIDDEN, 1.0 / math.sqrt(din))
    b_in = np.zeros(HIDDEN, dtype=np.float32)
    blocks = []
    for _ in range(NBLOCK):
        w1 = mat(HIDDEN, FF, 1.0 / math.sqrt(HIDDEN))
        b1 = np.zeros(FF, dtype=np.float32)
        w2 = mat(FF, HIDDEN, 0.5 / math.sqrt(FF))
        b2 = np.zeros(HIDDEN, dtype=np.float32)
        blocks.append((w1, b1, w2, b2))
    w_out = mat(HIDDEN, dim, 1.0 / math.sqrt(HIDDEN))
    b_out = np.zeros(dim, dtype=np.float32)
    return DenoiserWeights(w_in, b_in, blocks, w_out, b_out)


def fourier_feats(s, nfreq: int = NFREQ):
    """[sin(2^j pi s), cos(2^j pi s)]_{j<nfreq} time embedding, (B, 2*nfreq)."""
    freqs = (2.0 ** jnp.arange(nfreq)) * jnp.pi
    ang = s[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class SmallDenoiser:
    """Residual-MLP eps-net; hot spot runs through the fused_mlp kernel."""

    def __init__(self, dim: int, use_pallas: bool = True, name: str = "small_denoiser"):
        self.dim = dim
        self.use_pallas = use_pallas
        w = make_denoiser_weights(dim, name)
        self.w = jax.tree_util.tree_map(jnp.asarray, (
            w.w_in, w.b_in, [tuple(map(jnp.asarray, b)) for b in w.blocks], w.w_out, w.b_out,
        ))

    def eps(self, x, s, mask=None):
        del mask  # unconditional
        w_in, b_in, blocks, w_out, b_out = self.w
        inp = jnp.concatenate([x, fourier_feats(s)], axis=-1)
        h = ref.gelu_ref(inp @ w_in + b_in)
        for (w1, b1, w2, b2) in blocks:
            if self.use_pallas:
                h = fused_mlp_k.fused_mlp(h, w1, b1, w2, b2)
            else:
                h = ref.fused_mlp_ref(h, w1, b1, w2, b2)
        return h @ w_out + b_out


# --------------------------------------------------------------------------
# solver steps (each: one predictor-corrector-compatible deterministic map)
# --------------------------------------------------------------------------


def _upd(c1, c2, c3, x, y, z, use_pallas: bool):
    if use_pallas:
        return solver_step_k.axpbypcz(c1, c2, c3, x, y, z)
    return ref.axpbypcz_ref(c1, c2, c3, x, y, z)


def ddim_step(eps_fn, x, s_from, s_to, use_pallas=True):
    """One DDIM step (eta = 0), paper's default solver.

    x0_hat = (x - sigma_f * eps) / sab_f
    x'     = sab_t * x0_hat + sigma_t * eps
    rewritten as c1*x + c2*eps with c1 = sab_t/sab_f.
    """
    e = eps_fn(x, s_from)
    sab_f, sab_t = schedule.sqrt_ab(s_from), schedule.sqrt_ab(s_to)
    sig_f, sig_t = schedule.sigma(s_from), schedule.sigma(s_to)
    c1 = sab_t / sab_f
    c2 = sig_t - c1 * sig_f
    return _upd(c1, c2, jnp.zeros_like(c1), x, e, jnp.zeros_like(x), use_pallas)


def ddpm_step(eps_fn, x, s_from, s_to, noise, use_pallas=True, eta=1.0):
    """One DDIM(eta) step; eta=1 is ancestral DDPM.  ``noise`` is an input
    so the step stays a deterministic map (Parareal requires it) — the
    coordinator pre-samples noise per (seed, interval)."""
    e = eps_fn(x, s_from)
    ab_f, ab_t = schedule.alpha_bar(s_from), schedule.alpha_bar(s_to)
    sab_f, sab_t = jnp.sqrt(ab_f), jnp.sqrt(ab_t)
    sig_f, sig_t = schedule.sigma(s_from), schedule.sigma(s_to)
    # Song et al. (2020) eq. 16 generalized variance
    std = eta * (sig_t / sig_f) * jnp.sqrt(jnp.maximum(1.0 - ab_f / ab_t, 0.0))
    std = jnp.minimum(std, sig_t)
    dir_coeff = jnp.sqrt(jnp.maximum(sig_t * sig_t - std * std, 0.0))
    c1 = sab_t / sab_f
    c2 = dir_coeff - c1 * sig_f
    return _upd(c1, c2, std, x, e, noise, use_pallas)


def _pf_slope(eps_fn, x, s, use_pallas):
    """Probability-flow ODE slope dx/ds (s = denoising progress).

    dx/dtau = -0.5 beta (x - eps/sigma);  dx/ds = -dx/dtau at tau = 1-s.
    """
    tau = 1.0 - s
    b = schedule.beta(tau)
    sig = schedule.sigma(s)
    e = eps_fn(x, s)
    c = (0.5 * b)[:, None]
    return c * (x - e / sig[:, None])


def euler_step(eps_fn, x, s_from, s_to, use_pallas=True):
    """Explicit Euler on the probability-flow ODE."""
    d1 = _pf_slope(eps_fn, x, s_from, use_pallas)
    h = (s_to - s_from)
    return _upd(jnp.ones_like(h), h, jnp.zeros_like(h), x, d1, jnp.zeros_like(x), use_pallas)


def heun_step(eps_fn, x, s_from, s_to, use_pallas=True):
    """Heun's 2nd-order method (Karras et al. [13]); 2 model evals."""
    h = s_to - s_from
    d1 = _pf_slope(eps_fn, x, s_from, use_pallas)
    x_e = _upd(jnp.ones_like(h), h, jnp.zeros_like(h), x, d1, jnp.zeros_like(x), use_pallas)
    d2 = _pf_slope(eps_fn, x_e, s_to, use_pallas)
    return _upd(jnp.ones_like(h), 0.5 * h, 0.5 * h, x, d1, d2, use_pallas)


def dpm2_step(eps_fn, x, s_from, s_to, use_pallas=True):
    """DPM-Solver-2 (midpoint, Lu et al. [19]); 2 model evals.

    Exponential-integrator update in half-log-SNR (lambda) space:
      u   = (a_m/a_f) x - s_m (e^{h/2}-1) eps(x, s_from)
      x'  = (a_t/a_f) x - s_t (e^{h}-1)   eps(u, s_mid)
    """
    lam_f, lam_t = schedule.lam(s_from), schedule.lam(s_to)
    h = lam_t - lam_f
    s_mid = schedule.s_of_lam(lam_f + 0.5 * h)
    a_f, a_m, a_t = schedule.sqrt_ab(s_from), schedule.sqrt_ab(s_mid), schedule.sqrt_ab(s_to)
    g_m, g_t = schedule.sigma(s_mid), schedule.sigma(s_to)
    e1 = eps_fn(x, s_from)
    c1 = a_m / a_f
    c2 = -g_m * jnp.expm1(0.5 * h)
    u = _upd(c1, c2, jnp.zeros_like(c1), x, e1, jnp.zeros_like(x), use_pallas)
    e2 = eps_fn(u, s_mid)
    c1b = a_t / a_f
    c2b = -g_t * jnp.expm1(h)
    return _upd(c1b, c2b, jnp.zeros_like(c1b), x, e2, jnp.zeros_like(x), use_pallas)


def make_step_fn(model, solver: str, guided: bool, use_pallas: bool = True):
    """Build the AOT-lowerable step callable for (model, solver).

    Signatures (all f32):
      unconditional, deterministic:  (x[B,d], s_from[B], s_to[B])
      unconditional, ddpm:           (x, s_from, s_to, noise[B,d])
      guided (CondGmmModel):         (x, s_from, s_to, mask[B,K], w[])
      guided ddpm:                   (x, s_from, s_to, mask, w, noise)
    """

    def mk_eps(mask=None, w=None):
        if guided:
            return lambda x, s: model.eps_guided(x, s, mask, w)
        return lambda x, s: model.eps(x, s)

    if solver == "ddpm":
        if guided:
            def step(x, s_from, s_to, mask, w, noise):
                return ddpm_step(mk_eps(mask, w), x, s_from, s_to, noise, use_pallas)
        else:
            def step(x, s_from, s_to, noise):
                return ddpm_step(mk_eps(), x, s_from, s_to, noise, use_pallas)
        return step

    base = {"ddim": ddim_step, "euler": euler_step, "heun": heun_step, "dpm2": dpm2_step}[solver]
    if guided:
        def step(x, s_from, s_to, mask, w):
            return base(mk_eps(mask, w), x, s_from, s_to, use_pallas)
    else:
        def step(x, s_from, s_to):
            return base(mk_eps(), x, s_from, s_to, use_pallas)
    return step


def build_model(model_name: str, use_pallas: bool = True):
    """Model registry used by aot.py and the tests.

    ``gmm_<dataset>`` -> GmmModel over that dataset;
    ``gmm_latent_cond`` -> CondGmmModel (guided);
    ``small_denoiser`` -> SmallDenoiser (d = 256).
    Returns (model, guided, dim).
    """
    if model_name == "small_denoiser":
        return SmallDenoiser(256, use_pallas), False, 256
    if not model_name.startswith("gmm_"):
        raise ValueError(f"unknown model {model_name!r}")
    ds = model_name[len("gmm_"):]
    gmm = make_gmm(ds)
    if gmm.spec.n_classes > 1:
        return CondGmmModel(gmm, use_pallas), True, gmm.dim
    return GmmModel(gmm, use_pallas), False, gmm.dim
