"""AOT compile path: lower every (model, solver, batch) step to HLO text.

Python runs ONCE (`make artifacts`); the rust coordinator is self-contained
afterwards.  Interchange is HLO **text**, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Outputs under --out-dir (default ../artifacts):
  manifest.json                 registry the rust runtime loads
  step_<model>_<solver>_b<B>.hlo.txt
  golden/<artifact>.json        input/output vectors for rust golden tests
  schedule_golden.json          alpha_bar grid for the rust schedule test
  datasets_golden.json          GMM params for the rust data test
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import schedule
from .datasets import PIXEL_DATASETS, SPECS, make_gmm
from .model import EVALS_PER_STEP, SOLVERS, build_model, make_step_fn
from .rng import SplitMix64, seed_for

BATCH_BUCKETS = (1, 8, 32)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True: the rust
    side unwraps with to_tuple1).

    The default printer ELIDES large constants (`constant({...})`), which
    silently zeroes the model weights after the text round-trip — print
    with explicit HloPrintOptions instead.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # xla_extension 0.5.1's text parser predates newer metadata attributes
    # (e.g. source_end_line) — strip metadata for a parseable round-trip.
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def combos():
    """Every artifact we ship (DESIGN.md §Artifact inventory)."""
    out = []
    for ds in PIXEL_DATASETS:
        for b in BATCH_BUCKETS:
            out.append((f"gmm_{ds}", "ddim", b))
    for model in ("gmm_latent_cond", "small_denoiser"):
        for solver in SOLVERS:
            for b in BATCH_BUCKETS:
                out.append((model, solver, b))
    return out


def input_specs(model_name: str, solver: str, batch: int, dim: int, k: int):
    """Ordered (name, shape) input list for one artifact; the rust runtime
    marshals literals in exactly this order."""
    guided = model_name == "gmm_latent_cond"
    specs = [("x", (batch, dim)), ("s_from", (batch,)), ("s_to", (batch,))]
    if guided:
        specs += [("mask", (batch, k)), ("w", ())]
    if solver == "ddpm":
        specs += [("noise", (batch, dim))]
    return specs


def artifact_name(model_name: str, solver: str, batch: int) -> str:
    return f"step_{model_name}_{solver}_b{batch}"


def lower_one(model_name: str, solver: str, batch: int, use_pallas: bool = True):
    """Returns (jitted fn, abstract args, specs, dim, k)."""
    model, guided, dim = build_model(model_name, use_pallas=use_pallas)
    k = getattr(model, "k", 0)
    step = make_step_fn(model, solver, guided, use_pallas=use_pallas)

    def fn(*args):
        return (step(*args),)

    specs = input_specs(model_name, solver, batch, dim, k)
    abstract = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]
    return jax.jit(fn), abstract, specs, dim, k


def golden_inputs(name: str, specs, dim: int, k: int):
    """Deterministic concrete inputs for golden vectors (b=1 artifacts)."""
    rng = SplitMix64(seed_for(f"golden:{name}"))
    vals = {}
    for nm, shape in specs:
        n = int(np.prod(shape)) if shape else 1
        if nm == "x":
            a = np.array(rng.normals(n), dtype=np.float32)
        elif nm == "noise":
            a = np.array(rng.normals(n), dtype=np.float32)
        elif nm == "s_from":
            a = np.full(n, 0.25, dtype=np.float32)
        elif nm == "s_to":
            a = np.full(n, 0.375, dtype=np.float32)
        elif nm == "mask":
            a = np.zeros((n,), dtype=np.float32)
            # class 1 of 4 (latent_cond assigns comp_class = idx % n_classes)
            a[1::4] = 1.0
        elif nm == "w":
            a = np.array([7.5], dtype=np.float32)
        else:
            raise AssertionError(nm)
        vals[nm] = a.reshape(shape) if shape else a.reshape(())
    return vals


def emit_schedule_golden(path: str):
    s = np.linspace(0.0, 1.0, 257, dtype=np.float64)
    ab = np.asarray(schedule.alpha_bar(jnp.asarray(s, dtype=jnp.float32)))
    lam = np.asarray(schedule.lam(jnp.asarray(s, dtype=jnp.float32)))
    with open(path, "w") as f:
        json.dump({"s": s.tolist(), "alpha_bar": ab.astype(float).tolist(),
                   "lam": lam.astype(float).tolist()}, f)


def emit_datasets_golden(path: str):
    out = {}
    for name in SPECS:
        g = make_gmm(name)
        out[name] = {
            "dim": g.dim, "k": g.k, "n_classes": g.spec.n_classes,
            "means": g.means.flatten().astype(float).tolist(),
            "sigmas": g.sigmas.astype(float).tolist(),
            "weights": g.weights.astype(float).tolist(),
            "comp_class": g.comp_class.astype(int).tolist(),
        }
    with open(path, "w") as f:
        json.dump(out, f)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None, help="substring filter on artifact name")
    ap.add_argument("--no-pallas", action="store_true", help="lower the jnp reference path instead")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    todo = combos()
    if args.list:
        for m, s, b in todo:
            print(artifact_name(m, s, b))
        return

    out_dir = os.path.abspath(args.out_dir)
    golden_dir = os.path.join(out_dir, "golden")
    os.makedirs(golden_dir, exist_ok=True)

    manifest = {"schedule": {"beta_min": schedule.BETA_MIN, "beta_max": schedule.BETA_MAX,
                             "sigma_floor": schedule.SIGMA_FLOOR},
                "batch_buckets": list(BATCH_BUCKETS), "artifacts": []}

    for model_name, solver, batch in todo:
        name = artifact_name(model_name, solver, batch)
        if args.only and args.only not in name:
            continue
        fn, abstract, specs, dim, k = lower_one(model_name, solver, batch,
                                                use_pallas=not args.no_pallas)
        lowered = fn.lower(*abstract)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry = {
            "name": name, "file": fname, "model": model_name, "solver": solver,
            "batch": batch, "dim": dim, "k": k,
            "guided": model_name == "gmm_latent_cond",
            "evals_per_step": EVALS_PER_STEP[solver],
            "inputs": [{"name": n, "shape": list(s)} for n, s in specs],
        }
        manifest["artifacts"].append(entry)
        print(f"wrote {fname} ({len(text)} chars)")

        if batch == 1:  # golden vectors for the rust runtime tests
            vals = golden_inputs(name, specs, dim, k)
            out = np.asarray(fn(*[jnp.asarray(v) for v in vals.values()])[0])
            g = {"inputs": {n: np.asarray(v).flatten().astype(float).tolist()
                            for n, v in vals.items()},
                 "output": out.flatten().astype(float).tolist()}
            with open(os.path.join(golden_dir, f"{name}.json"), "w") as f:
                json.dump(g, f)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    emit_schedule_golden(os.path.join(out_dir, "schedule_golden.json"))
    emit_datasets_golden(os.path.join(out_dir, "datasets_golden.json"))
    print(f"manifest: {len(manifest['artifacts'])} artifacts -> {out_dir}")


if __name__ == "__main__":
    main()
