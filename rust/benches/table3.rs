//! Table 3: additional speedup from pipelining — vanilla SRDS vs
//! pipelined SRDS at N ∈ {961, 196, 25}, in effective serial evals
//! (schedule accounting) and measured wall-clock on the worker pool.
//!
//! `cargo bench --bench table3`

#[path = "common.rs"]
mod common;

use srds::coordinator::{prior_sample, SamplerSpec};
use srds::data::make_gmm;
use srds::exec::{measured_pipelined_srds, NativeFactory, WorkerPool};
use srds::model::{EpsModel, GmmEps};
use srds::report::{f1, f2, Table};
use srds::solvers::Solver;
use std::sync::Arc;

fn main() {
    let model: Arc<dyn EpsModel> = Arc::new(GmmEps::new(make_gmm("latent_cond")));
    let be = common::native("gmm_latent_cond", Solver::Ddim);
    let workers = 4;
    let pool = WorkerPool::new(Arc::new(NativeFactory::new(model, Solver::Ddim)), workers);
    let reps = 8u64;
    let tol = common::tol255(0.1);

    let mut t = Table::new(
        &format!("Table 3 — pipelined vs vanilla SRDS (native, {workers}-worker pool)"),
        &[
            "Method",
            "Serial Evals",
            "Eff. Serial (vanilla)",
            "Wall ms (vanilla)",
            "Eff. Serial (pipelined)",
            "Wall ms (pipelined)",
        ],
    );
    for n in [961usize, 196, 25] {
        let (mut ev, mut evp, mut ms_v, mut ms_p) = (0.0, 0.0, 0.0, 0.0);
        for s in 0..reps {
            let x0 = prior_sample(256, 40_000 + s);
            let cfg = SamplerSpec::srds(n).with_tol(tol).with_seed(40_000 + s);
            let t0 = std::time::Instant::now();
            let v = srds::coordinator::srds(&be, &x0, &cfg);
            ms_v += t0.elapsed().as_secs_f64() * 1e3;
            ev += v.stats.eff_serial_evals as f64;
            let t0 = std::time::Instant::now();
            let p = measured_pipelined_srds(&pool, &x0, &cfg);
            ms_p += t0.elapsed().as_secs_f64() * 1e3;
            evp += p.stats.eff_serial_evals_pipelined as f64;
            assert_eq!(v.stats.iters, p.stats.iters, "pipelining must not change iterates");
        }
        let r = reps as f64;
        t.row(vec![
            format!("DDIM N={n}"),
            format!("{n}"),
            f1(ev / r),
            f2(ms_v / r),
            f1(evp / r),
            f2(ms_p / r),
        ]);
    }
    t.print();
    println!("\npaper shape (Table 3): eff serial evals 93→63 (N=961), 42→27 (196), 15→9 (25).");
}
