//! Table 6 (App. D): device-count scaling — SRDS vs ParaDiGMS at 1/2/4
//! devices, N = 25 DDIM. Paper shape: SRDS's minimal communication lets
//! it convert added devices into latency better than ParaDiGMS, whose
//! per-sweep AllReduce eats the gains.
//!
//! Both modeled (simulated clock, deterministic) and measured (worker
//! pool wall-clock) numbers are reported.
//!
//! `cargo bench --bench table6`

#[path = "common.rs"]
mod common;

use srds::coordinator::{prior_sample, SamplerSpec};
use srds::data::make_gmm;
use srds::exec::{measured_pipelined_srds, simulate_paradigms, simulate_srds, NativeFactory, WorkerPool};
use srds::model::{EpsModel, GmmEps};
use srds::report::{f1, f2, Table};
use srds::schedule::Partition;
use srds::solvers::Solver;
use std::sync::Arc;

/// Per-sweep AllReduce/prefix-sum overhead in eval units. The paper's
/// App. D measures ParaDiGMS turning a 20x eff-step reduction into only
/// a 3.4x wallclock speedup — i.e. ~4 evals of per-sweep sync overhead.
const SYNC_COST: u64 = 4;

fn main() {
    let n = 25;
    let reps = 8u64;
    let tol = common::tol255(0.1);
    let be = common::native("gmm_latent_cond", Solver::Ddim);
    let model: Arc<dyn EpsModel> = Arc::new(GmmEps::new(make_gmm("latent_cond")));

    // SRDS iterations (device count doesn't change iterates).
    let mut srds_iters = 0.0;
    for s in 0..reps {
        let x0 = prior_sample(256, 70_000 + s);
        let cfg = SamplerSpec::srds(n).with_tol(tol).with_seed(70_000 + s);
        srds_iters += srds::coordinator::srds(&be, &x0, &cfg).stats.iters as f64;
    }
    let srds_iters = (srds_iters / reps as f64).round() as usize;
    // A "device" sustains `bpd` rows per eval slot (the SD-scale model
    // saturates a GPU at small batch; 2 here).
    let bpd = 2usize;

    let mut t = Table::new(
        &format!("Table 6 — device scaling, N={n} DDIM (SRDS iters={srds_iters}, PD tol 1e-2², batch/device={bpd})"),
        &[
            "Devices",
            "SRDS time (model)",
            "SRDS wall ms",
            "ParaDiGMS time (model)",
            "SRDS utilization",
        ],
    );
    let part = Partition::sqrt_n(n);
    for devices in [1usize, 2, 4] {
        let sim = simulate_srds(&part, srds_iters, 1, devices * bpd, true);
        // PD sweeps depend on the window = device capacity.
        let window = (devices * bpd).min(n);
        let mut pd_sweeps = 0.0;
        for s in 0..reps {
            let x0 = prior_sample(256, 70_000 + s);
            let pcfg = SamplerSpec::paradigms(n).with_tol(1e-4).with_window(window).with_seed(70_000 + s);
            pd_sweeps += srds::coordinator::paradigms(&be, &x0, &pcfg).stats.iters as f64;
        }
        let pd = simulate_paradigms((pd_sweeps / reps as f64).round() as usize, window, devices, bpd, 1, SYNC_COST);
        // Measured pool wall-clock.
        let pool =
            WorkerPool::new(Arc::new(NativeFactory::new(model.clone(), Solver::Ddim)), devices);
        let mut wall = 0.0;
        for s in 0..reps {
            let x0 = prior_sample(256, 70_000 + s);
            let cfg = SamplerSpec::srds(n).with_tol(tol).with_seed(70_000 + s);
            let t0 = std::time::Instant::now();
            let _ = measured_pipelined_srds(&pool, &x0, &cfg);
            wall += t0.elapsed().as_secs_f64() * 1e3;
        }
        t.row(vec![
            format!("{devices}"),
            f1(sim.makespan as f64),
            f2(wall / reps as f64),
            f1(pd.makespan as f64),
            format!("{:.0}%", sim.utilization * 100.0),
        ]);
    }
    t.print();
    println!("\npaper shape (Table 6): SRDS 1.62→1.08→0.82 s/sample over 1→2→4 devices;");
    println!("ParaDiGMS 2.71→2.01→1.51 — SRDS stays strictly faster at every width.");
}
