//! Table 8 (App. F): tolerance ablation on church, N = 1024 — KID of
//! SRDS samples vs the sequential KID as τ relaxes from 0.1 to 1.0
//! (pixel-255 units). Paper shape: looser τ cuts iterations ~35% with no
//! measurable KID change.
//!
//! `cargo bench --bench table8`

#[path = "common.rs"]
mod common;

use srds::coordinator::SamplerSpec;
use srds::data::make_gmm;
use srds::metrics::kid_poly;
use srds::report::{f1, f4, Table};
use srds::solvers::Solver;

fn main() {
    let n = 1024;
    let count = 160;
    let gmm = make_gmm("church");
    let be = common::native("gmm_church", Solver::Ddim);
    let reference = gmm.sample(count, 77, None);

    let (seq, _) = common::sequential_samples(&be, n, count, &Default::default(), 20_000);
    let kid_seq = kid_poly(&seq, count, &reference, count, gmm.dim());

    let mut t = Table::new(
        "Table 8 — tolerance ablation, church N=1024, KID vs analytic reference",
        &["Method", "SRDS Iters", "Eff. Serial Evals", "Total Evals", "KID"],
    );
    t.row(vec![
        "Sequential".into(),
        "-".into(),
        format!("{n}"),
        format!("{n}"),
        f4(kid_seq),
    ]);
    for tau in [0.1f32, 0.5, 1.0] {
        let cfg = SamplerSpec::srds(n).with_tol(common::tol255(tau));
        let agg = common::srds_samples(&be, &cfg, count, 20_000);
        let kid = kid_poly(&agg.samples, count, &reference, count, gmm.dim());
        t.row(vec![
            format!("SRDS - {tau}"),
            f1(agg.mean_iters),
            f1(agg.mean_eff_pipelined),
            f1(agg.mean_total),
            f4(kid),
        ]);
    }
    t.print();
    println!("\npaper shape: iters drop 5.7 → 3.7 across the ablation at constant KID.");
}
