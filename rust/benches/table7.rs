//! Table 7 (App. E): headline speedup comparison — pipelined SRDS vs
//! ParaDiGMS vs ParaTAA at N ∈ {100, 25}, all measured on identical
//! (simulated 4-device) hardware with each method's own convergence
//! behaviour. Paper shape: SRDS 2.73x/1.72x > ParaTAA 1.92x/1.17x >
//! ParaDiGMS 2.5x/1.0x.
//!
//! `cargo bench --bench table7`

#[path = "common.rs"]
mod common;

use srds::coordinator::{prior_sample, ParadigmsConfig, ParataaConfig, SrdsConfig};
use srds::exec::{simulate_paradigms, simulate_srds};
use srds::report::{speedup, Table};
use srds::schedule::Partition;
use srds::solvers::Solver;

/// Per-sweep AllReduce/prefix-sum overhead in eval units. The paper's
/// App. D measures ParaDiGMS turning a 20x eff-step reduction into only
/// a 3.4x wallclock speedup — i.e. ~4 evals of per-sweep sync overhead.
const SYNC_COST: u64 = 4;

fn main() {
    let be = common::native("gmm_latent_cond", Solver::Ddim);
    let devices = 4;
    let reps = 6u64;
    let tol = common::tol255(0.1);

    let mut t = Table::new(
        &format!("Table 7 — wallclock-model speedup vs serial ({devices} devices)"),
        &["Denoising Steps", "ParaDiGMS", "ParaTAA", "Pipelined SRDS"],
    );
    for n in [100usize, 25] {
        let serial = n as f64;
        let mut srds_time = 0.0;
        let mut pd_time = 0.0;
        let mut taa_time = 0.0;
        for s in 0..reps {
            let x0 = prior_sample(256, 80_000 + s);
            let cfg = SrdsConfig::new(n).with_tol(tol).with_seed(80_000 + s);
            let r = srds::coordinator::srds(&be, &x0, &cfg);
            // devices × 8 batched rows per eval slot (§3.4 batching).
            srds_time += simulate_srds(&Partition::sqrt_n(n), r.stats.iters, 1, devices * 8, true)
                .makespan as f64;

            // PD threshold is squared (paper quotes 1e-3; see config docs).
            let pcfg = ParadigmsConfig::new(n).with_tol(1e-6).with_window(devices * 8).with_seed(80_000 + s);
            let pr = srds::coordinator::paradigms(&be, &x0, &pcfg);
            pd_time += simulate_paradigms(pr.stats.iters, (devices * 8).min(n), devices, 8, 1, SYNC_COST)
                .makespan as f64;

            let tcfg = ParataaConfig::new(n).with_tol(tol).with_seed(80_000 + s);
            let tr = srds::coordinator::parataa(&be, &x0, &tcfg);
            // ParaTAA holds the whole trajectory in device memory (its
            // authors used 8×80GB A800s): one batched eval slot per
            // iteration + one sync.
            taa_time += (tr.stats.iters as u64 * (n.div_ceil(devices * 8) as u64 + SYNC_COST)) as f64;
        }
        let r = reps as f64;
        t.row(vec![
            format!("DDIM - {n}"),
            speedup(serial, pd_time / r),
            speedup(serial, taa_time / r),
            speedup(serial, srds_time / r),
        ]);
    }
    t.print();
    println!("\npaper shape (Table 7): SRDS 2.73x/1.72x > ParaTAA 1.92x/1.17x ≳ ParaDiGMS 2.5x/1.0x.");
}
