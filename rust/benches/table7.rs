//! Table 7 (App. E): headline speedup comparison — pipelined SRDS vs
//! ParaDiGMS vs ParaTAA at N ∈ {100, 25}, all measured on identical
//! (simulated 4-device) hardware with each method's own convergence
//! behaviour. Paper shape: SRDS 2.73x/1.72x > ParaTAA 1.92x/1.17x >
//! ParaDiGMS 2.5x/1.0x.
//!
//! The method list comes from `coordinator::api::registry()` — a sampler
//! added there gets a column here as soon as `modeled_time` learns its
//! hardware model (the exhaustive `SamplerKind` match below makes the
//! compiler point at the spot).
//!
//! `cargo bench --bench table7`

#[path = "common.rs"]
mod common;

use srds::coordinator::{prior_sample, registry, RunStats, Sampler, SamplerKind, SamplerSpec};
use srds::exec::{simulate_paradigms, simulate_srds};
use srds::report::{speedup, Table};
use srds::schedule::Partition;
use srds::solvers::Solver;

/// Per-sweep AllReduce/prefix-sum overhead in eval units. The paper's
/// App. D measures ParaDiGMS turning a 20x eff-step reduction into only
/// a 3.4x wallclock speedup — i.e. ~4 evals of per-sweep sync overhead.
const SYNC_COST: u64 = 4;

/// The spec each method runs under (paper Table 7 setup).
fn spec_for(sampler: &dyn Sampler, n: usize, seed: u64, devices: usize) -> SamplerSpec {
    let tol = common::tol255(0.1);
    let spec = SamplerSpec::for_kind(n, sampler.kind()).with_seed(seed);
    match spec.kind {
        // PD threshold is squared (paper quotes 1e-3; see SamplerSpec
        // docs) and its window is the device capacity.
        SamplerKind::Paradigms { .. } => spec.with_tol(1e-6).with_window(devices * 8),
        _ => spec.with_tol(tol),
    }
}

/// Wallclock model on `devices` simulated devices × 8 batched rows per
/// eval slot (§3.4 batching), from the measured convergence stats.
fn modeled_time(kind: SamplerKind, stats: &RunStats, n: usize, devices: usize) -> f64 {
    match kind {
        SamplerKind::Sequential => n as f64,
        SamplerKind::Srds => {
            simulate_srds(&Partition::sqrt_n(n), stats.iters, 1, devices * 8, true).makespan as f64
        }
        SamplerKind::Paradigms { .. } => {
            simulate_paradigms(stats.iters, (devices * 8).min(n), devices, 8, 1, SYNC_COST)
                .makespan as f64
        }
        // ParaTAA holds the whole trajectory in device memory (its
        // authors used 8×80GB A800s): one batched eval slot per
        // iteration + one sync.
        SamplerKind::Parataa { .. } => {
            (stats.iters as u64 * (n.div_ceil(devices * 8) as u64 + SYNC_COST)) as f64
        }
    }
}

fn main() {
    let be = common::native("gmm_latent_cond", Solver::Ddim);
    let devices = 4;
    let reps = 6u64;

    let reg = registry();
    let methods: Vec<&dyn Sampler> =
        reg.iter().filter(|s| s.kind() != SamplerKind::Sequential).collect();
    let mut headers = vec!["Denoising Steps"];
    headers.extend(methods.iter().map(|s| s.name()));
    let mut t = Table::new(
        &format!("Table 7 — wallclock-model speedup vs serial ({devices} devices)"),
        &headers,
    );
    for n in [100usize, 25] {
        let serial = n as f64;
        let mut row = vec![format!("DDIM - {n}")];
        for sampler in &methods {
            let mut time = 0.0;
            for s in 0..reps {
                let x0 = prior_sample(256, 80_000 + s);
                let spec = spec_for(*sampler, n, 80_000 + s, devices);
                let r = sampler.run(&be, &x0, &spec);
                time += modeled_time(spec.kind, &r.stats, n, devices);
            }
            row.push(speedup(serial, time / reps as f64));
        }
        t.row(row);
    }
    t.print();
    println!("\npaper shape (Table 7): SRDS 2.73x/1.72x > ParaTAA 1.92x/1.17x ≳ ParaDiGMS 2.5x/1.0x.");
}
