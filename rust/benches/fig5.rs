//! Figure 5: convergence of sample quality (CondScore) with SRDS
//! iteration count, for trajectories of length 25 and 100 — paper shape:
//! N = 25 converges after ~3 iterations, N = 100 after a single one
//! (longer trajectories converge faster).
//!
//! `cargo bench --bench fig5`

#[path = "common.rs"]
mod common;

use srds::coordinator::{prior_sample, sequential, Conditioning, SamplerSpec};
use srds::data::make_gmm;
use srds::metrics::cond_score;
use srds::solvers::Solver;

fn main() {
    let gmm = make_gmm("latent_cond");
    let be = common::native("gmm_latent_cond", Solver::Ddim);
    let count = 32u64;
    let w = 7.5;
    let max_show = 6;

    for n in [25usize, 100] {
        // CondScore of the iterate after k refinements, averaged over
        // chains (k = 0 is the coarse init).
        let mut scores = vec![0.0f64; max_show + 1];
        let mut seq_score = 0.0f64;
        for c in 0..count {
            let cls = (c % 4) as u32;
            let cond = Conditioning::class(gmm.class_mask(cls), w);
            let x0 = prior_sample(256, 90_000 + c);
            let cfg = SamplerSpec::srds(n)
                .with_tol(0.0)
                .with_max_iters(max_show)
                .with_iterates()
                .with_cond(cond.clone())
                .with_seed(90_000 + c);
            let r = srds::coordinator::srds(&be, &x0, &cfg);
            for k in 0..=max_show {
                let it = &r.iterates[k.min(r.iterates.len() - 1)];
                scores[k] += cond_score(it, 1, &gmm, Some(cls));
            }
            let (seq, _) = sequential(&be, &x0, n, &cond, 90_000 + c);
            seq_score += cond_score(&seq, 1, &gmm, Some(cls));
        }
        for s in scores.iter_mut() {
            *s /= count as f64;
        }
        seq_score /= count as f64;
        let seq_line = vec![seq_score; max_show + 1];
        println!("\n=== Fig. 5 — CondScore vs SRDS iteration, N = {n} (sequential = {seq_score:.3}) ===");
        println!(
            "{}",
            srds::viz::ascii_plot(
                &[("srds iterate", &scores), ("sequential", &seq_line)],
                48,
                12
            )
        );
        print!("iteration:");
        for k in 0..=max_show {
            print!("  k={k}: {:.3}", scores[k]);
        }
        println!();
    }
    println!("\npaper shape: N=25 converges by ~3 iterations, N=100 within 1.");
}
