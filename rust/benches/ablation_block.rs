//! Ablation (App. B / Prop. 4): coarse-resolution (block size B) sweep —
//! per-iteration schedule cost ⌈N/B⌉+B, measured iterations to converge,
//! and the resulting pipelined latency, for N = 1024. The paper argues
//! B ≈ √N is runtime-optimal under constant iteration count; we verify
//! both the model and the measured end-to-end effect.
//!
//! `cargo bench --bench ablation_block`

#[path = "common.rs"]
mod common;

use srds::coordinator::{prior_sample, SamplerSpec};
use srds::exec::simulate_srds;
use srds::report::{f1, Table};
use srds::schedule::Partition;
use srds::solvers::Solver;

fn main() {
    let n = 1024;
    let reps = 6u64;
    let tol = common::tol255(0.1);
    let be = common::native("gmm_church", Solver::Ddim);

    let mut t = Table::new(
        &format!("App. B ablation — block size sweep at N={n} (sqrt(N)=32)"),
        &[
            "Block B",
            "Blocks M",
            "cost/iter (M+B)",
            "Mean iters",
            "Eff serial evals (pipelined)",
            "Modeled time (M+1 devices)",
        ],
    );
    for b in [4usize, 8, 16, 32, 64, 128, 256] {
        let part = Partition::with_block(n, b);
        let m = part.num_blocks();
        let mut iters = 0.0;
        let mut effp = 0.0;
        for s in 0..reps {
            let x0 = prior_sample(64, 110_000 + s);
            let cfg = SamplerSpec::srds(n).with_block(b).with_tol(tol).with_seed(110_000 + s);
            let r = srds::coordinator::srds(&be, &x0, &cfg);
            iters += r.stats.iters as f64;
            effp += r.stats.eff_serial_evals_pipelined as f64;
        }
        let iters_mean = iters / reps as f64;
        let sim = simulate_srds(&part, iters_mean.round() as usize, 1, m + 1, true);
        t.row(vec![
            format!("{b}"),
            format!("{m}"),
            format!("{}", m + b),
            f1(iters_mean),
            f1(effp / reps as f64),
            f1(sim.makespan as f64),
        ]);
    }
    t.print();
    println!("\nexpected: cost/iter is minimized at B=32=√N (Prop. 4); deviations in");
    println!("iteration count (footnote 6) shift the end-to-end optimum only mildly.");
}
