//! Shared helpers for the table/figure benches (included per-bench via
//! `#[path = "common.rs"] mod common;`).
#![allow(dead_code)]

use srds::coordinator::{prior_sample, sequential, Conditioning, SamplerSpec};
use srds::data::make_gmm;
use srds::model::{EpsModel, GmmEps, SmallDenoiser};
use srds::runtime::{PjrtBackend, PjrtRuntime};
use srds::solvers::{NativeBackend, Solver, StepBackend};
use std::sync::Arc;

/// Native backend for a manifest-style model name.
pub fn native(model: &str, solver: Solver) -> NativeBackend {
    let m: Arc<dyn EpsModel> = if model == "small_denoiser" {
        Arc::new(SmallDenoiser::new(256))
    } else {
        Arc::new(GmmEps::new(make_gmm(model.trim_start_matches("gmm_"))))
    };
    NativeBackend::new(m, solver)
}

/// PJRT backend when artifacts exist (leaks one runtime per call — benches
/// are short-lived processes).
pub fn pjrt(model: &str, solver: Solver) -> Option<Box<dyn StepBackend>> {
    let rt = PjrtRuntime::open_default().ok()?;
    let rt: &'static PjrtRuntime = Box::leak(Box::new(rt));
    Some(Box::new(PjrtBackend::new(rt, model, solver).ok()?))
}

/// PJRT if available, else native (returned boxed for uniformity).
pub fn best_backend(model: &str, solver: Solver) -> (Box<dyn StepBackend>, &'static str) {
    match pjrt(model, solver) {
        Some(b) => (b, "pjrt"),
        None => (Box::new(native(model, solver)), "native"),
    }
}

/// Generate `count` samples with the sequential baseline; returns the
/// flat samples and mean wall ms per sample.
pub fn sequential_samples(
    be: &dyn StepBackend,
    n: usize,
    count: usize,
    cond: &Conditioning,
    seed0: u64,
) -> (Vec<f32>, f64) {
    let d = be.dim();
    let mut out = Vec::with_capacity(count * d);
    let t = std::time::Instant::now();
    for s in 0..count as u64 {
        let x0 = prior_sample(d, seed0 + s);
        let (xs, _) = sequential(be, &x0, n, cond, seed0 + s);
        out.extend_from_slice(&xs);
    }
    (out, t.elapsed().as_secs_f64() * 1e3 / count as f64)
}

/// SRDS statistics aggregated over `count` chains.
pub struct SrdsAgg {
    pub samples: Vec<f32>,
    pub mean_iters: f64,
    pub mean_eff: f64,
    pub mean_eff_pipelined: f64,
    pub mean_total: f64,
    pub ms_per_sample: f64,
}

pub fn srds_samples(
    be: &dyn StepBackend,
    spec_base: &SamplerSpec,
    count: usize,
    seed0: u64,
) -> SrdsAgg {
    let d = be.dim();
    let mut samples = Vec::with_capacity(count * d);
    let (mut it, mut eff, mut effp, mut tot) = (0.0, 0.0, 0.0, 0.0);
    let t = std::time::Instant::now();
    for s in 0..count as u64 {
        let x0 = prior_sample(d, seed0 + s);
        let spec = spec_base.clone().with_seed(seed0 + s);
        let r = srds::coordinator::srds(be, &x0, &spec);
        samples.extend_from_slice(&r.sample);
        it += r.stats.iters as f64;
        eff += r.stats.eff_serial_evals as f64;
        effp += r.stats.eff_serial_evals_pipelined as f64;
        tot += r.stats.total_evals as f64;
    }
    let c = count as f64;
    SrdsAgg {
        samples,
        mean_iters: it / c,
        mean_eff: eff / c,
        mean_eff_pipelined: effp / c,
        mean_total: tot / c,
        ms_per_sample: t.elapsed().as_secs_f64() * 1e3 / c,
    }
}

/// Paper pixel-255 tolerance mapped to native units.
pub fn tol255(t: f32) -> f32 {
    srds::coordinator::convergence::tol_from_pixel255(t)
}
