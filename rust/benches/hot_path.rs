//! Hot-path perf trail: steps/sec and *allocations per step* for the
//! zero-copy step loop, as JSON lines — the numbers the PR trajectory
//! tracks (the steady-state-zero-allocation claim of the `buf` layer,
//! measured, not asserted).
//!
//! Two sections:
//!
//! * `hot_path` — the raw solver step loop at dim 64 / 256 / 1024 and
//!   batch 1 / 8 / 32: a lockstep loop staging rows through one reused
//!   [`BatchStage`] into pooled [`StateBuf`]s, exactly the shape of the
//!   SRDS fine-solve inner loop. `allocs_per_step` counts pool misses
//!   per executed row-step — ~0 after warm-up is the claim.
//! * `hot_path_srds` — a full `coordinator::srds` run (church, N=256)
//!   reporting its run-local pool counters plus steps/sec.
//!
//! `cargo bench --bench hot_path`
//! One JSON object per line on stdout; no artifacts required.

use srds::buf::{BatchStage, BufPool, StateBuf};
use srds::coordinator::{prior_sample, SamplerSpec};
use srds::data::{make_gmm, rng::SplitMix64};
use srds::json::{self, Value};
use srds::model::{AffineModel, EpsModel, GmmEps};
use srds::solvers::{NativeBackend, Solver, StepBackend};
use std::sync::Arc;
use std::time::Instant;

/// Run `iters` lockstep batch-steps of `batch` rows at dimension `dim`;
/// returns (steps/sec over the timed phase, pool misses per row-step,
/// final pool stats).
fn step_loop(dim: usize, batch: usize, iters: usize) -> Value {
    let model: Arc<dyn EpsModel> = Arc::new(AffineModel::new(dim, 0.35, 0.1));
    let be = NativeBackend::new(model, Solver::Ddim);
    let pool = BufPool::new();
    let mut stage = BatchStage::new();
    let mut rng = SplitMix64::new(9);
    let x0 = rng.normals_f32(dim);
    let mut states: Vec<StateBuf> = (0..batch).map(|_| pool.take(&x0)).collect();

    let mut run = |iters: usize| {
        for t in 0..iters {
            let s0 = (t % 100) as f32 / 101.0;
            stage.reset(0.0);
            for st in states.iter() {
                stage.push_row(st, s0, s0 + 1e-3, 0, None);
            }
            let out = stage.execute(&be);
            for (r, st) in states.iter_mut().enumerate() {
                st.as_mut_slice().copy_from_slice(&out[r * dim..(r + 1) * dim]);
            }
        }
    };
    // Warm-up fills the stage and the (here trivial) pool demand.
    run(iters / 10 + 1);
    let warm = pool.stats();
    let t0 = Instant::now();
    run(iters);
    let wall = t0.elapsed().as_secs_f64();
    let end = pool.stats();

    let row_steps = (iters * batch) as f64;
    json::obj(vec![
        ("bench", Value::Str("hot_path".into())),
        ("dim", Value::Num(dim as f64)),
        ("batch", Value::Num(batch as f64)),
        ("steps_per_sec", Value::Num(row_steps / wall.max(1e-9))),
        (
            "allocs_per_step",
            Value::Num((end.misses - warm.misses) as f64 / row_steps),
        ),
        ("pool_hits", Value::Num(end.hits as f64)),
        ("pool_misses", Value::Num(end.misses as f64)),
        ("pool_high_water", Value::Num(end.high_water as f64)),
    ])
}

/// Full SRDS run on the church GMM: end-to-end steps/sec plus the
/// run-local pool trail out of `RunStats`.
fn srds_run(n: usize) -> Value {
    let model: Arc<dyn EpsModel> = Arc::new(GmmEps::new(make_gmm("church")));
    let be = NativeBackend::new(model, Solver::Ddim);
    let x0 = prior_sample(be.dim(), 3);
    let spec = SamplerSpec::srds(n).with_tol(0.0).with_max_iters(6).with_seed(3);
    let t0 = Instant::now();
    let out = srds::coordinator::srds(&be, &x0, &spec);
    let wall = t0.elapsed().as_secs_f64();
    json::obj(vec![
        ("bench", Value::Str("hot_path_srds".into())),
        ("n", Value::Num(n as f64)),
        ("iters", Value::Num(out.stats.iters as f64)),
        ("total_evals", Value::Num(out.stats.total_evals as f64)),
        (
            "steps_per_sec",
            Value::Num(out.stats.total_evals as f64 / wall.max(1e-9)),
        ),
        ("pool_hits", Value::Num(out.stats.pool_hits as f64)),
        ("pool_misses", Value::Num(out.stats.pool_misses as f64)),
        (
            "allocs_per_step",
            Value::Num(out.stats.pool_misses as f64 / out.stats.total_evals.max(1) as f64),
        ),
    ])
}

fn main() {
    for dim in [64usize, 256, 1024] {
        for batch in [1usize, 8, 32] {
            // Keep total work roughly constant across configurations.
            let iters = (1 << 22) / (dim * batch).max(1);
            let line = step_loop(dim, batch, iters.clamp(20, 20_000));
            println!("{}", json::to_string(&line));
        }
    }
    println!("{}", json::to_string(&srds_run(256)));
}
