//! Table 5 (App. C): SRDS with other off-the-shelf solvers — DDPM,
//! DPM-Solver-2, DDIM (plus Euler/Heun as extensions) on the latent
//! model. Paper shape: consistent speedups across solvers.
//!
//! `cargo bench --bench table5`

#[path = "common.rs"]
mod common;

use srds::coordinator::{prior_sample, sequential, Conditioning, SamplerSpec};
use srds::report::{f1, f2, speedup, Table};
use srds::solvers::Solver;

fn main() {
    let reps = 6u64;
    let tol = common::tol255(0.1);
    let mut t = Table::new(
        "Table 5 — SRDS with off-the-shelf solvers (latent model, native backend)",
        &[
            "Model",
            "Model Evals",
            "Time/Sample ms",
            "Eff Serial Evals",
            "SRDS Time ms",
            "Speedup (eff evals)",
        ],
    );
    let rows: [(Solver, usize); 6] = [
        (Solver::Ddpm, 961),
        (Solver::Ddpm, 196),
        (Solver::Dpm2, 196),
        (Solver::Dpm2, 25),
        (Solver::Ddim, 196),
        (Solver::Ddim, 25),
    ];
    for (solver, n) in rows {
        let be = common::native("gmm_latent_cond", solver);
        let epc = solver.evals_per_step();
        let (mut seq_ms, mut srds_ms, mut eff) = (0.0, 0.0, 0.0);
        for s in 0..reps {
            let x0 = prior_sample(256, 60_000 + s);
            let t0 = std::time::Instant::now();
            let _ = sequential(&be, &x0, n, &Conditioning::none(), 60_000 + s);
            seq_ms += t0.elapsed().as_secs_f64() * 1e3;
            let cfg = SamplerSpec::srds(n).with_tol(tol).with_seed(60_000 + s);
            let t0 = std::time::Instant::now();
            let r = srds::coordinator::srds(&be, &x0, &cfg);
            srds_ms += t0.elapsed().as_secs_f64() * 1e3;
            eff += r.stats.eff_serial_evals_pipelined as f64;
        }
        let r = reps as f64;
        let serial_evals = (n * epc) as f64;
        t.row(vec![
            format!("{} N={n}", solver.name().to_uppercase()),
            format!("{}", n * epc),
            f2(seq_ms / r),
            f1(eff / r),
            f2(srds_ms / r),
            speedup(serial_evals, eff / r),
        ]);
    }
    t.print();
    println!("\npaper shape (Table 5): 3.6x (DDPM-961), ~2.8-3x (196), ~1.4-1.5x (25)");
    println!("in wallclock on 4 A100s; here the speedup column is schedule-exact.");
}
