//! Table 2: conditional sampling quality (CondScore, our CLIP-score
//! substitute) and measured time per sample on the "latent" model,
//! guidance w = 7.5, DDIM N ∈ {100, 25}, with an iteration cap —
//! paper shape: SRDS at max-iter 1 matches sequential quality on long
//! trajectories at a fraction of the serial evals; a cap of 3 recovers
//! full quality at N = 25.
//!
//! `cargo bench --bench table2`

#[path = "common.rs"]
mod common;

use srds::coordinator::{Conditioning, SamplerSpec};
use srds::data::make_gmm;
use srds::metrics::cond_score;
use srds::report::{f1, f3, speedup, Table};
use srds::solvers::Solver;

fn main() {
    let gmm = make_gmm("latent_cond");
    let (be, kind) = common::best_backend("gmm_latent_cond", Solver::Ddim);
    let count = 24;
    let w = 7.5;
    let mut t = Table::new(
        &format!("Table 2 — CondScore + time/sample, latent model, w=7.5 ({kind} backend)"),
        &[
            "Method",
            "Serial Evals",
            "CondScore",
            "Time/Sample (ms)",
            "Max Iter",
            "Eff. Serial Evals",
            "Total Evals",
            "CondScore SRDS",
            "SRDS Time (ms)",
            "Speedup",
        ],
    );
    for (n, max_iter) in [(100usize, 1usize), (25, 1), (25, 3)] {
        // Per-chain class: rotate through the 4 "prompts".
        let mut seq_all = Vec::new();
        let mut srds_all = Vec::new();
        let mut seq_ms = 0.0;
        let mut agg_it = 0.0;
        let mut agg_eff = 0.0;
        let mut agg_tot = 0.0;
        let mut srds_ms = 0.0;
        for c in 0..count as u64 {
            let cls = (c % 4) as u32;
            let cond = Conditioning::class(gmm.class_mask(cls), w);
            let (seq, ms) = common::sequential_samples(be.as_ref(), n, 1, &cond, 30_000 + c);
            seq_ms += ms;
            seq_all.push((seq, cls));
            let cfg = SamplerSpec::srds(n)
                .with_tol(common::tol255(0.1))
                .with_max_iters(max_iter)
                .with_cond(cond);
            let agg = common::srds_samples(be.as_ref(), &cfg, 1, 30_000 + c);
            agg_it += agg.mean_iters;
            agg_eff += agg.mean_eff_pipelined;
            agg_tot += agg.mean_total;
            srds_ms += agg.ms_per_sample;
            srds_all.push((agg.samples, cls));
        }
        let cs = |set: &[(Vec<f32>, u32)]| -> f64 {
            set.iter().map(|(x, c)| cond_score(x, 1, &gmm, Some(*c))).sum::<f64>() / set.len() as f64
        };
        let cnt = count as f64;
        t.row(vec![
            format!("DDIM N={n}"),
            format!("{n}"),
            f3(cs(&seq_all)),
            f1(seq_ms / cnt),
            format!("{max_iter}"),
            f1(agg_eff / cnt),
            f1(agg_tot / cnt),
            f3(cs(&srds_all)),
            f1(srds_ms / cnt),
            speedup(seq_ms, srds_ms),
        ]);
    }
    t.print();
    println!("\npaper shape: N=100 cap-1 keeps quality at ~19 eff evals (2.3x); N=25 cap-1");
    println!("slightly degrades, cap-3 restores quality. ({count} chains/row.)");
}
