//! Table 1: FD (our FID substitute) of SRDS on the four pixel datasets,
//! N = 1024 DDIM, τ = 0.1 (pixel-255 units), vs the sequential baseline.
//!
//! Paper shape to reproduce: SRDS converges in ~4–6 iterations, needing
//! only ~15–20% of the serial steps (effective, pipelined), at *equal*
//! FID — the "approximation-free" headline.
//!
//! `cargo bench --bench table1`

#[path = "common.rs"]
mod common;

use srds::coordinator::SamplerSpec;
use srds::data::{make_gmm, PIXEL_DATASETS};
use srds::metrics::fd_vs_gmm;
use srds::report::{f1, f2, Table};
use srds::solvers::Solver;

fn main() {
    let n = 1024;
    let count = 256; // chains per dataset (paper: 5000 on GPUs)
    let tol = common::tol255(0.1);
    let mut t = Table::new(
        "Table 1 — FD of SRDS vs sequential, DDIM N=1024, tol=0.1/255 (native backend)",
        &[
            "Dataset",
            "Serial Evals",
            "FD (seq)",
            "SRDS Iters",
            "Eff. Serial Evals",
            "Total Evals",
            "FD (SRDS)",
        ],
    );
    for ds in PIXEL_DATASETS {
        let gmm = make_gmm(ds);
        let be = common::native(&format!("gmm_{ds}"), Solver::Ddim);
        let (seq, _) = common::sequential_samples(&be, n, count, &Default::default(), 10_000);
        let fd_seq = fd_vs_gmm(&seq, count, &gmm);
        let cfg = SamplerSpec::srds(n).with_tol(tol);
        let agg = common::srds_samples(&be, &cfg, count, 10_000);
        let fd_srds = fd_vs_gmm(&agg.samples, count, &gmm);
        t.row(vec![
            ds.to_string(),
            format!("{n}"),
            f2(fd_seq),
            f1(agg.mean_iters),
            f1(agg.mean_eff_pipelined),
            f1(agg.mean_total),
            f2(fd_srds),
        ]);
    }
    t.print();
    println!(
        "\npaper shape: 4-6 iters, eff evals ~15-20% of {n}, FD(SRDS) == FD(seq). \
         ({count} chains; paper used 5000 samples on GPU.)"
    );
}
