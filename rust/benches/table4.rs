//! Table 4: wall-clock speedups — serial vs pipelined SRDS vs ParaDiGMS
//! at thresholds {1e-3, 1e-2, 1e-1}, N ∈ {961, 196, 25}, on identical
//! "machines": a 4-device simulated clock (deterministic schedule math)
//! plus measured wall-clock on this host for reference.
//!
//! Paper shape: SRDS beats ParaDiGMS at every threshold; tight-threshold
//! ParaDiGMS is *slower than serial* on short trajectories.
//!
//! `cargo bench --bench table4`

#[path = "common.rs"]
mod common;

use srds::coordinator::{prior_sample, SamplerSpec};
use srds::exec::{simulate_paradigms, simulate_srds, simulate_sequential};
use srds::report::{f1, speedup, Table};
use srds::schedule::Partition;
use srds::solvers::Solver;

/// Per-sweep AllReduce/prefix-sum overhead in eval units. The paper's
/// App. D measures ParaDiGMS turning a 20x eff-step reduction into only
/// a 3.4x wallclock speedup — i.e. ~4 evals of per-sweep sync overhead.
const SYNC_COST: u64 = 4;

fn main() {
    let be = common::native("gmm_latent_cond", Solver::Ddim);
    let devices = 4;
    let batch_per_device = 8; // 4 x 8 = the 32-bucket of the artifacts
    let reps = 6u64;
    let tol = common::tol255(0.1);

    let mut t = Table::new(
        &format!("Table 4 — modeled time (eval units, {devices} devices) serial vs pipelined SRDS vs ParaDiGMS"),
        &[
            "Method",
            "Serial time",
            "SRDS time",
            "(speedup)",
            "PD@1e-3",
            "PD@1e-2",
            "PD@1e-1",
        ],
    );
    for n in [961usize, 196, 25] {
        // SRDS: measure iterations-to-converge, then model the pipelined
        // schedule on the device budget.
        let mut srds_time = 0.0;
        for s in 0..reps {
            let x0 = prior_sample(256, 50_000 + s);
            let cfg = SamplerSpec::srds(n).with_tol(tol).with_seed(50_000 + s);
            let r = srds::coordinator::srds(&be, &x0, &cfg);
            let part = Partition::sqrt_n(n);
            // A device runs `batch_per_device` independent rows per eval
            // slot (batched inference, §3.4), so the schedule sees
            // devices × batch "slots".
            let sim = simulate_srds(&part, r.stats.iters, 1, devices * batch_per_device, true);
            srds_time += sim.makespan as f64;
        }
        srds_time /= reps as f64;
        let serial_time = simulate_sequential(n, 1, devices).makespan as f64;

        // ParaDiGMS at each threshold: measure sweeps, then model the
        // windowed schedule incl. the per-sweep AllReduce (App. D).
        let mut pd = Vec::new();
        for thr in [1e-3f32, 1e-2, 1e-1] {
            let mut time = 0.0;
            for s in 0..reps {
                let x0 = prior_sample(256, 50_000 + s);
                // ParaDiGMS compares squared error against its τ
                // (config docs) — pass τ² to match the paper's 1e-3…1e-1.
                let cfg = SamplerSpec::paradigms(n)
                    .with_tol(thr * thr)
                    .with_window(devices * batch_per_device)
                    .with_seed(50_000 + s);
                let r = srds::coordinator::paradigms(&be, &x0, &cfg);
                let window = (devices * batch_per_device).min(n);
                let sim = simulate_paradigms(r.stats.iters, window, devices, batch_per_device, 1, SYNC_COST);
                time += sim.makespan as f64;
            }
            pd.push(time / reps as f64);
        }
        t.row(vec![
            format!("DDIM N={n}"),
            f1(serial_time),
            f1(srds_time),
            speedup(serial_time, srds_time),
            f1(pd[0]),
            f1(pd[1]),
            f1(pd[2]),
        ]);
    }
    t.print();
    println!("\npaper shape (Table 4): SRDS 4.3x/3.2x/1.7x vs serial; ParaDiGMS@1e-3 slower");
    println!("than serial at N=961 (275s vs 45s) and barely breaks even at N=25.");
}
