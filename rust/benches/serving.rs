//! Serving-throughput bench for the multi-tenant engine: closed-loop
//! concurrent clients hammering one shared `exec::engine` pool, at 1, 4
//! and 16 clients. Reports requests/sec and the engine's mean batch
//! occupancy per level, as one JSON line — the serving number the perf
//! trajectory tracks (occupancy > 1.0 at the concurrent levels means
//! cross-request step fusion is actually happening).
//!
//! `cargo bench --bench serving`

use srds::batching::BatchPolicy;
use srds::coordinator::{prior_sample, SamplerSpec};
use srds::data::make_gmm;
use srds::exec::{Engine, EngineConfig, NativeFactory};
use srds::json::{self, Value};
use srds::model::{EpsModel, GmmEps};
use srds::solvers::Solver;
use srds::workload::{generate_trace, percentile, ThroughputPoint, TraceConfig};
use std::sync::Arc;
use std::time::Instant;

const WORKERS: usize = 2;
const PER_CLIENT: usize = 8;
const N_STEPS: usize = 25;

fn main() {
    let model: Arc<dyn EpsModel> = Arc::new(GmmEps::new(make_gmm("church")));
    let mut points = Vec::new();
    for clients in [1usize, 4, 16] {
        // Fresh engine per level so occupancy reflects this level only.
        let engine = Arc::new(Engine::new(
            Arc::new(NativeFactory::new(model.clone(), Solver::Ddim)),
            EngineConfig { workers: WORKERS, batch: BatchPolicy::default() },
        ));
        let trace = generate_trace(&TraceConfig {
            rate_hz: 1000.0,
            num_requests: clients * PER_CLIENT,
            n_steps: N_STEPS,
            num_classes: 1,
            seed: 11,
        });
        let t0 = Instant::now();
        let mut threads = Vec::new();
        for c in 0..clients {
            let engine = engine.clone();
            let reqs: Vec<_> = trace[c * PER_CLIENT..(c + 1) * PER_CLIENT].to_vec();
            threads.push(std::thread::spawn(move || {
                let mut lat_ms = Vec::with_capacity(reqs.len());
                for r in reqs {
                    let x0 = prior_sample(engine.dim(), r.seed);
                    let spec = SamplerSpec::srds(r.n).with_tol(1e-4).with_seed(r.seed);
                    let t = Instant::now();
                    let out = engine.run_srds(&x0, &spec);
                    assert!(out.sample.iter().all(|v| v.is_finite()));
                    lat_ms.push(t.elapsed().as_secs_f64() * 1000.0);
                }
                lat_ms
            }));
        }
        let mut lat_ms: Vec<f64> =
            threads.into_iter().flat_map(|t| t.join().unwrap()).collect();
        let wall_s = t0.elapsed().as_secs_f64();
        lat_ms.sort_by(f64::total_cmp);
        let st = engine.stats();
        points.push(ThroughputPoint {
            clients,
            requests: clients * PER_CLIENT,
            wall_s,
            mean_batch_occupancy: st.mean_occupancy,
            p50_ms: percentile(&lat_ms, 0.5),
            p95_ms: percentile(&lat_ms, 0.95),
        });
    }
    let report = json::obj(vec![
        ("bench", Value::Str("serving_throughput".into())),
        ("model", Value::Str("gmm_church".into())),
        ("sampler", Value::Str("srds".into())),
        ("n", Value::Num(N_STEPS as f64)),
        ("workers", Value::Num(WORKERS as f64)),
        ("points", Value::Arr(points.iter().map(|p| p.to_json()).collect())),
    ]);
    println!("{}", json::to_string(&report));
}
