//! Serving-throughput bench for the multi-tenant engine: closed-loop
//! concurrent clients hammering one shared `exec::engine` pool, at 1, 4
//! and 16 clients. Reports requests/sec and the engine's mean batch
//! occupancy per level, as one JSON line — the serving number the perf
//! trajectory tracks (occupancy > 1.0 at the concurrent levels means
//! cross-request step fusion is actually happening).
//!
//! Since the engine-native task rework the engine serves *every*
//! registry sampler as a dispatcher-resident task, so the report also
//! carries a **mixed-fleet point**: one closed-loop client per
//! registered sampler, all four kinds in flight simultaneously, with
//! per-sampler rps + mean per-request batch occupancy — the
//! heterogeneous-tenant number.
//!
//! Third section, `qos`: a mixed interactive + batch fleet through the
//! weighted-DRR scheduler — three flooding batch-class clients against
//! one interactive client on the same engine, reporting **per-class**
//! rps / p50 / p95 plus the engine's per-class lanes. The number the
//! QoS layer is accountable for: interactive p95 staying a small
//! multiple of its unloaded latency while the flood saturates the pool.
//!
//! Fourth section, `sharded`: the same closed-loop fleet pushed through
//! an `exec::Router` at 1, 2 and 4 shards (steal mesh on, one worker
//! per shard so total worker count scales with the width). Per width:
//! rps, p50/p95, aggregated occupancy, and the fleet's steal count —
//! the scaling number the sharding layer is accountable for, gated by
//! `ci/bench_gate.py` against `BENCH_serving.json`.
//!
//! Fifth section, `repeat`: the hot-spec number for the shared-work
//! layer — four closed-loop clients all hammering the *same* spec+seed
//! (the repeated-prompt serving case), once with the coarse-spine
//! cache + in-flight coalescing on and once fully off. Reports
//! rps/p50/p95 per variant plus the cache counters and `hit_rate`
//! (hits over lookups; coalesced duplicates never reach the cache).
//! The `cache_on` hit rate and rps are gated — the cache going cold or
//! the dedupe table stopping absorbing is a structural regression.
//!
//! Sixth section, `streaming`: the serving submission path the wire's
//! v1 dialect rides, A/B'd with and without a per-iterate progress
//! sink at eight closed-loop clients. The streamed arm reports
//! time-to-first-iterate p50/p95 (the anytime latency a `"stream":
//! true` client actually sees) against time-to-final; both arms' rps
//! are gated — fanning each completed iterate out as a refcount share
//! must not cost meaningful throughput.
//!
//! `cargo bench --bench serving`

use srds::batching::BatchPolicy;
use srds::coordinator::{prior_sample, registry, QosClass, SamplerSpec};
use srds::data::make_gmm;
use srds::exec::{
    Engine, EngineConfig, IterateEvent, NativeFactory, ProgressSink, Router, RouterConfig,
    TaskReply,
};
use srds::json::{self, Value};
use srds::model::{EpsModel, GmmEps};
use srds::solvers::Solver;
use srds::workload::{generate_trace, percentile, ThroughputPoint, TraceConfig};
use std::sync::Arc;
use std::time::Instant;

const WORKERS: usize = 2;
const PER_CLIENT: usize = 8;
const N_STEPS: usize = 25;

fn fresh_engine(model: &Arc<dyn EpsModel>) -> Arc<Engine> {
    Arc::new(Engine::new(
        Arc::new(NativeFactory::new(model.clone(), Solver::Ddim)),
        EngineConfig { workers: WORKERS, batch: BatchPolicy::default(), ..EngineConfig::default() },
    ))
}

fn main() {
    let model: Arc<dyn EpsModel> = Arc::new(GmmEps::new(make_gmm("church")));
    let mut points = Vec::new();
    for clients in [1usize, 4, 16] {
        // Fresh engine per level so occupancy reflects this level only.
        let engine = fresh_engine(&model);
        let trace = generate_trace(&TraceConfig {
            rate_hz: 1000.0,
            num_requests: clients * PER_CLIENT,
            n_steps: N_STEPS,
            num_classes: 1,
            seed: 11,
        });
        let t0 = Instant::now();
        let mut threads = Vec::new();
        for c in 0..clients {
            let engine = engine.clone();
            let reqs: Vec<_> = trace[c * PER_CLIENT..(c + 1) * PER_CLIENT].to_vec();
            threads.push(std::thread::spawn(move || {
                let mut lat_ms = Vec::with_capacity(reqs.len());
                for r in reqs {
                    let x0 = prior_sample(engine.dim(), r.seed);
                    let spec = SamplerSpec::srds(r.n).with_tol(1e-4).with_seed(r.seed);
                    let t = Instant::now();
                    let out = engine.run(&x0, &spec);
                    assert!(out.sample.iter().all(|v| v.is_finite()));
                    lat_ms.push(t.elapsed().as_secs_f64() * 1000.0);
                }
                lat_ms
            }));
        }
        let mut lat_ms: Vec<f64> =
            threads.into_iter().flat_map(|t| t.join().unwrap()).collect();
        let wall_s = t0.elapsed().as_secs_f64();
        lat_ms.sort_by(f64::total_cmp);
        let st = engine.stats();
        points.push(ThroughputPoint {
            clients,
            requests: clients * PER_CLIENT,
            wall_s,
            mean_batch_occupancy: st.mean_occupancy,
            p50_ms: percentile(&lat_ms, 0.5),
            p95_ms: percentile(&lat_ms, 0.95),
        });
    }

    // Mixed fleet: one closed-loop client per registered sampler, all
    // kinds resident in the engine's task table at once. Per-sampler
    // throughput plus the mean per-request occupancy each kind saw
    // (from its responses' `batch_occupancy`, not the engine-wide mean).
    let engine = fresh_engine(&model);
    let sampler_names = registry().list();
    let t0 = Instant::now();
    let mut threads = Vec::new();
    for (i, name) in sampler_names.iter().enumerate() {
        let engine = engine.clone();
        let kind = registry().parse(name).unwrap().kind();
        threads.push(std::thread::spawn(move || {
            // Per-client wall clock: rps must reflect how fast THIS
            // sampler's closed loop ran, not the joint fleet wall (the
            // fastest kind finishes long before the slowest).
            let t_client = Instant::now();
            let mut lat_ms = Vec::with_capacity(PER_CLIENT);
            let mut occ_sum = 0.0f64;
            for j in 0..PER_CLIENT {
                let seed = 500 + (i * PER_CLIENT + j) as u64;
                let x0 = prior_sample(engine.dim(), seed);
                let spec = SamplerSpec::for_kind(N_STEPS, kind).with_tol(1e-4).with_seed(seed);
                let t = Instant::now();
                let out = engine.run(&x0, &spec);
                assert!(out.sample.iter().all(|v| v.is_finite()));
                lat_ms.push(t.elapsed().as_secs_f64() * 1000.0);
                occ_sum += out.stats.batch_occupancy;
            }
            lat_ms.sort_by(f64::total_cmp);
            (lat_ms, occ_sum / PER_CLIENT as f64, t_client.elapsed().as_secs_f64())
        }));
    }
    let per_sampler: Vec<(String, Vec<f64>, f64, f64)> = sampler_names
        .iter()
        .zip(threads)
        .map(|(name, t)| {
            let (lat, occ, wall_s) = t.join().unwrap();
            (name.to_string(), lat, occ, wall_s)
        })
        .collect();
    let mixed_wall_s = t0.elapsed().as_secs_f64();
    let mixed_stats = engine.stats();
    let mixed = json::obj(vec![
        ("clients", Value::Num(sampler_names.len() as f64)),
        ("requests", Value::Num((sampler_names.len() * PER_CLIENT) as f64)),
        ("wall_s", Value::Num(mixed_wall_s)),
        (
            "rps",
            Value::Num((sampler_names.len() * PER_CLIENT) as f64 / mixed_wall_s.max(1e-9)),
        ),
        ("engine_mean_occupancy", Value::Num(mixed_stats.mean_occupancy)),
        (
            "per_sampler",
            json::obj(
                per_sampler
                    .iter()
                    .map(|(name, lat, occ, wall_s)| {
                        (
                            name.as_str(),
                            json::obj(vec![
                                ("rps", Value::Num(PER_CLIENT as f64 / wall_s.max(1e-9))),
                                ("wall_s", Value::Num(*wall_s)),
                                ("mean_batch_occupancy", Value::Num(*occ)),
                                ("p50_ms", Value::Num(percentile(lat, 0.5))),
                                ("p95_ms", Value::Num(percentile(lat, 0.95))),
                            ]),
                        )
                    })
                    .collect(),
            ),
        ),
    ]);

    // QoS fleet: three closed-loop batch-class floods vs one interactive
    // client, all on one engine — the per-class latency number under
    // contention (weighted DRR should hold interactive p95 down while
    // the flood eats the leftover capacity).
    let engine = fresh_engine(&model);
    let qos_t0 = Instant::now();
    let mut threads = Vec::new();
    for (i, class) in [QosClass::Batch, QosClass::Batch, QosClass::Batch, QosClass::Interactive]
        .into_iter()
        .enumerate()
    {
        let engine = engine.clone();
        threads.push(std::thread::spawn(move || {
            let t_client = Instant::now();
            let mut lat_ms = Vec::with_capacity(PER_CLIENT);
            for j in 0..PER_CLIENT {
                let seed = 900 + (i * PER_CLIENT + j) as u64;
                let x0 = prior_sample(engine.dim(), seed);
                let spec = SamplerSpec::srds(N_STEPS)
                    .with_tol(1e-4)
                    .with_seed(seed)
                    .with_priority(class);
                let t = Instant::now();
                let out = engine.run(&x0, &spec);
                assert!(out.sample.iter().all(|v| v.is_finite()));
                lat_ms.push(t.elapsed().as_secs_f64() * 1000.0);
            }
            lat_ms.sort_by(f64::total_cmp);
            (class, lat_ms, t_client.elapsed().as_secs_f64())
        }));
    }
    let mut per_class: Vec<(QosClass, Vec<f64>, f64)> = Vec::new();
    for t in threads {
        let (class, mut lat, wall_s) = t.join().unwrap();
        match per_class.iter_mut().find(|(c, _, _)| *c == class) {
            Some((_, all, w)) => {
                all.append(&mut lat);
                all.sort_by(f64::total_cmp);
                *w = w.max(wall_s);
            }
            None => per_class.push((class, lat, wall_s)),
        }
    }
    let qos_stats = engine.stats();
    let qos = json::obj(vec![
        ("clients", Value::Num(4.0)),
        ("requests", Value::Num((4 * PER_CLIENT) as f64)),
        ("wall_s", Value::Num(qos_t0.elapsed().as_secs_f64())),
        ("class_weights", Value::Arr(
            BatchPolicy::default().class_weights.iter().map(|&w| Value::Num(w as f64)).collect(),
        )),
        (
            "per_class",
            json::obj(
                per_class
                    .iter()
                    .map(|(class, lat, wall_s)| {
                        let lane = qos_stats.class(*class);
                        (
                            class.name(),
                            json::obj(vec![
                                ("requests", Value::Num(lat.len() as f64)),
                                ("rps", Value::Num(lat.len() as f64 / wall_s.max(1e-9))),
                                ("p50_ms", Value::Num(percentile(lat, 0.5))),
                                ("p95_ms", Value::Num(percentile(lat, 0.95))),
                                ("engine_rows", Value::Num(lane.rows as f64)),
                                ("engine_mean_wall_ms", Value::Num(lane.mean_wall_ms)),
                            ]),
                        )
                    })
                    .collect(),
            ),
        ),
    ]);

    // Sharded fleet: the same closed-loop load through the router at
    // widths 1, 2 and 4, one worker per shard so capacity grows with
    // the width. Eight clients keep every width busy; the router places
    // by load and the steal mesh rebalances queue imbalance, so rps
    // should scale (sub-linearly — the model is tiny and the batcher
    // loses cross-request fusion as rows spread out) while outputs stay
    // bit-identical, which shard_determinism.rs pins separately.
    let mut sharded = Vec::new();
    for shards in [1usize, 2, 4] {
        let router = Arc::new(Router::new(
            Arc::new(NativeFactory::new(model.clone(), Solver::Ddim)),
            RouterConfig { shards, workers: 1, ..RouterConfig::default() },
        ));
        const SHARD_CLIENTS: usize = 8;
        let t0 = Instant::now();
        let mut threads = Vec::new();
        for c in 0..SHARD_CLIENTS {
            let router = router.clone();
            threads.push(std::thread::spawn(move || {
                let mut lat_ms = Vec::with_capacity(PER_CLIENT);
                for j in 0..PER_CLIENT {
                    let seed = 1300 + (c * PER_CLIENT + j) as u64;
                    let x0 = prior_sample(router.dim(), seed);
                    let spec = SamplerSpec::srds(N_STEPS).with_tol(1e-4).with_seed(seed);
                    let t = Instant::now();
                    let out = router.run(&x0, &spec);
                    assert!(out.sample.iter().all(|v| v.is_finite()));
                    lat_ms.push(t.elapsed().as_secs_f64() * 1000.0);
                }
                lat_ms
            }));
        }
        let mut lat_ms: Vec<f64> =
            threads.into_iter().flat_map(|t| t.join().unwrap()).collect();
        let wall_s = t0.elapsed().as_secs_f64();
        lat_ms.sort_by(f64::total_cmp);
        let st = router.stats();
        sharded.push(json::obj(vec![
            ("shards", Value::Num(shards as f64)),
            ("clients", Value::Num(SHARD_CLIENTS as f64)),
            ("requests", Value::Num((SHARD_CLIENTS * PER_CLIENT) as f64)),
            ("wall_s", Value::Num(wall_s)),
            (
                "rps",
                Value::Num((SHARD_CLIENTS * PER_CLIENT) as f64 / wall_s.max(1e-9)),
            ),
            ("p50_ms", Value::Num(percentile(&lat_ms, 0.5))),
            ("p95_ms", Value::Num(percentile(&lat_ms, 0.95))),
            ("mean_occupancy", Value::Num(st.mean_occupancy)),
            ("steals", Value::Num(st.steals as f64)),
        ]));
    }

    // Hot-spec repeat fleet: every client runs the same spec+seed, so
    // after the first run the whole load is shared work. A/B the
    // shared-work layer on vs off on otherwise identical engines; the
    // outputs are bit-identical either way (cache_identity.rs pins
    // that) — this section measures what sharing buys.
    let mut repeat_variants: Vec<(&str, Value)> = Vec::new();
    for (label, cap, coalesce) in [("cache_on", 64usize, true), ("cache_off", 0usize, false)] {
        let engine = Arc::new(Engine::new(
            Arc::new(NativeFactory::new(model.clone(), Solver::Ddim)),
            EngineConfig {
                workers: WORKERS,
                spine_cache_cap: cap,
                coalesce,
                ..EngineConfig::default()
            },
        ));
        const REPEAT_CLIENTS: usize = 4;
        let t0 = Instant::now();
        let mut threads = Vec::new();
        for _ in 0..REPEAT_CLIENTS {
            let engine = engine.clone();
            threads.push(std::thread::spawn(move || {
                let x0 = prior_sample(engine.dim(), 77);
                let spec = SamplerSpec::srds(N_STEPS).with_tol(1e-4).with_seed(77);
                let mut lat_ms = Vec::with_capacity(PER_CLIENT);
                for _ in 0..PER_CLIENT {
                    let t = Instant::now();
                    let out = engine.run(&x0, &spec);
                    assert!(out.sample.iter().all(|v| v.is_finite()));
                    lat_ms.push(t.elapsed().as_secs_f64() * 1000.0);
                }
                lat_ms
            }));
        }
        let mut lat_ms: Vec<f64> =
            threads.into_iter().flat_map(|t| t.join().unwrap()).collect();
        let wall_s = t0.elapsed().as_secs_f64();
        lat_ms.sort_by(f64::total_cmp);
        let st = engine.stats();
        let lookups = st.cache_hits + st.cache_misses;
        repeat_variants.push((
            label,
            json::obj(vec![
                ("clients", Value::Num(REPEAT_CLIENTS as f64)),
                ("requests", Value::Num((REPEAT_CLIENTS * PER_CLIENT) as f64)),
                ("wall_s", Value::Num(wall_s)),
                (
                    "rps",
                    Value::Num((REPEAT_CLIENTS * PER_CLIENT) as f64 / wall_s.max(1e-9)),
                ),
                ("p50_ms", Value::Num(percentile(&lat_ms, 0.5))),
                ("p95_ms", Value::Num(percentile(&lat_ms, 0.95))),
                ("cache_hits", Value::Num(st.cache_hits as f64)),
                ("cache_misses", Value::Num(st.cache_misses as f64)),
                ("cache_evictions", Value::Num(st.cache_evictions as f64)),
                ("coalesced", Value::Num(st.coalesced as f64)),
                ("hit_rate", Value::Num(st.cache_hits as f64 / lookups.max(1) as f64)),
            ]),
        ));
    }
    let repeat = json::obj(repeat_variants);

    // Streaming fleet: eight closed-loop clients through the serving
    // submission path, once with a per-iterate progress sink (the v1
    // `"stream": true` request) and once without. Time-to-first-iterate
    // is measured inside the sink; time-to-final at the done callback.
    // Fresh engines per arm so occupancy and pools don't bleed across.
    const STREAM_CLIENTS: usize = 8;
    let mut streaming_pairs: Vec<(&str, Value)> = vec![
        ("clients", Value::Num(STREAM_CLIENTS as f64)),
        ("requests", Value::Num((STREAM_CLIENTS * PER_CLIENT) as f64)),
    ];
    for stream in [true, false] {
        let engine = fresh_engine(&model);
        let t0 = Instant::now();
        let mut threads = Vec::new();
        for c in 0..STREAM_CLIENTS {
            let engine = engine.clone();
            threads.push(std::thread::spawn(move || {
                let mut ttfi_ms = Vec::with_capacity(PER_CLIENT);
                let mut ttfinal_ms = Vec::with_capacity(PER_CLIENT);
                let mut iterates = 0u64;
                for j in 0..PER_CLIENT {
                    let seed = 1700 + (c * PER_CLIENT + j) as u64;
                    let x0 = prior_sample(engine.dim(), seed);
                    let mut spec = SamplerSpec::srds(N_STEPS).with_tol(1e-4).with_seed(seed);
                    if stream {
                        spec = spec.with_stream();
                    }
                    let t = Instant::now();
                    // (first-iterate latency, iterate count), written by
                    // the sink on the dispatcher thread; all progress
                    // callbacks complete before `done` fires, so the
                    // post-recv read races nothing.
                    let first = Arc::new(std::sync::Mutex::new((None::<f64>, 0u64)));
                    let sink = stream.then(|| {
                        let first = first.clone();
                        Box::new(move |_ev: IterateEvent| {
                            let mut slot = first.lock().unwrap();
                            slot.0.get_or_insert_with(|| t.elapsed().as_secs_f64() * 1000.0);
                            slot.1 += 1;
                        }) as ProgressSink
                    });
                    let (tx, rx) = std::sync::mpsc::channel();
                    engine.submit_serving(x0, spec, None, sink, move |reply, _| {
                        let _ = tx.send(reply);
                    });
                    let reply = rx.recv().expect("engine dispatcher dropped mid-bench");
                    let wall_ms = t.elapsed().as_secs_f64() * 1000.0;
                    let TaskReply::Done(out) = reply else {
                        panic!("unbudgeted request timed out")
                    };
                    assert!(out.sample.iter().all(|v| v.is_finite()));
                    ttfinal_ms.push(wall_ms);
                    if stream {
                        let (ttfi, n) = *first.lock().unwrap();
                        ttfi_ms.push(ttfi.expect("streamed request produced no iterate"));
                        iterates += n;
                    }
                }
                (ttfi_ms, ttfinal_ms, iterates)
            }));
        }
        let (mut ttfi, mut ttfinal, mut iterates) = (Vec::new(), Vec::new(), 0u64);
        for th in threads {
            let (fi, fin, it) = th.join().unwrap();
            ttfi.extend(fi);
            ttfinal.extend(fin);
            iterates += it;
        }
        let wall_s = t0.elapsed().as_secs_f64();
        ttfi.sort_by(f64::total_cmp);
        ttfinal.sort_by(f64::total_cmp);
        let rps = (STREAM_CLIENTS * PER_CLIENT) as f64 / wall_s.max(1e-9);
        if stream {
            streaming_pairs.extend([
                ("stream_rps", Value::Num(rps)),
                ("stream_wall_s", Value::Num(wall_s)),
                ("ttfi_p50_ms", Value::Num(percentile(&ttfi, 0.5))),
                ("ttfi_p95_ms", Value::Num(percentile(&ttfi, 0.95))),
                ("ttfinal_p50_ms", Value::Num(percentile(&ttfinal, 0.5))),
                ("ttfinal_p95_ms", Value::Num(percentile(&ttfinal, 0.95))),
                (
                    "mean_iterates",
                    Value::Num(iterates as f64 / (STREAM_CLIENTS * PER_CLIENT) as f64),
                ),
            ]);
        } else {
            streaming_pairs.extend([
                ("nonstream_rps", Value::Num(rps)),
                ("nonstream_wall_s", Value::Num(wall_s)),
                ("nonstream_p50_ms", Value::Num(percentile(&ttfinal, 0.5))),
                ("nonstream_p95_ms", Value::Num(percentile(&ttfinal, 0.95))),
            ]);
        }
    }
    let streaming = json::obj(streaming_pairs);

    let report = json::obj(vec![
        ("bench", Value::Str("serving_throughput".into())),
        ("model", Value::Str("gmm_church".into())),
        ("sampler", Value::Str("srds".into())),
        ("n", Value::Num(N_STEPS as f64)),
        ("workers", Value::Num(WORKERS as f64)),
        ("points", Value::Arr(points.iter().map(|p| p.to_json()).collect())),
        ("mixed", mixed),
        ("qos", qos),
        ("sharded", Value::Arr(sharded)),
        ("repeat", repeat),
        ("streaming", streaming),
    ]);
    println!("{}", json::to_string(&report));
}
