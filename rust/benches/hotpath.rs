//! Hot-path micro benchmarks (the in-tree criterion substitute): per-op
//! medians for every layer the coordinator touches. §Perf of
//! EXPERIMENTS.md tracks these before/after each optimization.
//!
//! `cargo bench --bench hotpath`

#[path = "common.rs"]
mod common;

use srds::coordinator::{prior_sample, SamplerSpec};
use srds::data::{make_gmm, rng::SplitMix64};
use srds::exec::simulate_srds;
use srds::metrics::fit_moments;
use srds::model::{EpsModel, GmmEps, SmallDenoiser};
use srds::report::{time_median, Table};
use srds::schedule::Partition;
use srds::solvers::{ddim_coeffs, Solver, StepBackend, StepRequest};

fn bench<F: FnMut()>(t: &mut Table, name: &str, per: usize, f: F) {
    let d = time_median(f, 2, 9);
    let ns = d.as_nanos() as f64 / per.max(1) as f64;
    let unit = if ns > 1e6 {
        format!("{:.2} ms", ns / 1e6)
    } else if ns > 1e3 {
        format!("{:.2} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    };
    t.row(vec![name.to_string(), unit]);
}

fn main() {
    let mut t = Table::new("hot-path medians (per unit in name)", &["op", "median"]);
    let mut rng = SplitMix64::new(1);

    // L3 native model evals.
    let gmm = GmmEps::new(make_gmm("latent_cond"));
    let x32 = rng.normals_f32(32 * 256);
    let s32: Vec<f32> = (0..32).map(|i| i as f32 / 32.0).collect();
    let mut out = vec![0.0f32; 32 * 256];
    bench(&mut t, "gmm eps, batch 32 row (d=256,K=16)", 32, || {
        gmm.eps(&x32, &s32, None, &mut out);
    });
    let den = SmallDenoiser::new(256);
    bench(&mut t, "denoiser eps, batch 32 row", 32, || {
        den.eps(&x32, &s32, None, &mut out);
    });

    // Schedule + solver coefficient math.
    bench(&mut t, "ddim_coeffs x1000", 1000, || {
        for i in 0..1000 {
            let s = i as f32 / 1001.0;
            std::hint::black_box(ddim_coeffs(s, s + 1e-3));
        }
    });

    // Corrector update.
    let a = rng.normals_f32(256);
    let b = rng.normals_f32(256);
    let c = rng.normals_f32(256);
    let mut xo = vec![0.0f32; 256];
    bench(&mut t, "corrector update (d=256) x100", 100, || {
        for _ in 0..100 {
            for j in 0..256 {
                xo[j] = a[j] + (b[j] - c[j]);
            }
            std::hint::black_box(&xo);
        }
    });

    // Full native SRDS runs.
    let be = common::native("gmm_church", Solver::Ddim);
    let x0 = prior_sample(64, 3);
    bench(&mut t, "SRDS N=256 church (native, full run)", 1, || {
        let cfg = SamplerSpec::srds(256).with_tol(common::tol255(0.1)).with_seed(3);
        std::hint::black_box(srds::coordinator::srds(&be, &x0, &cfg));
    });

    // simclock scheduling throughput.
    let part = Partition::sqrt_n(1024);
    bench(&mut t, "simclock schedule N=1024, 5 iters", 1, || {
        std::hint::black_box(simulate_srds(&part, 5, 1, 33, true));
    });

    // Metrics.
    let xs = rng.normals_f32(256 * 64);
    bench(&mut t, "fit_moments 256x64", 1, || {
        std::hint::black_box(fit_moments(&xs, 256, 64));
    });

    // PJRT step latency per batch bucket (when artifacts exist).
    if let Some(be) = common::pjrt("gmm_church", Solver::Ddim) {
        for bsz in [1usize, 8, 32] {
            let x = rng.normals_f32(bsz * 64);
            let s_from: Vec<f32> = (0..bsz).map(|i| 0.3 + 1e-3 * i as f32).collect();
            let s_to: Vec<f32> = s_from.iter().map(|v| v + 0.01).collect();
            let seeds = vec![0u64; bsz];
            bench(&mut t, &format!("pjrt ddim step b={bsz} (church)"), 1, || {
                std::hint::black_box(be.step(&StepRequest {
                    x: &x,
                    s_from: &s_from,
                    s_to: &s_to,
                    mask: None,
                    guidance: 0.0,
                    seeds: &seeds,
                }));
            });
        }
        if let Some(bd) = common::pjrt("small_denoiser", Solver::Ddim) {
            let x = rng.normals_f32(32 * 256);
            let s_from: Vec<f32> = (0..32).map(|i| 0.3 + 1e-3 * i as f32).collect();
            let s_to: Vec<f32> = s_from.iter().map(|v| v + 0.01).collect();
            let seeds = vec![0u64; 32];
            bench(&mut t, "pjrt denoiser step b=32", 1, || {
                std::hint::black_box(bd.step(&StepRequest {
                    x: &x,
                    s_from: &s_from,
                    s_to: &s_to,
                    mask: None,
                    guidance: 0.0,
                    seeds: &seeds,
                }));
            });
        }
    } else {
        t.row(vec!["pjrt steps".into(), "(artifacts not built)".into()]);
    }
    t.print();
}
