//! Figure 7 (App. F): FD (FID substitute) as a function of SRDS
//! iteration count on church, N = 1024 — paper shape: rapid convergence
//! to the sequential FID (12.8 there) within a few iterations.
//!
//! `cargo bench --bench fig7`

#[path = "common.rs"]
mod common;

use srds::coordinator::{prior_sample, sequential, Conditioning, SamplerSpec};
use srds::data::make_gmm;
use srds::metrics::{fd_vs_gmm, fit_moments, fd_gaussian, gmm_moments};
use srds::solvers::Solver;

fn main() {
    let gmm = make_gmm("church");
    let be = common::native("gmm_church", Solver::Ddim);
    let n = 1024;
    let count = 192;
    let max_show = 5;

    // Collect the k-th iterate of every chain.
    let mut per_iter: Vec<Vec<f32>> = vec![Vec::new(); max_show + 1];
    let mut seq_samples = Vec::new();
    for c in 0..count as u64 {
        let x0 = prior_sample(64, 95_000 + c);
        let cfg = SamplerSpec::srds(n)
            .with_tol(0.0)
            .with_max_iters(max_show)
            .with_iterates()
            .with_seed(95_000 + c);
        let r = srds::coordinator::srds(&be, &x0, &cfg);
        for k in 0..=max_show {
            let it = &r.iterates[k.min(r.iterates.len() - 1)];
            per_iter[k].extend_from_slice(it);
        }
        let (seq, _) = sequential(&be, &x0, n, &Conditioning::none(), 95_000 + c);
        seq_samples.extend_from_slice(&seq);
    }
    let fd_seq = fd_vs_gmm(&seq_samples, count, &gmm);
    let reference = gmm_moments(&gmm, None);
    let fds: Vec<f64> = per_iter
        .iter()
        .map(|xs| fd_gaussian(&fit_moments(xs, count, 64), &reference))
        .collect();
    let seq_line = vec![fd_seq; fds.len()];
    println!("=== Fig. 7 — FD vs SRDS iteration, church N = {n} ({count} chains) ===");
    println!(
        "{}",
        srds::viz::ascii_plot(&[("srds", &fds), ("sequential", &seq_line)], 48, 12)
    );
    for (k, fd) in fds.iter().enumerate() {
        println!("  after iter {k}: FD = {fd:.3}");
    }
    println!("  sequential   : FD = {fd_seq:.3}");
    println!("\npaper shape: FID snaps to the sequential value within a few iterations.");
}
