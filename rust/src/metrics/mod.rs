//! Sample-quality metrics — the substitutes for the paper's FID / KID /
//! CLIP score (DESIGN.md §Substitutions). All have analytic references
//! against the known GMM data distribution.
//!
//! * [`fd_gaussian`] — Fréchet distance between Gaussian fits in sample
//!   space (exactly the FID formula, minus the Inception embedding).
//! * [`kid_poly`] — unbiased MMD² with the KID polynomial kernel.
//! * [`cond_score`] — mean class-conditional log-likelihood under the
//!   target mixture (the CLIP-score analogue for "prompt" adherence).

use crate::data::Gmm;
use crate::linalg::{matmul, sqrtm_psd, trace};

/// Gaussian moments fitted to a flat `(n, d)` sample matrix.
#[derive(Debug, Clone)]
pub struct Moments {
    pub dim: usize,
    pub mean: Vec<f64>,
    /// Row-major `d×d` covariance (unbiased).
    pub cov: Vec<f64>,
    pub count: usize,
}

/// Fit mean + covariance to samples.
pub fn fit_moments(xs: &[f32], n: usize, d: usize) -> Moments {
    assert_eq!(xs.len(), n * d);
    assert!(n >= 2, "need at least two samples");
    let mut mean = vec![0.0f64; d];
    for i in 0..n {
        for j in 0..d {
            mean[j] += xs[i * d + j] as f64;
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    let mut cov = vec![0.0f64; d * d];
    for i in 0..n {
        for a in 0..d {
            let da = xs[i * d + a] as f64 - mean[a];
            for b in a..d {
                let db = xs[i * d + b] as f64 - mean[b];
                cov[a * d + b] += da * db;
            }
        }
    }
    for a in 0..d {
        for b in a..d {
            let v = cov[a * d + b] / (n - 1) as f64;
            cov[a * d + b] = v;
            cov[b * d + a] = v;
        }
    }
    Moments { dim: d, mean, cov, count: n }
}

/// Analytic reference moments of a GMM (class-restricted if `cls`).
pub fn gmm_moments(gmm: &Gmm, cls: Option<u32>) -> Moments {
    match cls {
        None => Moments { dim: gmm.dim(), mean: gmm.mean().iter().map(|&x| x as f64).collect(), cov: gmm.cov(), count: usize::MAX },
        Some(c) => {
            // Restrict + renormalize the mixture, then moments.
            let mask = gmm.class_mask(c);
            let d = gmm.dim();
            let wsum: f64 = gmm
                .weights
                .iter()
                .zip(&mask)
                .map(|(&w, &m)| (w * m) as f64)
                .sum();
            let mut mean = vec![0.0f64; d];
            for k in 0..gmm.k() {
                let w = (gmm.weights[k] * mask[k]) as f64 / wsum;
                for (j, &mj) in gmm.mean_of(k).iter().enumerate() {
                    mean[j] += w * mj as f64;
                }
            }
            let mut cov = vec![0.0f64; d * d];
            for k in 0..gmm.k() {
                let w = (gmm.weights[k] * mask[k]) as f64 / wsum;
                if w == 0.0 {
                    continue;
                }
                let mk = gmm.mean_of(k);
                let s2 = (gmm.sigmas[k] as f64) * (gmm.sigmas[k] as f64);
                for a in 0..d {
                    let da = mk[a] as f64 - mean[a];
                    for b in 0..d {
                        let db = mk[b] as f64 - mean[b];
                        cov[a * d + b] += w * da * db;
                    }
                    cov[a * d + a] += w * s2;
                }
            }
            Moments { dim: d, mean, cov, count: usize::MAX }
        }
    }
}

/// Fréchet distance between two Gaussian fits:
/// `‖μ1−μ2‖² + tr(C1 + C2 − 2 (C1^{1/2} C2 C1^{1/2})^{1/2})`.
pub fn fd_gaussian(a: &Moments, b: &Moments) -> f64 {
    assert_eq!(a.dim, b.dim);
    let d = a.dim;
    let mean_term: f64 = a
        .mean
        .iter()
        .zip(&b.mean)
        .map(|(x, y)| (x - y) * (x - y))
        .sum();
    let s1 = sqrtm_psd(&a.cov, d);
    let inner = matmul(&matmul(&s1, &b.cov, d), &s1, d);
    let cross = sqrtm_psd(&inner, d);
    let tr = trace(&a.cov, d) + trace(&b.cov, d) - 2.0 * trace(&cross, d);
    (mean_term + tr).max(0.0)
}

/// Convenience: FD of generated samples against the analytic GMM
/// reference.
pub fn fd_vs_gmm(xs: &[f32], n: usize, gmm: &Gmm) -> f64 {
    fd_gaussian(&fit_moments(xs, n, gmm.dim()), &gmm_moments(gmm, None))
}

/// Unbiased MMD² with the KID kernel `k(x,y) = (xᵀy/d + 1)³` between two
/// flat sample matrices (this *is* the Kernel Inception Distance
/// estimator, applied to raw sample features).
pub fn kid_poly(xs: &[f32], nx: usize, ys: &[f32], ny: usize, d: usize) -> f64 {
    assert!(nx >= 2 && ny >= 2);
    let kf = |a: &[f32], b: &[f32]| -> f64 {
        let dot: f64 = a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
        let v = dot / d as f64 + 1.0;
        v * v * v
    };
    fn row(m: &[f32], i: usize, d: usize) -> &[f32] {
        &m[i * d..(i + 1) * d]
    }
    let mut kxx = 0.0;
    for i in 0..nx {
        for j in 0..nx {
            if i != j {
                kxx += kf(row(xs, i, d), row(xs, j, d));
            }
        }
    }
    kxx /= (nx * (nx - 1)) as f64;
    let mut kyy = 0.0;
    for i in 0..ny {
        for j in 0..ny {
            if i != j {
                kyy += kf(row(ys, i, d), row(ys, j, d));
            }
        }
    }
    kyy /= (ny * (ny - 1)) as f64;
    let mut kxy = 0.0;
    for i in 0..nx {
        for j in 0..ny {
            kxy += kf(row(xs, i, d), row(ys, j, d));
        }
    }
    kxy /= (nx * ny) as f64;
    kxx + kyy - 2.0 * kxy
}

/// Mean log-likelihood of samples under the (class-restricted) mixture —
/// the CLIP-score analogue: higher = better adherence to the "prompt"
/// (class). Computed per-dimension for scale comparability.
pub fn cond_score(xs: &[f32], n: usize, gmm: &Gmm, cls: Option<u32>) -> f64 {
    let d = gmm.dim();
    let mask = match cls {
        Some(c) => gmm.class_mask(c),
        None => vec![1.0; gmm.k()],
    };
    let wsum: f64 = gmm.weights.iter().zip(&mask).map(|(&w, &m)| (w * m) as f64).sum();
    let mut total = 0.0f64;
    for i in 0..n {
        let x = &xs[i * d..(i + 1) * d];
        // log sum_k w_k N(x; mu_k, sigma_k^2 I) via logsumexp
        let mut logs = Vec::with_capacity(gmm.k());
        for k in 0..gmm.k() {
            if mask[k] == 0.0 {
                continue;
            }
            let w = gmm.weights[k] as f64 / wsum;
            let s2 = (gmm.sigmas[k] as f64) * (gmm.sigmas[k] as f64);
            let mk = gmm.mean_of(k);
            let sq: f64 = x
                .iter()
                .zip(mk)
                .map(|(a, b)| ((*a - *b) as f64) * ((*a - *b) as f64))
                .sum();
            logs.push(w.ln() - 0.5 * d as f64 * (2.0 * std::f64::consts::PI * s2).ln() - 0.5 * sq / s2);
        }
        let mx = logs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = mx + logs.iter().map(|l| (l - mx).exp()).sum::<f64>().ln();
        total += lse;
    }
    total / (n as f64 * d as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::make_gmm;

    #[test]
    fn fd_of_identical_moments_is_zero() {
        let g = make_gmm("church");
        let m = gmm_moments(&g, None);
        let fd = fd_gaussian(&m, &m);
        assert!(fd < 1e-6, "fd = {fd}");
    }

    #[test]
    fn fd_of_true_samples_is_small_and_shifted_is_large() {
        let g = make_gmm("cifar");
        let n = 2000;
        let xs = g.sample(n, 42, None);
        let fd_true = fd_vs_gmm(&xs, n, &g);
        // Shift every sample by 1.0 in every dim: FD grows by ≈ d.
        let shifted: Vec<f32> = xs.iter().map(|&x| x + 1.0).collect();
        let fd_shift = fd_vs_gmm(&shifted, n, &g);
        assert!(fd_true < 5.0, "fd_true {fd_true}");
        assert!(fd_shift > fd_true + 50.0, "fd_shift {fd_shift}");
    }

    #[test]
    fn kid_separates_matching_and_mismatched_sets() {
        let g = make_gmm("church");
        let a = g.sample(200, 1, None);
        let b = g.sample(200, 2, None);
        let kid_same = kid_poly(&a, 200, &b, 200, g.dim());
        let shifted: Vec<f32> = a.iter().map(|&x| x + 0.5).collect();
        let kid_diff = kid_poly(&shifted, 200, &b, 200, g.dim());
        assert!(kid_same.abs() < 0.5, "kid_same {kid_same}");
        assert!(kid_diff > kid_same + 0.2, "kid_diff {kid_diff}");
    }

    #[test]
    fn cond_score_prefers_matching_class() {
        let g = make_gmm("latent_cond");
        let xs = g.sample(64, 9, Some(1));
        let right = cond_score(&xs, 64, &g, Some(1));
        let wrong = cond_score(&xs, 64, &g, Some(3));
        assert!(right > wrong, "{right} vs {wrong}");
    }

    #[test]
    fn moments_of_reference_samples_match_analytic() {
        let g = make_gmm("bedroom");
        let n = 4000;
        let xs = g.sample(n, 77, None);
        let fit = fit_moments(&xs, n, g.dim());
        let anal = gmm_moments(&g, None);
        for j in 0..g.dim() {
            assert!(
                (fit.mean[j] - anal.mean[j]).abs() < 0.12,
                "mean dim {j}: {} vs {}",
                fit.mean[j],
                anal.mean[j]
            );
        }
        // diagonal covariance entries in the right ballpark
        for j in 0..g.dim() {
            let a = fit.cov[j * g.dim() + j];
            let b = anal.cov[j * g.dim() + j];
            assert!((a - b).abs() < 0.2 * (1.0 + b), "cov({j},{j}): {a} vs {b}");
        }
    }
}
