//! Deterministic cross-language RNG (splitmix64) — mirrors
//! `python/compile/rng.py` exactly.

/// splitmix64 PRNG (Steele et al.) on wrapping u64 arithmetic; the stream
/// is identical to the python implementation (ints masked to 64 bits).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller (f64 math, two uniforms per draw —
    /// no caching, so the call sequence is language-independent).
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > 0.0 {
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normals_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_normal() as f32).collect()
    }

    /// Fill `out` with standard normals (f32).
    pub fn fill_normals(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next_normal() as f32;
        }
    }
}

/// Stable 64-bit seed from a short ascii name (FNV-1a) — mirrors
/// `rng.seed_for` in python.
pub fn seed_for(name: &str) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in name.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Derived key for per-(seed, time) noise draws, used by the DDPM solver
/// so noise is a pure function of the trajectory position (Parareal needs
/// the step map deterministic). Mixing is splitmix-style.
pub fn noise_key(seed: u64, s_from_bits: u32, row: u64) -> u64 {
    let mut z = seed ^ (s_from_bits as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ row.wrapping_mul(0xD1B5_4A32_D192_ED03);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 0 (cross-checked against python rng.py).
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(42);
        for _ in 0..1000 {
            let u = r.next_f64();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normals_have_sane_moments() {
        let mut r = SplitMix64::new(7);
        let xs = r.normals_f32(20_000);
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn seed_for_is_fnv1a() {
        // FNV-1a of "church" (cross-checked against python seed_for).
        assert_eq!(seed_for(""), 0xCBF2_9CE4_8422_2325);
        assert_ne!(seed_for("church"), seed_for("bedroom"));
    }

    #[test]
    fn noise_key_distinguishes_rows_and_times() {
        let k0 = noise_key(1, 0x3f000000, 0);
        assert_ne!(k0, noise_key(1, 0x3f000000, 1));
        assert_ne!(k0, noise_key(1, 0x3f000001, 0));
        assert_eq!(k0, noise_key(1, 0x3f000000, 0));
    }
}
