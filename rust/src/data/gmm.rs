//! Gaussian-mixture datasets — mirrors `python/compile/datasets.py`.

use super::rng::{seed_for, SplitMix64};

/// Static description of one dataset (mirrors python `GmmSpec`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GmmSpec {
    pub name: &'static str,
    pub dim: usize,
    pub n_components: usize,
    pub n_classes: usize,
    pub mean_scale: f32,
    pub sigma_lo: f32,
    pub sigma_hi: f32,
}

impl GmmSpec {
    const fn new(name: &'static str, dim: usize, k: usize) -> Self {
        GmmSpec { name, dim, n_components: k, n_classes: 1, mean_scale: 1.0, sigma_lo: 0.15, sigma_hi: 0.6 }
    }
}

/// The zoo — must match `datasets.SPECS` in python.
pub const SPECS: &[GmmSpec] = &[
    GmmSpec::new("church", 64, 8),
    GmmSpec::new("bedroom", 64, 8),
    GmmSpec::new("imagenet64", 64, 10),
    GmmSpec { mean_scale: 0.8, ..GmmSpec::new("cifar", 64, 8) },
    GmmSpec { n_classes: 4, ..GmmSpec::new("latent_cond", 256, 16) },
    GmmSpec { mean_scale: 1.5, ..GmmSpec::new("toy2d", 2, 6) },
];

/// Pixel datasets standing in for the paper's Table 1 image sets.
pub const PIXEL_DATASETS: [&str; 4] = ["church", "bedroom", "imagenet64", "cifar"];

/// Concrete mixture parameters (all f32, row-major `means[k*dim..]`).
#[derive(Debug, Clone)]
pub struct Gmm {
    pub spec: GmmSpec,
    pub means: Vec<f32>,
    pub sigmas: Vec<f32>,
    pub weights: Vec<f32>,
    pub comp_class: Vec<u32>,
}

impl Gmm {
    pub fn dim(&self) -> usize {
        self.spec.dim
    }

    pub fn k(&self) -> usize {
        self.spec.n_components
    }

    pub fn mean_of(&self, k: usize) -> &[f32] {
        &self.means[k * self.dim()..(k + 1) * self.dim()]
    }

    /// Component mask selecting one class (all-ones if unconditional).
    pub fn class_mask(&self, cls: u32) -> Vec<f32> {
        if self.spec.n_classes <= 1 {
            return vec![1.0; self.k()];
        }
        self.comp_class.iter().map(|&c| if c == cls { 1.0 } else { 0.0 }).collect()
    }

    /// Analytic mixture mean (FD reference).
    pub fn mean(&self) -> Vec<f32> {
        let d = self.dim();
        let mut mu = vec![0.0f32; d];
        for k in 0..self.k() {
            let m = self.mean_of(k);
            for j in 0..d {
                mu[j] += self.weights[k] * m[j];
            }
        }
        mu
    }

    /// Analytic mixture covariance, row-major `d × d` in f64 (FD reference).
    pub fn cov(&self) -> Vec<f64> {
        let d = self.dim();
        let mu = self.mean();
        let mut c = vec![0.0f64; d * d];
        for k in 0..self.k() {
            let w = self.weights[k] as f64;
            let m = self.mean_of(k);
            let s2 = (self.sigmas[k] as f64) * (self.sigmas[k] as f64);
            for i in 0..d {
                let di = (m[i] - mu[i]) as f64;
                for j in 0..d {
                    let dj = (m[j] - mu[j]) as f64;
                    c[i * d + j] += w * di * dj;
                }
                c[i * d + i] += w * s2;
            }
        }
        c
    }

    /// Draw exact reference samples (flat `n × dim`), optionally from one
    /// class. Same draw order as python `Gmm.sample`.
    pub fn sample(&self, n: usize, seed: u64, cls: Option<u32>) -> Vec<f32> {
        let d = self.dim();
        let k = self.k();
        let mut rng = SplitMix64::new(seed);
        let mask = match cls {
            Some(c) => self.class_mask(c),
            None => vec![1.0; k],
        };
        let mut w: Vec<f64> = (0..k).map(|i| (self.weights[i] * mask[i]) as f64).collect();
        let tot: f64 = w.iter().sum();
        for v in w.iter_mut() {
            *v /= tot;
        }
        let mut cdf = vec![0.0f64; k];
        let mut acc = 0.0;
        for i in 0..k {
            acc += w[i];
            cdf[i] = acc;
        }
        let mut out = vec![0.0f32; n * d];
        for i in 0..n {
            let u = rng.next_f64();
            let mut comp = k - 1;
            for (j, &c) in cdf.iter().enumerate() {
                if u < c {
                    comp = j;
                    break;
                }
            }
            let m = self.mean_of(comp);
            let s = self.sigmas[comp];
            for j in 0..d {
                out[i * d + j] = m[j] + s * rng.next_normal() as f32;
            }
        }
        out
    }
}

/// Deterministically generate the mixture for a dataset name.
///
/// Draw order matters and matches `datasets.make_gmm`: means (K·d
/// normals), sigmas (K uniforms), weights (K uniforms), one splitmix64
/// stream seeded by FNV-1a(name).
pub fn make_gmm(name: &str) -> Gmm {
    let spec = *SPECS
        .iter()
        .find(|s| s.name == name)
        .unwrap_or_else(|| panic!("unknown dataset {name:?}"));
    let mut rng = SplitMix64::new(seed_for(name));
    let (k, d) = (spec.n_components, spec.dim);
    let scale = spec.mean_scale / (d as f32).sqrt() * 4.0;
    // f64 intermediate like python: (normal * scale_f64) then cast f32.
    let mut means = vec![0.0f32; k * d];
    for m in means.iter_mut() {
        *m = (rng.next_normal() * scale as f64) as f32;
    }
    let sigmas: Vec<f32> = (0..k)
        .map(|_| (spec.sigma_lo as f64 + (spec.sigma_hi - spec.sigma_lo) as f64 * rng.next_f64()) as f32)
        .collect();
    let raw: Vec<f64> = (0..k).map(|_| 0.5 + rng.next_f64()).collect();
    let tot: f64 = raw.iter().sum();
    let weights: Vec<f32> = raw.iter().map(|&w| (w / tot) as f32).collect();
    let comp_class: Vec<u32> = (0..k as u32).map(|i| i % spec.n_classes.max(1) as u32).collect();
    Gmm { spec, means, sigmas, weights, comp_class }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_is_complete() {
        for name in PIXEL_DATASETS {
            let g = make_gmm(name);
            assert_eq!(g.dim(), 64);
        }
        assert_eq!(make_gmm("latent_cond").spec.n_classes, 4);
    }

    #[test]
    fn weights_normalized() {
        for spec in SPECS {
            let g = make_gmm(spec.name);
            let s: f32 = g.weights.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "{}: {s}", spec.name);
            assert!(g.weights.iter().all(|&w| w > 0.0));
        }
    }

    #[test]
    fn deterministic() {
        let a = make_gmm("church");
        let b = make_gmm("church");
        assert_eq!(a.means, b.means);
        assert_eq!(a.sigmas, b.sigmas);
    }

    #[test]
    fn datasets_differ() {
        assert_ne!(make_gmm("church").means, make_gmm("bedroom").means);
    }

    #[test]
    fn class_mask_partitions_components() {
        let g = make_gmm("latent_cond");
        let mut covered = vec![0u32; g.k()];
        for c in 0..4 {
            for (i, &m) in g.class_mask(c).iter().enumerate() {
                if m > 0.0 {
                    covered[i] += 1;
                }
            }
        }
        assert!(covered.iter().all(|&c| c == 1));
    }

    #[test]
    fn sample_moments_match_analytic() {
        let g = make_gmm("cifar");
        let n = 4000;
        let xs = g.sample(n, 123, None);
        let d = g.dim();
        let mu = g.mean();
        for j in 0..d {
            let m: f32 = (0..n).map(|i| xs[i * d + j]).sum::<f32>() / n as f32;
            assert!((m - mu[j]).abs() < 0.12, "dim {j}: {m} vs {}", mu[j]);
        }
    }

    #[test]
    fn conditional_sampling_respects_class() {
        let g = make_gmm("latent_cond");
        let xs = g.sample(64, 5, Some(2));
        // Every sample should be closest (in z-score) to a class-2 component.
        let d = g.dim();
        for i in 0..64 {
            let x = &xs[i * d..(i + 1) * d];
            let mut best = (f32::MAX, 0usize);
            for k in 0..g.k() {
                let m = g.mean_of(k);
                let dist: f32 = x.iter().zip(m).map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < best.0 {
                    best = (dist, k);
                }
            }
            assert_eq!(g.comp_class[best.1], 2, "sample {i}");
        }
    }
}
