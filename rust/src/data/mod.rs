//! Synthetic Gaussian-mixture dataset zoo — the stand-in for the paper's
//! pretrained-checkpoint datasets (DESIGN.md §Substitutions).
//!
//! Parameters are generated from the shared splitmix64 stream ([`rng`])
//! so they agree bit-for-bit with `python/compile/datasets.py` without
//! shipping parameter files (the `datasets_golden.json` artifact
//! cross-checks this in `rust/tests/golden.rs`).

mod gmm;
pub mod rng;

pub use gmm::{make_gmm, Gmm, GmmSpec, PIXEL_DATASETS};
