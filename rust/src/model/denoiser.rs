//! Native `SmallDenoiser` — the seeded residual-MLP eps-net, mirroring
//! `python/compile/model.py` (weights regenerated from the shared
//! splitmix64 stream; forward pass matches the fused_mlp Pallas kernel).
//!
//! The forward pass runs on the blocked [`crate::kernels::matmul_acc`]
//! and keeps its activations in per-thread scratch, so steady-state
//! `eps` calls allocate nothing.

use super::EpsModel;
use crate::buf::sized;
use crate::data::rng::{seed_for, SplitMix64};
use crate::kernels;
use std::cell::RefCell;

pub const NFREQ: usize = 16;
pub const HIDDEN: usize = 256;
pub const FF: usize = 512;
pub const NBLOCK: usize = 2;

/// tanh-approximation GELU — matches `kernels/ref.py:gelu_ref` (f32).
#[inline]
pub fn gelu(x: f32) -> f32 {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

struct Block {
    w1: Vec<f32>, // (HIDDEN, FF) row-major
    b1: Vec<f32>,
    w2: Vec<f32>, // (FF, HIDDEN)
    b2: Vec<f32>,
}

thread_local! {
    /// Per-thread activation scratch `(inp, h, a)`: the model itself is
    /// shared across engine workers (`EpsModel: Sync`), so reusable
    /// activations can't live on `self`. Sized lazily to the largest
    /// batch each thread sees; every element is overwritten before use.
    static ACT: RefCell<(Vec<f32>, Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new(), Vec::new())) };
}

/// Residual-MLP eps-net (~0.5M params) with Fourier time features.
pub struct SmallDenoiser {
    dim: usize,
    w_in: Vec<f32>, // (dim + 2*NFREQ, HIDDEN)
    b_in: Vec<f32>,
    blocks: Vec<Block>,
    w_out: Vec<f32>, // (HIDDEN, dim)
    b_out: Vec<f32>,
}

impl SmallDenoiser {
    /// Weights from the shared stream; draw order matches python
    /// `make_denoiser_weights` (w_in row-major, b_in, per block w1 b1 w2
    /// b2, then w_out b_out; biases are zero but drawn as zeros there).
    pub fn new(dim: usize) -> Self {
        Self::named(dim, "small_denoiser")
    }

    pub fn named(dim: usize, name: &str) -> Self {
        let mut rng = SplitMix64::new(seed_for(&format!("{name}:{dim}")));
        let din = dim + 2 * NFREQ;
        let mat = |rng: &mut SplitMix64, r: usize, c: usize, scale: f64| -> Vec<f32> {
            (0..r * c).map(|_| (rng.next_normal() * scale) as f32).collect()
        };
        let w_in = mat(&mut rng, din, HIDDEN, 1.0 / (din as f64).sqrt());
        let b_in = vec![0.0; HIDDEN];
        let mut blocks = Vec::with_capacity(NBLOCK);
        for _ in 0..NBLOCK {
            let w1 = mat(&mut rng, HIDDEN, FF, 1.0 / (HIDDEN as f64).sqrt());
            let b1 = vec![0.0; FF];
            let w2 = mat(&mut rng, FF, HIDDEN, 0.5 / (FF as f64).sqrt());
            let b2 = vec![0.0; HIDDEN];
            blocks.push(Block { w1, b1, w2, b2 });
        }
        let w_out = mat(&mut rng, HIDDEN, dim, 1.0 / (HIDDEN as f64).sqrt());
        let b_out = vec![0.0; dim];
        SmallDenoiser { dim, w_in, b_in, blocks, w_out, b_out }
    }

    /// Approximate parameter count (for reporting).
    pub fn num_params(&self) -> usize {
        self.w_in.len() + self.b_in.len() + self.w_out.len() + self.b_out.len()
            + self.blocks.iter().map(|b| b.w1.len() + b.b1.len() + b.w2.len() + b.b2.len()).sum::<usize>()
    }
}

impl EpsModel for SmallDenoiser {
    fn dim(&self) -> usize {
        self.dim
    }

    // lint: hot-path
    fn eps(&self, x: &[f32], s: &[f32], _mask: Option<&[f32]>, out: &mut [f32]) {
        let b = s.len();
        let d = self.dim;
        let din = d + 2 * NFREQ;
        ACT.with(|act| {
            let (inp, h, a) = &mut *act.borrow_mut();
            sized(inp, b * din);
            sized(h, b * HIDDEN);
            sized(a, b * FF);
            // input = [x, sin(2^j pi s), cos(2^j pi s)]
            for r in 0..b {
                inp[r * din..r * din + d].copy_from_slice(&x[r * d..(r + 1) * d]);
                for j in 0..NFREQ {
                    let ang = s[r] * (2.0f32).powi(j as i32) * std::f32::consts::PI;
                    inp[r * din + d + j] = ang.sin();
                    inp[r * din + d + NFREQ + j] = ang.cos();
                }
            }
            // h = gelu(inp @ w_in + b_in)
            for r in 0..b {
                h[r * HIDDEN..(r + 1) * HIDDEN].copy_from_slice(&self.b_in);
            }
            kernels::matmul_acc(inp, b, din, &self.w_in, HIDDEN, h);
            h.iter_mut().for_each(|v| *v = gelu(*v));
            // residual blocks: h = h + gelu(h@w1+b1)@w2 + b2
            for blk in &self.blocks {
                for r in 0..b {
                    a[r * FF..(r + 1) * FF].copy_from_slice(&blk.b1);
                }
                kernels::matmul_acc(h, b, HIDDEN, &blk.w1, FF, a);
                a.iter_mut().for_each(|v| *v = gelu(*v));
                // h += a @ w2 + b2
                for hr in h.chunks_exact_mut(HIDDEN) {
                    kernels::axpby(1.0, &blk.b2, 1.0, hr);
                }
                kernels::matmul_acc(a, b, FF, &blk.w2, HIDDEN, h);
            }
            // out = h @ w_out + b_out
            for r in 0..b {
                out[r * d..(r + 1) * d].copy_from_slice(&self.b_out);
            }
            kernels::matmul_acc(h, b, HIDDEN, &self.w_out, d, out);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_sized() {
        let m = SmallDenoiser::new(256);
        assert!(m.num_params() > 400_000, "params = {}", m.num_params());
        let m2 = SmallDenoiser::new(256);
        assert_eq!(m.w_in, m2.w_in);
    }

    #[test]
    fn batched_equals_rowwise() {
        let m = SmallDenoiser::new(64);
        let d = 64;
        let b = 3;
        let mut rng = SplitMix64::new(11);
        let x = rng.normals_f32(b * d);
        let s = [0.2f32, 0.5, 0.9];
        let mut batched = vec![0.0; b * d];
        m.eps(&x, &s, None, &mut batched);
        for i in 0..b {
            let mut row = vec![0.0; d];
            m.eps(&x[i * d..(i + 1) * d], &s[i..=i], None, &mut row);
            for j in 0..d {
                assert!((batched[i * d + j] - row[j]).abs() < 1e-5, "row {i} dim {j}");
            }
        }
    }

    #[test]
    fn output_is_bounded() {
        // Variance-scaled weights keep the net ~1-Lipschitz; outputs on
        // unit-normal inputs should be O(1).
        let m = SmallDenoiser::new(64);
        let mut rng = SplitMix64::new(5);
        let x = rng.normals_f32(64);
        let mut out = vec![0.0; 64];
        m.eps(&x, &[0.5], None, &mut out);
        let norm: f32 = out.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(norm.is_finite() && norm < 50.0, "|eps| = {norm}");
    }

    #[test]
    fn time_conditioning_matters() {
        let m = SmallDenoiser::new(64);
        let x = vec![0.3f32; 64];
        let (mut a, mut b) = (vec![0.0; 64], vec![0.0; 64]);
        m.eps(&x, &[0.1], None, &mut a);
        m.eps(&x, &[0.9], None, &mut b);
        let diff: f32 = a.iter().zip(&b).map(|(p, q)| (p - q).abs()).sum();
        assert!(diff > 1e-3, "time embedding should change the output");
    }

    #[test]
    fn gelu_matches_known_values() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
    }
}
