//! Native eps-models (`ε_θ`).
//!
//! The coordinator is generic over where a solver step executes (see
//! [`crate::solvers::StepBackend`]); these are the pure-rust model
//! implementations used by tests, the simulated executor, and the
//! native fallback path. They match the JAX models in
//! `python/compile/model.py` to f32 tolerance (golden-tested against the
//! AOT artifacts).

mod denoiser;
mod gmm_eps;
mod mock;

pub use denoiser::SmallDenoiser;
pub use gmm_eps::{CondGmmEps, GmmEps};
pub use mock::{AffineModel, ZeroModel};

/// A batched eps-prediction model: `eps(x, s) → ε̂` with optional
/// class-conditioning (component `mask` + guidance weight `w`).
///
/// `x` is flat row-major `(b, dim)`; `s` has length `b`; the result is
/// flat `(b, dim)`.
pub trait EpsModel: Send + Sync {
    fn dim(&self) -> usize;

    /// Unconditional (or mask-conditioned) eps prediction.
    fn eps(&self, x: &[f32], s: &[f32], mask: Option<&[f32]>, out: &mut [f32]);

    /// Classifier-free-guided prediction:
    /// `eps_u + w (eps_c − eps_u)` (diffusers convention, paper Table 2
    /// uses w = 7.5). Default composes two [`EpsModel::eps`] calls.
    fn eps_guided(&self, x: &[f32], s: &[f32], mask: &[f32], w: f32, out: &mut [f32]) {
        let b = s.len();
        let d = self.dim();
        let mut e_c = vec![0.0f32; b * d];
        self.eps(x, s, None, out); // unconditional branch
        self.eps(x, s, Some(mask), &mut e_c);
        for i in 0..b * d {
            out[i] += w * (e_c[i] - out[i]);
        }
    }

    /// Number of mixture components / mask width (0 if unconditional).
    fn k(&self) -> usize {
        0
    }
}
