//! Analytic Gaussian-mixture eps-model — mirrors the `gmm_score` Pallas
//! kernel (`python/compile/kernels/gmm_score.py`) and its jnp oracle.
//!
//! The per-row math runs on [`crate::kernels`]: lane-tiled scaled
//! distances for the logits, the shared softmax, and fused
//! accumulate-scaled-diff passes for the score. The guided entry point
//! overrides the two-pass trait default with a single fused pass (see
//! `eps_guided_row` below).

use super::EpsModel;
use crate::data::Gmm;
use crate::kernels;
use crate::schedule;

/// Largest supported mixture size (stack-allocated logit lanes).
const MAX_K: usize = 64;

/// Per-row schedule constants `(ᾱ, √ᾱ, σ)` at progress `s`.
// lint: hot-path
fn row_schedule(s: f32) -> (f32, f32, f32) {
    let tau = 1.0 - s;
    let ab = schedule::log_alpha_bar(tau).exp();
    let sab = ab.sqrt();
    let sig = (1.0 - ab).max(0.0).sqrt().max(schedule::SIGMA_FLOOR);
    (ab, sab, sig)
}

/// Exact eps-prediction of a diffused GMM (the "pretrained model"
/// substitute, DESIGN.md §Substitutions).
///
/// Diffused marginal at progress `s`:
/// `p_s = Σ_k w_k N(√ᾱ μ_k, v_k I)`, `v_k = ᾱ σ_k² + (1-ᾱ)`; then
/// `ε̂ = σ(s) Σ_k r_k (x − √ᾱ μ_k) / v_k` with softmaxed
/// responsibilities `r_k`.
#[derive(Debug, Clone)]
pub struct GmmEps {
    gmm: Gmm,
    log_w: Vec<f32>,
    sig2: Vec<f32>,
}

impl GmmEps {
    pub fn new(gmm: Gmm) -> Self {
        let log_w = gmm.weights.iter().map(|w| w.ln()).collect();
        let sig2 = gmm.sigmas.iter().map(|s| s * s).collect();
        GmmEps { gmm, log_w, sig2 }
    }

    pub fn gmm(&self) -> &Gmm {
        &self.gmm
    }

    // lint: hot-path
    fn eps_row(&self, x: &[f32], s: f32, mask: Option<&[f32]>, out: &mut [f32]) {
        let d = self.gmm.dim();
        let k = self.gmm.k();
        let (ab, sab, sig) = row_schedule(s);

        // logits_k = log w_k + log(mask_k + 1e-30) − d/2 log v_k − ‖x−√ᾱμ‖²/(2v_k)
        debug_assert!(k <= MAX_K);
        let mut logits = [0.0f32; MAX_K];
        let mut vk = [0.0f32; MAX_K];
        for c in 0..k {
            let v = ab * self.sig2[c] + (1.0 - ab);
            vk[c] = v;
            let sq = kernels::sq_dist_scaled(x, sab, self.gmm.mean_of(c));
            let lm = match mask {
                Some(ms) => (ms[c] + 1e-30).ln(),
                None => 0.0,
            };
            logits[c] = self.log_w[c] + lm - 0.5 * d as f32 * v.ln() - 0.5 * sq / v;
        }
        let rsum = kernels::softmax(&mut logits[..k]);
        // out = sig * Σ_k (r_k / v_k) (x − √ᾱ μ_k)
        out.fill(0.0);
        for c in 0..k {
            let coeff = logits[c] / rsum / vk[c];
            if coeff == 0.0 {
                continue;
            }
            kernels::acc_scaled_diff(coeff, sab, x, self.gmm.mean_of(c), out);
        }
        kernels::scale(sig, out);
    }

    /// Fused classifier-free-guidance row. The unconditional and
    /// conditional scores share every distance `‖x−√ᾱμ_k‖²` and
    /// variance `v_k`, and both have the form `Σ_k c_k (x−√ᾱμ_k)` — so
    /// instead of two full score passes plus a blend buffer (the trait
    /// default), compute both responsibility sets from one distance pass
    /// and accumulate once with the blended coefficient
    /// `((1−w)·r^u_k + w·r^c_k) / v_k`. Bit-exact vs the plain `eps`
    /// paths at `w ∈ {0, 1}` (`guided_interpolates` pins this).
    // lint: hot-path
    fn eps_guided_row(&self, x: &[f32], s: f32, mask: &[f32], w: f32, out: &mut [f32]) {
        let d = self.gmm.dim();
        let k = self.gmm.k();
        let (ab, sab, sig) = row_schedule(s);
        debug_assert!(k <= MAX_K);
        let mut lu = [0.0f32; MAX_K];
        let mut lc = [0.0f32; MAX_K];
        let mut vk = [0.0f32; MAX_K];
        for c in 0..k {
            let v = ab * self.sig2[c] + (1.0 - ab);
            vk[c] = v;
            let sq = kernels::sq_dist_scaled(x, sab, self.gmm.mean_of(c));
            let lw = self.log_w[c];
            let lm = (mask[c] + 1e-30).ln();
            // Same op order as eps_row so w ∈ {0, 1} reproduces its bits.
            lu[c] = lw - 0.5 * d as f32 * v.ln() - 0.5 * sq / v;
            lc[c] = lw + lm - 0.5 * d as f32 * v.ln() - 0.5 * sq / v;
        }
        let usum = kernels::softmax(&mut lu[..k]);
        let csum = kernels::softmax(&mut lc[..k]);
        out.fill(0.0);
        for c in 0..k {
            let coeff = ((1.0 - w) * (lu[c] / usum) + w * (lc[c] / csum)) / vk[c];
            if coeff == 0.0 {
                continue;
            }
            kernels::acc_scaled_diff(coeff, sab, x, self.gmm.mean_of(c), out);
        }
        kernels::scale(sig, out);
    }
}

impl EpsModel for GmmEps {
    fn dim(&self) -> usize {
        self.gmm.dim()
    }

    fn k(&self) -> usize {
        self.gmm.k()
    }

    fn eps(&self, x: &[f32], s: &[f32], mask: Option<&[f32]>, out: &mut [f32]) {
        let d = self.dim();
        let k = self.k();
        for (i, &si) in s.iter().enumerate() {
            let m = mask.map(|ms| &ms[i * k..(i + 1) * k]);
            self.eps_row(&x[i * d..(i + 1) * d], si, m, &mut out[i * d..(i + 1) * d]);
        }
    }

    fn eps_guided(&self, x: &[f32], s: &[f32], mask: &[f32], w: f32, out: &mut [f32]) {
        let d = self.dim();
        let k = self.k();
        for (i, &si) in s.iter().enumerate() {
            let m = &mask[i * k..(i + 1) * k];
            self.eps_guided_row(&x[i * d..(i + 1) * d], si, m, w, &mut out[i * d..(i + 1) * d]);
        }
    }
}

/// Guided conditional wrapper (same struct, guided entry point is on the
/// trait). Exists so call sites can name the conditional model.
pub type CondGmmEps = GmmEps;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::make_gmm;

    fn model(name: &str) -> GmmEps {
        GmmEps::new(make_gmm(name))
    }

    #[test]
    fn single_gaussian_matches_closed_form() {
        // For a 1-component mixture with mean mu, sigma: eps has closed form
        // sig * (x - sab*mu) / v.
        let mut g = make_gmm("church");
        g.spec.n_components = 1;
        g.means.truncate(g.dim());
        g.sigmas.truncate(1);
        g.weights = vec![1.0];
        g.comp_class.truncate(1);
        let m = GmmEps::new(g.clone());
        let d = g.dim();
        let x: Vec<f32> = (0..d).map(|i| (i as f32 * 0.1).sin()).collect();
        let s = 0.35f32;
        let mut out = vec![0.0; d];
        m.eps(&x, &[s], None, &mut out);
        let ab = crate::schedule::alpha_bar(s);
        let sab = ab.sqrt();
        let sig = (1.0 - ab).sqrt();
        let v = ab * g.sigmas[0] * g.sigmas[0] + (1.0 - ab);
        for j in 0..d {
            let expect = sig * (x[j] - sab * g.means[j]) / v;
            assert!((out[j] - expect).abs() < 1e-5, "{j}: {} vs {expect}", out[j]);
        }
    }

    #[test]
    fn eps_magnitude_near_noise_end_is_xlike() {
        // At s→0, ab→0, v→1, sig→1: eps ≈ x (softmax over similar logits).
        let m = model("church");
        let d = m.dim();
        let x = vec![0.5f32; d];
        let mut out = vec![0.0; d];
        m.eps(&x, &[0.0], None, &mut out);
        for j in 0..d {
            assert!((out[j] - x[j]).abs() < 0.2, "{}: {} vs {}", j, out[j], x[j]);
        }
    }

    #[test]
    fn batched_equals_rowwise() {
        let m = model("imagenet64");
        let d = m.dim();
        let b = 5;
        let mut rng = crate::data::rng::SplitMix64::new(9);
        let x = rng.normals_f32(b * d);
        let s: Vec<f32> = (0..b).map(|i| 0.1 + 0.15 * i as f32).collect();
        let mut batched = vec![0.0; b * d];
        m.eps(&x, &s, None, &mut batched);
        for i in 0..b {
            let mut row = vec![0.0; d];
            m.eps(&x[i * d..(i + 1) * d], &s[i..=i], None, &mut row);
            assert_eq!(&batched[i * d..(i + 1) * d], &row[..], "row {i}");
        }
    }

    #[test]
    fn guided_interpolates() {
        let m = model("latent_cond");
        let d = m.dim();
        let k = m.k();
        let mut rng = crate::data::rng::SplitMix64::new(3);
        let x = rng.normals_f32(d);
        let s = [0.4f32];
        let mask = m.gmm().class_mask(1);
        let (mut e_u, mut e_c, mut e_g) = (vec![0.0; d], vec![0.0; d], vec![0.0; d]);
        m.eps(&x, &s, None, &mut e_u);
        m.eps(&x, &s, Some(&mask), &mut e_c);
        m.eps_guided(&x, &s, &mask, 1.0, &mut e_g);
        for j in 0..d {
            assert!((e_g[j] - e_c[j]).abs() < 1e-5, "w=1 reduces to conditional");
        }
        m.eps_guided(&x, &s, &mask, 0.0, &mut e_g);
        for j in 0..d {
            assert!((e_g[j] - e_u[j]).abs() < 1e-5, "w=0 reduces to unconditional");
        }
        let _ = k;
    }

    #[test]
    fn fused_guidance_matches_two_pass_blend() {
        // The fused single-pass override must agree with the trait
        // default (two eps calls + blend) to fp tolerance at an
        // extrapolating guidance weight.
        let m = model("latent_cond");
        let d = m.dim();
        let mut rng = crate::data::rng::SplitMix64::new(13);
        let b = 3;
        let x = rng.normals_f32(b * d);
        let s = [0.15f32, 0.5, 0.85];
        let mask: Vec<f32> = (0..b as u32).flat_map(|i| m.gmm().class_mask(i % 2)).collect();
        let w = 7.5;
        let mut fused = vec![0.0; b * d];
        m.eps_guided(&x, &s, &mask, w, &mut fused);
        // Trait-default blend, inlined.
        let (mut e_u, mut e_c) = (vec![0.0; b * d], vec![0.0; b * d]);
        m.eps(&x, &s, None, &mut e_u);
        m.eps(&x, &s, Some(&mask), &mut e_c);
        for i in 0..b * d {
            let want = e_u[i] + w * (e_c[i] - e_u[i]);
            assert!(
                (fused[i] - want).abs() < 1e-4 * want.abs().max(1.0),
                "[{i}]: {} vs {want}",
                fused[i]
            );
        }
    }
}
