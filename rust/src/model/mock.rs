//! Mock eps-models for unit and property tests of the coordinator: cheap,
//! smooth, deterministic maps with known structure.

use super::EpsModel;
use crate::kernels;

/// `ε̂ = a·x + c·s` — an affine model giving a linear ODE whose flows are
/// contractive/expansive in a controlled way. Proptests on the Parareal
/// invariants (Props 1–3) use this.
#[derive(Debug, Clone)]
pub struct AffineModel {
    pub dim: usize,
    pub a: f32,
    pub c: f32,
}

impl AffineModel {
    pub fn new(dim: usize, a: f32, c: f32) -> Self {
        AffineModel { dim, a, c }
    }
}

impl EpsModel for AffineModel {
    fn dim(&self) -> usize {
        self.dim
    }

    // The hot-path benches drive this model, so it runs on the same
    // lane-tiled kernels as the real ones (bitwise-equal to the scalar
    // loop: `a*x[j] + c*s` element for element).
    // lint: hot-path
    fn eps(&self, x: &[f32], s: &[f32], _mask: Option<&[f32]>, out: &mut [f32]) {
        let d = self.dim;
        let rows = x.chunks_exact(d).zip(out.chunks_exact_mut(d));
        for ((xr, o), &si) in rows.zip(s) {
            kernels::axpc(self.a, xr, self.c * si, o);
        }
    }
}

/// `ε̂ = 0` — under DDIM this gives the exactly-solvable flow
/// `x' = √(ᾱ_to/ᾱ_from) · x`, used to pin solver coefficients in tests.
#[derive(Debug, Clone)]
pub struct ZeroModel {
    pub dim: usize,
}

impl EpsModel for ZeroModel {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eps(&self, _x: &[f32], s: &[f32], _mask: Option<&[f32]>, out: &mut [f32]) {
        out[..s.len() * self.dim].fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn affine_is_affine() {
        let m = AffineModel::new(3, 2.0, 0.5);
        let x = [1.0f32, 2.0, 3.0];
        let mut out = [0.0f32; 3];
        m.eps(&x, &[0.4], None, &mut out);
        assert_eq!(out, [2.2, 4.2, 6.2]);
    }

    #[test]
    fn zero_is_zero() {
        let m = ZeroModel { dim: 2 };
        let mut out = [1.0f32; 4];
        m.eps(&[9.0; 4], &[0.1, 0.2], None, &mut out);
        assert_eq!(out, [0.0; 4]);
    }
}
