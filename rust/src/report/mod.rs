//! Table rendering used by every bench so outputs mirror the paper's
//! rows, plus a tiny timing harness (criterion is unavailable offline).

use std::time::Instant;

/// A fixed-column text table.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<width$} ", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helpers shared by the benches.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn f4(x: f64) -> String {
    format!("{x:.4}")
}

pub fn speedup(base: f64, ours: f64) -> String {
    if ours <= 0.0 {
        return "-".into();
    }
    format!("{:.1}x", base / ours)
}

/// Median-of-runs micro timing (the in-tree stand-in for criterion).
pub fn time_median<F: FnMut()>(mut f: F, warmup: usize, runs: usize) -> std::time::Duration {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<std::time::Duration> = (0..runs.max(1))
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed()
        })
        .collect();
    samples.sort();
    samples[samples.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "blong"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("333"));
        let lines: Vec<&str> = s.lines().filter(|l| l.contains('|')).collect();
        assert_eq!(lines.len(), 3);
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "aligned columns");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn timing_returns_positive() {
        let d = time_median(
            || {
                std::hint::black_box((0..100).sum::<u64>());
            },
            1,
            5,
        );
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn speedup_format() {
        assert_eq!(speedup(10.0, 5.0), "2.0x");
        assert_eq!(speedup(1.0, 0.0), "-");
    }
}
