//! Output rendering: PGM image dumps (sample figures), ASCII line plots
//! (convergence figures) and gantt charts (the Fig. 4 pipeline trace).

use std::io::Write;
use std::path::Path;

/// Write a flat `(h, w)` f32 buffer as a binary PGM (P5), min-max
/// normalized to 0..255. The 8×8 / 16×16 "images" of the GMM zoo render
/// through this for Figs. 1/6/8.
pub fn write_pgm(path: &Path, data: &[f32], w: usize, h: usize) -> crate::Result<()> {
    assert_eq!(data.len(), w * h);
    let lo = data.iter().cloned().fold(f32::MAX, f32::min);
    let hi = data.iter().cloned().fold(f32::MIN, f32::max);
    let scale = if hi > lo { 255.0 / (hi - lo) } else { 0.0 };
    let mut f = std::fs::File::create(path)?;
    write!(f, "P5\n{w} {h}\n255\n")?;
    let bytes: Vec<u8> = data.iter().map(|&v| ((v - lo) * scale) as u8).collect();
    f.write_all(&bytes)?;
    Ok(())
}

/// Render a sample vector as an ASCII intensity grid (for terminal
/// figure output), using a 10-level ramp.
pub fn ascii_image(data: &[f32], w: usize, h: usize) -> String {
    const RAMP: &[u8] = b" .:-=+*#%@";
    let lo = data.iter().cloned().fold(f32::MAX, f32::min);
    let hi = data.iter().cloned().fold(f32::MIN, f32::max);
    let scale = if hi > lo { (RAMP.len() - 1) as f32 / (hi - lo) } else { 0.0 };
    let mut out = String::new();
    for r in 0..h {
        for c in 0..w {
            let v = ((data[r * w + c] - lo) * scale) as usize;
            let ch = RAMP[v.min(RAMP.len() - 1)] as char;
            out.push(ch);
            out.push(ch); // double width for aspect ratio
        }
        out.push('\n');
    }
    out
}

/// ASCII line plot of one or more series on a shared x-axis.
pub fn ascii_plot(series: &[(&str, &[f64])], width: usize, height: usize) -> String {
    let mut lo = f64::MAX;
    let mut hi = f64::MIN;
    let mut maxlen = 0usize;
    for (_, ys) in series {
        maxlen = maxlen.max(ys.len());
        for &y in *ys {
            lo = lo.min(y);
            hi = hi.max(y);
        }
    }
    if maxlen == 0 || !lo.is_finite() {
        return String::from("(no data)\n");
    }
    if hi <= lo {
        hi = lo + 1.0;
    }
    let marks = [b'*', b'o', b'+', b'x', b'#'];
    let mut grid = vec![vec![b' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (i, &y) in ys.iter().enumerate() {
            let cx = if maxlen == 1 { 0 } else { i * (width - 1) / (maxlen - 1) };
            let fy = (y - lo) / (hi - lo);
            let cy = height - 1 - ((fy * (height - 1) as f64).round() as usize).min(height - 1);
            grid[cy][cx] = marks[si % marks.len()];
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{hi:>12.4} ┐\n"));
    for row in &grid {
        out.push_str("             │");
        out.push_str(std::str::from_utf8(row).unwrap());
        out.push('\n');
    }
    out.push_str(&format!("{lo:>12.4} ┴{}\n", "─".repeat(width)));
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (n, _))| format!("{} {}", marks[i % marks.len()] as char, n))
        .collect();
    out.push_str(&format!("              {}\n", legend.join("   ")));
    out
}

/// ASCII gantt chart of scheduled task spans `(label, lane, start, end)`.
pub fn ascii_gantt(spans: &[(String, usize, u64, u64)], width: usize) -> String {
    let lanes = spans.iter().map(|s| s.1).max().map(|m| m + 1).unwrap_or(0);
    let t_max = spans.iter().map(|s| s.3).max().unwrap_or(1).max(1);
    let mut out = String::new();
    for lane in 0..lanes {
        let mut row = vec![b'.'; width];
        for (label, l, s, e) in spans {
            if *l != lane {
                continue;
            }
            let cs = (*s as usize * (width - 1) / t_max as usize).min(width - 1);
            let ce = (*e as usize * (width - 1) / t_max as usize).min(width - 1);
            let ch = label.bytes().next().unwrap_or(b'#');
            for c in cs..=ce {
                row[c] = ch;
            }
        }
        out.push_str(&format!("dev{lane:<3}│"));
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out.push_str(&format!("      0{}t={}\n", " ".repeat(width.saturating_sub(8)), t_max));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pgm_roundtrip_header() {
        let dir = std::env::temp_dir().join("srds_viz_test.pgm");
        write_pgm(&dir, &[0.0, 0.5, 1.0, 0.25], 2, 2).unwrap();
        let bytes = std::fs::read(&dir).unwrap();
        assert!(bytes.starts_with(b"P5\n2 2\n255\n"));
        assert_eq!(bytes.len(), b"P5\n2 2\n255\n".len() + 4);
        assert_eq!(*bytes.last().unwrap(), 63); // 0.25 → 63/255
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn ascii_image_shape() {
        let img = ascii_image(&[0.0, 1.0, 0.5, 0.2], 2, 2);
        let lines: Vec<&str> = img.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].len(), 4);
        assert!(img.contains('@'));
    }

    #[test]
    fn ascii_plot_renders_series() {
        let ys = [1.0, 2.0, 3.0, 2.0];
        let s = ascii_plot(&[("err", &ys)], 20, 6);
        assert!(s.contains('*'));
        assert!(s.contains("err"));
    }

    #[test]
    fn gantt_renders_lanes() {
        let spans = vec![
            ("F".to_string(), 0usize, 0u64, 5u64),
            ("G".to_string(), 1, 2, 3),
        ];
        let g = ascii_gantt(&spans, 30);
        assert!(g.contains("dev0"));
        assert!(g.contains('F'));
        assert!(g.contains('G'));
    }
}
