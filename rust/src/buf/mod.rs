//! Zero-copy state buffers: the pooled [`StateBuf`] slab and the
//! reusable [`BatchStage`] staging area.
//!
//! The paper's speedup analysis assumes the per-step model evaluation is
//! the *only* cost on the trajectory (§3.4, §3.6); trajectory-parallel
//! sampling at useful batch sizes is memory-bandwidth bound (ParaDiGMS,
//! ParaTAA make the same observation), so the serving hot path cannot
//! afford a `Vec<f32>` allocation per solver step. This module is the
//! crate-wide answer:
//!
//! * [`BufPool`] — a thread-safe, dim-bucketed slab pool. `get(len)`
//!   pops a recycled buffer off the bucket's free list (a *hit*) or
//!   allocates fresh (a *miss*); dropping the last [`StateBuf`] handle
//!   returns the slab to the pool. Free lists are bounded
//!   (`max_free_per_bucket`, excess slabs are simply freed) and the pool
//!   is observable via [`BufPool::stats`] — `pool_hits` / `pool_misses`
//!   surface in [`crate::coordinator::RunStats`] and over the wire, so
//!   "steady-state steps allocate nothing" is a measurable claim, not a
//!   hope.
//! * [`StateBuf`] — a refcounted `dim`-sized state vector. `clone()` is
//!   a refcount bump (samplers and the engine share boundary states
//!   across iterations and across queued step rows without copying);
//!   mutation via [`StateBuf::as_mut_slice`] requires unique ownership —
//!   write first, share after.
//! * [`BatchStage`] — a reusable flat staging buffer for batched
//!   [`StepRequest`]s: callers push rows (`x`, `s_from`, `s_to`, `seed`,
//!   per-row mask) into persistent vectors and [`BatchStage::execute`]
//!   executes the whole batch via [`StepBackend::step_into`] into a
//!   persistent output buffer. After warm-up a stage never reallocates.
//!
//! Recycled buffer contents are *unspecified*: every consumer writes the
//! full buffer (solver steps write all `rows × dim` outputs) before
//! reading it.

use crate::solvers::{StepBackend, StepRequest};
use std::collections::HashMap;
use std::fmt;
use std::ops::Deref;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Weak};

/// Point-in-time pool counters (monotone except `live`/`free`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// `get()` calls served from a free list (no allocation).
    pub hits: u64,
    /// `get()` calls that had to allocate a fresh slab.
    pub misses: u64,
    /// Buffers currently checked out (live `StateBuf`s).
    pub live: usize,
    /// Maximum of `live` ever observed — the leak detector: bounded
    /// workloads must keep this bounded.
    pub high_water: usize,
    /// Buffers currently parked on the free lists.
    pub free: usize,
}

struct PoolShared {
    /// Free lists keyed by buffer length (the dim buckets).
    free: Mutex<HashMap<usize, Vec<Box<[f32]>>>>,
    /// Per-bucket free-list cap; returned slabs past it are freed.
    max_free_per_bucket: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    live: AtomicUsize,
    high_water: AtomicUsize,
}

impl PoolShared {
    /// Return a slab to its bucket (or free it past the cap).
    // lint: hot-path
    fn put(&self, data: Box<[f32]>) {
        self.live.fetch_sub(1, Ordering::Relaxed);
        let mut free = self.free.lock().unwrap();
        let bucket = free.entry(data.len()).or_default();
        if bucket.len() < self.max_free_per_bucket {
            bucket.push(data);
        }
    }
}

/// Thread-safe slab pool of `f32` state buffers, bucketed by length.
/// Cheap to clone (a handle); all clones share the same slabs and
/// counters.
#[derive(Clone)]
pub struct BufPool {
    shared: Arc<PoolShared>,
}

impl Default for BufPool {
    fn default() -> Self {
        Self::new()
    }
}

impl BufPool {
    /// Default per-bucket free-list bound. Generous: at dim 1024 this
    /// caps one bucket at 1 MiB of parked slabs.
    pub const DEFAULT_MAX_FREE: usize = 256;

    pub fn new() -> BufPool {
        Self::with_max_free(Self::DEFAULT_MAX_FREE)
    }

    /// A pool whose free lists hold at most `max_free_per_bucket` slabs
    /// per length bucket.
    pub fn with_max_free(max_free_per_bucket: usize) -> BufPool {
        BufPool {
            shared: Arc::new(PoolShared {
                free: Mutex::new(HashMap::new()),
                max_free_per_bucket,
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                live: AtomicUsize::new(0),
                high_water: AtomicUsize::new(0),
            }),
        }
    }

    /// Check out a buffer of exactly `len` floats. Contents are
    /// unspecified (recycled slabs keep their old values) — write before
    /// reading.
    // lint: hot-path
    pub fn get(&self, len: usize) -> StateBuf {
        let recycled = self.shared.free.lock().unwrap().get_mut(&len).and_then(Vec::pop);
        let data = match recycled {
            Some(d) => {
                self.shared.hits.fetch_add(1, Ordering::Relaxed);
                d
            }
            None => {
                self.shared.misses.fetch_add(1, Ordering::Relaxed);
                // lint-allow(hot-path-alloc): the pool miss path is the one sanctioned allocation site
                vec![0.0f32; len].into_boxed_slice()
            }
        };
        let live = self.shared.live.fetch_add(1, Ordering::Relaxed) + 1;
        self.shared.high_water.fetch_max(live, Ordering::Relaxed);
        StateBuf {
            inner: Arc::new(BufInner { data: Some(data), pool: Arc::downgrade(&self.shared) }),
        }
    }

    /// Check out a buffer initialized to a copy of `data`.
    pub fn take(&self, data: &[f32]) -> StateBuf {
        let mut buf = self.get(data.len());
        buf.as_mut_slice().copy_from_slice(data);
        buf
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.shared.hits.load(Ordering::Relaxed),
            misses: self.shared.misses.load(Ordering::Relaxed),
            live: self.shared.live.load(Ordering::Relaxed),
            high_water: self.shared.high_water.load(Ordering::Relaxed),
            free: self.shared.free.lock().unwrap().values().map(Vec::len).sum(),
        }
    }
}

struct BufInner {
    /// `Some` until drop; `Option` so `Drop` can move the slab back to
    /// the pool without unsafe code.
    data: Option<Box<[f32]>>,
    /// Weak: a buffer outliving its pool just frees normally.
    pool: Weak<PoolShared>,
}

impl Drop for BufInner {
    fn drop(&mut self) {
        // `data` is `None` when `into_vec` already stole the slab.
        if let Some(data) = self.data.take() {
            if let Some(pool) = self.pool.upgrade() {
                pool.put(data);
            }
        }
    }
}

/// A refcounted, pool-backed state vector. `clone()` bumps a refcount;
/// the slab returns to its pool when the last handle drops. Mutable
/// access requires unique ownership ([`StateBuf::as_mut_slice`]) —
/// the write-then-share discipline every sampler follows.
pub struct StateBuf {
    inner: Arc<BufInner>,
}

impl StateBuf {
    /// A pool-less buffer owning `data` directly (tests, one-off
    /// callers); dropping it frees rather than recycles.
    pub fn detached(data: Vec<f32>) -> StateBuf {
        StateBuf {
            inner: Arc::new(BufInner {
                data: Some(data.into_boxed_slice()),
                pool: Weak::new(),
            }),
        }
    }

    fn data(&self) -> &[f32] {
        self.inner.data.as_deref().expect("slab present until drop")
    }

    pub fn len(&self) -> usize {
        self.data().len()
    }

    pub fn is_empty(&self) -> bool {
        self.data().is_empty()
    }

    /// Whether this handle is the only owner (mutation is allowed).
    pub fn is_unique(&self) -> bool {
        Arc::strong_count(&self.inner) == 1
    }

    /// Mutable view. Panics when the buffer is shared: mutate before
    /// sharing (the zero-copy discipline — a shared state is immutable
    /// by construction, so readers never race writers).
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        Arc::get_mut(&mut self.inner)
            .expect("StateBuf mutated while shared; write before sharing")
            .data
            .as_deref_mut()
            .expect("slab present until drop")
    }

    pub fn to_vec(&self) -> Vec<f32> {
        self.data().to_vec()
    }

    /// Consume the handle into a plain `Vec<f32>`. Unique handles steal
    /// the slab (no copy, nothing returns to the pool); shared handles
    /// copy.
    pub fn into_vec(self) -> Vec<f32> {
        match Arc::try_unwrap(self.inner) {
            Ok(mut inner) => {
                let data = inner.data.take().expect("slab present until drop");
                if let Some(pool) = inner.pool.upgrade() {
                    // The slab leaves the pool's accounting for good.
                    pool.live.fetch_sub(1, Ordering::Relaxed);
                    inner.pool = Weak::new();
                }
                data.into_vec()
            }
            Err(inner) => inner.data.as_deref().expect("slab present until drop").to_vec(),
        }
    }
}

impl Clone for StateBuf {
    fn clone(&self) -> Self {
        StateBuf { inner: self.inner.clone() }
    }
}

impl Deref for StateBuf {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        self.data()
    }
}

impl fmt::Debug for StateBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "StateBuf(len={}, refs={})", self.len(), Arc::strong_count(&self.inner))
    }
}

/// Resize `v` to exactly `n` elements, skipping all work (including the
/// fill) when the length already matches — the common steady-state case.
/// On a size change the whole buffer is zero-filled once (`clear` first,
/// so old contents are never memcpy'd around by a realloc); callers
/// always overwrite before reading, so the zeros are never observed.
pub(crate) fn sized(v: &mut Vec<f32>, n: usize) {
    if v.len() != n {
        v.clear();
        v.resize(n, 0.0);
    }
}

/// Reusable structure-of-arrays staging buffer for one batched
/// [`StepRequest`]: the `(b, dim)` states, the per-row time / seed /
/// mask lanes, and the batch output each live in their own contiguous
/// persistent vector that survives `reset()`. The SoA split is what the
/// lane-tiled kernel layer ([`crate::kernels`]) wants — solvers sweep
/// `s_from`/`s_to` once to fill per-row coefficient lanes, then stream
/// `x` row-contiguously — and every lane is exposed read-only
/// ([`BatchStage::x`], [`BatchStage::s_from`], [`BatchStage::s_to`],
/// [`BatchStage::seeds`], [`BatchStage::mask`]) so de-batching callers
/// (the engine's workers, sampler drift rebuilds) index rows without
/// copies. One stage per call site (a worker thread, a sampler run)
/// makes the steady-state step loop allocation-free.
#[derive(Default)]
pub struct BatchStage {
    x: Vec<f32>,
    s_from: Vec<f32>,
    s_to: Vec<f32>,
    seeds: Vec<u64>,
    mask: Vec<f32>,
    has_mask: bool,
    guidance: f32,
    out: Vec<f32>,
}

impl BatchStage {
    pub fn new() -> BatchStage {
        BatchStage::default()
    }

    /// Clear the staged rows (keeping every allocation) and set the
    /// batch-wide guidance weight.
    pub fn reset(&mut self, guidance: f32) {
        self.x.clear();
        self.s_from.clear();
        self.s_to.clear();
        self.seeds.clear();
        self.mask.clear();
        self.has_mask = false;
        self.guidance = guidance;
    }

    /// Stage one row. Rows of one batch must agree on maskedness (the
    /// engine's batch key guarantees it; direct callers pass one
    /// conditioning per run).
    // lint: hot-path
    pub fn push_row(&mut self, x: &[f32], s_from: f32, s_to: f32, seed: u64, mask: Option<&[f32]>) {
        debug_assert!(
            self.s_from.is_empty() || self.has_mask == mask.is_some(),
            "rows of one batch must agree on maskedness"
        );
        self.x.extend_from_slice(x);
        self.s_from.push(s_from);
        self.s_to.push(s_to);
        self.seeds.push(seed);
        if let Some(m) = mask {
            self.has_mask = true;
            self.mask.extend_from_slice(m);
        }
    }

    pub fn rows(&self) -> usize {
        self.s_from.len()
    }

    pub fn is_empty(&self) -> bool {
        self.s_from.is_empty()
    }

    /// The staged flat `(rows, dim)` input states (pre-step values; they
    /// survive [`BatchStage::execute`], which ParaDiGMS's drift rebuild
    /// reads).
    pub fn x(&self) -> &[f32] {
        &self.x
    }

    /// The last batch's flat `(rows, dim)` output.
    pub fn out(&self) -> &[f32] {
        &self.out
    }

    /// Per-row start times (length [`BatchStage::rows`]).
    pub fn s_from(&self) -> &[f32] {
        &self.s_from
    }

    /// Per-row target times (length [`BatchStage::rows`]).
    pub fn s_to(&self) -> &[f32] {
        &self.s_to
    }

    /// Per-row noise seeds (length [`BatchStage::rows`]).
    pub fn seeds(&self) -> &[u64] {
        &self.seeds
    }

    /// The row-major `(rows, k)` conditioning mask, or `None` when the
    /// staged batch is unconditional.
    pub fn mask(&self) -> Option<&[f32]> {
        if self.has_mask {
            Some(&self.mask)
        } else {
            None
        }
    }

    /// The batch-wide guidance weight set by [`BatchStage::reset`].
    pub fn guidance(&self) -> f32 {
        self.guidance
    }

    /// Execute the staged batch via [`StepBackend::step_into`] into the
    /// persistent output buffer and return it. (Named `execute` rather
    /// than `step` so the srds-lint ban on the allocating
    /// `StepBackend::step` convenience stays a clean lexical check.)
    // lint: hot-path
    pub fn execute(&mut self, backend: &dyn StepBackend) -> &[f32] {
        let rows = self.s_from.len();
        let d = backend.dim();
        sized(&mut self.out, rows * d);
        let req = StepRequest {
            x: &self.x,
            s_from: &self.s_from,
            s_to: &self.s_to,
            mask: if self.has_mask { Some(self.mask.as_slice()) } else { None },
            guidance: self.guidance,
            seeds: &self.seeds,
        };
        backend.step_into(&req, &mut self.out);
        &self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ZeroModel;
    use crate::solvers::{NativeBackend, Solver};
    use std::sync::Arc as StdArc;

    #[test]
    fn pool_recycles_and_counts() {
        let pool = BufPool::new();
        let a = pool.get(8);
        assert_eq!(a.len(), 8);
        let st = pool.stats();
        assert_eq!((st.hits, st.misses, st.live, st.high_water), (0, 1, 1, 1));
        drop(a);
        assert_eq!(pool.stats().free, 1);
        let b = pool.get(8);
        let st = pool.stats();
        assert_eq!((st.hits, st.misses, st.live), (1, 1, 1));
        // A different length is a different bucket — a fresh miss.
        let c = pool.get(4);
        assert_eq!(pool.stats().misses, 2);
        assert_eq!(pool.stats().high_water, 2);
        drop((b, c));
        assert_eq!(pool.stats().live, 0);
        assert_eq!(pool.stats().free, 2);
    }

    #[test]
    fn take_copies_contents() {
        let pool = BufPool::new();
        let src = vec![1.0f32, -2.0, 3.5];
        let b = pool.take(&src);
        assert_eq!(&b[..], &src[..]);
    }

    #[test]
    fn recycled_slabs_are_reused_not_reallocated() {
        let pool = BufPool::new();
        for _ in 0..100 {
            let _b = pool.take(&[0.0; 16]);
        }
        let st = pool.stats();
        assert_eq!(st.misses, 1, "steady state allocates nothing");
        assert_eq!(st.hits, 99);
        assert_eq!(st.high_water, 1);
    }

    #[test]
    fn free_list_is_bounded() {
        let pool = BufPool::with_max_free(2);
        let bufs: Vec<StateBuf> = (0..5).map(|_| pool.get(8)).collect();
        assert_eq!(pool.stats().high_water, 5);
        drop(bufs);
        let st = pool.stats();
        assert_eq!(st.free, 2, "excess slabs are freed, not hoarded");
        assert_eq!(st.live, 0);
    }

    #[test]
    fn shared_bufs_are_immutable_until_unique() {
        let pool = BufPool::new();
        let mut a = pool.take(&[1.0, 2.0]);
        assert!(a.is_unique());
        a.as_mut_slice()[0] = 9.0;
        let b = a.clone();
        assert!(!a.is_unique());
        assert_eq!(&a[..], &b[..]);
        drop(b);
        assert!(a.is_unique());
        a.as_mut_slice()[1] = 7.0;
        assert_eq!(&a[..], &[9.0, 7.0]);
    }

    #[test]
    #[should_panic(expected = "write before sharing")]
    fn mutating_a_shared_buf_panics() {
        let pool = BufPool::new();
        let mut a = pool.get(2);
        let _b = a.clone();
        a.as_mut_slice()[0] = 1.0;
    }

    #[test]
    fn into_vec_steals_unique_slabs() {
        let pool = BufPool::new();
        let a = pool.take(&[1.0, 2.0]);
        let v = a.into_vec();
        assert_eq!(v, vec![1.0, 2.0]);
        let st = pool.stats();
        assert_eq!(st.live, 0, "stolen slab left the pool's accounting");
        assert_eq!(st.free, 0, "stolen slab did not return to the pool");
        // Shared handles copy instead.
        let a = pool.take(&[3.0]);
        let b = a.clone();
        assert_eq!(a.into_vec(), vec![3.0]);
        assert_eq!(&b[..], &[3.0]);
    }

    #[test]
    fn detached_buf_ignores_pools() {
        let b = StateBuf::detached(vec![4.0; 3]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.into_vec(), vec![4.0; 3]);
    }

    #[test]
    fn stage_roundtrips_rows_and_reuses_buffers() {
        let be = NativeBackend::new(StdArc::new(ZeroModel { dim: 2 }), Solver::Ddim);
        let mut stage = BatchStage::new();
        for trial in 0..3 {
            stage.reset(0.0);
            assert!(stage.is_empty());
            stage.push_row(&[1.0 + trial as f32, 2.0], 0.2, 0.3, 0, None);
            stage.push_row(&[3.0, 4.0], 0.4, 0.5, 1, None);
            assert_eq!(stage.rows(), 2);
            let out = stage.execute(&be);
            assert_eq!(out.len(), 4);
            // ZeroModel DDIM: x' = c1·x with c2·0 — rows keep their order.
            let c1 = crate::schedule::sqrt_ab(0.3) / crate::schedule::sqrt_ab(0.2);
            assert!((out[0] - c1 * (1.0 + trial as f32)).abs() < 1e-6);
            assert_eq!(stage.x()[2], 3.0, "staged inputs survive the step");
        }
    }

    #[test]
    fn stage_carries_per_row_masks() {
        let mut stage = BatchStage::new();
        stage.reset(7.5);
        stage.push_row(&[0.0], 0.1, 0.2, 0, Some(&[1.0, 0.0]));
        stage.push_row(&[0.0], 0.1, 0.2, 0, Some(&[0.0, 1.0]));
        assert_eq!(stage.rows(), 2);
        // The staged mask is the row-major concatenation.
        let be = NativeBackend::new(StdArc::new(ZeroModel { dim: 1 }), Solver::Ddim);
        stage.execute(&be);
        assert_eq!(stage.out().len(), 2);
    }

    #[test]
    fn stage_exposes_soa_lanes() {
        let mut stage = BatchStage::new();
        stage.reset(1.5);
        assert_eq!(stage.mask(), None);
        stage.push_row(&[1.0, 2.0], 0.1, 0.2, 7, Some(&[1.0, 0.0]));
        stage.push_row(&[3.0, 4.0], 0.3, 0.4, 8, Some(&[0.0, 1.0]));
        assert_eq!(stage.x(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(stage.s_from(), &[0.1, 0.3]);
        assert_eq!(stage.s_to(), &[0.2, 0.4]);
        assert_eq!(stage.seeds(), &[7, 8]);
        assert_eq!(stage.mask(), Some(&[1.0, 0.0, 0.0, 1.0][..]));
        assert_eq!(stage.guidance(), 1.5);
    }
}
