//! Lane-tiled, allocation-free math kernels for the batched hot path.
//!
//! Everything the solvers and native models do per step reduces to a
//! handful of fused row primitives: scale-adds (`axpby` and friends for
//! the DDIM/DDPM/Heun/DPM2 updates), a scaled squared distance and a
//! softmax for the GMM score, and a matmul for the small denoiser. This
//! module implements them once, in stable Rust, shaped so LLVM's
//! autovectorizer turns them into SIMD:
//!
//! * the body of every elementwise kernel walks paired
//!   [`LANE`]-wide `chunks_exact` windows — known-size slices, so the
//!   inner `for j in 0..LANE` loop has no bounds checks and vectorizes
//!   cleanly — followed by a scalar remainder loop for ragged tails;
//! * reductions ([`sq_dist_scaled`]) keep [`LANE`] partial accumulators
//!   and combine them in one fixed pairwise order, so the floating-point
//!   op sequence for a row never depends on anything but that row;
//! * the blocked [`matmul_acc`] tiles `MR = 4` rows × `NR = 16` output
//!   columns with per-row accumulators, and its per-row accumulation
//!   order is identical between the blocked body and the 1-row tail.
//!
//! **Bit-identity contract.** No kernel ever mixes data across rows, and
//! every per-row reduction order is fixed. Combined with the solver /
//! model layers calling these kernels one row-slice at a time, a row's
//! output is bit-identical regardless of batch composition, row order,
//! or how the engine chunk-splits a batch across workers
//! (`tests/batch_shape.rs` pins this for all five solvers on both
//! native models; the engine's fusion tests pin it end to end).
//!
//! All entry points are `// lint: hot-path`: `srds-lint` mechanically
//! enforces that they stay allocation-free. See the "kernel layer"
//! section of `DESIGN.md` for the staging (SoA) layout these kernels
//! expect and the engine's batch-splitting heuristic that feeds them.

/// Vector lane width the tiled loops are written for: 8 × f32 covers an
/// AVX2 register and two NEON registers; narrower targets just unroll.
pub const LANE: usize = 8;

/// Row-block height of the blocked [`matmul_acc`] (register tiling).
pub const MR: usize = 4;

/// Output-column tile width of the blocked [`matmul_acc`].
pub const NR: usize = 16;

/// `out[j] = a * x[j] + c` — affine map of one row (constant offset).
// lint: hot-path
pub fn axpc(a: f32, x: &[f32], c: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let mut xc = x.chunks_exact(LANE);
    let mut oc = out.chunks_exact_mut(LANE);
    for (xs, os) in (&mut xc).zip(&mut oc) {
        for j in 0..LANE {
            os[j] = a * xs[j] + c;
        }
    }
    for (xs, os) in xc.remainder().iter().zip(oc.into_remainder()) {
        *os = a * xs + c;
    }
}

/// `out[j] = a * x[j] + b * out[j]` — fused scale-add into the output
/// row (the DDIM / Euler / DPM2-full-step update shape).
// lint: hot-path
pub fn axpby(a: f32, x: &[f32], b: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let mut xc = x.chunks_exact(LANE);
    let mut oc = out.chunks_exact_mut(LANE);
    for (xs, os) in (&mut xc).zip(&mut oc) {
        for j in 0..LANE {
            os[j] = a * xs[j] + b * os[j];
        }
    }
    for (xs, os) in xc.remainder().iter().zip(oc.into_remainder()) {
        *os = a * xs + b * *os;
    }
}

/// `out[j] = a * x[j] + b * y[j]` — two-term linear combination written
/// to a third row (the DPM2 midpoint shape).
// lint: hot-path
pub fn lincomb(a: f32, x: &[f32], b: f32, y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert_eq!(y.len(), out.len());
    let mut xc = x.chunks_exact(LANE);
    let mut yc = y.chunks_exact(LANE);
    let mut oc = out.chunks_exact_mut(LANE);
    for ((xs, ys), os) in (&mut xc).zip(&mut yc).zip(&mut oc) {
        for j in 0..LANE {
            os[j] = a * xs[j] + b * ys[j];
        }
    }
    for ((xs, ys), os) in xc.remainder().iter().zip(yc.remainder()).zip(oc.into_remainder()) {
        *os = a * xs + b * ys;
    }
}

/// `out[j] = a * x[j] + b * out[j] + c * z[j]` — three-term fused update
/// (the DDPM posterior + noise shape). Evaluation order matches the
/// scalar expression `a*x + b*out + c*z` left to right.
// lint: hot-path
pub fn axpbypcz(a: f32, x: &[f32], b: f32, c: f32, z: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert_eq!(z.len(), out.len());
    let mut xc = x.chunks_exact(LANE);
    let mut zc = z.chunks_exact(LANE);
    let mut oc = out.chunks_exact_mut(LANE);
    for ((xs, zs), os) in (&mut xc).zip(&mut zc).zip(&mut oc) {
        for j in 0..LANE {
            os[j] = a * xs[j] + b * os[j] + c * zs[j];
        }
    }
    for ((xs, zs), os) in xc.remainder().iter().zip(zc.remainder()).zip(oc.into_remainder()) {
        *os = a * xs + b * *os + c * zs;
    }
}

/// `out[j] = x[j] + h * d[j]` — explicit-Euler predictor step.
// lint: hot-path
pub fn add_scaled(x: &[f32], h: f32, d: &[f32], out: &mut [f32]) {
    lincomb(1.0, x, h, d, out);
}

/// `out[j] = x[j] + c * (d1[j] + out[j])` — Heun trapezoidal corrector
/// (`out` holds the second slope on entry, the corrected state on exit).
// lint: hot-path
pub fn avg_step(x: &[f32], c: f32, d1: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert_eq!(d1.len(), out.len());
    let mut xc = x.chunks_exact(LANE);
    let mut dc = d1.chunks_exact(LANE);
    let mut oc = out.chunks_exact_mut(LANE);
    for ((xs, ds), os) in (&mut xc).zip(&mut dc).zip(&mut oc) {
        for j in 0..LANE {
            os[j] = xs[j] + c * (ds[j] + os[j]);
        }
    }
    for ((xs, ds), os) in xc.remainder().iter().zip(dc.remainder()).zip(oc.into_remainder()) {
        *os = xs + c * (ds + *os);
    }
}

/// `out[j] = c * (x[j] - out[j] / sig)` — probability-flow ODE slope
/// from an in-place eps prediction. The division is kept (rather than a
/// hoisted reciprocal) to preserve the historical rounding the golden
/// artifacts were recorded against.
// lint: hot-path
pub fn pf_transform(c: f32, sig: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let mut xc = x.chunks_exact(LANE);
    let mut oc = out.chunks_exact_mut(LANE);
    for (xs, os) in (&mut xc).zip(&mut oc) {
        for j in 0..LANE {
            os[j] = c * (xs[j] - os[j] / sig);
        }
    }
    for (xs, os) in xc.remainder().iter().zip(oc.into_remainder()) {
        *os = c * (xs - *os / sig);
    }
}

/// `out[j] *= c` — in-place row scale.
// lint: hot-path
pub fn scale(c: f32, out: &mut [f32]) {
    for v in out.iter_mut() {
        *v *= c;
    }
}

/// `out[j] += c * (x[j] - sab * m[j])` — accumulate one scaled
/// component-mean difference (the GMM score inner loop).
// lint: hot-path
pub fn acc_scaled_diff(c: f32, sab: f32, x: &[f32], m: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert_eq!(m.len(), out.len());
    let mut xc = x.chunks_exact(LANE);
    let mut mc = m.chunks_exact(LANE);
    let mut oc = out.chunks_exact_mut(LANE);
    for ((xs, ms), os) in (&mut xc).zip(&mut mc).zip(&mut oc) {
        for j in 0..LANE {
            os[j] += c * (xs[j] - sab * ms[j]);
        }
    }
    for ((xs, ms), os) in xc.remainder().iter().zip(mc.remainder()).zip(oc.into_remainder()) {
        *os += c * (xs - sab * ms);
    }
}

/// `sum_j (x[j] - sab * m[j])^2` with a **fixed, batch-independent
/// reduction order**: [`LANE`] partial accumulators over the chunked
/// body, a serial scalar tail, then one pairwise combine. The op
/// sequence for a row depends only on the row length, which is what
/// keeps per-row outputs bit-identical across batch shapes.
// lint: hot-path
pub fn sq_dist_scaled(x: &[f32], sab: f32, m: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), m.len());
    let mut acc = [0.0f32; LANE];
    let mut xc = x.chunks_exact(LANE);
    let mut mc = m.chunks_exact(LANE);
    for (xs, ms) in (&mut xc).zip(&mut mc) {
        for j in 0..LANE {
            let d = xs[j] - sab * ms[j];
            acc[j] += d * d;
        }
    }
    let mut tail = 0.0f32;
    for (xs, ms) in xc.remainder().iter().zip(mc.remainder()) {
        let d = xs - sab * ms;
        tail += d * d;
    }
    ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7])) + tail
}

/// In-place softmax numerator: `l[j] = exp(l[j] - max(l))`; returns the
/// sum of the exponentials (so `l[j] / sum` are the probabilities).
/// Max and sum are serial left-to-right — same fixed order for a given
/// length, and `exp` calls dominate anyway for the `k <= 64` mixture
/// sizes this serves.
// lint: hot-path
pub fn softmax(l: &mut [f32]) -> f32 {
    let mut m = f32::NEG_INFINITY;
    for &v in l.iter() {
        if v > m {
            m = v;
        }
    }
    let mut sum = 0.0f32;
    for v in l.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    sum
}

/// `log(sum_j exp(l[j]))`, max-shifted for stability. Destroys `l`
/// (leaves the softmax numerators behind, like [`softmax`]).
// lint: hot-path
pub fn log_sum_exp(l: &mut [f32]) -> f32 {
    if l.is_empty() {
        return f32::NEG_INFINITY;
    }
    let mut m = f32::NEG_INFINITY;
    for &v in l.iter() {
        if v > m {
            m = v;
        }
    }
    softmax(l).ln() + m
}

/// Blocked accumulating matmul: `out[r, j] += sum_i x[r, i] * w[i, j]`
/// for `x: rows × cin` (row-major), `w: cin × cout` (row-major),
/// `out: rows × cout`.
///
/// Register-tiled [`MR`] rows × [`NR`] output columns; `w` is streamed
/// row by row so the inner loop is a pure fused multiply-add over a
/// contiguous `w` window. The per-row accumulation order (ascending
/// `i`, tile-major `j`) is identical between the [`MR`]-row body and
/// the 1-row tail, so each output row is bit-identical no matter how
/// many rows are in the batch.
// lint: hot-path
pub fn matmul_acc(x: &[f32], rows: usize, cin: usize, w: &[f32], cout: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * cin);
    debug_assert_eq!(w.len(), cin * cout);
    debug_assert_eq!(out.len(), rows * cout);
    let mut r = 0;
    while r + MR <= rows {
        let (xs, os) = (&x[r * cin..(r + MR) * cin], &mut out[r * cout..(r + MR) * cout]);
        matmul_rows::<MR>(xs, cin, w, cout, os);
        r += MR;
    }
    while r < rows {
        let (xs, os) = (&x[r * cin..(r + 1) * cin], &mut out[r * cout..(r + 1) * cout]);
        matmul_rows::<1>(xs, cin, w, cout, os);
        r += 1;
    }
}

/// One `R`-row block of [`matmul_acc`]. The accumulator for row slot
/// `rr` sees exactly the ops `acc[j] += x[rr, i] * w[i, j]` for `i`
/// ascending within each `j`-tile — independent of `R`, which is the
/// bit-identity argument for the blocked/tail split above.
// lint: hot-path
fn matmul_rows<const R: usize>(x: &[f32], cin: usize, w: &[f32], cout: usize, out: &mut [f32]) {
    let mut jt = 0;
    while jt < cout {
        let tw = NR.min(cout - jt);
        let mut acc = [[0.0f32; NR]; R];
        for i in 0..cin {
            let wr = &w[i * cout + jt..i * cout + jt + tw];
            for (rr, accr) in acc.iter_mut().enumerate() {
                let xi = x[rr * cin + i];
                for j in 0..tw {
                    accr[j] += xi * wr[j];
                }
            }
        }
        for (rr, accr) in acc.iter().enumerate() {
            let or = &mut out[rr * cout + jt..rr * cout + jt + tw];
            for j in 0..tw {
                or[j] += accr[j];
            }
        }
        jt += tw;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::SplitMix64;

    /// Ragged lengths around the lane width: all-remainder, exact
    /// chunks, and chunk + tail shapes.
    const LENS: &[usize] = &[1, 5, 7, 8, 9, 16, 23, 64];

    fn fill(n: usize, seed: u64) -> Vec<f32> {
        SplitMix64::new(seed).normals_f32(n)
    }

    #[test]
    fn elementwise_kernels_match_scalar_reference() {
        for &n in LENS {
            let x = fill(n, 1);
            let y = fill(n, 2);
            let base = fill(n, 3);

            let mut out = base.clone();
            axpc(0.7, &x, -0.3, &mut out);
            for j in 0..n {
                assert_eq!(out[j], 0.7 * x[j] - 0.3);
            }

            let mut out = base.clone();
            axpby(0.7, &x, 1.3, &mut out);
            for j in 0..n {
                assert_eq!(out[j], 0.7 * x[j] + 1.3 * base[j]);
            }

            let mut out = base.clone();
            lincomb(0.7, &x, -0.2, &y, &mut out);
            for j in 0..n {
                assert_eq!(out[j], 0.7 * x[j] + -0.2 * y[j]);
            }

            let mut out = base.clone();
            axpbypcz(0.7, &x, 1.3, 0.11, &y, &mut out);
            for j in 0..n {
                assert_eq!(out[j], 0.7 * x[j] + 1.3 * base[j] + 0.11 * y[j]);
            }

            let mut out = base.clone();
            add_scaled(&x, 0.25, &y, &mut out);
            for j in 0..n {
                assert_eq!(out[j], x[j] + 0.25 * y[j]);
            }

            let mut out = base.clone();
            avg_step(&x, 0.5, &y, &mut out);
            for j in 0..n {
                assert_eq!(out[j], x[j] + 0.5 * (y[j] + base[j]));
            }

            let mut out = base.clone();
            pf_transform(0.4, 0.9, &x, &mut out);
            for j in 0..n {
                assert_eq!(out[j], 0.4 * (x[j] - base[j] / 0.9));
            }

            let mut out = base.clone();
            acc_scaled_diff(0.6, 0.8, &x, &y, &mut out);
            for j in 0..n {
                assert_eq!(out[j], base[j] + 0.6 * (x[j] - 0.8 * y[j]));
            }

            let mut out = base.clone();
            scale(1.7, &mut out);
            for j in 0..n {
                assert_eq!(out[j], base[j] * 1.7);
            }
        }
    }

    #[test]
    fn sq_dist_is_length_deterministic_and_close_to_reference() {
        for &n in LENS {
            let x = fill(n, 4);
            let m = fill(n, 5);
            let got = sq_dist_scaled(&x, 0.9, &m);
            // Same inputs, same length -> bitwise-identical result.
            assert_eq!(got, sq_dist_scaled(&x, 0.9, &m));
            // And numerically the serial sum, within f32 reassociation.
            let mut want = 0.0f32;
            for j in 0..n {
                let d = x[j] - 0.9 * m[j];
                want += d * d;
            }
            let tol = 1e-5 * want.abs().max(1.0);
            assert!((got - want).abs() < tol, "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn softmax_normalizes_and_is_shift_stable() {
        let mut l = [1.0f32, 2.0, 3.0, -1.0];
        let mut shifted = [1001.0f32, 1002.0, 1003.0, 999.0];
        let s = softmax(&mut l);
        let ss = softmax(&mut shifted);
        for j in 0..l.len() {
            assert!((l[j] / s - shifted[j] / ss).abs() < 1e-6);
        }
        let p: f32 = l.iter().map(|e| e / s).sum();
        assert!((p - 1.0).abs() < 1e-6);
    }

    #[test]
    fn log_sum_exp_matches_direct_sum() {
        let mut l = [0.3f32, -1.2, 2.5, 0.0, 0.9];
        let want = l.iter().map(|v| (*v as f64).exp()).sum::<f64>().ln() as f32;
        assert!((log_sum_exp(&mut l) - want).abs() < 1e-5);
        assert_eq!(log_sum_exp(&mut []), f32::NEG_INFINITY);
    }

    #[test]
    fn matmul_matches_naive_reference() {
        // Ragged in every dimension: rows over/under MR, cout over/under
        // NR, cin not a multiple of anything.
        for &(rows, cin, cout) in &[(1, 3, 2), (4, 7, 16), (5, 13, 17), (9, 24, 33), (2, 64, 15)] {
            let x = fill(rows * cin, 6);
            let w = fill(cin * cout, 7);
            let mut out = fill(rows * cout, 8);
            let mut want = out.clone();
            for r in 0..rows {
                for j in 0..cout {
                    let mut s = want[r * cout + j] as f64;
                    for i in 0..cin {
                        s += (x[r * cin + i] as f64) * (w[i * cout + j] as f64);
                    }
                    want[r * cout + j] = s as f32;
                }
            }
            matmul_acc(&x, rows, cin, &w, cout, &mut out);
            for idx in 0..rows * cout {
                let tol = 1e-4 * want[idx].abs().max(1.0);
                assert!(
                    (out[idx] - want[idx]).abs() < tol,
                    "({rows},{cin},{cout})[{idx}]: {} vs {}",
                    out[idx],
                    want[idx]
                );
            }
        }
    }

    #[test]
    fn matmul_rows_are_bit_identical_across_row_counts() {
        // Row r of an n-row product must equal the same row computed
        // solo — the MR-block/tail split may not change any row's bits.
        let cin = 13;
        let cout = 33;
        let rows = 9;
        let x = fill(rows * cin, 9);
        let w = fill(cin * cout, 10);
        let mut full = vec![0.0f32; rows * cout];
        matmul_acc(&x, rows, cin, &w, cout, &mut full);
        for r in 0..rows {
            let mut solo = vec![0.0f32; cout];
            matmul_acc(&x[r * cin..(r + 1) * cin], 1, cin, &w, cout, &mut solo);
            assert_eq!(&full[r * cout..(r + 1) * cout], &solo[..], "row {r}");
        }
    }
}
