//! Lazy field-scanning request reader.
//!
//! [`LazyObj::parse`] makes exactly one allocation-free structural pass
//! over a request line: it validates the whole line as strictly as
//! [`super::parse`] does (same whitespace set, same escape and surrogate
//! rules, numbers checked through `f64::from_str`), but builds no tree —
//! it only records the byte spans of the top-level fields. Field access
//! ([`LazyObj::get`] / [`LazyObj::num`]) then runs the full parser over
//! just the requested value span, so a request kind pays tree-building
//! cost only for the handful of fields it actually reads. On serving
//! request lines, where most fields of most requests are never touched
//! (`kind:"stats"` probes read one field of a line that may carry a
//! whole conditioning block), partial extraction is an order of
//! magnitude cheaper than the tree parse.
//!
//! Two invariants keep this honest, both pinned by `tests/wire_fuzz.rs`:
//!
//! * **Acceptance parity** — `LazyObj::parse(s)` succeeds if and only if
//!   `super::parse(s)` succeeds *and* yields a top-level object (the
//!   wire protocol requires object request lines). The skip-scanner
//!   mirrors every validation the tree parser performs, including
//!   `f64`-parsing each number span and checking `\u` escapes,
//!   surrogate pairing, and codepoint validity.
//! * **Extraction parity** — for every accepted line and every key,
//!   `lazy.get(key)` equals the tree parse's `obj[key]`; duplicate keys
//!   resolve to the last occurrence, matching `BTreeMap::insert`.

use super::Value;
use crate::Result;

/// One top-level field: byte spans of its key (including quotes) and
/// value (trimmed of surrounding whitespace) within the source line.
#[derive(Clone, Copy)]
struct Field {
    key_start: usize,
    key_end: usize,
    val_start: usize,
    val_end: usize,
}

/// A validated top-level JSON object over a borrowed request line.
pub struct LazyObj<'a> {
    src: &'a str,
    fields: Vec<Field>,
}

impl<'a> LazyObj<'a> {
    /// Validate `text` as a single top-level JSON object (with the exact
    /// strictness of [`super::parse`], including the trailing-garbage
    /// check) and index its top-level fields without building values.
    pub fn parse(text: &'a str) -> Result<LazyObj<'a>> {
        let mut s = Scan { b: text.as_bytes(), i: 0 };
        s.ws();
        anyhow::ensure!(s.peek() == Some(b'{'), "request must be a JSON object");
        s.i += 1;
        let mut fields = Vec::new();
        s.ws();
        if s.peek() == Some(b'}') {
            s.i += 1;
        } else {
            loop {
                s.ws();
                let key_start = s.i;
                s.skip_string()?;
                let key_end = s.i;
                s.ws();
                s.eat(b':')?;
                s.ws();
                let val_start = s.i;
                s.skip_value()?;
                fields.push(Field { key_start, key_end, val_start, val_end: s.i });
                s.ws();
                match s.peek() {
                    Some(b',') => s.i += 1,
                    Some(b'}') => {
                        s.i += 1;
                        break;
                    }
                    _ => anyhow::bail!("expected ',' or '}}' in object"),
                }
            }
        }
        s.ws();
        anyhow::ensure!(s.i == s.b.len(), "trailing garbage");
        Ok(LazyObj { src: text, fields })
    }

    /// The last field whose (unescaped) key equals `key` — last, because
    /// the tree parser's `BTreeMap::insert` makes later duplicates win.
    fn find(&self, key: &str) -> Option<Field> {
        self.fields.iter().rev().find(|f| self.key_matches(f, key)).copied()
    }

    fn key_matches(&self, f: &Field, key: &str) -> bool {
        let raw = &self.src[f.key_start + 1..f.key_end - 1];
        if !raw.contains('\\') {
            return raw == key;
        }
        // Escaped key: fall back to the tree parser on the key span.
        matches!(super::parse(&self.src[f.key_start..f.key_end]), Ok(Value::Str(s)) if s == key)
    }

    /// Whether a top-level field named `key` is present.
    pub fn has(&self, key: &str) -> bool {
        self.find(key).is_some()
    }

    /// Parse and return the value of `key`, if present. Only this span
    /// is tree-parsed; the rest of the line stays untouched.
    pub fn get(&self, key: &str) -> Option<Value> {
        let f = self.find(key)?;
        // The span was already validated by the structural scan, so this
        // cannot fail; going through the tree parser pins extraction
        // semantics to `super::parse` by construction.
        super::parse(&self.src[f.val_start..f.val_end]).ok()
    }

    /// Numeric field accessor (`None` if absent or not a number).
    pub fn num(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }

    /// The unescaped top-level key names, in source order (duplicates
    /// included). Used by the strict-mode unknown-key check.
    pub fn keys(&self) -> impl Iterator<Item = String> + '_ {
        self.fields.iter().map(|f| {
            let raw = &self.src[f.key_start + 1..f.key_end - 1];
            if !raw.contains('\\') {
                return raw.to_string();
            }
            match super::parse(&self.src[f.key_start..f.key_end]) {
                Ok(Value::Str(s)) => s,
                _ => raw.to_string(), // unreachable: span validated
            }
        })
    }
}

/// Structural skip-scanner. Each `skip_*` consumes exactly the bytes the
/// corresponding [`super::Parser`] method would, and fails on exactly
/// the inputs it would fail on.
struct Scan<'a> {
    b: &'a [u8],
    i: usize,
}

impl Scan<'_> {
    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        anyhow::ensure!(self.peek() == Some(c), "expected {:?}", c as char);
        self.i += 1;
        Ok(())
    }

    fn skip_value(&mut self) -> Result<()> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.skip_object(),
            Some(b'[') => self.skip_array(),
            Some(b'"') => self.skip_string(),
            Some(b't') => self.lit("true"),
            Some(b'f') => self.lit("false"),
            Some(b'n') => self.lit("null"),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.skip_number(),
            other => anyhow::bail!("unexpected token {:?}", other.map(|c| c as char)),
        }
    }

    fn lit(&mut self, s: &str) -> Result<()> {
        anyhow::ensure!(self.b[self.i..].starts_with(s.as_bytes()), "bad literal");
        self.i += s.len();
        Ok(())
    }

    fn skip_object(&mut self) -> Result<()> {
        self.eat(b'{')?;
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.skip_string()?;
            self.ws();
            self.eat(b':')?;
            self.skip_value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => anyhow::bail!("expected ',' or '}}' in object"),
            }
        }
    }

    fn skip_array(&mut self) -> Result<()> {
        self.eat(b'[')?;
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.skip_value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => anyhow::bail!("expected ',' or ']' in array"),
            }
        }
    }

    /// Mirror of `Parser::string` without building the `String`: same
    /// escape set, same `\u` handling (hex, surrogate pairing, codepoint
    /// validity), same tolerance for raw control bytes. Multi-byte UTF-8
    /// advances by the lead byte's length — the source is `&str`, so the
    /// tree parser's `from_utf8` re-check can never fail here.
    fn skip_string(&mut self) -> Result<()> {
        self.eat(b'"')?;
        loop {
            let c = self.peek().ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(()),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| anyhow::anyhow!("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't' => {}
                        b'u' => {
                            let code = self.hex4()?;
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                anyhow::ensure!(
                                    self.b.get(self.i) == Some(&b'\\')
                                        && self.b.get(self.i + 1) == Some(&b'u'),
                                    "lone high surrogate"
                                );
                                self.i += 2;
                                let low = self.hex4()?;
                                anyhow::ensure!((0xDC00..0xE000).contains(&low), "bad low surrogate");
                                char::from_u32(0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00))
                            } else {
                                char::from_u32(code)
                            };
                            anyhow::ensure!(ch.is_some(), "bad codepoint");
                        }
                        _ => anyhow::bail!("bad escape"),
                    }
                }
                _ => self.i = self.i - 1 + super::utf8_len(c),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        anyhow::ensure!(self.i + 4 <= self.b.len(), "bad \\u escape");
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
        let code = u32::from_str_radix(hex, 16)?;
        self.i += 4;
        Ok(code)
    }

    /// Mirror of `Parser::number`: greedy consume over the number
    /// alphabet, then validate the whole span through `f64::from_str`.
    fn skip_number(&mut self) -> Result<()> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        s.parse::<f64>()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::parse;
    use super::*;

    #[test]
    fn extracts_only_requested_fields() {
        let line = r#"{"id": 7, "sampler": "srds", "n": 25, "tol": 2.5e-3, "stream": true}"#;
        let o = LazyObj::parse(line).unwrap();
        assert_eq!(o.num("id"), Some(7.0));
        assert_eq!(o.num("tol"), Some(2.5e-3));
        assert_eq!(o.get("sampler").unwrap().as_str().unwrap(), "srds");
        assert_eq!(o.get("stream").unwrap().as_bool(), Some(true));
        assert!(o.get("missing").is_none());
        assert!(o.has("n") && !o.has("kind"));
    }

    #[test]
    fn nested_values_are_single_spans() {
        let line = r#"{"cond": {"class": 3, "w": [1, 2.5]}, "id": 1}"#;
        let o = LazyObj::parse(line).unwrap();
        let cond = o.get("cond").unwrap();
        assert_eq!(cond.get("class").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(cond.get("w").unwrap().as_f32_vec().unwrap(), vec![1.0, 2.5]);
        assert_eq!(o.num("id"), Some(1.0));
    }

    #[test]
    fn duplicate_keys_resolve_to_the_last_occurrence() {
        let line = r#"{"n": 1, "n": 2}"#;
        let o = LazyObj::parse(line).unwrap();
        assert_eq!(o.num("n"), Some(2.0));
        // Same answer as the tree parser.
        assert_eq!(parse(line).unwrap().get("n").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn escaped_keys_unescape_before_matching() {
        let line = "{\"a\\u0062c\": 5}";
        let o = LazyObj::parse(line).unwrap();
        assert_eq!(o.num("abc"), Some(5.0));
        assert_eq!(o.keys().collect::<Vec<_>>(), vec!["abc".to_string()]);
    }

    #[test]
    fn keys_come_back_in_source_order() {
        let o = LazyObj::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        assert_eq!(o.keys().collect::<Vec<_>>(), vec!["z", "a", "m"]);
    }

    #[test]
    fn rejects_what_the_tree_parser_rejects() {
        for bad in [
            "",
            "{",
            r#"{"a": }"#,
            r#"{"a": 1,}"#,
            r#"{"a" 1}"#,
            r#"{"a": 1} extra"#,
            r#"{"a": 01e}"#,
            r#"{"a": "\q"}"#,
            r#"{"a": "\uD800x"}"#,
            r#"{"a": "unterminated"#,
            r#"{"a": [1, 2}"#,
        ] {
            assert!(LazyObj::parse(bad).is_err(), "accepted {bad:?}");
            assert!(parse(bad).is_err(), "tree parser accepted {bad:?}");
        }
    }

    #[test]
    fn rejects_valid_non_object_lines() {
        for doc in ["42", r#""str""#, "[1, 2]", "null", "true"] {
            assert!(parse(doc).is_ok());
            assert!(LazyObj::parse(doc).is_err(), "accepted non-object {doc:?}");
        }
    }

    #[test]
    fn empty_object_parses_with_no_fields() {
        let o = LazyObj::parse("  { }  ").unwrap();
        assert_eq!(o.keys().count(), 0);
        assert!(!o.has("anything"));
    }

    #[test]
    fn unicode_and_surrogates_match_tree_semantics() {
        let line = r#"{"s": "é 𝄞 é"}"#;
        let o = LazyObj::parse(line).unwrap();
        let tree = parse(line).unwrap();
        assert_eq!(o.get("s").unwrap().as_str(), tree.get("s").unwrap().as_str());
    }
}
