//! Minimal JSON substrate (parser + writer + lazy request reader).
//!
//! The build environment is offline with no `serde` in the vendored crate
//! set, so the artifact manifest, golden vectors, and the serving
//! protocol use this in-tree implementation: a strict recursive-descent
//! parser over the JSON grammar plus a compact writer. Only what the repo
//! needs — no datetime/arbitrary-precision extensions.
//!
//! The serving hot path does not build the tree at all: [`lazy::LazyObj`]
//! is a field-scanning reader that validates a request line structurally
//! in one pass and re-parses only the value spans a request kind actually
//! asks for. Its acceptance set is pinned to [`parse`]'s (restricted to
//! top-level objects) by the `wire_fuzz` suite.

pub mod lazy;

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Decode a JSON number array into f32s.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        let a = self.as_arr()?;
        let mut out = Vec::with_capacity(a.len());
        for v in a {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }

    /// Field access that reports the path on failure.
    pub fn req(&self, key: &str) -> crate::Result<&Value> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing JSON field {key:?}"))
    }
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> crate::Result<Value> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    anyhow::ensure!(p.i == p.b.len(), "trailing garbage at byte {}", p.i);
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> crate::Result<()> {
        anyhow::ensure!(self.peek() == Some(c), "expected {:?} at byte {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> crate::Result<Value> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(s.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += s.len();
        Ok(v)
    }

    fn value(&mut self) -> crate::Result<Value> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i),
        }
    }

    fn object(&mut self) -> crate::Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                other => anyhow::bail!("expected , or }} got {:?} at byte {}", other.map(|c| c as char), self.i),
            }
        }
    }

    fn array(&mut self) -> crate::Result<Value> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                other => anyhow::bail!("expected , or ] got {:?} at byte {}", other.map(|c| c as char), self.i),
            }
        }
    }

    fn string(&mut self) -> crate::Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| anyhow::anyhow!("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs: join with the low half. The
                            // low half must itself be a low surrogate —
                            // anything else is an error line, never an
                            // arithmetic underflow (this parser faces the
                            // wire, so malformed escapes must not panic).
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                anyhow::ensure!(
                                    self.b.get(self.i) == Some(&b'\\') && self.b.get(self.i + 1) == Some(&b'u'),
                                    "lone high surrogate"
                                );
                                self.i += 2;
                                anyhow::ensure!(self.i + 4 <= self.b.len(), "bad \\u escape");
                                let hex2 = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                                let low = u32::from_str_radix(hex2, 16)?;
                                self.i += 4;
                                anyhow::ensure!((0xDC00..0xE000).contains(&low), "bad low surrogate");
                                char::from_u32(0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00))
                            } else {
                                char::from_u32(code)
                            };
                            s.push(ch.ok_or_else(|| anyhow::anyhow!("bad codepoint"))?);
                        }
                        _ => anyhow::bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // Consume the rest of a multi-byte UTF-8 sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> crate::Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>()?))
    }
}

pub(crate) fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Compact JSON writer (used by the server protocol and report dumps).
pub fn write(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = fmt::Write::write_fmt(out, format_args!("{}", *n as i64));
            } else {
                let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
            }
        }
        Value::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => {
                        let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Value::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write(v, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write(&Value::Str(k.clone()), out);
                out.push(':');
                write(v, out);
            }
            out.push('}');
        }
    }
}

pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write(v, &mut s);
    s
}

/// Convenience constructors for building documents.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f32(xs: &[f32]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" null ").unwrap(), Value::Null);
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"arr":[1,2.5,-3],"nested":{"s":"hi \"there\""},"z":null}"#;
        let v = parse(doc).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
    }

    #[test]
    fn f32_vec() {
        let v = parse("[1, 2, 3.5]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.0, 3.5]);
    }
}
