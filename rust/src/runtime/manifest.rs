//! `manifest.json` — the artifact registry emitted by `python/compile/aot.py`.

use crate::json::Value;
use crate::solvers::Solver;
use crate::Result;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub model: String,
    pub solver: String,
    pub batch: usize,
    pub dim: usize,
    pub k: usize,
    pub guided: bool,
    pub evals_per_step: usize,
    pub inputs: Vec<InputSpec>,
}

impl ArtifactMeta {
    pub fn solver_enum(&self) -> Option<Solver> {
        Solver::parse(&self.solver)
    }

    fn from_json(v: &Value) -> Result<Self> {
        let s = |k: &str| -> Result<String> { Ok(v.req(k)?.as_str().unwrap_or_default().to_string()) };
        let u = |k: &str| -> Result<usize> {
            v.req(k)?.as_usize().ok_or_else(|| anyhow::anyhow!("field {k} not a number"))
        };
        let mut inputs = Vec::new();
        for iv in v.req("inputs")?.as_arr().unwrap_or(&[]) {
            let shape = iv
                .req("shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_usize())
                .collect();
            inputs.push(InputSpec { name: iv.req("name")?.as_str().unwrap_or_default().to_string(), shape });
        }
        Ok(ArtifactMeta {
            name: s("name")?,
            file: s("file")?,
            model: s("model")?,
            solver: s("solver")?,
            batch: u("batch")?,
            dim: u("dim")?,
            k: u("k")?,
            guided: v.req("guided")?.as_bool().unwrap_or(false),
            evals_per_step: u("evals_per_step")?,
            inputs,
        })
    }
}

#[derive(Debug, Clone)]
pub struct ScheduleMeta {
    pub beta_min: f32,
    pub beta_max: f32,
    pub sigma_floor: f32,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub schedule: ScheduleMeta,
    pub batch_buckets: Vec<usize>,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = crate::json::parse(text)?;
        let sc = v.req("schedule")?;
        let schedule = ScheduleMeta {
            beta_min: sc.req("beta_min")?.as_f64().unwrap_or(0.0) as f32,
            beta_max: sc.req("beta_max")?.as_f64().unwrap_or(0.0) as f32,
            sigma_floor: sc.req("sigma_floor")?.as_f64().unwrap_or(0.0) as f32,
        };
        // The schedule constants are baked into the HLO; refuse to run
        // against artifacts built with a different schedule than this
        // binary's native mirror.
        anyhow::ensure!(
            (schedule.beta_min - crate::schedule::BETA_MIN).abs() < 1e-9
                && (schedule.beta_max - crate::schedule::BETA_MAX).abs() < 1e-9,
            "artifact schedule ({}, {}) != native schedule ({}, {})",
            schedule.beta_min,
            schedule.beta_max,
            crate::schedule::BETA_MIN,
            crate::schedule::BETA_MAX,
        );
        let batch_buckets = v
            .req("batch_buckets")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|x| x.as_usize())
            .collect();
        let mut artifacts = Vec::new();
        for av in v.req("artifacts")?.as_arr().unwrap_or(&[]) {
            artifacts.push(ArtifactMeta::from_json(av)?);
        }
        Ok(Manifest { schedule, batch_buckets, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All artifacts for one (model, solver), sorted by batch descending.
    pub fn steps_for(&self, model: &str, solver: &str) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model && a.solver == solver)
            .collect();
        v.sort_by(|a, b| b.batch.cmp(&a.batch));
        v
    }

    pub fn models(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.iter().map(|a| a.model.as_str()).collect();
        v.sort();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "schedule": {"beta_min": 0.1, "beta_max": 20.0, "sigma_floor": 1e-4},
      "batch_buckets": [1, 8, 32],
      "artifacts": [
        {"name": "step_gmm_church_ddim_b1", "file": "step_gmm_church_ddim_b1.hlo.txt",
         "model": "gmm_church", "solver": "ddim", "batch": 1, "dim": 64, "k": 8,
         "guided": false, "evals_per_step": 1,
         "inputs": [{"name": "x", "shape": [1, 64]}, {"name": "s_from", "shape": [1]},
                    {"name": "s_to", "shape": [1]}]}
      ]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch_buckets, vec![1, 8, 32]);
        let a = m.artifact("step_gmm_church_ddim_b1").unwrap();
        assert_eq!(a.dim, 64);
        assert_eq!(a.solver_enum(), Some(Solver::Ddim));
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].shape, vec![1, 64]);
    }

    #[test]
    fn rejects_schedule_mismatch() {
        let bad = SAMPLE.replace("\"beta_max\": 20.0", "\"beta_max\": 10.0");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn steps_for_sorts_descending() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.steps_for("gmm_church", "ddim").len(), 1);
        assert!(m.steps_for("gmm_church", "heun").is_empty());
        assert_eq!(m.models(), vec!["gmm_church"]);
    }
}
