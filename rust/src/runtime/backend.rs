//! [`StepBackend`] over PJRT executables: marshals batched step requests
//! into artifact calls, chunking across batch buckets.

use super::{lit0, lit1, lit2, LoadedStep, PjrtRuntime};
use crate::solvers::{ddpm_noise, BackendFactory, Solver, StepBackend, StepRequest};
use crate::Result;
use std::cell::{Cell, RefCell};
use std::path::PathBuf;
use std::rc::Rc;

/// Bucket-padding scratch reused across chunks and calls: the padded
/// `x` / `s_from` / `s_to` / mask / noise marshalling buffers. Keeps the
/// steady-state step loop free of fresh allocations on the host side
/// (the PJRT call itself still materializes device literals).
#[derive(Default)]
struct PadScratch {
    xb: Vec<f32>,
    sf: Vec<f32>,
    st: Vec<f32>,
    mb: Vec<f32>,
    noise: Vec<f32>,
}

/// PJRT-backed solver step for one (model, solver) pair.
///
/// A request of `b` rows is split greedily over the available batch
/// buckets (e.g. 32, 8, 1); the tail chunk is padded up to the smallest
/// bucket and the pad rows discarded. Padding wastes a little compute but
/// keeps the executable set small — mirroring bucketed dynamic batching
/// in production serving stacks.
pub struct PjrtBackend {
    /// (bucket size, executable), sorted descending by bucket.
    steps: Vec<(usize, Rc<LoadedStep>)>,
    model: String,
    dim: usize,
    k: usize,
    guided: bool,
    solver: Solver,
    /// Model evaluations actually executed (incl. padding), diagnostics.
    evals_executed: Cell<u64>,
    calls: Cell<u64>,
    scratch: RefCell<PadScratch>,
}

impl PjrtBackend {
    /// Load every batch bucket of `(model, solver)` from the runtime.
    pub fn new(rt: &PjrtRuntime, model: &str, solver: Solver) -> Result<Self> {
        let metas = rt.manifest().steps_for(model, solver.name());
        anyhow::ensure!(
            !metas.is_empty(),
            "no artifacts for model={model} solver={}; run `make artifacts`",
            solver.name()
        );
        let mut steps = Vec::new();
        for meta in &metas {
            steps.push((meta.batch, rt.load(&meta.name)?));
        }
        let m0 = &steps[0].1.meta;
        Ok(PjrtBackend {
            dim: m0.dim,
            k: m0.k,
            guided: m0.guided,
            model: model.to_string(),
            solver,
            steps,
            evals_executed: Cell::new(0),
            calls: Cell::new(0),
            scratch: RefCell::new(PadScratch::default()),
        })
    }

    pub fn model_name(&self) -> &str {
        &self.model
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Model evaluations actually executed, including padding.
    pub fn evals_executed(&self) -> u64 {
        self.evals_executed.get()
    }

    pub fn calls(&self) -> u64 {
        self.calls.get()
    }

    /// Pick the execution plan for `rows`: greedy large-to-small buckets,
    /// final remainder padded to the smallest covering bucket.
    fn plan(&self, rows: usize) -> Vec<(usize, usize)> {
        // returns (bucket, real_rows) chunks
        let mut plan = Vec::new();
        let mut left = rows;
        for &(b, _) in &self.steps {
            while left >= b {
                plan.push((b, b));
                left -= b;
            }
        }
        if left > 0 {
            // smallest bucket >= left
            let bucket = self
                .steps
                .iter()
                .map(|&(b, _)| b)
                .filter(|&b| b >= left)
                .min()
                .unwrap_or_else(|| self.steps[0].0);
            plan.push((bucket, left));
        }
        plan
    }

    fn exe_for(&self, bucket: usize) -> &LoadedStep {
        &self.steps.iter().find(|&&(b, _)| b == bucket).expect("bucket").1
    }

    /// Execute one padded bucket, writing the `rows * dim` real outputs
    /// into `out` (pad rows are discarded).
    // lint: hot-path
    #[allow(clippy::too_many_arguments)]
    fn run_chunk(
        &self,
        bucket: usize,
        rows: usize,
        x: &[f32],
        s_from: &[f32],
        s_to: &[f32],
        mask: Option<&[f32]>,
        guidance: f32,
        seeds: &[u64],
        out: &mut [f32],
    ) -> Result<()> {
        let d = self.dim;
        let k = self.k;
        let mut sc = self.scratch.borrow_mut();
        // Pad by replicating the last real row (keeps values finite).
        let pad = |dst: &mut Vec<f32>, src: &[f32], width: usize| {
            dst.clear();
            dst.extend_from_slice(&src[..rows * width]);
            for _ in rows..bucket {
                dst.extend_from_slice(&src[(rows - 1) * width..rows * width]);
            }
        };
        let PadScratch { xb, sf, st, mb, noise } = &mut *sc;
        pad(xb, x, d);
        pad(sf, s_from, 1);
        pad(st, s_to, 1);
        // lint-allow(hot-path-alloc): PJRT literal marshalling materializes device buffers; the host padding scratch above is reused
        let mut lits: Vec<xla::Literal> = vec![lit2(xb, bucket, d)?, lit1(sf), lit1(st)];
        if self.guided {
            match mask {
                Some(m) => pad(mb, m, k),
                None => {
                    mb.clear();
                    mb.resize(bucket * k, 1.0);
                }
            }
            lits.push(lit2(mb, bucket, k)?);
            lits.push(lit0(if mask.is_some() { guidance } else { 0.0 }));
        }
        if self.solver.stochastic() {
            noise.clear();
            noise.resize(bucket * d, 0.0);
            for r in 0..bucket {
                let rr = r.min(rows - 1);
                ddpm_noise(seeds[rr], sf[r], d, &mut noise[r * d..(r + 1) * d]);
            }
            lits.push(lit2(noise, bucket, d)?);
        }
        let res = self.exe_for(bucket).run(&lits)?;
        self.evals_executed
            .set(self.evals_executed.get() + (bucket * self.solver.evals_per_step()) as u64);
        self.calls.set(self.calls.get() + 1);
        out[..rows * d].copy_from_slice(&res[..rows * d]);
        Ok(())
    }
}

impl StepBackend for PjrtBackend {
    fn dim(&self) -> usize {
        self.dim
    }

    fn solver(&self) -> Solver {
        self.solver
    }

    // lint: hot-path
    fn step_into(&self, req: &StepRequest, out: &mut [f32]) {
        let rows = req.rows();
        let d = self.dim;
        debug_assert_eq!(out.len(), rows * d, "step_into output must be exactly (b, dim)");
        let mut off = 0usize;
        for (bucket, real) in self.plan(rows) {
            self.run_chunk(
                bucket,
                real,
                &req.x[off * d..(off + real) * d],
                &req.s_from[off..off + real],
                &req.s_to[off..off + real],
                req.mask.map(|m| &m[off * self.k.max(1)..(off + real) * self.k.max(1)]),
                req.guidance,
                &req.seeds[off..off + real],
                &mut out[off * d..(off + real) * d],
            )
            .expect("pjrt step execution failed");
            off += real;
        }
    }
}

/// Opens a fresh [`PjrtRuntime`] per worker thread (the client is
/// thread-bound) and hands out backends for one (model, solver).
pub struct PjrtFactory {
    dir: PathBuf,
    model: String,
    solver: Solver,
    dim: usize,
}

impl PjrtFactory {
    pub fn new(dir: impl Into<PathBuf>, model: &str, solver: Solver) -> Result<Self> {
        let dir = dir.into();
        // Validate eagerly on the calling thread so errors surface early.
        let rt = PjrtRuntime::open(&dir)?;
        let be = PjrtBackend::new(&rt, model, solver)?;
        Ok(PjrtFactory { dir, model: model.to_string(), solver, dim: be.dim })
    }
}

impl BackendFactory for PjrtFactory {
    fn create(&self) -> Box<dyn StepBackend> {
        let rt = PjrtRuntime::open(&self.dir).expect("open artifacts");
        Box::new(PjrtBackend::new(&rt, &self.model, self.solver).expect("load backend"))
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn solver(&self) -> Solver {
        self.solver
    }
}

#[cfg(test)]
mod tests {
    // Plan logic is pure; exercised here without PJRT.
    use super::*;

    fn fake(steps: Vec<usize>) -> Vec<(usize, usize)> {
        // emulate plan() with the same greedy logic
        let mut buckets = steps;
        buckets.sort_unstable_by(|a, b| b.cmp(a));
        buckets
            .into_iter()
            .map(|b| (b, b))
            .collect()
    }

    #[test]
    fn greedy_plan_shape() {
        // 70 rows over {32, 8, 1} → 32+32+8(6 used)... emulated via the
        // fake above only sanity-checks ordering; the real plan() is
        // covered by the pjrt integration test in rust/tests/.
        let f = fake(vec![8, 32, 1]);
        assert_eq!(f[0].0, 32);
    }
}
