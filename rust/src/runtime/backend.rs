//! [`StepBackend`] over PJRT executables: marshals batched step requests
//! into artifact calls, chunking across batch buckets.

use super::{lit0, lit1, lit2, LoadedStep, PjrtRuntime};
use crate::solvers::{ddpm_noise, BackendFactory, Solver, StepBackend, StepRequest};
use crate::Result;
use std::cell::Cell;
use std::path::PathBuf;
use std::rc::Rc;

/// PJRT-backed solver step for one (model, solver) pair.
///
/// A request of `b` rows is split greedily over the available batch
/// buckets (e.g. 32, 8, 1); the tail chunk is padded up to the smallest
/// bucket and the pad rows discarded. Padding wastes a little compute but
/// keeps the executable set small — mirroring bucketed dynamic batching
/// in production serving stacks.
pub struct PjrtBackend {
    /// (bucket size, executable), sorted descending by bucket.
    steps: Vec<(usize, Rc<LoadedStep>)>,
    model: String,
    dim: usize,
    k: usize,
    guided: bool,
    solver: Solver,
    /// Model evaluations actually executed (incl. padding), diagnostics.
    evals_executed: Cell<u64>,
    calls: Cell<u64>,
}

impl PjrtBackend {
    /// Load every batch bucket of `(model, solver)` from the runtime.
    pub fn new(rt: &PjrtRuntime, model: &str, solver: Solver) -> Result<Self> {
        let metas = rt.manifest().steps_for(model, solver.name());
        anyhow::ensure!(
            !metas.is_empty(),
            "no artifacts for model={model} solver={}; run `make artifacts`",
            solver.name()
        );
        let mut steps = Vec::new();
        for meta in &metas {
            steps.push((meta.batch, rt.load(&meta.name)?));
        }
        let m0 = &steps[0].1.meta;
        Ok(PjrtBackend {
            dim: m0.dim,
            k: m0.k,
            guided: m0.guided,
            model: model.to_string(),
            solver,
            steps,
            evals_executed: Cell::new(0),
            calls: Cell::new(0),
        })
    }

    pub fn model_name(&self) -> &str {
        &self.model
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Model evaluations actually executed, including padding.
    pub fn evals_executed(&self) -> u64 {
        self.evals_executed.get()
    }

    pub fn calls(&self) -> u64 {
        self.calls.get()
    }

    /// Pick the execution plan for `rows`: greedy large-to-small buckets,
    /// final remainder padded to the smallest covering bucket.
    fn plan(&self, rows: usize) -> Vec<(usize, usize)> {
        // returns (bucket, real_rows) chunks
        let mut plan = Vec::new();
        let mut left = rows;
        for &(b, _) in &self.steps {
            while left >= b {
                plan.push((b, b));
                left -= b;
            }
        }
        if left > 0 {
            // smallest bucket >= left
            let bucket = self
                .steps
                .iter()
                .map(|&(b, _)| b)
                .filter(|&b| b >= left)
                .min()
                .unwrap_or_else(|| self.steps[0].0);
            plan.push((bucket, left));
        }
        plan
    }

    fn exe_for(&self, bucket: usize) -> &LoadedStep {
        &self.steps.iter().find(|&&(b, _)| b == bucket).expect("bucket").1
    }

    fn run_chunk(
        &self,
        bucket: usize,
        rows: usize,
        x: &[f32],
        s_from: &[f32],
        s_to: &[f32],
        mask: Option<&[f32]>,
        guidance: f32,
        seeds: &[u64],
    ) -> Result<Vec<f32>> {
        let d = self.dim;
        let k = self.k;
        // Pad by replicating the last real row (keeps values finite).
        let pad = |src: &[f32], width: usize| -> Vec<f32> {
            let mut v = Vec::with_capacity(bucket * width);
            v.extend_from_slice(&src[..rows * width]);
            for _ in rows..bucket {
                v.extend_from_slice(&src[(rows - 1) * width..rows * width]);
            }
            v
        };
        let xb = pad(x, d);
        let sf = pad(s_from, 1);
        let st = pad(s_to, 1);
        let mut lits: Vec<xla::Literal> = vec![lit2(&xb, bucket, d)?, lit1(&sf), lit1(&st)];
        if self.guided {
            let mb = match mask {
                Some(m) => pad(m, k),
                None => vec![1.0f32; bucket * k],
            };
            lits.push(lit2(&mb, bucket, k)?);
            lits.push(lit0(if mask.is_some() { guidance } else { 0.0 }));
        }
        if self.solver.stochastic() {
            let mut noise = vec![0.0f32; bucket * d];
            for r in 0..bucket {
                let rr = r.min(rows - 1);
                ddpm_noise(seeds[rr], sf[r], d, &mut noise[r * d..(r + 1) * d]);
            }
            lits.push(lit2(&noise, bucket, d)?);
        }
        let out = self.exe_for(bucket).run(&lits)?;
        self.evals_executed
            .set(self.evals_executed.get() + (bucket * self.solver.evals_per_step()) as u64);
        self.calls.set(self.calls.get() + 1);
        Ok(out[..rows * d].to_vec())
    }
}

impl StepBackend for PjrtBackend {
    fn dim(&self) -> usize {
        self.dim
    }

    fn solver(&self) -> Solver {
        self.solver
    }

    fn step(&self, req: &StepRequest) -> Vec<f32> {
        let rows = req.rows();
        let d = self.dim;
        let mut out = Vec::with_capacity(rows * d);
        let mut off = 0usize;
        for (bucket, real) in self.plan(rows) {
            let chunk = self
                .run_chunk(
                    bucket,
                    real,
                    &req.x[off * d..(off + real) * d],
                    &req.s_from[off..off + real],
                    &req.s_to[off..off + real],
                    req.mask.map(|m| &m[off * self.k.max(1)..(off + real) * self.k.max(1)]),
                    req.guidance,
                    &req.seeds[off..off + real],
                )
                .expect("pjrt step execution failed");
            out.extend_from_slice(&chunk);
            off += real;
        }
        out
    }
}

/// Opens a fresh [`PjrtRuntime`] per worker thread (the client is
/// thread-bound) and hands out backends for one (model, solver).
pub struct PjrtFactory {
    dir: PathBuf,
    model: String,
    solver: Solver,
    dim: usize,
}

impl PjrtFactory {
    pub fn new(dir: impl Into<PathBuf>, model: &str, solver: Solver) -> Result<Self> {
        let dir = dir.into();
        // Validate eagerly on the calling thread so errors surface early.
        let rt = PjrtRuntime::open(&dir)?;
        let be = PjrtBackend::new(&rt, model, solver)?;
        Ok(PjrtFactory { dir, model: model.to_string(), solver, dim: be.dim })
    }
}

impl BackendFactory for PjrtFactory {
    fn create(&self) -> Box<dyn StepBackend> {
        let rt = PjrtRuntime::open(&self.dir).expect("open artifacts");
        Box::new(PjrtBackend::new(&rt, &self.model, self.solver).expect("load backend"))
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn solver(&self) -> Solver {
        self.solver
    }
}

#[cfg(test)]
mod tests {
    // Plan logic is pure; exercised here without PJRT.
    use super::*;

    fn fake(steps: Vec<usize>) -> Vec<(usize, usize)> {
        // emulate plan() with the same greedy logic
        let mut buckets = steps;
        buckets.sort_unstable_by(|a, b| b.cmp(a));
        buckets
            .into_iter()
            .map(|b| (b, b))
            .collect()
    }

    #[test]
    fn greedy_plan_shape() {
        // 70 rows over {32, 8, 1} → 32+32+8(6 used)... emulated via the
        // fake above only sanity-checks ordering; the real plan() is
        // covered by the pjrt integration test in rust/tests/.
        let f = fake(vec![8, 32, 1]);
        assert_eq!(f[0].0, 32);
    }
}
