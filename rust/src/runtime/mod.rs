//! PJRT runtime: load AOT-compiled HLO-text artifacts (`make artifacts`)
//! and execute them from the rust hot path.
//!
//! Interchange is HLO **text** — jax ≥ 0.5 emits `HloModuleProto`s with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see `python/compile/aot.py`).
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (thread-bound); the
//! measured multi-device executor therefore opens one [`PjrtRuntime`] per
//! worker thread via [`PjrtFactory`].

mod backend;
mod manifest;

pub use backend::{PjrtBackend, PjrtFactory};
pub use manifest::{ArtifactMeta, InputSpec, Manifest};

use crate::Result;
use anyhow::{anyhow, Context};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

/// A compiled step executable plus its manifest entry.
pub struct LoadedStep {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedStep {
    /// Execute with input literals in manifest order; returns the flat
    /// f32 output (the artifact returns a 1-tuple, see aot.py).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let bufs = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = bufs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple1()?.to_vec::<f32>()?)
    }
}

/// One PJRT CPU client + a lazily-compiled executable cache over the
/// artifact directory.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<LoadedStep>>>,
}

impl PjrtRuntime {
    /// Open the artifacts directory (reads `manifest.json`, creates the
    /// PJRT CPU client; executables compile lazily on first use).
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {} (run `make artifacts`)", dir.display()))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtRuntime { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Open the default artifacts dir (`$SRDS_ARTIFACTS` or `./artifacts`).
    pub fn open_default() -> Result<Self> {
        Self::open(crate::artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile-and-cache) one artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Rc<LoadedStep>> {
        if let Some(s) = self.cache.borrow().get(name) {
            return Ok(s.clone());
        }
        let meta = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?
            .clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let step = Rc::new(LoadedStep { meta, exe });
        self.cache.borrow_mut().insert(name.to_string(), step.clone());
        Ok(step)
    }

    /// Number of executables compiled so far (diagnostics).
    pub fn loaded_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// Build a rank-2 literal from a flat slice.
pub fn lit2(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), rows * cols);
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

/// Build a rank-1 literal.
pub fn lit1(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Build a rank-0 (scalar) literal.
pub fn lit0(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}
