//! Dynamic batching: coalesce concurrently-pending step work into
//! bucketed batch sizes (the request-level complement of SRDS's
//! within-sample batching from §3.4).
//!
//! The server collects step rows from multiple in-flight samplers for up
//! to `max_wait` and flushes when a bucket fills — classic
//! vLLM-router-style batching adapted to diffusion steps.

use std::time::{Duration, Instant};

/// One row of pending step work (request-agnostic payload).
#[derive(Debug, Clone)]
pub struct PendingRow {
    /// Opaque owner tag (request id, block id, …).
    pub tag: u64,
    pub x: Vec<f32>,
    pub s_from: f32,
    pub s_to: f32,
    pub mask: Option<Vec<f32>>,
    pub guidance: f32,
    pub seed: u64,
}

/// Batch assembly policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Available batch sizes, descending preference (from the artifact
    /// manifest's `batch_buckets`).
    pub buckets: Vec<usize>,
    /// Flush incomplete batches after this long.
    pub max_wait: Duration,
    /// Hard cap on queued rows before back-pressuring producers.
    pub max_queue: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { buckets: vec![32, 8, 1], max_wait: Duration::from_millis(2), max_queue: 1024 }
    }
}

/// Accumulates rows and decides when a batch should flush.
pub struct Batcher {
    policy: BatchPolicy,
    queue: Vec<PendingRow>,
    oldest: Option<Instant>,
    /// Flush statistics: (batches, rows, padded_rows).
    pub flushed_batches: u64,
    pub flushed_rows: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, queue: Vec::new(), oldest: None, flushed_batches: 0, flushed_rows: 0 }
    }

    /// Push a row; returns `false` (back-pressure) when the queue is full.
    pub fn push(&mut self, row: PendingRow) -> bool {
        if self.queue.len() >= self.policy.max_queue {
            return false;
        }
        if self.queue.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.queue.push(row);
        true
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    fn max_bucket(&self) -> usize {
        self.policy.buckets.iter().copied().max().unwrap_or(1)
    }

    /// Whether a flush should happen now: the largest bucket is full, or
    /// the oldest queued row has waited past `max_wait`.
    pub fn should_flush(&self) -> bool {
        if self.queue.len() >= self.max_bucket() {
            return true;
        }
        match self.oldest {
            Some(t) => !self.queue.is_empty() && t.elapsed() >= self.policy.max_wait,
            None => false,
        }
    }

    /// Remove and return the next batch (rows in FIFO order), up to the
    /// largest bucket; sub-bucket remainders are padded downstream by the
    /// runtime's bucket plan.
    pub fn take_batch(&mut self) -> Vec<PendingRow> {
        let take = self.queue.len().min(self.max_bucket());
        let batch: Vec<PendingRow> = self.queue.drain(..take).collect();
        self.oldest = if self.queue.is_empty() { None } else { Some(Instant::now()) };
        self.flushed_batches += 1;
        self.flushed_rows += batch.len() as u64;
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(tag: u64) -> PendingRow {
        PendingRow { tag, x: vec![0.0; 4], s_from: 0.1, s_to: 0.2, mask: None, guidance: 0.0, seed: 0 }
    }

    #[test]
    fn fills_largest_bucket_first() {
        let mut b = Batcher::new(BatchPolicy { buckets: vec![4, 2, 1], max_wait: Duration::from_secs(10), max_queue: 100 });
        for i in 0..5 {
            assert!(b.push(row(i)));
        }
        assert!(b.should_flush());
        let batch = b.take_batch();
        assert_eq!(batch.len(), 4);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn timeout_flushes_partial() {
        let mut b = Batcher::new(BatchPolicy { buckets: vec![8], max_wait: Duration::from_millis(1), max_queue: 100 });
        b.push(row(1));
        assert!(!b.should_flush());
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.should_flush());
        assert_eq!(b.take_batch().len(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn backpressure_at_capacity() {
        let mut b = Batcher::new(BatchPolicy { buckets: vec![2], max_wait: Duration::from_secs(1), max_queue: 2 });
        assert!(b.push(row(1)));
        assert!(b.push(row(2)));
        assert!(!b.push(row(3)), "queue full must refuse");
    }

    #[test]
    fn max_wait_runs_from_first_push() {
        // `oldest` tracks the first queued row, not the last: a steady
        // trickle of new rows must not starve the head of the queue.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![8],
            max_wait: Duration::from_millis(5),
            max_queue: 100,
        });
        b.push(row(1));
        std::thread::sleep(Duration::from_millis(7));
        b.push(row(2)); // newer row; head has already expired
        assert!(b.should_flush(), "expiry is measured from the oldest row");
    }

    #[test]
    fn partial_drain_resets_oldest() {
        // 3 rows over a 2-bucket: take_batch() drains 2 and must restart
        // the max-wait clock for the remainder — the leftover row is
        // "fresh" again, not instantly expired.
        // A generous window: the !should_flush assert below only flakes
        // if the test thread is preempted for more than max_wait between
        // two adjacent statements.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![2],
            max_wait: Duration::from_millis(1000),
            max_queue: 100,
        });
        for i in 0..3 {
            b.push(row(i));
        }
        assert!(b.should_flush(), "bucket full");
        assert_eq!(b.take_batch().len(), 2);
        assert_eq!(b.pending(), 1);
        assert!(
            !b.should_flush(),
            "leftover row got a fresh max-wait clock on partial drain"
        );
        std::thread::sleep(Duration::from_millis(1100));
        assert!(b.should_flush(), "leftover row expires after a full max_wait");
    }

    #[test]
    fn full_drain_clears_oldest() {
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![4],
            max_wait: Duration::from_millis(1),
            max_queue: 100,
        });
        b.push(row(1));
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.should_flush());
        assert_eq!(b.take_batch().len(), 1);
        assert_eq!(b.pending(), 0);
        // Empty queue: no oldest row, so the expiry clause can never fire.
        std::thread::sleep(Duration::from_millis(3));
        assert!(!b.should_flush(), "empty batcher must not flush");
        assert_eq!(b.flushed_batches, 1);
        assert_eq!(b.flushed_rows, 1);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(Batcher::new(BatchPolicy::default()).policy.clone());
        for i in 0..3 {
            b.push(row(i));
        }
        std::thread::sleep(Duration::from_millis(3));
        let batch = b.take_batch();
        let tags: Vec<u64> = batch.iter().map(|r| r.tag).collect();
        assert_eq!(tags, vec![0, 1, 2]);
    }
}
