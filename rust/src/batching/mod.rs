//! Dynamic batching: coalesce concurrently-pending step work into
//! bucketed batch sizes (the request-level complement of SRDS's
//! within-sample batching from §3.4).
//!
//! The engine collects step rows from multiple in-flight sampler tasks
//! (`crate::exec::task` — every registered sampler emits its steps as
//! rows here, whole sweeps at a time for the window/trajectory
//! samplers) for up to `max_wait` and flushes when a bucket fills —
//! classic vLLM-router-style batching adapted to diffusion steps.
//!
//! **QoS row selection.** Rows carry a [`QosClass`]
//! (`interactive` / `standard` / `batch`) and each [`Batcher`] keeps one
//! FIFO lane per class. Draining is **weighted deficit round robin**
//! over the lanes ([`BatchPolicy::class_weights`]): each visit to a
//! non-empty lane recharges its deficit by the class weight and takes up
//! to that many rows, so over any contention window the classes' service
//! shares converge to the weight ratio and — because every weight is
//! ≥ 1 — no lane is ever starved (a flooding `batch` tenant cannot
//! freeze `interactive` rows, and vice versa). Within a lane rows drain
//! FIFO with an urgent head region ([`Batcher::push_urgent`], the SRDS
//! coarse spine). When only one class has traffic, DRR degenerates to
//! exactly the old single-queue FIFO order — single-class workloads are
//! bit-identical to the pre-QoS engine.

use crate::buf::{BatchStage, StateBuf};
use crate::coordinator::QosClass;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One row of pending step work (request-agnostic payload).
///
/// Zero-copy: the state is a refcounted [`StateBuf`] (queueing a row
/// shares the producer's buffer, it does not copy it) and the mask is an
/// `Arc` slice shared by every row of a request — a `clone()` of the row
/// is two refcount bumps, no float moves.
#[derive(Debug, Clone)]
pub struct PendingRow {
    /// Opaque owner tag (request id, block id, …).
    pub tag: u64,
    pub x: StateBuf,
    pub s_from: f32,
    pub s_to: f32,
    pub mask: Option<Arc<[f32]>>,
    pub guidance: f32,
    pub seed: u64,
    /// QoS lane this row drains from (the owning request's priority
    /// class). Selection-only: never changes the row's value.
    pub class: QosClass,
}

/// Assemble `rows` into `stage` (cleared first): the flat `(b, dim)`
/// states, per-row times/seeds and the concatenated masks, ready for one
/// [`crate::solvers::StepBackend::step_into`] call. All rows must share
/// one guidance weight and maskedness — the engine's batch key
/// guarantees exactly that.
// lint: hot-path
pub fn stage_rows(rows: &[PendingRow], stage: &mut BatchStage) {
    stage.reset(rows.first().map(|r| r.guidance).unwrap_or(0.0));
    for r in rows {
        stage.push_row(&r.x, r.s_from, r.s_to, r.seed, r.mask.as_deref());
    }
}

/// Default DRR weights, in [`QosClass::ALL`] order
/// (`[interactive, standard, batch]`): interactive gets 8 rows per
/// standard's 3 per batch's 1 under full contention. Every weight is
/// ≥ 1, so every class makes progress each DRR cycle.
pub const DEFAULT_CLASS_WEIGHTS: [u64; 3] = [8, 3, 1];

/// Batch assembly policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Available batch sizes, descending preference (from the artifact
    /// manifest's `batch_buckets`).
    pub buckets: Vec<usize>,
    /// Flush incomplete batches after this long.
    pub max_wait: Duration,
    /// Hard cap on queued rows before back-pressuring producers.
    pub max_queue: usize,
    /// Weighted-DRR service shares per [`QosClass`], in
    /// [`QosClass::ALL`] order. Weights of 0 are treated as 1 (no class
    /// may be configured into starvation).
    pub class_weights: [u64; 3],
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // A full power-of-two ladder rather than the sparse {32, 8, 1}:
        // with bucket-preferring drains (see [`Batcher::take_batch`]) a
        // finer ladder wastes less padding and lets the engine size
        // batches close to whatever is actually pending.
        BatchPolicy {
            buckets: vec![32, 16, 8, 4, 2, 1],
            max_wait: Duration::from_millis(2),
            max_queue: 1024,
            class_weights: DEFAULT_CLASS_WEIGHTS,
        }
    }
}

impl BatchPolicy {
    /// Policy for the measured executor: flush immediately (never hold a
    /// row hoping for co-tenants) and never refuse a push — the engine's
    /// dispatcher is the only producer, so back-pressure belongs at the
    /// admission layer above it, not here.
    pub fn immediate() -> Self {
        BatchPolicy { max_wait: Duration::ZERO, max_queue: usize::MAX, ..Self::default() }
    }
}

/// One QoS lane: a FIFO queue with an urgent head region.
#[derive(Default)]
struct Lane {
    rows: Vec<PendingRow>,
    /// Length of the critical-path head region: rows `[0, urgent)` were
    /// pushed via [`Batcher::push_urgent`] and drain before this lane's
    /// normal rows, FIFO among themselves.
    urgent: usize,
    /// When this lane's head row was queued (the max-wait clock).
    oldest: Option<Instant>,
}

/// Accumulates rows and decides when a batch should flush. One FIFO
/// lane per [`QosClass`]; draining is weighted deficit round robin over
/// the lanes (see the module docs for the fairness invariants).
pub struct Batcher {
    policy: BatchPolicy,
    /// Per-class lanes, indexed by [`QosClass::index`].
    lanes: [Lane; 3],
    /// DRR deficit counters: rows each lane may still take before the
    /// cursor moves past it. Bounded by one weight quantum (recharged
    /// only from zero, when the cursor arrives), and an emptied lane's
    /// deficit resets to 0 — idle classes bank no credit (the standard
    /// DRR rule; otherwise a long-idle batch lane could burst past
    /// interactive traffic on wake-up).
    deficit: [u64; 3],
    /// Next lane the DRR visit starts from.
    cursor: usize,
    /// Flush statistics.
    pub flushed_batches: u64,
    pub flushed_rows: u64,
    /// Rows *drained* per class, in [`QosClass::ALL`] order, counted at
    /// selection time. NOT the engine's wire stat: a drained row can
    /// still be dropped by the engine's dead-row filter before reaching
    /// a worker, so the engine keeps its own dispatched-row counter
    /// (`classes[].rows`) and this one stays a batcher-local
    /// scheduling-share observable (tests, debugging).
    pub flushed_rows_class: [u64; 3],
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            lanes: Default::default(),
            deficit: [0; 3],
            cursor: 0,
            flushed_batches: 0,
            flushed_rows: 0,
            flushed_rows_class: [0; 3],
        }
    }

    /// Push a row onto its class lane; returns `false` (back-pressure)
    /// when the batcher is at `max_queue` total rows.
    // lint: hot-path
    pub fn push(&mut self, row: PendingRow) -> bool {
        if self.pending() >= self.policy.max_queue {
            return false;
        }
        let lane = &mut self.lanes[row.class.index()];
        if lane.rows.is_empty() {
            lane.oldest = Some(Instant::now());
        }
        lane.rows.push(row);
        true
    }

    /// Push a critical-path row into its class lane's *urgent head
    /// region* so it drains before that lane's normal rows (FIFO among
    /// urgent rows). The engine marks SRDS coarse steps urgent: the G
    /// chain is the serial spine of the schedule (Prop. 2), and
    /// speculative fine work queued earlier must not delay it — the
    /// FIFO-queue analogue of the old worker pool's priority heap.
    /// Urgency is *within-class* only: a batch-class spine never jumps
    /// interactive rows (class isolation is the DRR invariant).
    // lint: hot-path
    pub fn push_urgent(&mut self, row: PendingRow) -> bool {
        if self.pending() >= self.policy.max_queue {
            return false;
        }
        let lane = &mut self.lanes[row.class.index()];
        if lane.rows.is_empty() {
            lane.oldest = Some(Instant::now());
        }
        let at = lane.urgent;
        lane.rows.insert(at, row);
        lane.urgent += 1;
        true
    }

    /// Remove up to `max` rows from the **tail** of this batcher for
    /// cross-shard work stealing (`exec::router`): lowest-priority lanes
    /// donate first (`batch`, then `standard`, then `interactive`) and a
    /// lane's *urgent head region is never donated* — the SRDS coarse
    /// spine is the serial critical path and must stay on the shard
    /// whose dispatcher is sequencing it. Remaining rows keep their FIFO
    /// order and urgent markers, so a partial steal never reorders the
    /// victim's own drain. Row values are position-independent (the
    /// rows-never-interact contract), so executing a stolen tail on
    /// another shard's workers is numerically invisible.
    pub fn steal_tail(&mut self, max: usize) -> Vec<PendingRow> {
        let mut stolen = Vec::new();
        for class in QosClass::ALL.into_iter().rev() {
            if stolen.len() >= max {
                break;
            }
            let lane = &mut self.lanes[class.index()];
            let donatable = lane.rows.len() - lane.urgent;
            let take = donatable.min(max - stolen.len());
            if take == 0 {
                continue;
            }
            stolen.extend(lane.rows.split_off(lane.rows.len() - take));
            if lane.rows.is_empty() {
                lane.oldest = None;
            }
        }
        stolen
    }

    /// Remove every queued row failing `keep` (dead-request purge) and
    /// return the removed rows, preserving order among the kept ones.
    pub fn purge<F: FnMut(&PendingRow) -> bool>(&mut self, mut keep: F) -> Vec<PendingRow> {
        let mut removed = Vec::new();
        for lane in &mut self.lanes {
            let urgent_was = lane.urgent;
            let mut kept = Vec::with_capacity(lane.rows.len());
            let mut kept_urgent = 0usize;
            for (idx, r) in lane.rows.drain(..).enumerate() {
                if keep(&r) {
                    if idx < urgent_was {
                        kept_urgent += 1;
                    }
                    kept.push(r);
                } else {
                    removed.push(r);
                }
            }
            lane.rows = kept;
            lane.urgent = kept_urgent;
            if lane.rows.is_empty() {
                lane.oldest = None;
            }
        }
        removed
    }

    /// Total queued rows, all classes.
    pub fn pending(&self) -> usize {
        self.lanes.iter().map(|l| l.rows.len()).sum()
    }

    /// Queued rows of one class.
    pub fn pending_class(&self, class: QosClass) -> usize {
        self.lanes[class.index()].rows.len()
    }

    /// Earliest queue instant over the non-empty lanes (`None` when
    /// nothing is pending). The engine drains the *longest-waiting*
    /// eager batcher first, so a flooding tenant whose rows land in a
    /// different batcher (different guidance / mask shape) cannot starve
    /// co-tenants through map iteration order — the cross-batcher
    /// complement of the in-batcher DRR fairness.
    pub fn oldest_since(&self) -> Option<Instant> {
        self.lanes
            .iter()
            .filter_map(|l| if l.rows.is_empty() { None } else { l.oldest })
            .min()
    }

    fn max_bucket(&self) -> usize {
        self.policy.buckets.iter().copied().max().unwrap_or(1)
    }

    /// Whether a flush should happen now: the largest bucket is full
    /// across all lanes, or *any* lane's head row has waited past
    /// `max_wait` (each class keeps its own clock, so a low-traffic
    /// class's head cannot be aged-out-by-proxy through another class's
    /// churn).
    pub fn should_flush(&self) -> bool {
        if self.pending() >= self.max_bucket() {
            return true;
        }
        self.lanes.iter().any(|l| {
            !l.rows.is_empty()
                && l.oldest.map(|t| t.elapsed() >= self.policy.max_wait).unwrap_or(false)
        })
    }

    /// Remove and return the next batch, honoring the descending
    /// `buckets` preference list for its *size*: the largest bucket that
    /// the pending rows can *fill completely* wins. When even the
    /// smallest bucket cannot be filled (the timeout-flush case), every
    /// pending row is drained — a sub-bucket remainder that the runtime's
    /// bucket plan pads up to the smallest compiled size. Row *selection*
    /// is weighted DRR over the class lanes; with a single class in play
    /// it is plain FIFO (urgent head first).
    pub fn take_batch(&mut self) -> Vec<PendingRow> {
        self.take_up_to(usize::MAX)
    }

    /// [`Self::take_batch`] with an additional caller-imposed cap on the
    /// batch size. The engine drains whole (`cap = pending`) and then
    /// *splits* the drained rows into contiguous chunks across its idle
    /// workers, so fusion only grows once every worker already has
    /// work (see `exec::engine`'s flush-policy docs).
    // lint: hot-path
    pub fn take_up_to(&mut self, cap: usize) -> Vec<PendingRow> {
        let avail = self.pending().min(cap);
        let take = self
            .policy
            .buckets
            .iter()
            .copied()
            .filter(|&b| b <= avail)
            .max()
            // No bucket fits under `avail`: drain it whole (it is below
            // the smallest bucket, so downstream pads it up to one).
            .unwrap_or(avail);
        // lint-allow(hot-path-alloc): the returned batch is the worker handoff — O(batch) row handles, not state copies
        let mut batch: Vec<PendingRow> = Vec::with_capacity(take);
        // Weighted DRR: the cursor *stays on a lane until its deficit is
        // spent* (or the lane empties), and a lane's deficit recharges
        // to exactly one weight quantum only when the cursor arrives
        // with it at zero. Both the cursor and the unspent deficits
        // persist across batches, so service shares converge to the
        // weight ratio even when every individual take is tiny (the
        // engine's spread-first flush often takes one row at a time —
        // recharging per visit there would collapse the weights to
        // 1:1:1). Deficits are bounded by one quantum, so no lane can
        // bank credit and burst. Terminates: `take <= pending`, and
        // every full cycle over non-empty lanes drains at least one row.
        while batch.len() < take {
            let c = self.cursor;
            let lane = &mut self.lanes[c];
            if lane.rows.is_empty() {
                // Idle classes bank no credit (the standard DRR rule).
                self.deficit[c] = 0;
                self.cursor = (c + 1) % self.lanes.len();
                continue;
            }
            if self.deficit[c] == 0 {
                self.deficit[c] = self.policy.class_weights[c].max(1);
            }
            let n = (self.deficit[c].min(usize::MAX as u64) as usize)
                .min(take - batch.len())
                .min(lane.rows.len());
            self.deficit[c] -= n as u64;
            self.flushed_rows_class[c] += n as u64;
            batch.extend(lane.rows.drain(..n));
            lane.urgent = lane.urgent.saturating_sub(n);
            if lane.rows.is_empty() {
                self.deficit[c] = 0;
                lane.oldest = None;
                self.cursor = (c + 1) % self.lanes.len();
            } else {
                // Partial drain restarts the lane's max-wait clock (the
                // leftover head is "fresh" again, same as pre-QoS).
                lane.oldest = Some(Instant::now());
                if self.deficit[c] == 0 {
                    // Share spent: move on. Otherwise the batch filled
                    // mid-quantum — stay here for the next take.
                    self.cursor = (c + 1) % self.lanes.len();
                }
            }
        }
        if !batch.is_empty() {
            self.flushed_batches += 1;
            self.flushed_rows += batch.len() as u64;
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row_class(tag: u64, class: QosClass) -> PendingRow {
        PendingRow {
            tag,
            x: StateBuf::detached(vec![0.0; 4]),
            s_from: 0.1,
            s_to: 0.2,
            mask: None,
            guidance: 0.0,
            seed: 0,
            class,
        }
    }

    fn row(tag: u64) -> PendingRow {
        row_class(tag, QosClass::Standard)
    }

    #[test]
    fn queued_rows_share_state_buffers() {
        // Pushing a row must not copy the state: the queued row aliases
        // the producer's buffer via refcount.
        let buf = StateBuf::detached(vec![1.0, 2.0]);
        let mut b = Batcher::new(BatchPolicy::default());
        assert!(b.push(PendingRow {
            tag: 1,
            x: buf.clone(),
            s_from: 0.1,
            s_to: 0.2,
            mask: None,
            guidance: 0.0,
            seed: 0,
            class: QosClass::Standard,
        }));
        assert!(!buf.is_unique(), "queue holds a share, not a copy");
        let batch = b.take_batch();
        assert_eq!(&batch[0].x[..], &[1.0, 2.0]);
    }

    #[test]
    fn stage_rows_flattens_in_fifo_order() {
        let mask: std::sync::Arc<[f32]> = vec![1.0f32, 0.0].into();
        let rows: Vec<PendingRow> = (0..3)
            .map(|i| PendingRow {
                tag: i,
                x: StateBuf::detached(vec![i as f32; 2]),
                s_from: 0.1 * i as f32,
                s_to: 0.1 * i as f32 + 0.05,
                mask: Some(mask.clone()),
                guidance: 7.5,
                seed: i,
                class: QosClass::Standard,
            })
            .collect();
        let mut stage = crate::buf::BatchStage::new();
        stage_rows(&rows, &mut stage);
        assert_eq!(stage.rows(), 3);
        assert_eq!(stage.x(), &[0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
        // Restaging reuses the same buffers and replaces the contents.
        stage_rows(&rows[..1], &mut stage);
        assert_eq!(stage.rows(), 1);
    }

    #[test]
    fn fills_largest_bucket_first() {
        let mut b = Batcher::new(BatchPolicy { buckets: vec![4, 2, 1], max_wait: Duration::from_secs(10), max_queue: 100, class_weights: DEFAULT_CLASS_WEIGHTS });
        for i in 0..5 {
            assert!(b.push(row(i)));
        }
        assert!(b.should_flush());
        let batch = b.take_batch();
        assert_eq!(batch.len(), 4);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn timeout_flushes_partial() {
        let mut b = Batcher::new(BatchPolicy { buckets: vec![8], max_wait: Duration::from_millis(1), max_queue: 100, class_weights: DEFAULT_CLASS_WEIGHTS });
        b.push(row(1));
        assert!(!b.should_flush());
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.should_flush());
        assert_eq!(b.take_batch().len(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn backpressure_at_capacity() {
        let mut b = Batcher::new(BatchPolicy { buckets: vec![2], max_wait: Duration::from_secs(1), max_queue: 2, class_weights: DEFAULT_CLASS_WEIGHTS });
        assert!(b.push(row(1)));
        assert!(b.push(row(2)));
        assert!(!b.push(row(3)), "queue full must refuse");
    }

    #[test]
    fn max_wait_runs_from_first_push() {
        // `oldest` tracks the first queued row, not the last: a steady
        // trickle of new rows must not starve the head of the queue.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![8],
            max_wait: Duration::from_millis(5),
            max_queue: 100,
            class_weights: DEFAULT_CLASS_WEIGHTS,
        });
        b.push(row(1));
        std::thread::sleep(Duration::from_millis(7));
        b.push(row(2)); // newer row; head has already expired
        assert!(b.should_flush(), "expiry is measured from the oldest row");
    }

    #[test]
    fn partial_drain_resets_oldest() {
        // 3 rows over a 2-bucket: take_batch() drains 2 and must restart
        // the max-wait clock for the remainder — the leftover row is
        // "fresh" again, not instantly expired.
        // A generous window: the !should_flush assert below only flakes
        // if the test thread is preempted for more than max_wait between
        // two adjacent statements.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![2],
            max_wait: Duration::from_millis(1000),
            max_queue: 100,
            class_weights: DEFAULT_CLASS_WEIGHTS,
        });
        for i in 0..3 {
            b.push(row(i));
        }
        assert!(b.should_flush(), "bucket full");
        assert_eq!(b.take_batch().len(), 2);
        assert_eq!(b.pending(), 1);
        assert!(
            !b.should_flush(),
            "leftover row got a fresh max-wait clock on partial drain"
        );
        std::thread::sleep(Duration::from_millis(1100));
        assert!(b.should_flush(), "leftover row expires after a full max_wait");
    }

    #[test]
    fn full_drain_clears_oldest() {
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![4],
            max_wait: Duration::from_millis(1),
            max_queue: 100,
            class_weights: DEFAULT_CLASS_WEIGHTS,
        });
        b.push(row(1));
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.should_flush());
        assert_eq!(b.take_batch().len(), 1);
        assert_eq!(b.pending(), 0);
        // Empty queue: no oldest row, so the expiry clause can never fire.
        std::thread::sleep(Duration::from_millis(3));
        assert!(!b.should_flush(), "empty batcher must not flush");
        assert_eq!(b.flushed_batches, 1);
        assert_eq!(b.flushed_rows, 1);
    }

    #[test]
    fn fifo_order_preserved() {
        // A single bucket of 4: three pending rows drain whole (timeout
        // fallback) and in push order.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![4],
            max_wait: Duration::from_millis(1),
            max_queue: 100,
            class_weights: DEFAULT_CLASS_WEIGHTS,
        });
        for i in 0..3 {
            b.push(row(i));
        }
        std::thread::sleep(Duration::from_millis(3));
        let batch = b.take_batch();
        let tags: Vec<u64> = batch.iter().map(|r| r.tag).collect();
        assert_eq!(tags, vec![0, 1, 2]);
    }

    #[test]
    fn take_batch_prefers_largest_fitting_bucket() {
        // 11 pending over {8, 4, 2}: 8 is the largest completely-fillable
        // bucket — not 11 rows, and not the max bucket unconditionally.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![8, 4, 2],
            max_wait: Duration::from_secs(10),
            max_queue: 100,
            class_weights: DEFAULT_CLASS_WEIGHTS,
        });
        for i in 0..11 {
            b.push(row(i));
        }
        assert_eq!(b.take_batch().len(), 8);
        // 3 left: only the 2-bucket fits.
        assert_eq!(b.take_batch().len(), 2);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn timeout_flush_falls_back_below_smallest_bucket() {
        // 3 pending rows, smallest bucket 4: nothing fills a bucket, so a
        // timeout flush drains all 3 (padded downstream to the 4-bucket)
        // instead of starving the queue head forever.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![8, 4],
            max_wait: Duration::from_millis(1),
            max_queue: 100,
            class_weights: DEFAULT_CLASS_WEIGHTS,
        });
        for i in 0..3 {
            b.push(row(i));
        }
        assert!(!b.should_flush(), "no full bucket yet");
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.should_flush(), "max_wait expired");
        assert_eq!(b.take_batch().len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn urgent_rows_jump_the_queue_fifo_among_themselves() {
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![4, 2, 1],
            max_wait: Duration::from_secs(10),
            max_queue: 4,
            class_weights: DEFAULT_CLASS_WEIGHTS,
        });
        assert!(b.push(row(1)));
        assert!(b.push(row(2)));
        // Two urgent rows: both jump the normal rows, and keep their own
        // submission order (8 before 9) — no LIFO inversion.
        assert!(b.push_urgent(row(8)));
        assert!(b.push_urgent(row(9)));
        let batch = b.take_batch();
        let tags: Vec<u64> = batch.iter().map(|r| r.tag).collect();
        assert_eq!(tags, vec![8, 9, 1, 2], "urgent head region first, FIFO within");
        // Back-pressure applies to urgent rows too.
        for i in 3..7 {
            assert!(b.push(row(i)));
        }
        assert!(!b.push_urgent(row(99)), "full queue refuses urgent rows as well");
        // Draining past the urgent region resets it: later normal pushes
        // are not mistaken for urgent rows.
        assert_eq!(b.take_batch().len(), 4);
        assert!(b.push_urgent(row(42)));
        assert_eq!(b.take_batch().first().unwrap().tag, 42);
    }

    #[test]
    fn steal_tail_takes_low_priority_tail_and_spares_urgent_heads() {
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![8],
            max_wait: Duration::from_secs(10),
            max_queue: usize::MAX,
            class_weights: DEFAULT_CLASS_WEIGHTS,
        });
        // Interactive lane: one urgent spine row + one normal row.
        assert!(b.push_urgent(row_class(1, QosClass::Interactive)));
        assert!(b.push(row_class(2, QosClass::Interactive)));
        // Batch lane: one urgent spine row + three normal rows.
        assert!(b.push_urgent(row_class(10, QosClass::Batch)));
        for t in 11..14 {
            assert!(b.push(row_class(t, QosClass::Batch)));
        }
        // Steal 4: the batch lane's non-urgent tail donates first (in
        // FIFO order), then the interactive tail — never an urgent head.
        let stolen: Vec<u64> = b.steal_tail(4).iter().map(|r| r.tag).collect();
        assert_eq!(stolen, vec![11, 12, 13, 2]);
        assert_eq!(b.pending_class(QosClass::Interactive), 1);
        assert_eq!(b.pending_class(QosClass::Batch), 1);
        // Only urgent heads remain: even an unbounded steal gets nothing.
        assert!(b.steal_tail(usize::MAX).is_empty());
        assert_eq!(b.pending(), 2);
        // The survivors are exactly the two spine rows, still urgent.
        let rest: Vec<u64> = b.take_batch().iter().map(|r| r.tag).collect();
        assert_eq!(rest.len(), 2);
        assert!(rest.contains(&1) && rest.contains(&10), "urgent spines survived: {rest:?}");
    }

    #[test]
    fn purge_removes_matching_rows_and_returns_them() {
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![8],
            max_wait: Duration::from_millis(1),
            max_queue: 100,
            class_weights: DEFAULT_CLASS_WEIGHTS,
        });
        for i in 0..5 {
            b.push(row(i));
        }
        let dead = b.purge(|r| r.tag % 2 == 0);
        let dead_tags: Vec<u64> = dead.iter().map(|r| r.tag).collect();
        assert_eq!(dead_tags, vec![1, 3]);
        assert_eq!(b.pending(), 3);
        // Purging everything clears the max-wait clock.
        let dead = b.purge(|_| false);
        assert_eq!(dead.len(), 3);
        assert_eq!(b.pending(), 0);
        std::thread::sleep(Duration::from_millis(3));
        assert!(!b.should_flush(), "empty batcher after purge must not flush");
    }

    #[test]
    fn drr_shares_converge_to_class_weights() {
        // Full contention: both lanes always non-empty. Over many
        // batches the per-class row counts must track the configured
        // weight ratio (8:1 here), not FIFO arrival order.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![8],
            max_wait: Duration::from_secs(10),
            max_queue: usize::MAX,
            class_weights: [8, 3, 1],
        });
        // The flood arrives first: pure FIFO would serve all 400 batch
        // rows before the first interactive one.
        for i in 0..400 {
            assert!(b.push(row_class(i, QosClass::Batch)));
        }
        for i in 1000..1400 {
            assert!(b.push(row_class(i, QosClass::Interactive)));
        }
        let mut served = [0usize; 3];
        for _ in 0..20 {
            for r in b.take_batch() {
                served[r.class.index()] += 1;
            }
        }
        let (inter, batch) = (served[0] as f64, served[2] as f64);
        assert_eq!(inter + batch, 160.0, "20 full 8-buckets drained");
        let ratio = inter / batch.max(1.0);
        assert!(
            (6.0..=10.0).contains(&ratio),
            "interactive:batch service ratio {ratio} should track weight 8:1 ({served:?})"
        );
        assert_eq!(b.flushed_rows_class[0] as usize, served[0]);
        assert_eq!(b.flushed_rows_class[2] as usize, served[2]);
    }

    #[test]
    fn drr_weights_hold_under_tiny_takes() {
        // The engine's spread-first flush often takes ONE row at a time
        // (cap = pending / idle_workers). The cursor must park on a lane
        // until its quantum is spent, or single-row takes would collapse
        // every weight ratio to 1:1:1. With weights 8:1 and both lanes
        // saturated, 108 single-row takes split exactly 96:12.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![1],
            max_wait: Duration::from_secs(10),
            max_queue: usize::MAX,
            class_weights: [8, 3, 1],
        });
        for i in 0..120 {
            assert!(b.push(row_class(i, QosClass::Interactive)));
            assert!(b.push(row_class(1000 + i, QosClass::Batch)));
        }
        let mut served = [0usize; 3];
        for _ in 0..108 {
            let batch = b.take_up_to(1);
            assert_eq!(batch.len(), 1);
            served[batch[0].class.index()] += 1;
        }
        assert_eq!(
            served,
            [96, 0, 12],
            "single-row takes must still honor the 8:1 weight ratio exactly"
        );
    }

    #[test]
    fn no_class_starves_under_flood() {
        // The fairness invariant: with any weights (every weight >= 1
        // after clamping), a flooded lane still progresses every DRR
        // cycle — here the *batch* lane under an interactive flood, the
        // inverse of the usual worry.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![4],
            max_wait: Duration::from_secs(10),
            max_queue: usize::MAX,
            class_weights: [8, 3, 0], // 0 clamps to 1: starvation unconfigurable
        });
        for i in 0..100 {
            assert!(b.push(row_class(i, QosClass::Interactive)));
        }
        assert!(b.push(row_class(999, QosClass::Batch)));
        let mut drained_batch_row_at = None;
        for k in 0..10 {
            if b.take_batch().iter().any(|r| r.class == QosClass::Batch) {
                drained_batch_row_at = Some(k);
                break;
            }
        }
        // Weight 8 vs 1 over 4-row batches: the batch row must surface
        // within the first few cycles (bounded queue age), never "after
        // the flood drains".
        let at = drained_batch_row_at.expect("batch row starved through 10 batches");
        assert!(at <= 2, "batch row waited {at} batches under clamped weight");
    }

    #[test]
    fn interactive_head_bounded_under_batch_flood() {
        // One tenant floods batch rows and keeps feeding them; a late
        // interactive row still rides the very next batch (its lane's
        // deficit recharges on first visit). This is the bounded-queue-
        // age half of the ISSUE invariant at the batcher level.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![8],
            max_wait: Duration::from_secs(10),
            max_queue: usize::MAX,
            class_weights: DEFAULT_CLASS_WEIGHTS,
        });
        for i in 0..64 {
            assert!(b.push(row_class(i, QosClass::Batch)));
        }
        // Warm the DRR state mid-flood, as a live engine would.
        assert_eq!(b.take_batch().len(), 8);
        assert!(b.push(row_class(777, QosClass::Interactive)));
        let next: Vec<u64> = b.take_batch().iter().map(|r| r.tag).collect();
        assert!(next.contains(&777), "interactive row missed the next batch: {next:?}");
    }

    #[test]
    fn single_class_drains_exactly_like_pre_qos_fifo() {
        // With one class in play the DRR degenerates to the old single
        // queue: FIFO order, urgent head region first, bucket
        // quantization unchanged — the "bit-identical single-class
        // traffic" half of the QoS contract, at the row-order level.
        for class in QosClass::ALL {
            let mut b = Batcher::new(BatchPolicy {
                buckets: vec![4, 2, 1],
                max_wait: Duration::from_secs(10),
                max_queue: 100,
                class_weights: [8, 3, 1],
            });
            for i in 0..5 {
                assert!(b.push(row_class(i, class)));
            }
            assert!(b.push_urgent(row_class(100, class)));
            let tags: Vec<u64> = b.take_batch().iter().map(|r| r.tag).collect();
            assert_eq!(tags, vec![100, 0, 1, 2], "{class:?}: urgent head then FIFO");
            let tags: Vec<u64> = b.take_batch().iter().map(|r| r.tag).collect();
            assert_eq!(tags, vec![3, 4], "{class:?}: remainder in order");
        }
    }

    #[test]
    fn per_class_max_wait_clocks_are_independent() {
        // A fresh interactive row must not inherit the batch lane's
        // expired clock, and an expired batch head must flush even while
        // interactive churns.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![8],
            max_wait: Duration::from_millis(5),
            max_queue: 100,
            class_weights: DEFAULT_CLASS_WEIGHTS,
        });
        b.push(row_class(1, QosClass::Batch));
        std::thread::sleep(Duration::from_millis(7));
        b.push(row_class(2, QosClass::Interactive));
        assert!(b.should_flush(), "expired batch head flushes despite fresh interactive row");
        // Drain everything; fresh pushes restart per-lane clocks.
        b.take_batch();
        b.push(row_class(3, QosClass::Interactive));
        assert!(!b.should_flush(), "fresh interactive lane has its own clock");
    }

    #[test]
    fn take_up_to_caps_then_bucket_quantizes() {
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![8, 4, 2, 1],
            max_wait: Duration::from_secs(10),
            max_queue: 100,
            class_weights: DEFAULT_CLASS_WEIGHTS,
        });
        for i in 0..10 {
            b.push(row(i));
        }
        // Cap 3 → largest bucket ≤ 3 is 2.
        assert_eq!(b.take_up_to(3).len(), 2);
        // Cap larger than pending → plain bucket preference over pending.
        assert_eq!(b.take_up_to(100).len(), 8);
        assert_eq!(b.pending(), 0);
        // Draining an empty queue is not a flushed batch.
        let before = b.flushed_batches;
        assert!(b.take_up_to(4).is_empty());
        assert_eq!(b.flushed_batches, before);
    }
}
