//! Dynamic batching: coalesce concurrently-pending step work into
//! bucketed batch sizes (the request-level complement of SRDS's
//! within-sample batching from §3.4).
//!
//! The engine collects step rows from multiple in-flight sampler tasks
//! (`crate::exec::task` — every registered sampler emits its steps as
//! rows here, whole sweeps at a time for the window/trajectory
//! samplers) for up to `max_wait` and flushes when a bucket fills —
//! classic vLLM-router-style batching adapted to diffusion steps.

use crate::buf::{BatchStage, StateBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One row of pending step work (request-agnostic payload).
///
/// Zero-copy: the state is a refcounted [`StateBuf`] (queueing a row
/// shares the producer's buffer, it does not copy it) and the mask is an
/// `Arc` slice shared by every row of a request — a `clone()` of the row
/// is two refcount bumps, no float moves.
#[derive(Debug, Clone)]
pub struct PendingRow {
    /// Opaque owner tag (request id, block id, …).
    pub tag: u64,
    pub x: StateBuf,
    pub s_from: f32,
    pub s_to: f32,
    pub mask: Option<Arc<[f32]>>,
    pub guidance: f32,
    pub seed: u64,
}

/// Assemble `rows` into `stage` (cleared first): the flat `(b, dim)`
/// states, per-row times/seeds and the concatenated masks, ready for one
/// [`crate::solvers::StepBackend::step_into`] call. All rows must share
/// one guidance weight and maskedness — the engine's batch key
/// guarantees exactly that.
pub fn stage_rows(rows: &[PendingRow], stage: &mut BatchStage) {
    stage.reset(rows.first().map(|r| r.guidance).unwrap_or(0.0));
    for r in rows {
        stage.push_row(&r.x, r.s_from, r.s_to, r.seed, r.mask.as_deref());
    }
}

/// Batch assembly policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Available batch sizes, descending preference (from the artifact
    /// manifest's `batch_buckets`).
    pub buckets: Vec<usize>,
    /// Flush incomplete batches after this long.
    pub max_wait: Duration,
    /// Hard cap on queued rows before back-pressuring producers.
    pub max_queue: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // A full power-of-two ladder rather than the sparse {32, 8, 1}:
        // with bucket-preferring drains (see [`Batcher::take_batch`]) a
        // finer ladder wastes less padding and lets the engine size
        // batches close to whatever is actually pending.
        BatchPolicy {
            buckets: vec![32, 16, 8, 4, 2, 1],
            max_wait: Duration::from_millis(2),
            max_queue: 1024,
        }
    }
}

impl BatchPolicy {
    /// Policy for the measured executor: flush immediately (never hold a
    /// row hoping for co-tenants) and never refuse a push — the engine's
    /// dispatcher is the only producer, so back-pressure belongs at the
    /// admission layer above it, not here.
    pub fn immediate() -> Self {
        BatchPolicy { max_wait: Duration::ZERO, max_queue: usize::MAX, ..Self::default() }
    }
}

/// Accumulates rows and decides when a batch should flush.
pub struct Batcher {
    policy: BatchPolicy,
    queue: Vec<PendingRow>,
    /// Length of the critical-path head region: rows `[0, urgent)` were
    /// pushed via [`Self::push_urgent`] and drain before normal rows,
    /// FIFO among themselves.
    urgent: usize,
    oldest: Option<Instant>,
    /// Flush statistics: (batches, rows, padded_rows).
    pub flushed_batches: u64,
    pub flushed_rows: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            queue: Vec::new(),
            urgent: 0,
            oldest: None,
            flushed_batches: 0,
            flushed_rows: 0,
        }
    }

    /// Push a row; returns `false` (back-pressure) when the queue is full.
    pub fn push(&mut self, row: PendingRow) -> bool {
        if self.queue.len() >= self.policy.max_queue {
            return false;
        }
        if self.queue.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.queue.push(row);
        true
    }

    /// Push a critical-path row into the queue's *urgent head region* so
    /// it drains before every normal row (FIFO among urgent rows). The
    /// engine marks SRDS coarse steps urgent: the G chain is the serial
    /// spine of the schedule (Prop. 2), and speculative fine work queued
    /// earlier must not delay it — the FIFO-queue analogue of the old
    /// worker pool's priority heap.
    pub fn push_urgent(&mut self, row: PendingRow) -> bool {
        if self.queue.len() >= self.policy.max_queue {
            return false;
        }
        if self.queue.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.queue.insert(self.urgent, row);
        self.urgent += 1;
        true
    }

    /// Remove every queued row failing `keep` (dead-request purge) and
    /// return the removed rows, preserving order among the kept ones.
    pub fn purge<F: FnMut(&PendingRow) -> bool>(&mut self, mut keep: F) -> Vec<PendingRow> {
        let urgent_was = self.urgent;
        let mut removed = Vec::new();
        let mut kept = Vec::with_capacity(self.queue.len());
        let mut kept_urgent = 0usize;
        for (idx, r) in self.queue.drain(..).enumerate() {
            if keep(&r) {
                if idx < urgent_was {
                    kept_urgent += 1;
                }
                kept.push(r);
            } else {
                removed.push(r);
            }
        }
        self.queue = kept;
        self.urgent = kept_urgent;
        if self.queue.is_empty() {
            self.oldest = None;
        }
        removed
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    fn max_bucket(&self) -> usize {
        self.policy.buckets.iter().copied().max().unwrap_or(1)
    }

    /// Whether a flush should happen now: the largest bucket is full, or
    /// the oldest queued row has waited past `max_wait`.
    pub fn should_flush(&self) -> bool {
        if self.queue.len() >= self.max_bucket() {
            return true;
        }
        match self.oldest {
            Some(t) => !self.queue.is_empty() && t.elapsed() >= self.policy.max_wait,
            None => false,
        }
    }

    /// Remove and return the next batch (rows in FIFO order), honoring
    /// the descending `buckets` preference list: the largest bucket that
    /// the pending rows can *fill completely* wins. When even the
    /// smallest bucket cannot be filled (the timeout-flush case), every
    /// pending row is drained — a sub-bucket remainder that the runtime's
    /// bucket plan pads up to the smallest compiled size.
    pub fn take_batch(&mut self) -> Vec<PendingRow> {
        self.take_up_to(usize::MAX)
    }

    /// [`Self::take_batch`] with an additional caller-imposed cap on the
    /// batch size. The engine uses this to *spread* rows across idle
    /// workers instead of fusing everything onto one: the cap is
    /// `ceil(pending / idle_workers)` there, so fusion only grows once
    /// every worker already has work.
    pub fn take_up_to(&mut self, cap: usize) -> Vec<PendingRow> {
        let avail = self.queue.len().min(cap);
        let take = self
            .policy
            .buckets
            .iter()
            .copied()
            .filter(|&b| b <= avail)
            .max()
            // No bucket fits under `avail`: drain it whole (it is below
            // the smallest bucket, so downstream pads it up to one).
            .unwrap_or(avail);
        let batch: Vec<PendingRow> = self.queue.drain(..take).collect();
        self.urgent = self.urgent.saturating_sub(take);
        self.oldest = if self.queue.is_empty() { None } else { Some(Instant::now()) };
        if !batch.is_empty() {
            self.flushed_batches += 1;
            self.flushed_rows += batch.len() as u64;
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(tag: u64) -> PendingRow {
        PendingRow {
            tag,
            x: StateBuf::detached(vec![0.0; 4]),
            s_from: 0.1,
            s_to: 0.2,
            mask: None,
            guidance: 0.0,
            seed: 0,
        }
    }

    #[test]
    fn queued_rows_share_state_buffers() {
        // Pushing a row must not copy the state: the queued row aliases
        // the producer's buffer via refcount.
        let buf = StateBuf::detached(vec![1.0, 2.0]);
        let mut b = Batcher::new(BatchPolicy::default());
        assert!(b.push(PendingRow {
            tag: 1,
            x: buf.clone(),
            s_from: 0.1,
            s_to: 0.2,
            mask: None,
            guidance: 0.0,
            seed: 0,
        }));
        assert!(!buf.is_unique(), "queue holds a share, not a copy");
        let batch = b.take_batch();
        assert_eq!(&batch[0].x[..], &[1.0, 2.0]);
    }

    #[test]
    fn stage_rows_flattens_in_fifo_order() {
        let mask: std::sync::Arc<[f32]> = vec![1.0f32, 0.0].into();
        let rows: Vec<PendingRow> = (0..3)
            .map(|i| PendingRow {
                tag: i,
                x: StateBuf::detached(vec![i as f32; 2]),
                s_from: 0.1 * i as f32,
                s_to: 0.1 * i as f32 + 0.05,
                mask: Some(mask.clone()),
                guidance: 7.5,
                seed: i,
            })
            .collect();
        let mut stage = crate::buf::BatchStage::new();
        stage_rows(&rows, &mut stage);
        assert_eq!(stage.rows(), 3);
        assert_eq!(stage.x(), &[0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
        // Restaging reuses the same buffers and replaces the contents.
        stage_rows(&rows[..1], &mut stage);
        assert_eq!(stage.rows(), 1);
    }

    #[test]
    fn fills_largest_bucket_first() {
        let mut b = Batcher::new(BatchPolicy { buckets: vec![4, 2, 1], max_wait: Duration::from_secs(10), max_queue: 100 });
        for i in 0..5 {
            assert!(b.push(row(i)));
        }
        assert!(b.should_flush());
        let batch = b.take_batch();
        assert_eq!(batch.len(), 4);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn timeout_flushes_partial() {
        let mut b = Batcher::new(BatchPolicy { buckets: vec![8], max_wait: Duration::from_millis(1), max_queue: 100 });
        b.push(row(1));
        assert!(!b.should_flush());
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.should_flush());
        assert_eq!(b.take_batch().len(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn backpressure_at_capacity() {
        let mut b = Batcher::new(BatchPolicy { buckets: vec![2], max_wait: Duration::from_secs(1), max_queue: 2 });
        assert!(b.push(row(1)));
        assert!(b.push(row(2)));
        assert!(!b.push(row(3)), "queue full must refuse");
    }

    #[test]
    fn max_wait_runs_from_first_push() {
        // `oldest` tracks the first queued row, not the last: a steady
        // trickle of new rows must not starve the head of the queue.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![8],
            max_wait: Duration::from_millis(5),
            max_queue: 100,
        });
        b.push(row(1));
        std::thread::sleep(Duration::from_millis(7));
        b.push(row(2)); // newer row; head has already expired
        assert!(b.should_flush(), "expiry is measured from the oldest row");
    }

    #[test]
    fn partial_drain_resets_oldest() {
        // 3 rows over a 2-bucket: take_batch() drains 2 and must restart
        // the max-wait clock for the remainder — the leftover row is
        // "fresh" again, not instantly expired.
        // A generous window: the !should_flush assert below only flakes
        // if the test thread is preempted for more than max_wait between
        // two adjacent statements.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![2],
            max_wait: Duration::from_millis(1000),
            max_queue: 100,
        });
        for i in 0..3 {
            b.push(row(i));
        }
        assert!(b.should_flush(), "bucket full");
        assert_eq!(b.take_batch().len(), 2);
        assert_eq!(b.pending(), 1);
        assert!(
            !b.should_flush(),
            "leftover row got a fresh max-wait clock on partial drain"
        );
        std::thread::sleep(Duration::from_millis(1100));
        assert!(b.should_flush(), "leftover row expires after a full max_wait");
    }

    #[test]
    fn full_drain_clears_oldest() {
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![4],
            max_wait: Duration::from_millis(1),
            max_queue: 100,
        });
        b.push(row(1));
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.should_flush());
        assert_eq!(b.take_batch().len(), 1);
        assert_eq!(b.pending(), 0);
        // Empty queue: no oldest row, so the expiry clause can never fire.
        std::thread::sleep(Duration::from_millis(3));
        assert!(!b.should_flush(), "empty batcher must not flush");
        assert_eq!(b.flushed_batches, 1);
        assert_eq!(b.flushed_rows, 1);
    }

    #[test]
    fn fifo_order_preserved() {
        // A single bucket of 4: three pending rows drain whole (timeout
        // fallback) and in push order.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![4],
            max_wait: Duration::from_millis(1),
            max_queue: 100,
        });
        for i in 0..3 {
            b.push(row(i));
        }
        std::thread::sleep(Duration::from_millis(3));
        let batch = b.take_batch();
        let tags: Vec<u64> = batch.iter().map(|r| r.tag).collect();
        assert_eq!(tags, vec![0, 1, 2]);
    }

    #[test]
    fn take_batch_prefers_largest_fitting_bucket() {
        // 11 pending over {8, 4, 2}: 8 is the largest completely-fillable
        // bucket — not 11 rows, and not the max bucket unconditionally.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![8, 4, 2],
            max_wait: Duration::from_secs(10),
            max_queue: 100,
        });
        for i in 0..11 {
            b.push(row(i));
        }
        assert_eq!(b.take_batch().len(), 8);
        // 3 left: only the 2-bucket fits.
        assert_eq!(b.take_batch().len(), 2);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn timeout_flush_falls_back_below_smallest_bucket() {
        // 3 pending rows, smallest bucket 4: nothing fills a bucket, so a
        // timeout flush drains all 3 (padded downstream to the 4-bucket)
        // instead of starving the queue head forever.
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![8, 4],
            max_wait: Duration::from_millis(1),
            max_queue: 100,
        });
        for i in 0..3 {
            b.push(row(i));
        }
        assert!(!b.should_flush(), "no full bucket yet");
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.should_flush(), "max_wait expired");
        assert_eq!(b.take_batch().len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn urgent_rows_jump_the_queue_fifo_among_themselves() {
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![4, 2, 1],
            max_wait: Duration::from_secs(10),
            max_queue: 4,
        });
        assert!(b.push(row(1)));
        assert!(b.push(row(2)));
        // Two urgent rows: both jump the normal rows, and keep their own
        // submission order (8 before 9) — no LIFO inversion.
        assert!(b.push_urgent(row(8)));
        assert!(b.push_urgent(row(9)));
        let batch = b.take_batch();
        let tags: Vec<u64> = batch.iter().map(|r| r.tag).collect();
        assert_eq!(tags, vec![8, 9, 1, 2], "urgent head region first, FIFO within");
        // Back-pressure applies to urgent rows too.
        for i in 3..7 {
            assert!(b.push(row(i)));
        }
        assert!(!b.push_urgent(row(99)), "full queue refuses urgent rows as well");
        // Draining past the urgent region resets it: later normal pushes
        // are not mistaken for urgent rows.
        assert_eq!(b.take_batch().len(), 4);
        assert!(b.push_urgent(row(42)));
        assert_eq!(b.take_batch().first().unwrap().tag, 42);
    }

    #[test]
    fn purge_removes_matching_rows_and_returns_them() {
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![8],
            max_wait: Duration::from_millis(1),
            max_queue: 100,
        });
        for i in 0..5 {
            b.push(row(i));
        }
        let dead = b.purge(|r| r.tag % 2 == 0);
        let dead_tags: Vec<u64> = dead.iter().map(|r| r.tag).collect();
        assert_eq!(dead_tags, vec![1, 3]);
        assert_eq!(b.pending(), 3);
        // Purging everything clears the max-wait clock.
        let dead = b.purge(|_| false);
        assert_eq!(dead.len(), 3);
        assert_eq!(b.pending(), 0);
        std::thread::sleep(Duration::from_millis(3));
        assert!(!b.should_flush(), "empty batcher after purge must not flush");
    }

    #[test]
    fn take_up_to_caps_then_bucket_quantizes() {
        let mut b = Batcher::new(BatchPolicy {
            buckets: vec![8, 4, 2, 1],
            max_wait: Duration::from_secs(10),
            max_queue: 100,
        });
        for i in 0..10 {
            b.push(row(i));
        }
        // Cap 3 → largest bucket ≤ 3 is 2.
        assert_eq!(b.take_up_to(3).len(), 2);
        // Cap larger than pending → plain bucket preference over pending.
        assert_eq!(b.take_up_to(100).len(), 8);
        assert_eq!(b.pending(), 0);
        // Draining an empty queue is not a flushed batch.
        let before = b.flushed_batches;
        assert!(b.take_up_to(4).is_empty());
        assert_eq!(b.flushed_batches, before);
    }
}
