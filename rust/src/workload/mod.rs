//! Serving workload generation: Poisson request arrivals with "prompt"
//! classes — drives the end-to-end serving example and the throughput
//! bench (the small-batch, latency-sensitive use case the paper's
//! Limitations section motivates).

use crate::data::rng::SplitMix64;

/// One sampling request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival offset from trace start, milliseconds.
    pub arrival_ms: u64,
    /// "Prompt": class id for conditional models, `None` for pixel zoo.
    pub class: Option<u32>,
    /// Denoising steps requested.
    pub n: usize,
    /// Chain seed.
    pub seed: u64,
}

/// Trace generator configuration.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Mean arrival rate, requests/second.
    pub rate_hz: f64,
    pub num_requests: usize,
    pub n_steps: usize,
    pub num_classes: u32,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { rate_hz: 2.0, num_requests: 32, n_steps: 25, num_classes: 4, seed: 7 }
    }
}

/// Generate a Poisson arrival trace (exponential inter-arrival gaps).
pub fn generate_trace(cfg: &TraceConfig) -> Vec<Request> {
    let mut rng = SplitMix64::new(cfg.seed);
    let mut t_ms = 0.0f64;
    let mut out = Vec::with_capacity(cfg.num_requests);
    for id in 0..cfg.num_requests {
        let u = loop {
            let u = rng.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let gap_s = -u.ln() / cfg.rate_hz;
        t_ms += gap_s * 1000.0;
        let class = if cfg.num_classes > 1 {
            Some((rng.next_u64() % cfg.num_classes as u64) as u32)
        } else {
            None
        };
        out.push(Request {
            id: id as u64,
            arrival_ms: t_ms as u64,
            class,
            n: cfg.n_steps,
            seed: cfg.seed ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        });
    }
    out
}

/// One concurrency level's serving-throughput measurement — what the
/// `serving` bench records per client count and emits as JSON, so the
/// perf trajectory has a serving number (requests/sec) next to the
/// engine's fusion number (mean batch occupancy).
#[derive(Debug, Clone)]
pub struct ThroughputPoint {
    /// Concurrent closed-loop clients.
    pub clients: usize,
    /// Total requests completed.
    pub requests: usize,
    /// Wall-clock for the whole level, seconds.
    pub wall_s: f64,
    /// Engine-wide mean rows per flushed batch over the level.
    pub mean_batch_occupancy: f64,
    /// Median request latency, milliseconds.
    pub p50_ms: f64,
    /// 95th-percentile request latency, milliseconds.
    pub p95_ms: f64,
}

impl ThroughputPoint {
    /// Requests per second over the level's wall-clock.
    pub fn rps(&self) -> f64 {
        self.requests as f64 / self.wall_s.max(1e-9)
    }

    pub fn to_json(&self) -> crate::json::Value {
        crate::json::obj(vec![
            ("clients", crate::json::Value::Num(self.clients as f64)),
            ("requests", crate::json::Value::Num(self.requests as f64)),
            ("wall_s", crate::json::Value::Num(self.wall_s)),
            ("rps", crate::json::Value::Num(self.rps())),
            (
                "mean_batch_occupancy",
                crate::json::Value::Num(self.mean_batch_occupancy),
            ),
            ("p50_ms", crate::json::Value::Num(self.p50_ms)),
            ("p95_ms", crate::json::Value::Num(self.p95_ms)),
        ])
    }
}

/// Latency percentiles helper for the serving reports.
///
/// Rounding convention: *nearest rank* over the sorted input —
/// `idx = round((len − 1) · p)`, with `f64::round` ties going away from
/// zero. Consequences worth pinning down (and pinned by the tests):
///
/// * `p = 0.0` → the minimum, `p = 1.0` → the maximum, always.
/// * `p = 0.5` on an even-length list picks the **upper** median
///   (`(len−1)/2` is `x.5`, which rounds up) — there is no interpolation.
/// * A single-element input returns that element for every `p`.
/// * An empty input returns `0.0` (serving reports render it as such
///   rather than panicking on an idle window).
///
/// `p` outside `[0, 1]` is not meaningful; callers pass fixed report
/// quantiles (0.5/0.95/0.99).
pub fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() - 1) as f64 * p).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_ordered() {
        let cfg = TraceConfig::default();
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        assert_eq!(a.len(), cfg.num_requests);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.class, y.class);
        }
        assert!(a.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
    }

    #[test]
    fn mean_rate_roughly_matches() {
        let cfg = TraceConfig { rate_hz: 10.0, num_requests: 2000, ..Default::default() };
        let tr = generate_trace(&cfg);
        let span_s = tr.last().unwrap().arrival_ms as f64 / 1000.0;
        let rate = cfg.num_requests as f64 / span_s;
        assert!((rate - 10.0).abs() < 1.5, "empirical rate {rate}");
    }

    #[test]
    fn classes_in_range() {
        let tr = generate_trace(&TraceConfig { num_classes: 4, ..Default::default() });
        assert!(tr.iter().all(|r| r.class.unwrap() < 4));
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
    }

    #[test]
    fn percentile_rounding_convention() {
        // Even length: p=0.5 lands on (len−1)/2 = 1.5, which rounds away
        // from zero → the upper median, no interpolation.
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        // p=0.95 on 4 elements: 3·0.95 = 2.85 → index 3.
        assert_eq!(percentile(&xs, 0.95), 4.0);

        // Single element: every p returns it.
        let one = [7.5];
        for p in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(percentile(&one, p), 7.5, "p={p}");
        }

        // Empty input: defined as 0.0, not a panic.
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn throughput_point_json_roundtrip() {
        let p = ThroughputPoint {
            clients: 4,
            requests: 32,
            wall_s: 2.0,
            mean_batch_occupancy: 3.5,
            p50_ms: 10.0,
            p95_ms: 20.0,
        };
        assert!((p.rps() - 16.0).abs() < 1e-12);
        let v = crate::json::parse(&crate::json::to_string(&p.to_json())).unwrap();
        assert_eq!(v.get("clients").unwrap().as_f64(), Some(4.0));
        assert_eq!(v.get("rps").unwrap().as_f64(), Some(16.0));
        assert_eq!(v.get("mean_batch_occupancy").unwrap().as_f64(), Some(3.5));
    }

    #[test]
    fn trace_seeds_are_unique_per_request_id() {
        // Chain seeds derive from the request id via an odd-constant
        // wrapping multiply (a bijection on u64), so no two requests of a
        // trace may share a seed — duplicate seeds would silently serve
        // identical samples to different users.
        let cfg = TraceConfig { num_requests: 512, ..Default::default() };
        let tr = generate_trace(&cfg);
        let mut seeds: Vec<u64> = tr.iter().map(|r| r.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), cfg.num_requests, "duplicate chain seeds in trace");
        // And the derivation is stable across runs (serving replays rely
        // on it).
        let again = generate_trace(&cfg);
        assert!(tr.iter().zip(&again).all(|(a, b)| a.seed == b.seed));
    }
}
