//! JSON-line sampling server — the L3 request path.
//!
//! Protocol (one JSON object per line, over TCP; see DESIGN.md's
//! "Wire protocol v1" section for the full frame grammar and field
//! tables):
//!
//! ```json
//! {"v": 1, "id": 1, "sampler": "srds", "n": 25, "class": 2,
//!  "guidance": 7.5, "seed": 42, "tol": 0.0025, "max_iters": 3,
//!  "block": 5, "window": 32, "history": 2, "norm": "l1_mean",
//!  "priority": "interactive", "deadline": 120, "timeout_ms": 250,
//!  "stream": true}
//! ```
//!
//! `"v"` is the protocol version. Absent (or `0`) selects the legacy
//! single-frame dialect: exactly one response object per request, with
//! the historical key set — existing clients never see a new envelope.
//! `"v": 1` selects the framed dialect: every response line carries
//! `{"v": 1, "frame": "ack"|"iterate"|"final"|"error"|"stats", ...}`,
//! unknown top-level request keys become strict errors
//! (`kind: "unknown_field"`), and `"stream": true` is allowed.
//! Request lines are scanned by the lazy field reader
//! ([`crate::json::lazy::LazyObj`]) — field spans are located without
//! building a tree, and only the handful of scalar knobs the server
//! reads are ever materialized; acceptance is bit-compatible with the
//! full parser.
//!
//! `sampler` must name an entry of [`registry`] — unknown names are
//! rejected with an `ok: false` error line rather than silently falling
//! back. The kind-specific knobs (`block` for SRDS, `window` for
//! ParaDiGMS, `history` for ParaTAA) are optional and ignored by
//! samplers they don't apply to. `priority`
//! (`interactive`/`standard`/`batch`, default `standard`) selects the
//! request's QoS lane in the engine's weighted-DRR batcher; `deadline`
//! is the anytime eval budget (model evals) after which SRDS finalizes
//! from its best completed iterate (`deadline_hit: true` in the
//! response) — unset requests inherit
//! [`ServeConfig::default_deadline`]. `timeout_ms` is the wall-clock
//! twin, enforced by the owning shard dispatcher: when it expires, an
//! SRDS request finalizes from its newest completed iterate
//! (`timed_out: true` in the response, honestly reported next to
//! `converged: false`), and a sampler with no anytime iterate to fall
//! back on gets a `kind: "timeout"` error frame instead.
//!
//! `"stream": true` (v1, SRDS only) turns the anytime property into
//! wire traffic. The lifecycle is `ack`, then one `iterate` frame per
//! completed Parareal refinement — each a *valid sample*, published
//! zero-copy from the engine as a refcounted state-buffer share — then
//! exactly one terminal `final` (or `error`) frame:
//!
//! ```json
//! {"v": 1, "frame": "ack", "id": 1, "ok": true, "sampler": "srds", "stream": true}
//! {"v": 1, "frame": "iterate", "id": 1, "ok": true, "iter": 1, "residual": 0.31, "sample": [...]}
//! {"v": 1, "frame": "final", "id": 1, "ok": true, "iters": 2, ...}
//! ```
//!
//! A client that disconnects mid-stream aborts the request inside the
//! engine (liveness flag → dispatcher reap), exactly like the
//! non-streaming path.
//!
//! Response line:
//!
//! ```json
//! {"id": 1, "ok": true, "sampler": "srds", "iters": 2, "converged": true,
//!  "deadline_hit": false, "priority": "interactive",
//!  "eff_serial_evals": 25, "eff_serial_evals_pipelined": 17,
//!  "total_evals": 74, "peak_states": 17, "wall_ms": 12.3,
//!  "batch_occupancy": 3.4, "engine_rows": 74,
//!  "queue_depth": 12, "active_tasks": 3, "flushed_batches": 210,
//!  "split_batches": 4,
//!  "classes": {"interactive": {"active": 1, "completed": 7, "rows": 310,
//!              "mean_wall_ms": 4.2, "deadline_hits": 0}, "standard": {},
//!              "batch": {}},
//!  "sample": [...]}
//! ```
//!
//! A request arriving while the connection is at its in-flight cap is
//! shed immediately with the structured admission error
//! (`{"id": …, "ok": false, "error_kind": "overloaded",
//! "retry_after_ms": …}` — see [`overloaded_response`]) instead of
//! stalling the read loop. A `{"kind": "stats"}` line is the
//! observability probe: it returns the fleet-aggregated engine snapshot
//! (including `shards` / `steals`) without running any sampler and
//! without taking an admission slot, so health checks work even on a
//! saturated connection.
//!
//! `batch_occupancy` / `engine_rows` are per-request fusion stats;
//! `queue_depth` / `active_tasks` / `flushed_batches` /
//! `split_batches` (flush fan-outs across idle workers) are engine-wide
//! snapshots taken at completion (absent when a request is executed
//! off-engine, e.g. via [`run_request`] in unit tests). `active_tasks`
//! is the depth of the engine's heterogeneous task table — how many
//! requests, of any sampler kind, were still resident when this one
//! finished.
//!
//! Every request is dispatched into the sharded engine fleet
//! ([`crate::exec::router`] fronting N [`crate::exec::engine`] shards)
//! as an engine-native [`crate::exec::task::SamplerTask`]: SRDS,
//! sequential, ParaDiGMS and ParaTAA all run as dependency-driven
//! state machines inside a shard's dispatcher, and each solver step
//! becomes a batch row that can fuse with co-tenant requests' rows
//! (`batch_occupancy` in the response reports how much fusion the
//! request actually saw). There are **no per-request threads and no
//! per-connection threads**: one nonblocking poll loop owns every
//! socket (accept, partial-line reassembly, write backpressure), the
//! router places each request onto a shard by load + QoS class, and
//! shard dispatchers steal queued rows from saturated siblings — the
//! process runs exactly `1 + shards × (1 + workers)` threads no matter
//! how many connections or requests are live. A connection that dies
//! flips its requests' liveness flags, and the owning dispatchers
//! abort them (queued rows purged, `aborted` counted) instead of
//! computing results nobody will read. Python is never involved.

use crate::batching::BatchPolicy;
use crate::buf::StateBuf;
use crate::coordinator::{
    prior_sample, registry, Conditioning, ConvNorm, QosClass, SampleOutput, SamplerKind,
    SamplerSpec,
};
use crate::data::make_gmm;
use crate::exec::{
    Engine, EngineStats, IterateEvent, ProgressSink, Router, RouterConfig, TaskReply,
};
use crate::json::{self, lazy::LazyObj, Value};
use crate::solvers::{BackendFactory, StepBackend};
use crate::Result;
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// A parsed sampling request: the sampler name plus every
/// [`SamplerSpec`] knob the wire protocol exposes.
#[derive(Debug, Clone)]
pub struct SampleRequest {
    /// Protocol version (`"v"` on the wire): 0/absent = legacy
    /// single-frame dialect, 1 = framed dialect (envelope on every
    /// response, strict unknown-key rejection, streaming allowed).
    pub v: u64,
    pub id: u64,
    pub sampler: String,
    pub n: usize,
    pub class: Option<u32>,
    pub guidance: f32,
    pub seed: u64,
    pub tol: f32,
    pub norm: ConvNorm,
    pub max_iters: Option<usize>,
    /// SRDS fine steps per block.
    pub block: Option<usize>,
    /// ParaDiGMS sliding window.
    pub window: Option<usize>,
    /// ParaTAA Anderson history depth.
    pub history: Option<usize>,
    /// QoS priority class (`"priority"` on the wire:
    /// `interactive`/`standard`/`batch`; default standard). Scheduling
    /// only — never changes the sample.
    pub priority: QosClass,
    /// Anytime eval budget (`"deadline"` on the wire, in model evals):
    /// SRDS finalizes from its best completed iterate once spent,
    /// reporting `deadline_hit: true` + `converged: false`. `None`
    /// (absent) falls back to [`ServeConfig::default_deadline`] on the
    /// serve loop; an explicit `Some(0)` means *unbudgeted* — the
    /// client's opt-out from the server default.
    pub deadline: Option<u64>,
    /// Wall-clock budget (`"timeout_ms"` on the wire), enforced by the
    /// owning shard dispatcher. On expiry SRDS finalizes from its
    /// newest completed iterate (`timed_out: true` on the response);
    /// kinds with no anytime iterate fail with a `timeout` error
    /// frame. `Some(0)` is legal and expires before the first model
    /// eval — the probe for "what does the coarse init look like".
    pub timeout_ms: Option<u64>,
    /// `"stream": true` (v1 + SRDS only): publish every completed
    /// refinement as an `iterate` frame before the terminal `final`.
    pub stream: bool,
    pub return_sample: bool,
    /// Return the per-refinement final-sample iterates too.
    pub return_iterates: bool,
}

/// Every top-level key the request parser understands. Under `"v"` >= 1
/// the parser is strict: a key outside this set is rejected with a
/// `kind: "unknown_field"` error instead of being silently ignored —
/// a misspelled `"timeout_ms"` must not become an unbudgeted request.
/// (v0 keeps the historical tolerant behavior.)
const KNOWN_KEYS: [&str; 20] = [
    "v",
    "id",
    "kind",
    "sampler",
    "n",
    "class",
    "guidance",
    "seed",
    "tol",
    "norm",
    "max_iters",
    "block",
    "window",
    "history",
    "priority",
    "deadline",
    "timeout_ms",
    "stream",
    "sample",
    "iterates",
];

impl SampleRequest {
    /// Parse a request off the lazy field reader: the line was
    /// structurally scanned once, and only the scalar knobs listed here
    /// are ever materialized into [`Value`]s — the dominant cost of the
    /// old tree parser (allocating every field of every request, used
    /// or not) is gone. Acceptance is bit-compatible with
    /// [`crate::json::parse`] on object lines.
    // lint: request-path
    pub fn from_json(o: &LazyObj) -> std::result::Result<Self, WireError> {
        let num = |k: &str, default: f64| o.num(k).unwrap_or(default);
        let id = num("id", 0.0) as u64;
        // Version gate first: every later error can then be blamed on a
        // version the server actually speaks.
        let v = match o.num("v") {
            None => 0,
            Some(x) if x == 0.0 => 0,
            Some(x) if x == 1.0 => 1,
            Some(x) => {
                return Err(WireError::invalid(
                    id,
                    format!("unsupported protocol version {x} (supported: 0, 1)"),
                ))
            }
        };
        // Strict mode rides the version opt-in: a v1 client asked for
        // the checked dialect, so a key outside the schema is an error,
        // not a silent no-op. v0 keeps the historical tolerance.
        if v >= 1 {
            if let Some(k) = o.keys().find(|k| !KNOWN_KEYS.contains(&k.as_str())) {
                return Err(WireError::unknown_field(id, &k));
            }
        }
        // "kind" selects the request flavor: absent or "sample" is a
        // sampling request (this parser); "stats" is the engine-snapshot
        // probe, which the serving entry points intercept *before*
        // from_json — one reaching here means the caller has no engine
        // to snapshot.
        match o.get("kind").and_then(|x| x.as_str().map(str::to_string)).as_deref() {
            None | Some("sample") => {}
            Some(k) => {
                return Err(WireError::invalid(
                    id,
                    format!(
                        "unsupported kind {k:?} here (\"sample\"; \"stats\" is served by \
                         engine-backed endpoints)"
                    ),
                ))
            }
        }
        let norm = match o.get("norm").and_then(|x| x.as_str().map(str::to_string)) {
            None => ConvNorm::L1Mean,
            Some(s) => ConvNorm::parse(&s).ok_or_else(|| {
                WireError::invalid(id, format!("unknown norm {s:?} (l1_mean/l2_mean/linf)"))
            })?,
        };
        // Unknown priority names are an error, not a silent downgrade to
        // standard — a tenant must know its interactive flag didn't take.
        let priority = match o.get("priority").and_then(|x| x.as_str().map(str::to_string)) {
            None => QosClass::Standard,
            Some(s) => QosClass::parse(&s).ok_or_else(|| {
                WireError::invalid(id, format!("unknown priority {s:?} (interactive/standard/batch)"))
            })?,
        };
        // Budget semantics: absent → inherit the server's default;
        // explicit 0 → opt OUT of any budget (the escape hatch a
        // convergence-critical client needs when the operator set
        // --default-deadline); >= 1 → that many model evals. Negative
        // is rejected rather than degraded (the f64 → u64 cast would
        // saturate to a coarse-init-only run no client can have meant).
        let deadline = match o.num("deadline") {
            None => None,
            Some(d) if d >= 0.0 => Some(d as u64),
            Some(d) => {
                return Err(WireError::invalid(
                    id,
                    format!("deadline must be >= 0 (0 = explicitly unbudgeted), got {d}"),
                ))
            }
        };
        // Unlike deadline, 0 is not an opt-out here: a zero wall-clock
        // budget expires before the first model eval, which is exactly
        // what it says. Negative is rejected for the same
        // cast-saturation reason as deadline.
        let timeout_ms = match o.num("timeout_ms") {
            None => None,
            Some(t) if t >= 0.0 => Some(t as u64),
            Some(t) => {
                return Err(WireError::invalid(
                    id,
                    format!("timeout_ms must be >= 0 (0 = expires immediately), got {t}"),
                ))
            }
        };
        let stream = o.get("stream").and_then(|x| x.as_bool()).unwrap_or(false);
        if stream && v == 0 {
            return Err(WireError::invalid(
                id,
                "\"stream\": true requires the framed dialect (\"v\": 1)".to_string(),
            ));
        }
        Ok(SampleRequest {
            v,
            id,
            sampler: o
                .get("sampler")
                .and_then(|x| x.as_str().map(str::to_string))
                .unwrap_or_else(|| "srds".to_string()),
            n: num("n", 25.0) as usize,
            class: o.num("class").map(|c| c as u32),
            guidance: num("guidance", 0.0) as f32,
            seed: num("seed", 0.0) as u64,
            tol: num("tol", 2.5e-3) as f32,
            norm,
            max_iters: o.get("max_iters").and_then(|x| x.as_usize()),
            block: o.get("block").and_then(|x| x.as_usize()),
            window: o.get("window").and_then(|x| x.as_usize()),
            history: o.get("history").and_then(|x| x.as_usize()),
            priority,
            deadline,
            timeout_ms,
            stream,
            return_sample: o.get("sample").and_then(|x| x.as_bool()).unwrap_or(true),
            return_iterates: o.get("iterates").and_then(|x| x.as_bool()).unwrap_or(false),
        })
    }

    /// Build the [`SamplerSpec`] this request describes, given the
    /// sampler's default kind and the request's conditioning.
    pub fn to_spec(&self, kind: crate::coordinator::SamplerKind, cond: Conditioning) -> SamplerSpec {
        let mut kind = kind;
        if let Some(w) = self.window {
            kind = kind.with_window(w);
        }
        if let Some(h) = self.history {
            kind = kind.with_history(h);
        }
        let mut spec = SamplerSpec::for_kind(self.n, kind)
            .with_tol(self.tol)
            .with_norm(self.norm)
            .with_seed(self.seed)
            .with_cond(cond);
        spec.block = self.block;
        spec.max_iters = self.max_iters;
        spec.keep_iterates = self.return_iterates;
        spec.priority = self.priority;
        // An explicit 0 is the opt-out: no budget, even when the serve
        // loop injected the server default into `deadline`.
        spec.deadline_evals = self.deadline.filter(|&d| d > 0);
        // Wall-clock twin (0 is NOT an opt-out here — it expires
        // immediately) and the streaming flag; both enforced by the
        // engine dispatcher, neither changes a converged sample.
        spec.timeout_ms = self.timeout_ms;
        spec.stream = self.stream;
        spec
    }
}

/// Machine-readable classification of every way the server can refuse
/// or abandon a request. One enum — there is no reject path that
/// bypasses it, so a new failure mode is a new variant here plus a row
/// in DESIGN.md's `wire-error-kinds` table, never an ad-hoc object.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrKind {
    /// The line is not valid JSON, or not a JSON object.
    Parse,
    /// Well-formed but unserviceable: unknown sampler, bad norm, bad
    /// priority, out-of-range knob, unsupported protocol version…
    Invalid,
    /// Strict mode (`"v"` >= 1): a top-level key outside the request
    /// schema.
    UnknownField,
    /// Admission control: the connection is at its in-flight cap.
    Overloaded,
    /// `timeout_ms` expired on a sampler with no anytime iterate to
    /// finalize from (SRDS never takes this path — it degrades to its
    /// newest iterate and reports `timed_out: true` on a success
    /// frame).
    Timeout,
}

/// The wire name of each error kind (the `kind` field of v1 `error`
/// frames, `error_kind` at v0). The match arms below are the source of
/// truth for DESIGN.md's `wire-error-kinds` table — srds-lint reads the
/// literals out of this function's body.
// lint: request-path
fn kind_name(k: ErrKind) -> &'static str {
    match k {
        ErrKind::Parse => "parse",
        ErrKind::Invalid => "invalid",
        ErrKind::UnknownField => "unknown_field",
        ErrKind::Overloaded => "overloaded",
        ErrKind::Timeout => "timeout",
    }
}

/// A typed refusal on its way to the wire: every reject path in the
/// module builds one of these and serializes it through
/// [`error_frame`] — the shape of an error line is decided in exactly
/// one place.
#[derive(Debug, Clone)]
pub struct WireError {
    /// Echoed request id; `None` only when the line was malformed
    /// beyond extracting one.
    pub id: Option<u64>,
    pub kind: ErrKind,
    /// Human-readable diagnosis. Not a contract — clients key on
    /// `kind`.
    pub detail: String,
    /// Backoff hint, carried by sheds ([`ErrKind::Overloaded`]).
    pub retry_after_ms: Option<u64>,
    /// The in-flight cap the request hit, carried by sheds.
    pub max_inflight: Option<usize>,
}

impl WireError {
    /// Malformed line: no id to echo.
    pub fn parse(detail: String) -> WireError {
        WireError { id: None, kind: ErrKind::Parse, detail, retry_after_ms: None, max_inflight: None }
    }

    pub fn invalid(id: u64, detail: String) -> WireError {
        WireError { id: Some(id), kind: ErrKind::Invalid, detail, retry_after_ms: None, max_inflight: None }
    }

    pub fn unknown_field(id: u64, key: &str) -> WireError {
        WireError {
            id: Some(id),
            kind: ErrKind::UnknownField,
            detail: format!("unknown request field {key:?} (strict mode: \"v\" >= 1)"),
            retry_after_ms: None,
            max_inflight: None,
        }
    }

    pub fn overloaded(id: u64, max_inflight: usize, retry_after_ms: u64) -> WireError {
        WireError {
            id: Some(id),
            kind: ErrKind::Overloaded,
            detail: format!(
                "overloaded: connection already has {max_inflight} requests in flight; \
                 back off and retry"
            ),
            retry_after_ms: Some(retry_after_ms),
            max_inflight: Some(max_inflight),
        }
    }

    pub fn timeout(id: u64, timeout_ms: Option<u64>) -> WireError {
        WireError {
            id: Some(id),
            kind: ErrKind::Timeout,
            detail: format!(
                "timed out after {} ms with no anytime iterate to finalize from \
                 (only srds degrades to a partial sample)",
                timeout_ms.unwrap_or(0)
            ),
            retry_after_ms: None,
            max_inflight: None,
        }
    }
}

/// Default backoff hint carried by overloaded error frames
/// (`retry_after_ms`): a couple of typical small-request service times
/// — long enough that an immediate resend is unlikely to be shed
/// again, short enough not to idle an interactive client. A hint, not
/// a contract: clients may retry sooner and risk another shed.
pub const DEFAULT_RETRY_AFTER_MS: u64 = 25;

/// The v1 frame envelope: every framed response line leads with the
/// protocol version and its frame discriminator.
// lint: request-path
fn frame_head(v: u64, frame: &str) -> Vec<(&'static str, Value)> {
    vec![
        ("v", Value::Num(v as f64)),
        ("frame", Value::Str(frame.to_string())),
    ]
}

/// Stamp the v1 envelope onto a response body. v0 callers never reach
/// this — the legacy dialect has no envelope.
// lint: request-path
fn with_envelope(body: Value, v: u64, frame: &str) -> Value {
    match body {
        Value::Obj(mut m) => {
            for (k, val) in frame_head(v, frame) {
                m.insert(k.to_string(), val);
            }
            Value::Obj(m)
        }
        other => other,
    }
}

/// The streaming handshake (v1 only): the request was admitted, its
/// sampler resolved, and `iterate` frames will follow.
// lint: request-path
fn ack_frame(id: u64, sampler: &str) -> Value {
    let body = json::obj(vec![
        ("id", Value::Num(id as f64)),
        ("ok", Value::Bool(true)),
        ("sampler", Value::Str(sampler.to_string())),
        ("stream", Value::Bool(true)),
    ]);
    with_envelope(body, 1, "ack")
}

/// One streamed anytime iterate (v1 only): refinement index, its
/// convergence residual, and — unless the request opted out with
/// `"sample": false` — the full sample this iterate would return if it
/// were the last.
// lint: request-path
fn iterate_frame(id: u64, iter: usize, residual: f32, sample: Option<&[f32]>) -> Value {
    let mut pairs = vec![
        ("id", Value::Num(id as f64)),
        ("ok", Value::Bool(true)),
        ("iter", Value::Num(iter as f64)),
        ("residual", Value::Num(residual as f64)),
    ];
    if let Some(s) = sample {
        pairs.push(("sample", json::arr_f32(s)));
    }
    with_envelope(json::obj(pairs), 1, "iterate")
}

/// THE error serializer: every refusal in the module goes through here,
/// shaped by the request's protocol version. v0 reproduces the legacy
/// key sets byte-for-byte (`{ok, error}` for parse errors,
/// `{id, ok, error}` for validation, the structured
/// `{id, ok, error_kind, error, max_inflight, retry_after_ms}` shed);
/// v1 wraps the typed form — `kind` plus the optional backoff fields —
/// in the frame envelope.
// lint: request-path
pub fn error_frame(e: &WireError, v: u64) -> Value {
    let mut pairs: Vec<(&'static str, Value)> = Vec::new();
    if let Some(id) = e.id {
        pairs.push(("id", Value::Num(id as f64)));
    }
    pairs.push(("ok", Value::Bool(false)));
    pairs.push(("error", Value::Str(e.detail.clone())));
    if v == 0 {
        // Legacy dialect: parse/validation errors carry no kind field
        // (the historical shape); structured kinds ride `error_kind`.
        if !matches!(e.kind, ErrKind::Parse | ErrKind::Invalid) {
            pairs.push(("error_kind", Value::Str(kind_name(e.kind).into())));
        }
    } else {
        pairs.push(("kind", Value::Str(kind_name(e.kind).into())));
    }
    if let Some(m) = e.max_inflight {
        pairs.push(("max_inflight", Value::Num(m as f64)));
    }
    if let Some(ms) = e.retry_after_ms {
        pairs.push(("retry_after_ms", Value::Num(ms as f64)));
    }
    let body = json::obj(pairs);
    if v == 0 {
        body
    } else {
        with_envelope(body, v, "error")
    }
}

/// Back-compat veneer over [`error_frame`] for the legacy (v0) shed
/// line — the admission-control error clients key their backoff on.
// lint: request-path
pub fn overloaded_response(id: u64, max_inflight: usize, retry_after_ms: u64) -> Value {
    error_frame(&WireError::overloaded(id, max_inflight, retry_after_ms), 0)
}

/// Conditioning for a request: the mask comes from the dataset zoo when
/// the model is a conditional GMM.
// lint: request-path
fn request_cond(model_name: &str, req: &SampleRequest) -> Conditioning {
    match req.class {
        Some(c) if model_name.contains("latent_cond") => {
            let gmm = make_gmm("latent_cond");
            Conditioning::class(gmm.class_mask(c), req.guidance)
        }
        _ => Conditioning::none(),
    }
}

/// Resolve the request's sampler kind and build its validated spec, or
/// the typed error to send back.
// lint: request-path
fn request_spec(model_name: &str, req: &SampleRequest) -> std::result::Result<SamplerSpec, WireError> {
    let reg = registry();
    let Some(sampler) = reg.parse(&req.sampler) else {
        return Err(WireError::invalid(
            req.id,
            format!(
                "unknown sampler {:?}; available: {}",
                req.sampler,
                reg.list().join(", ")
            ),
        ));
    };
    // Streaming needs the anytime property: only the SRDS task
    // publishes a valid sample per completed refinement. The baselines'
    // iterates are whole-sweep refinements with no per-iterate
    // completion hook, so `"stream"` on them is an error, not a silent
    // single-frame downgrade.
    if req.stream && !matches!(sampler.kind(), SamplerKind::Srds) {
        return Err(WireError::invalid(
            req.id,
            format!(
                "\"stream\": true requires an anytime sampler (srds); {:?} has no \
                 per-iterate samples to stream",
                req.sampler
            ),
        ));
    }
    let spec = req.to_spec(sampler.kind(), request_cond(model_name, req));
    // A range error must be an error line, not a worker-thread panic.
    if let Err(msg) = spec.validate() {
        return Err(WireError::invalid(req.id, msg));
    }
    Ok(spec)
}

/// Serialize a completed run; `engine` adds the engine-wide snapshot
/// fields next to the per-request ones in `out.stats` (the snapshot is
/// taken at completion — for callback-submitted requests the engine's
/// dispatcher provides it consistently at finalize time).
// lint: request-path
fn success_response(
    req: &SampleRequest,
    sampler_name: &str,
    out: &SampleOutput,
    wall_ms: f64,
    engine: Option<&EngineStats>,
) -> Value {
    let mut pairs = vec![
        ("id", Value::Num(req.id as f64)),
        ("ok", Value::Bool(true)),
        ("sampler", Value::Str(sampler_name.to_string())),
        ("iters", Value::Num(out.stats.iters as f64)),
        ("converged", Value::Bool(out.stats.converged)),
        ("deadline_hit", Value::Bool(out.stats.deadline_hit)),
        // Wall-clock twin of deadline_hit: the dispatcher's timeout
        // fired and SRDS finalized from its newest completed iterate.
        ("timed_out", Value::Bool(out.stats.timed_out)),
        ("priority", Value::Str(req.priority.name().into())),
        ("eff_serial_evals", Value::Num(out.stats.eff_serial_evals as f64)),
        (
            "eff_serial_evals_pipelined",
            Value::Num(out.stats.eff_serial_evals_pipelined as f64),
        ),
        ("total_evals", Value::Num(out.stats.total_evals as f64)),
        ("peak_states", Value::Num(out.stats.peak_states as f64)),
        // State-buffer pool accounting (run-local for direct runs,
        // engine-pool snapshot for engine-resident tasks): steady-state
        // zero allocation shows up as flat pool_misses across responses.
        ("pool_hits", Value::Num(out.stats.pool_hits as f64)),
        ("pool_misses", Value::Num(out.stats.pool_misses as f64)),
        ("wall_ms", Value::Num(wall_ms)),
    ];
    if let Some(st) = engine {
        pairs.push(("batch_occupancy", Value::Num(out.stats.batch_occupancy)));
        pairs.push(("engine_rows", Value::Num(out.stats.engine_rows as f64)));
        pairs.push(("queue_depth", Value::Num(st.queue_depth as f64)));
        pairs.push(("active_tasks", Value::Num(st.active_tasks as f64)));
        pairs.push(("flushed_batches", Value::Num(st.flushed_batches as f64)));
        pairs.push(("split_batches", Value::Num(st.split_batches as f64)));
        // Fleet shape: shard count and cross-shard row migrations
        // (stolen rows execute on a sibling's workers — scheduling
        // only, never a value change).
        pairs.push(("shards", Value::Num(st.shards as f64)));
        pairs.push(("steals", Value::Num(st.steals as f64)));
        pairs.push(("pool_high_water", Value::Num(st.pool_high_water as f64)));
        // Shared-work layer: coarse-spine cache traffic and in-flight
        // coalesced duplicates, fleet-aggregated.
        pairs.push(("cache_hits", Value::Num(st.cache_hits as f64)));
        pairs.push(("cache_misses", Value::Num(st.cache_misses as f64)));
        pairs.push(("cache_evictions", Value::Num(st.cache_evictions as f64)));
        pairs.push(("coalesced", Value::Num(st.coalesced as f64)));
        // Per-QoS-class lanes (snapshot at completion): the operator's
        // starvation dashboard, one object per class. (stats_response
        // duplicates this block: the wire-schema lint reads the literal
        // keys out of *this* function's body, so they can't move into a
        // shared helper.)
        pairs.push((
            "classes",
            json::obj(
                QosClass::ALL
                    .into_iter()
                    .map(|c| {
                        let lane = st.class(c);
                        (
                            c.name(),
                            json::obj(vec![
                                ("active", Value::Num(lane.active() as f64)),
                                ("completed", Value::Num(lane.completed as f64)),
                                ("aborted", Value::Num(lane.aborted as f64)),
                                ("rows", Value::Num(lane.rows as f64)),
                                ("mean_wall_ms", Value::Num(lane.mean_wall_ms)),
                                ("deadline_hits", Value::Num(lane.deadline_hits as f64)),
                            ]),
                        )
                    })
                    .collect(),
            ),
        ));
    }
    if req.return_sample {
        pairs.push(("sample", json::arr_f32(&out.sample)));
    }
    if req.return_iterates {
        pairs.push((
            "iterates",
            Value::Arr(out.iterates.iter().map(|v| json::arr_f32(v)).collect()),
        ));
    }
    json::obj(pairs)
}

/// Detect the `{"kind": "stats"}` observability probe and return its
/// echoed id. Engine-backed entry points intercept this *before*
/// [`SampleRequest::from_json`]: the probe runs no sampler, takes no
/// admission slot (it is explicitly exempt from the `max_inflight`
/// check — health checks must answer on a saturated connection), and
/// is answered synchronously on the serving thread through the typed
/// frame path ([`versioned_stats`]).
// lint: request-path
fn stats_probe_id(o: &LazyObj) -> Option<u64> {
    match o.get("kind").and_then(|x| x.as_str().map(str::to_string)).as_deref() {
        Some("stats") => Some(o.num("id").unwrap_or(0.0) as u64),
        _ => None,
    }
}

/// Lenient version extraction for response *shaping* on paths where
/// [`SampleRequest::from_json`] (the authoritative validator) either
/// wasn't reached or already failed: anything other than an explicit
/// `"v": 1` shapes as legacy — a client speaking an unknown version
/// can't be assumed to understand v1 frames.
// lint: request-path
fn shaping_version(o: &LazyObj) -> u64 {
    match o.num("v") {
        Some(x) if x == 1.0 => 1,
        _ => 0,
    }
}

/// The stats probe response in the dialect the probe asked for:
/// the legacy bare object at v0, a framed `stats` line at v1.
// lint: request-path
fn versioned_stats(id: u64, v: u64, st: &EngineStats) -> Value {
    let body = stats_response(id, st);
    if v >= 1 {
        with_envelope(body, v, "stats")
    } else {
        body
    }
}

/// Serialize the `{"kind": "stats"}` probe response: the
/// fleet-aggregated engine snapshot with no sampler run attached
/// (documented in DESIGN.md under its own `wire-stats-fields` table —
/// the wire-schema lint scans `success_response`, not this fn).
// lint: request-path
pub fn stats_response(id: u64, st: &EngineStats) -> Value {
    json::obj(vec![
        ("id", Value::Num(id as f64)),
        ("ok", Value::Bool(true)),
        ("kind", Value::Str("stats".into())),
        ("shards", Value::Num(st.shards as f64)),
        ("steals", Value::Num(st.steals as f64)),
        ("workers", Value::Num(st.workers as f64)),
        ("queue_depth", Value::Num(st.queue_depth as f64)),
        ("active_tasks", Value::Num(st.active_tasks as f64)),
        ("flushed_batches", Value::Num(st.flushed_batches as f64)),
        ("flushed_rows", Value::Num(st.flushed_rows as f64)),
        ("split_batches", Value::Num(st.split_batches as f64)),
        ("mean_occupancy", Value::Num(st.mean_occupancy)),
        ("pool_hits", Value::Num(st.pool_hits as f64)),
        ("pool_misses", Value::Num(st.pool_misses as f64)),
        ("pool_high_water", Value::Num(st.pool_high_water as f64)),
        ("cache_hits", Value::Num(st.cache_hits as f64)),
        ("cache_misses", Value::Num(st.cache_misses as f64)),
        ("cache_evictions", Value::Num(st.cache_evictions as f64)),
        ("coalesced", Value::Num(st.coalesced as f64)),
        // Same lane shape as success_response's `classes` (that copy is
        // the lint-scanned one; see the note there).
        (
            "classes",
            json::obj(
                QosClass::ALL
                    .into_iter()
                    .map(|c| {
                        let lane = st.class(c);
                        (
                            c.name(),
                            json::obj(vec![
                                ("active", Value::Num(lane.active() as f64)),
                                ("completed", Value::Num(lane.completed as f64)),
                                ("aborted", Value::Num(lane.aborted as f64)),
                                ("rows", Value::Num(lane.rows as f64)),
                                ("mean_wall_ms", Value::Num(lane.mean_wall_ms)),
                                ("deadline_hits", Value::Num(lane.deadline_hits as f64)),
                            ]),
                        )
                    })
                    .collect(),
            ),
        ),
    ])
}

/// The error every blocking, single-response entry point returns for a
/// `"stream": true` request: those paths have nowhere to put iterate
/// frames, and a silent downgrade to one final frame would violate the
/// ack/iterate/final lifecycle the client asked for.
fn stream_unsupported(id: u64) -> WireError {
    WireError::invalid(
        id,
        "\"stream\": true requires the serving loop (persistent connection); \
         this endpoint is single-response"
            .to_string(),
    )
}

/// Shape a blocking engine/router reply in the request's dialect:
/// bare legacy object at v0, a framed `final` (or `error`) at v1.
fn blocking_reply(
    req: &SampleRequest,
    name: &'static str,
    reply: TaskReply,
    stats: &EngineStats,
    wall_ms: f64,
) -> Value {
    match reply {
        TaskReply::Done(out) => {
            let resp = success_response(req, name, &out, wall_ms, Some(stats));
            if req.v >= 1 {
                with_envelope(resp, req.v, "final")
            } else {
                resp
            }
        }
        TaskReply::TimedOut => error_frame(&WireError::timeout(req.id, req.timeout_ms), req.v),
    }
}

/// Execute one request directly on a backend via the sampler registry —
/// the single-tenant path (unit tests, library callers without an
/// engine). No dispatcher exists here, so `timeout_ms` is not enforced
/// (the run completes) and `stream` is rejected.
pub fn run_request(
    backend: &dyn StepBackend,
    model_name: &str,
    req: &SampleRequest,
) -> Value {
    if req.stream {
        return error_frame(&stream_unsupported(req.id), req.v);
    }
    let spec = match request_spec(model_name, req) {
        Ok(s) => s,
        Err(e) => return error_frame(&e, req.v),
    };
    let x0 = prior_sample(backend.dim(), req.seed);
    let t0 = std::time::Instant::now();
    // spec.run dispatches through the registry on spec.kind, which
    // request_spec resolved from the request's sampler name.
    let out: SampleOutput = spec.run(backend, &x0);
    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
    let resp = success_response(req, spec.kind.name(), &out, wall_ms, None);
    if req.v >= 1 {
        with_envelope(resp, req.v, "final")
    } else {
        resp
    }
}

/// Execute one request on the shared multi-tenant engine and block for
/// the result (tests, simple callers). Every sampler kind — SRDS,
/// sequential, ParaDiGMS, ParaTAA — runs as an engine-resident
/// [`crate::exec::task::SamplerTask`], cross-request batched; only this
/// caller's thread waits, nothing inside the engine blocks per request.
/// Submitted through the serving path so `timeout_ms` is honored: a
/// timed-out SRDS run comes back as a success with `timed_out: true`,
/// a timed-out baseline as a `timeout` error frame.
pub fn run_request_engine(engine: &Engine, model_name: &str, req: &SampleRequest) -> Value {
    if req.stream {
        return error_frame(&stream_unsupported(req.id), req.v);
    }
    let spec = match request_spec(model_name, req) {
        Ok(s) => s,
        Err(e) => return error_frame(&e, req.v),
    };
    let x0 = prior_sample(engine.dim(), req.seed);
    let t0 = std::time::Instant::now();
    let name = spec.kind.name();
    let (tx, rx) = std::sync::mpsc::channel();
    engine.submit_serving(x0, spec, None, None, move |reply, stats| {
        let _ = tx.send((reply, stats));
    });
    let (reply, stats) = rx.recv().expect("engine dispatcher dropped mid-request");
    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
    blocking_reply(req, name, reply, &stats, wall_ms)
}

/// Execute one request on a sharded fleet and block for the result
/// (tests, simple callers): the router places it by load + QoS class,
/// and the response carries the **fleet-aggregated** stats snapshot.
/// Same timeout semantics as [`run_request_engine`].
pub fn run_request_router(router: &Router, model_name: &str, req: &SampleRequest) -> Value {
    if req.stream {
        return error_frame(&stream_unsupported(req.id), req.v);
    }
    let spec = match request_spec(model_name, req) {
        Ok(s) => s,
        Err(e) => return error_frame(&e, req.v),
    };
    let x0 = prior_sample(router.dim(), req.seed);
    let t0 = std::time::Instant::now();
    let name = spec.kind.name();
    let (tx, rx) = std::sync::mpsc::channel();
    router.submit_serving(x0, spec, None, None, move |reply, stats| {
        let _ = tx.send((reply, stats));
    });
    let (reply, stats) = rx.recv().expect("router dispatcher dropped mid-request");
    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
    blocking_reply(req, name, reply, &stats, wall_ms)
}

/// Package a terminal [`TaskReply`] as the [`PendingResponse`] for the
/// outbox: a deferred `Finished` payload on success (serialization on
/// the poll thread, never the dispatcher), an eagerly serialized
/// `timeout` error frame when the dispatcher gave up on a
/// no-anytime-iterate sampler.
// lint: request-path
fn pending_from_reply(
    req: SampleRequest,
    name: &'static str,
    reply: TaskReply,
    stats: EngineStats,
    wall_ms: f64,
) -> PendingResponse {
    match reply {
        TaskReply::Done(out) => PendingResponse::Finished(Box::new(FinishedResponse {
            req,
            name,
            out,
            stats,
            wall_ms,
        })),
        TaskReply::TimedOut => PendingResponse::Ready(json::to_string(&error_frame(
            &WireError::timeout(req.id, req.timeout_ms),
            req.v,
        ))),
    }
}

/// Submit an already-parsed request onto the fleet without blocking —
/// the poll loop's shape. Validation errors invoke `done` inline;
/// otherwise the router places the request onto a shard and `done`
/// fires from that shard's completion callback with the
/// fleet-aggregated stats. `alive` is the dead-connection purge hook:
/// the poll loop flips it when the client goes away and the owning
/// dispatcher aborts the task instead of finishing it.
///
/// `progress` is the streaming tap: for a `"stream": true` request it
/// receives one [`PendingResponse::Progress`] per completed SRDS
/// iterate, called from the shard dispatcher with a refcounted share
/// of the iterate's state buffer — no copy is made until the poll
/// thread serializes the frame. `None` on a streaming request is a
/// caller bug and comes back as a validation error.
// lint: request-path
pub fn submit_request_serving(
    router: &Router,
    model_name: &str,
    req: SampleRequest,
    alive: Arc<AtomicBool>,
    progress: Option<Box<dyn FnMut(PendingResponse) + Send>>,
    done: impl FnOnce(PendingResponse) + Send + 'static,
) {
    let spec = match request_spec(model_name, &req) {
        Ok(s) => s,
        Err(e) => return done(PendingResponse::Ready(json::to_string(&error_frame(&e, req.v)))),
    };
    if req.stream && progress.is_none() {
        let e = stream_unsupported(req.id);
        return done(PendingResponse::Ready(json::to_string(&error_frame(&e, req.v))));
    }
    let x0 = prior_sample(router.dim(), req.seed);
    let t0 = std::time::Instant::now();
    let name = spec.kind.name();
    let rid = req.id;
    let want_sample = req.return_sample;
    let sink: Option<ProgressSink> = progress.map(|mut push| {
        Box::new(move |ev: IterateEvent| {
            push(PendingResponse::Progress(Box::new(ProgressUpdate {
                id: rid,
                iter: ev.iter,
                residual: ev.residual,
                // The refcount share rides to the poll thread; the
                // float formatting happens there, in into_line.
                sample: if want_sample { Some(ev.sample) } else { None },
            })));
        }) as ProgressSink
    });
    router.submit_serving(x0, spec, Some(alive), sink, move |reply, stats| {
        let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
        done(pending_from_reply(req, name, reply, stats, wall_ms));
    });
}

/// [`submit_request_serving`] without a streaming tap — the historical
/// non-streaming submission shape, kept for callers that never stream.
// lint: request-path
pub fn submit_request_router(
    router: &Router,
    model_name: &str,
    req: SampleRequest,
    alive: Arc<AtomicBool>,
    done: impl FnOnce(PendingResponse) + Send + 'static,
) {
    submit_request_serving(router, model_name, req, alive, None, done);
}

/// Handle one raw request line on the sharded fleet, blocking for the
/// response (tests, simple callers — the poll loop uses the
/// non-blocking [`submit_request_router`]). This is the one blocking
/// entry point that also answers the `{"kind": "stats"}` probe.
pub fn handle_line_router(router: &Router, model_name: &str, line: &str) -> String {
    let o = match LazyObj::parse(line) {
        Ok(o) => o,
        Err(e) => return json::to_string(&error_frame(&WireError::parse(format!("{e:#}")), 0)),
    };
    if let Some(id) = stats_probe_id(&o) {
        return json::to_string(&versioned_stats(id, shaping_version(&o), &router.stats()));
    }
    let resp = match SampleRequest::from_json(&o) {
        Ok(req) => run_request_router(router, model_name, &req),
        Err(e) => error_frame(&e, shaping_version(&o)),
    };
    json::to_string(&resp)
}

/// A response on its way out of [`submit_line_engine`]: either already
/// serialized (parse/validation errors) or *deferred* — the completed
/// run plus everything needed to serialize it. The engine invokes the
/// completion callback on its dispatcher thread, which must stay free
/// to form batches; deferring lets the receiver (the serve loop's poll
/// thread) pay for the JSON formatting of the
/// sample vector instead.
pub enum PendingResponse {
    /// Serialized eagerly (error lines — cheap, no sample payload).
    Ready(String),
    /// A completed run (boxed: the payload carries the whole sample);
    /// serialization deferred to [`PendingResponse::into_line`].
    Finished(Box<FinishedResponse>),
    /// One streamed anytime iterate (v1 `iterate` frame). The sample
    /// rides as a refcounted [`StateBuf`] share straight out of the
    /// SRDS grid — never copied; float formatting is deferred to
    /// [`PendingResponse::into_line`] like any completion. Not a
    /// terminal frame: it does not release the admission slot.
    Progress(Box<ProgressUpdate>),
}

/// The deferred payload of [`PendingResponse::Finished`].
pub struct FinishedResponse {
    req: SampleRequest,
    name: &'static str,
    out: SampleOutput,
    stats: EngineStats,
    wall_ms: f64,
}

/// The deferred payload of [`PendingResponse::Progress`]: everything
/// an `iterate` frame needs.
pub struct ProgressUpdate {
    id: u64,
    iter: usize,
    residual: f32,
    /// `None` when the request opted out with `"sample": false`
    /// (residual-only progress ticker).
    sample: Option<StateBuf>,
}

impl PendingResponse {
    /// Whether this response closes out its request. Terminal frames
    /// release the connection's admission slot; `iterate` frames are
    /// interior to a stream and do not.
    pub fn is_terminal(&self) -> bool {
        !matches!(self, PendingResponse::Progress(_))
    }

    /// Serialize to the wire line. For engine completions this is the
    /// heavy part (formatting `d` floats, plus iterates when requested)
    /// — call it off the dispatcher thread.
    pub fn into_line(self) -> String {
        match self {
            PendingResponse::Ready(s) => s,
            PendingResponse::Finished(f) => {
                let resp = success_response(&f.req, f.name, &f.out, f.wall_ms, Some(&f.stats));
                let resp = if f.req.v >= 1 {
                    with_envelope(resp, f.req.v, "final")
                } else {
                    resp
                };
                json::to_string(&resp)
            }
            PendingResponse::Progress(p) => {
                json::to_string(&iterate_frame(p.id, p.iter, p.residual, p.sample.as_deref()))
            }
        }
    }
}

/// Parse and submit one request line onto the engine **without
/// blocking**: `done` receives the [`PendingResponse`] when the request
/// completes (immediately, for parse/validation errors; otherwise from
/// the engine's completion callback). This is what the TCP read loop
/// calls — a request's whole lifetime lives inside the engine's
/// dispatcher + workers, and no per-request thread exists anywhere.
/// `done` may run on the dispatcher thread: it must be cheap and must
/// not block — the serve loop's forwards the still-unserialized
/// response to the connection's writer thread, which does the JSON
/// formatting via [`PendingResponse::into_line`].
// lint: request-path
pub fn submit_line_engine(
    engine: &Engine,
    model_name: &str,
    line: &str,
    done: impl FnOnce(PendingResponse) + Send + 'static,
) {
    let req = match line_to_request(line) {
        Ok(r) => r,
        Err((e, v)) => return done(PendingResponse::Ready(json::to_string(&error_frame(&e, v)))),
    };
    submit_request_engine(engine, model_name, req, done);
}

/// Submit an already-parsed request onto the engine without blocking —
/// the serve loop calls this after its admission check (so a shed
/// request never reaches the engine), [`submit_line_engine`] after
/// parsing. Validation errors invoke `done` inline; otherwise `done`
/// fires from the engine's completion callback. Single-response:
/// `timeout_ms` is honored, `stream` is rejected (the streaming tap
/// lives on the router path, [`submit_request_serving`]).
// lint: request-path
pub fn submit_request_engine(
    engine: &Engine,
    model_name: &str,
    req: SampleRequest,
    done: impl FnOnce(PendingResponse) + Send + 'static,
) {
    if req.stream {
        let e = stream_unsupported(req.id);
        return done(PendingResponse::Ready(json::to_string(&error_frame(&e, req.v))));
    }
    let spec = match request_spec(model_name, &req) {
        Ok(s) => s,
        Err(e) => return done(PendingResponse::Ready(json::to_string(&error_frame(&e, req.v)))),
    };
    let x0 = prior_sample(engine.dim(), req.seed);
    let t0 = std::time::Instant::now();
    let name = spec.kind.name();
    engine.submit_serving(x0, spec, None, None, move |reply, stats| {
        let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
        done(pending_from_reply(req, name, reply, stats, wall_ms));
    });
}

// lint: request-path
fn line_to_request(line: &str) -> std::result::Result<SampleRequest, (WireError, u64)> {
    match LazyObj::parse(line) {
        // Request-level validation errors still echo the id (inside the
        // WireError) so pipelined clients can correlate them; the
        // shaping version rides along so the error frame speaks the
        // dialect the client asked for.
        Ok(o) => SampleRequest::from_json(&o).map_err(|e| {
            let v = shaping_version(&o);
            (e, v)
        }),
        // Malformed JSON (or a non-object line): no id to echo, and no
        // version to trust — shape as legacy.
        Err(e) => Err((WireError::parse(format!("{e:#}")), 0)),
    }
}

/// Handle one raw request line on a dedicated backend (exposed for
/// tests; no socket, no engine).
pub fn handle_line(backend: &dyn StepBackend, model_name: &str, line: &str) -> String {
    let resp = match line_to_request(line) {
        Ok(req) => run_request(backend, model_name, &req),
        Err((e, v)) => error_frame(&e, v),
    };
    json::to_string(&resp)
}

/// Handle one raw request line on the shared engine, blocking for the
/// response (tests, simple callers — the TCP loop uses the non-blocking
/// [`submit_line_engine`] instead).
pub fn handle_line_engine(engine: &Engine, model_name: &str, line: &str) -> String {
    let resp = match line_to_request(line) {
        Ok(req) => run_request_engine(engine, model_name, &req),
        Err((e, v)) => error_frame(&e, v),
    };
    json::to_string(&resp)
}

/// Default per-connection admission cap (see [`ServeConfig::max_inflight`]).
pub const DEFAULT_MAX_INFLIGHT: usize = 64;

/// Default per-shard coarse-spine cache capacity for the serving layer
/// (see [`ServeConfig::spine_cache_cap`]). The library-level
/// [`crate::exec::EngineConfig`] default is 0 (off); a server opts in
/// because repeat specs are the serving workload's common case.
pub const DEFAULT_SPINE_CACHE_CAP: usize = 64;

/// Server configuration.
pub struct ServeConfig {
    pub addr: String,
    /// Engine shards (`--shards` on the CLI; the default is one shard
    /// per `workers`-sized core group, see
    /// [`crate::exec::router::default_shards`]). Each shard is a full
    /// engine — dispatcher + `workers` worker threads + its own
    /// `BufPool` — behind the router's load/QoS placement, with
    /// cross-shard work stealing of queued rows. Placement and stealing
    /// are pure scheduling: outputs are bit-identical at any width.
    pub shards: usize,
    /// Engine worker threads *per shard* (each owns one backend
    /// instance).
    pub workers: usize,
    pub model_name: String,
    pub factory: Arc<dyn BackendFactory>,
    /// Cross-request batch assembly policy for the engine
    /// (`--batch-wait` / `--buckets` on the CLI).
    pub batch: BatchPolicy,
    /// Admission control: in-flight requests per connection
    /// (`--max-inflight` on the CLI, [`DEFAULT_MAX_INFLIGHT`] by
    /// default). A request arriving past the cap is **shed immediately**
    /// with the structured [`overloaded_response`] error line
    /// (`error_kind: "overloaded"`) so the client can back off — the
    /// read loop never stalls, and responses for in-flight work keep
    /// streaming while the connection is over cap.
    pub max_inflight: usize,
    /// Default anytime eval budget applied to requests that don't carry
    /// their own `"deadline"` field (`--default-deadline` on the CLI).
    /// `None` → no budget: requests refine to convergence/cap. Clients
    /// opt out per request with an explicit `"deadline": 0`.
    pub default_deadline: Option<u64>,
    /// Per-shard coarse-spine cache capacity (`--spine-cache-cap` on
    /// the CLI, [`DEFAULT_SPINE_CACHE_CAP`] by default, 0 disables): a
    /// repeat SRDS request warm-starts from the retained iteration-0
    /// boundary states and skips the serial coarse sweep entirely,
    /// bit-identically.
    pub spine_cache_cap: usize,
    /// In-flight coalescing (`--no-coalesce` turns it off): identical
    /// concurrent submissions share one resident task and fan out
    /// bit-identical responses.
    pub coalesce: bool,
}

/// Run the blocking accept loop on a fresh listener bound to `cfg.addr`.
pub fn serve(cfg: ServeConfig) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)?;
    serve_on(listener, cfg)
}

/// Write-backpressure bound: while a connection's pending response
/// bytes exceed this, the poll loop stops *reading* from it (already
/// queued responses keep draining) — a client that won't read its
/// responses can't balloon server memory by pipelining more work.
const MAX_OUTBUF: usize = 1 << 20;

/// How long the poll loop parks on the completion outbox when no socket
/// made progress. Engine completions notify the condvar, so a finished
/// request wakes the loop immediately; the timeout only bounds how
/// stale a WouldBlock retry can get.
const POLL_WAIT: Duration = Duration::from_millis(1);

/// Completed work on its way back to connections: shard dispatchers
/// push `(conn, response)` here from their completion callbacks (cheap
/// — no serialization), and the poll thread drains it, doing the heavy
/// JSON formatting off the dispatchers.
struct Outbox {
    queue: Mutex<Vec<(u64, PendingResponse)>>,
    cv: Condvar,
}

impl Outbox {
    fn new() -> Outbox {
        Outbox { queue: Mutex::new(Vec::new()), cv: Condvar::new() }
    }

    // lint: request-path
    fn push(&self, conn: u64, resp: PendingResponse) {
        // lint-allow(panic-policy): a poisoned outbox means a panicked poll thread — process-fatal, not request-controlled
        self.queue.lock().unwrap().push((conn, resp));
        self.cv.notify_one();
    }

    // lint: request-path
    fn drain(&self) -> Vec<(u64, PendingResponse)> {
        // lint-allow(panic-policy): poisoned outbox, see push
        std::mem::take(&mut *self.queue.lock().unwrap())
    }

    /// Park until either `timeout` passes or a completion lands.
    // lint: request-path
    fn wait(&self, timeout: Duration) {
        // lint-allow(panic-policy): poisoned outbox, see push
        let q = self.queue.lock().unwrap();
        if q.is_empty() {
            // lint-allow(panic-policy): poisoned outbox, see push
            let _ = self.cv.wait_timeout(q, timeout).unwrap();
        }
    }
}

/// Per-connection state in the poll loop: the nonblocking socket plus
/// read/write buffers and the liveness flag its in-flight tasks carry.
struct Conn {
    stream: TcpStream,
    peer: String,
    /// Bytes read but not yet terminated by `\n` (partial-line
    /// reassembly).
    inbuf: Vec<u8>,
    /// Serialized response bytes not yet accepted by the socket.
    outbuf: Vec<u8>,
    /// Requests handed to the router for this connection. Poll-thread
    /// local (only the poll thread submits), so the admission check and
    /// the drain-then-close decision are race-free by construction —
    /// no completion-side counter can be read at the wrong moment.
    submitted: u64,
    /// Terminal router responses routed into `outbuf` so far. Every
    /// submission on a live connection produces exactly one *terminal*
    /// outbox entry (inline validation errors included); streamed
    /// `iterate` frames ride the outbox too but are interior to their
    /// request and don't count — so `submitted - delivered` is the
    /// connection's true in-flight count.
    delivered: u64,
    /// Flipped to `false` when the connection dies; every task
    /// submitted for it holds a clone, and the owning dispatcher aborts
    /// flagged tasks on its next sweep.
    alive: Arc<AtomicBool>,
    /// The peer half-closed its write side (EOF on read): accept no
    /// more requests, but keep draining responses for work already in
    /// flight, then close once everything submitted was delivered.
    read_closed: bool,
}

impl Conn {
    /// Requests submitted to the router and not yet answered.
    fn pending(&self) -> u64 {
        self.submitted - self.delivered
    }
}

/// Everything [`serve_on`]'s poll loop needs per event, bundled so the
/// per-connection handlers are methods instead of 8-argument functions.
struct PollLoop {
    router: Arc<Router>,
    model_name: String,
    default_deadline: Option<u64>,
    max_inflight: usize,
    outbox: Arc<Outbox>,
}

impl PollLoop {
    /// Flush this connection's pending response bytes. Returns `false`
    /// when the socket is dead.
    // lint: request-path
    fn write_side(&self, conn: &mut Conn, progress: &mut bool) -> bool {
        let mut wrote = 0;
        while wrote < conn.outbuf.len() {
            match conn.stream.write(&conn.outbuf[wrote..]) {
                Ok(0) => return false,
                Ok(n) => {
                    wrote += n;
                    *progress = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        conn.outbuf.drain(..wrote);
        true
    }

    /// Read whatever the socket has, reassemble complete lines, and
    /// dispatch each. Returns `false` when the socket is dead.
    // lint: request-path
    fn read_side(&self, id: u64, conn: &mut Conn, progress: &mut bool) -> bool {
        if conn.read_closed || conn.outbuf.len() >= MAX_OUTBUF {
            // Backpressure: a client that won't drain its responses
            // doesn't get to queue more work.
            return true;
        }
        let mut chunk = [0u8; 8192];
        loop {
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    // EOF. A trailing unterminated line still counts
                    // (matches the old BufRead::lines behavior), then
                    // the read side is done — responses keep flowing.
                    conn.read_closed = true;
                    *progress = true;
                    if !conn.inbuf.is_empty() {
                        let tail = std::mem::take(&mut conn.inbuf);
                        let line = String::from_utf8_lossy(&tail).to_string();
                        if !line.trim().is_empty() {
                            self.on_line(id, conn, line.trim());
                        }
                    }
                    return true;
                }
                Ok(n) => {
                    *progress = true;
                    conn.inbuf.extend_from_slice(&chunk[..n]);
                    self.drain_lines(id, conn);
                    if conn.outbuf.len() >= MAX_OUTBUF {
                        return true;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
    }

    /// Split the connection's read buffer on `\n` and dispatch every
    /// complete line; the tail stays buffered until its newline arrives.
    // lint: request-path
    fn drain_lines(&self, id: u64, conn: &mut Conn) {
        while let Some(pos) = conn.inbuf.iter().position(|&b| b == b'\n') {
            let raw: Vec<u8> = conn.inbuf.drain(..=pos).collect();
            let line = String::from_utf8_lossy(&raw[..raw.len() - 1]).to_string();
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            self.on_line(id, conn, line);
        }
    }

    /// One complete request line: parse errors and the stats probe are
    /// answered inline by the poll thread (straight into the write
    /// buffer); sampling requests pass admission and go to the router,
    /// whose completion callback posts to the outbox. Streaming
    /// requests additionally get their `ack` frame pushed synchronously
    /// here — outbox entries are only drained on later poll
    /// iterations, so the ack always precedes the first `iterate`.
    // lint: request-path
    fn on_line(&self, id: u64, conn: &mut Conn, line: &str) {
        let o = match LazyObj::parse(line) {
            Ok(o) => o,
            Err(e) => {
                // Malformed JSON (or a non-object line): no id to echo.
                let err = error_frame(&WireError::parse(format!("{e:#}")), 0);
                return push_line(&mut conn.outbuf, &json::to_string(&err));
            }
        };
        // The stats probe runs no sampler and takes no admission slot —
        // it must answer even (especially) on a saturated connection.
        if let Some(pid) = stats_probe_id(&o) {
            let resp = versioned_stats(pid, shaping_version(&o), &self.router.stats());
            return push_line(&mut conn.outbuf, &json::to_string(&resp));
        }
        let mut req = match SampleRequest::from_json(&o) {
            Ok(r) => r,
            Err(e) => {
                // Request-level validation errors still echo the id
                // (inside the WireError) so pipelined clients can
                // correlate them.
                let err = error_frame(&e, shaping_version(&o));
                return push_line(&mut conn.outbuf, &json::to_string(&err));
            }
        };
        if req.deadline.is_none() {
            req.deadline = self.default_deadline;
        }
        // Non-blocking admission: over the cap, shed with the
        // structured overloaded error (carrying the retry_after_ms
        // backoff hint) instead of stalling the poll loop. The slot
        // frees when the *terminal* response is routed back to this
        // connection — a stream occupies exactly one slot for its whole
        // ack/iterate*/final lifetime.
        if conn.pending() >= self.max_inflight as u64 {
            let shed = error_frame(
                &WireError::overloaded(req.id, self.max_inflight, DEFAULT_RETRY_AFTER_MS),
                req.v,
            );
            return push_line(&mut conn.outbuf, &json::to_string(&shed));
        }
        let progress: Option<Box<dyn FnMut(PendingResponse) + Send>> = if req.stream {
            // Validate *before* acking: an invalid streaming request
            // gets one error frame and no ack — the lifecycle is
            // strictly ack, iterate*, then final or error.
            match request_spec(&self.model_name, &req) {
                Err(e) => {
                    let err = error_frame(&e, req.v);
                    return push_line(&mut conn.outbuf, &json::to_string(&err));
                }
                Ok(spec) => {
                    let ack = ack_frame(req.id, spec.kind.name());
                    push_line(&mut conn.outbuf, &json::to_string(&ack));
                }
            }
            let outbox = self.outbox.clone();
            Some(Box::new(move |resp| outbox.push(id, resp)))
        } else {
            None
        };
        conn.submitted += 1;
        // Submit and move on: the shard's completion callback posts the
        // still-unserialized response to the outbox; the poll thread
        // formats it (and releases the admission slot) next wake-up. No
        // thread exists for this request — streamed or not.
        let outbox = self.outbox.clone();
        submit_request_serving(
            &self.router,
            &self.model_name,
            req,
            conn.alive.clone(),
            progress,
            move |resp| {
                outbox.push(id, resp);
            },
        );
    }
}

// lint: request-path
fn push_line(out: &mut Vec<u8>, line: &str) {
    out.extend_from_slice(line.as_bytes());
    out.push(b'\n');
}

/// Run the serve loop on an already-bound listener (tests bind an
/// ephemeral port first, then hand it over — no drop-and-rebind race).
///
/// One sharded engine fleet serves every connection through a **single
/// nonblocking poll loop** on the calling thread: nonblocking accept,
/// per-connection read/write buffers with partial-line reassembly,
/// write backpressure (a connection whose response backlog passes
/// [`MAX_OUTBUF`] is not read from until it drains), and a
/// dead-connection purge that flips the liveness flag carried by the
/// connection's in-flight tasks so shard dispatchers abort them. The
/// whole process runs `1 + shards × (1 + workers)` threads — connection
/// count and request count create none (the old design spent a reader
/// + writer thread pair per connection).
///
/// In-flight requests are capped at [`ServeConfig::max_inflight`] per
/// connection — a request past the cap is shed *immediately* with the
/// structured [`overloaded_response`] line (`error_kind: "overloaded"`,
/// `retry_after_ms` hint), never parked. `{"kind": "stats"}` probes are
/// answered inline from the fleet gauges without touching admission.
pub fn serve_on(listener: TcpListener, cfg: ServeConfig) -> Result<()> {
    let shards = cfg.shards.max(1);
    let router = Arc::new(Router::new(
        cfg.factory.clone(),
        RouterConfig {
            shards,
            workers: cfg.workers,
            batch: cfg.batch.clone(),
            steal: true,
            spine_cache_cap: cfg.spine_cache_cap,
            coalesce: cfg.coalesce,
        },
    ));
    eprintln!(
        "srds-server listening on {} (model={}, shards={}, workers/shard={}, buckets={:?}, \
         class-weights={:?}, max-inflight/conn={}, default-deadline={:?}, spine-cache-cap={}, \
         coalesce={}, samplers={})",
        listener.local_addr().map(|a| a.to_string()).unwrap_or_else(|_| cfg.addr.clone()),
        cfg.model_name,
        shards,
        cfg.workers,
        cfg.batch.buckets,
        cfg.batch.class_weights,
        cfg.max_inflight,
        cfg.default_deadline,
        cfg.spine_cache_cap,
        cfg.coalesce,
        registry().list().join("/")
    );
    listener.set_nonblocking(true)?;
    let lp = PollLoop {
        router,
        model_name: cfg.model_name.clone(),
        default_deadline: cfg.default_deadline,
        max_inflight: cfg.max_inflight.max(1),
        outbox: Arc::new(Outbox::new()),
    };
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_id: u64 = 0;
    let mut dead: Vec<u64> = Vec::new();
    loop {
        let mut progress = false;
        // 1. Accept every waiting connection.
        loop {
            match listener.accept() {
                Ok((stream, peer)) => {
                    if let Err(e) = stream.set_nonblocking(true) {
                        eprintln!("connection setup error: {e}");
                        continue;
                    }
                    conns.insert(
                        next_id,
                        Conn {
                            stream,
                            peer: peer.to_string(),
                            inbuf: Vec::new(),
                            outbuf: Vec::new(),
                            submitted: 0,
                            delivered: 0,
                            alive: Arc::new(AtomicBool::new(true)),
                            read_closed: false,
                        },
                    );
                    next_id += 1;
                    progress = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                // A broken listener can't be served around (matches the
                // old accept loop's `stream?`).
                Err(e) => return Err(e.into()),
            }
        }
        // 2. Route completed work into its connection's write buffer —
        // serialization happens here, on the poll thread, never on a
        // shard dispatcher. A completion for a vanished connection is
        // dropped (its client is gone; late results have no reader).
        for (conn_id, resp) in lp.outbox.drain() {
            if let Some(conn) = conns.get_mut(&conn_id) {
                // Streamed iterate frames ride the outbox but are
                // interior to their request: only the terminal
                // final/error frame releases the admission slot.
                if resp.is_terminal() {
                    conn.delivered += 1;
                }
                push_line(&mut conn.outbuf, &resp.into_line());
                progress = true;
            }
        }
        // 3. Per-connection I/O: drain writes first (completed work
        // must stream out even if the client never sends another
        // byte), then read + dispatch new request lines.
        for (&id, conn) in conns.iter_mut() {
            let open = lp.write_side(conn, &mut progress)
                && lp.read_side(id, conn, &mut progress)
                && !(conn.read_closed && conn.outbuf.is_empty() && conn.pending() == 0);
            if !open {
                dead.push(id);
            }
        }
        // 4. Purge dead connections: dropping the socket closes it, and
        // flipping `alive` makes the dispatchers abort any of its
        // still-queued work instead of computing unread results.
        for id in dead.drain(..) {
            if let Some(conn) = conns.remove(&id) {
                conn.alive.store(false, Ordering::SeqCst);
                eprintln!("connection {} done", conn.peer);
            }
        }
        // 5. Nothing moved: park until a completion lands or the poll
        // interval elapses (bounds the WouldBlock retry latency).
        if !progress {
            lp.outbox.wait(POLL_WAIT);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ConvNorm;
    use crate::exec::{EngineConfig, NativeFactory};
    use crate::model::GmmEps;
    use crate::solvers::Solver;

    fn backend() -> Box<dyn StepBackend> {
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(make_gmm("toy2d")));
        NativeFactory::new(model, Solver::Ddim).create()
    }

    #[test]
    fn handle_line_srds() {
        let be = backend();
        let resp = handle_line(be.as_ref(), "gmm_toy2d", r#"{"id": 5, "n": 16, "tol": 0.001}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("id").unwrap().as_f64(), Some(5.0));
        assert_eq!(v.get("sampler").unwrap().as_str(), Some("srds"));
        assert_eq!(v.get("sample").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn handle_line_every_registered_sampler() {
        let be = backend();
        for sampler in registry().list() {
            let line = format!(r#"{{"id":1,"sampler":"{sampler}","n":16,"sample":false}}"#);
            let resp = handle_line(be.as_ref(), "gmm_toy2d", &line);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{sampler}: {resp}");
            assert_eq!(v.get("sampler").unwrap().as_str(), Some(sampler));
            assert!(v.get("sample").is_none());
            assert!(v.get("eff_serial_evals_pipelined").is_some(), "{sampler}: {resp}");
            // The zero-copy satellite: pool accounting is on the wire.
            assert!(v.get("pool_hits").is_some(), "{sampler}: {resp}");
            assert!(v.get("pool_misses").is_some(), "{sampler}: {resp}");
        }
    }

    #[test]
    fn handle_line_rejects_unknown_sampler() {
        // No silent SRDS fallback: unknown names are an explicit error.
        let be = backend();
        let resp =
            handle_line(be.as_ref(), "gmm_toy2d", r#"{"id": 9, "sampler": "ddim", "n": 16}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert_eq!(v.get("id").unwrap().as_f64(), Some(9.0), "error echoes the request id");
        let err = v.get("error").unwrap().as_str().unwrap().to_string();
        assert!(err.contains("unknown sampler"), "{err}");
        assert!(err.contains("srds"), "error lists the registry: {err}");
        assert!(v.get("sample").is_none());
    }

    #[test]
    fn handle_line_rejects_out_of_range_block() {
        // block is asserted deep inside Partition::with_block; the server
        // must reject it up front instead of panicking a worker thread.
        let be = backend();
        for bad in [r#"{"id":2,"n":16,"block":0}"#, r#"{"id":2,"n":16,"block":17}"#, r#"{"id":2,"n":0}"#] {
            let resp = handle_line(be.as_ref(), "gmm_toy2d", bad);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{bad} -> {resp}");
        }
        // Boundary values are fine: block == n is one block of n steps.
        let resp = handle_line(be.as_ref(), "gmm_toy2d", r#"{"id":3,"n":16,"block":16}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
    }

    #[test]
    fn handle_line_rejects_unknown_norm() {
        let be = backend();
        let resp = handle_line(be.as_ref(), "gmm_toy2d", r#"{"id":7,"n":16,"norm":"l7"}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        // Validation errors echo the id so pipelined clients can
        // correlate them with the failed request.
        assert_eq!(v.get("id").unwrap().as_f64(), Some(7.0), "{resp}");
    }

    #[test]
    fn paradigms_max_iters_zero_still_runs_one_sweep() {
        // max_iters is clamped to >= 1 in every sampler; a cap of 0 must
        // not return the untouched prior as a "sample".
        let be = backend();
        let resp = handle_line(
            be.as_ref(),
            "gmm_toy2d",
            r#"{"id":1,"sampler":"paradigms","n":16,"max_iters":0}"#,
        );
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert!(v.get("iters").unwrap().as_f64().unwrap() >= 1.0, "{resp}");
    }

    #[test]
    fn handle_line_bad_json() {
        let be = backend();
        let resp = handle_line(be.as_ref(), "gmm_toy2d", "{nope");
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn request_knobs_reach_the_spec() {
        let o = LazyObj::parse(
            r#"{"sampler":"paradigms","n":64,"window":16,"history":5,"block":4,
                "norm":"linf","max_iters":7,"tol":0.5,"iterates":true}"#,
        )
        .unwrap();
        let req = SampleRequest::from_json(&o).unwrap();
        let kind = registry().parse(&req.sampler).unwrap().kind();
        let spec = req.to_spec(kind, Conditioning::none());
        assert_eq!(spec.window(), Some(16), "window reaches ParaDiGMS");
        assert_eq!(spec.block, Some(4));
        assert_eq!(spec.norm, ConvNorm::LInf);
        assert_eq!(spec.max_iters, Some(7));
        assert!(spec.keep_iterates);
        // history is a ParaTAA knob; on a paradigms request it's ignored.
        assert_eq!(spec.history(), 2);
    }

    fn engine() -> Engine {
        let model: Arc<dyn crate::model::EpsModel> =
            Arc::new(GmmEps::new(make_gmm("toy2d")));
        Engine::new(
            Arc::new(NativeFactory::new(model, Solver::Ddim)),
            EngineConfig { workers: 2, ..EngineConfig::default() },
        )
    }

    fn router(shards: usize) -> Router {
        let model: Arc<dyn crate::model::EpsModel> =
            Arc::new(GmmEps::new(make_gmm("toy2d")));
        Router::new(
            Arc::new(NativeFactory::new(model, Solver::Ddim)),
            RouterConfig {
                shards,
                workers: 1,
                spine_cache_cap: DEFAULT_SPINE_CACHE_CAP,
                ..RouterConfig::default()
            },
        )
    }

    #[test]
    fn priority_and_deadline_reach_the_spec() {
        let o = LazyObj::parse(
            r#"{"sampler":"srds","n":36,"priority":"interactive","deadline":120}"#,
        )
        .unwrap();
        let req = SampleRequest::from_json(&o).unwrap();
        assert_eq!(req.priority, QosClass::Interactive);
        assert_eq!(req.deadline, Some(120));
        let kind = registry().parse(&req.sampler).unwrap().kind();
        let spec = req.to_spec(kind, Conditioning::none());
        assert_eq!(spec.priority, QosClass::Interactive);
        assert_eq!(spec.deadline_evals, Some(120));
        // Defaults: standard class, no budget, v0, no stream/timeout.
        let o = LazyObj::parse(r#"{"sampler":"srds","n":36}"#).unwrap();
        let req = SampleRequest::from_json(&o).unwrap();
        assert_eq!(req.priority, QosClass::Standard);
        assert_eq!(req.deadline, None);
        assert_eq!(req.v, 0);
        assert_eq!(req.timeout_ms, None);
        assert!(!req.stream);
    }

    #[test]
    fn unknown_priority_is_rejected_not_downgraded() {
        let be = backend();
        let resp =
            handle_line(be.as_ref(), "gmm_toy2d", r#"{"id":4,"n":16,"priority":"urgent"}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert_eq!(v.get("id").unwrap().as_f64(), Some(4.0));
        assert!(
            v.get("error").unwrap().as_str().unwrap().contains("unknown priority"),
            "{resp}"
        );
    }

    #[test]
    fn deadline_zero_opts_out_and_negative_is_rejected() {
        // Explicit 0 is the client's escape hatch from a server-side
        // --default-deadline: it must parse as "unbudgeted", never as a
        // zero-eval budget. Negative would saturate to exactly that
        // coarse-init-only run, so it's rejected, not degraded.
        let o = LazyObj::parse(r#"{"sampler":"srds","n":16,"deadline":0}"#).unwrap();
        let req = SampleRequest::from_json(&o).unwrap();
        assert_eq!(req.deadline, Some(0), "explicit opt-out is preserved, not treated as absent");
        let kind = registry().parse(&req.sampler).unwrap().kind();
        assert_eq!(
            req.to_spec(kind, Conditioning::none()).deadline_evals,
            None,
            "0 reaches the sampler as 'no budget'"
        );
        let be = backend();
        let resp = handle_line(be.as_ref(), "gmm_toy2d", r#"{"id":6,"n":16,"deadline":-3}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert_eq!(v.get("id").unwrap().as_f64(), Some(6.0), "{resp}");
        assert!(v.get("error").unwrap().as_str().unwrap().contains("deadline"), "{resp}");
        // Boundary: 1 is a legal (if brutal) budget.
        let resp = handle_line(be.as_ref(), "gmm_toy2d", r#"{"id":7,"n":16,"deadline":1}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
    }

    #[test]
    fn engine_responses_carry_qos_fields() {
        let eng = engine();
        let line = r#"{"id":1,"sampler":"srds","n":16,"priority":"interactive","sample":false}"#;
        let v = json::parse(&handle_line_engine(&eng, "gmm_toy2d", line)).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("priority").unwrap().as_str(), Some("interactive"));
        assert_eq!(v.get("deadline_hit").unwrap().as_bool(), Some(false));
        let classes = v.get("classes").expect("per-class lanes on the wire");
        for c in QosClass::ALL {
            let lane = classes.get(c.name()).unwrap_or_else(|| panic!("{} lane", c.name()));
            assert!(lane.get("completed").is_some());
            assert!(lane.get("active").is_some());
            assert!(lane.get("aborted").is_some());
            assert!(lane.get("rows").is_some());
            assert!(lane.get("mean_wall_ms").is_some());
            assert!(lane.get("deadline_hits").is_some());
        }
        let inter = classes.get("interactive").unwrap();
        assert_eq!(inter.get("completed").unwrap().as_f64(), Some(1.0));
        assert!(inter.get("rows").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            classes.get("batch").unwrap().get("completed").unwrap().as_f64(),
            Some(0.0)
        );
    }

    #[test]
    fn deadline_truncation_is_honest_over_the_wire() {
        // tol 0 forces all iterations; a tiny eval budget must come back
        // as deadline_hit: true + converged: false, with a valid sample.
        let eng = engine();
        let line = r#"{"id":9,"sampler":"srds","n":36,"tol":0.0,"deadline":40,"seed":5}"#;
        let v = json::parse(&handle_line_engine(&eng, "gmm_toy2d", line)).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("deadline_hit").unwrap().as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("converged").unwrap().as_bool(), Some(false));
        let sample = v.get("sample").unwrap().as_f32_vec().unwrap();
        assert!(sample.iter().all(|x| x.is_finite()));
        let classes = v.get("classes").unwrap();
        assert_eq!(
            classes.get("standard").unwrap().get("deadline_hits").unwrap().as_f64(),
            Some(1.0)
        );
    }

    #[test]
    fn overloaded_response_is_structured() {
        let v = overloaded_response(42, 2, DEFAULT_RETRY_AFTER_MS);
        assert_eq!(v.get("id").unwrap().as_f64(), Some(42.0));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("error_kind").unwrap().as_str(), Some("overloaded"));
        assert_eq!(v.get("max_inflight").unwrap().as_f64(), Some(2.0));
        assert!(v.get("error").unwrap().as_str().unwrap().contains("overloaded"));
        // The backoff hint is structured, not prose (ROADMAP's
        // resilience edge): clients sleep retry_after_ms and resend.
        assert_eq!(
            v.get("retry_after_ms").unwrap().as_f64(),
            Some(DEFAULT_RETRY_AFTER_MS as f64)
        );
        // Round-trips through the wire serialization.
        let parsed = json::parse(&json::to_string(&v)).unwrap();
        assert_eq!(parsed.get("error_kind").unwrap().as_str(), Some("overloaded"));
        assert_eq!(
            parsed.get("retry_after_ms").unwrap().as_f64(),
            Some(DEFAULT_RETRY_AFTER_MS as f64)
        );
        // The hint is caller-controlled (an adaptive serve loop can
        // scale it with load without a schema change).
        let v = overloaded_response(1, 4, 250);
        assert_eq!(v.get("retry_after_ms").unwrap().as_f64(), Some(250.0));
    }

    #[test]
    fn stats_probe_answers_without_running_a_sampler() {
        // `{"kind": "stats"}` is the poll loop's health probe: it
        // reports the aggregated fleet snapshot (shards, steals, lanes)
        // and never touches the sampler registry or an admission slot.
        let r = router(2);
        // Warm the fleet so the probe has nonzero counters to show.
        let warm =
            handle_line_router(&r, "gmm_toy2d", r#"{"id":1,"sampler":"srds","n":16,"sample":false}"#);
        let wv = json::parse(&warm).unwrap();
        assert_eq!(wv.get("ok").unwrap().as_bool(), Some(true), "{warm}");
        let resp = handle_line_router(&r, "gmm_toy2d", r#"{"id":7,"kind":"stats"}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert_eq!(v.get("id").unwrap().as_f64(), Some(7.0));
        assert_eq!(v.get("kind").unwrap().as_str(), Some("stats"));
        assert_eq!(v.get("shards").unwrap().as_f64(), Some(2.0));
        assert!(v.get("steals").unwrap().as_f64().is_some(), "{resp}");
        assert_eq!(v.get("workers").unwrap().as_f64(), Some(2.0), "2 shards × 1 worker");
        assert!(v.get("flushed_rows").unwrap().as_f64().unwrap() > 0.0, "{resp}");
        assert_eq!(v.get("active_tasks").unwrap().as_f64(), Some(0.0));
        // No sampler ran: a stats line carries no sample payload.
        assert!(v.get("sample").is_none());
        assert!(v.get("sampler").is_none());
        let classes = v.get("classes").expect("per-class lanes ride the probe");
        let std_lane = classes.get("standard").unwrap();
        assert_eq!(std_lane.get("completed").unwrap().as_f64(), Some(1.0), "{resp}");
        assert_eq!(std_lane.get("aborted").unwrap().as_f64(), Some(0.0), "{resp}");
        // An explicit kind "sample" still parses as a normal request...
        let o = LazyObj::parse(r#"{"kind":"sample","n":16}"#).unwrap();
        assert!(SampleRequest::from_json(&o).is_ok());
        // ...while an unknown kind is rejected, not silently sampled.
        let o = LazyObj::parse(r#"{"kind":"metrics","n":16}"#).unwrap();
        assert!(SampleRequest::from_json(&o).is_err());
    }

    #[test]
    fn router_path_matches_engine_path_and_reports_fleet_fields() {
        // The serve loop's actual substrate is the sharded router; the
        // wire contract must be byte-compatible with the single-engine
        // path, plus the fleet fields (shards / steals).
        let eng = engine();
        let r = router(2);
        for line in [
            r#"{"id":1,"sampler":"srds","n":25,"seed":3,"tol":1e-5}"#,
            r#"{"id":2,"sampler":"sequential","n":25,"seed":3}"#,
        ] {
            let engined = json::parse(&handle_line_engine(&eng, "gmm_toy2d", line)).unwrap();
            let routed = json::parse(&handle_line_router(&r, "gmm_toy2d", line)).unwrap();
            assert_eq!(routed.get("ok").unwrap().as_bool(), Some(true), "{line}");
            assert_eq!(
                routed.get("sample").unwrap().as_f32_vec().unwrap(),
                engined.get("sample").unwrap().as_f32_vec().unwrap(),
                "{line}: sharded fleet vs single engine"
            );
            assert_eq!(routed.get("shards").unwrap().as_f64(), Some(2.0), "{line}");
            assert!(routed.get("steals").unwrap().as_f64().is_some(), "{line}");
            // The single-engine snapshot is a width-1 fleet on the wire.
            assert_eq!(engined.get("shards").unwrap().as_f64(), Some(1.0), "{line}");
            assert_eq!(engined.get("steals").unwrap().as_f64(), Some(0.0), "{line}");
        }
    }

    #[test]
    fn handle_line_engine_every_registered_sampler() {
        // The engine-dispatched serving path: every registry entry works
        // and reports the engine stats fields.
        let eng = engine();
        for sampler in registry().list() {
            let line = format!(r#"{{"id":1,"sampler":"{sampler}","n":16,"sample":false}}"#);
            let resp = handle_line_engine(&eng, "gmm_toy2d", &line);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{sampler}: {resp}");
            assert_eq!(v.get("sampler").unwrap().as_str(), Some(sampler));
            let occ = v.get("batch_occupancy").unwrap().as_f64().unwrap();
            assert!(occ >= 1.0, "{sampler} occupancy {occ}: {resp}");
            assert!(v.get("engine_rows").unwrap().as_f64().unwrap() > 0.0, "{sampler}: {resp}");
            assert!(v.get("queue_depth").is_some(), "{sampler}: {resp}");
            // The task-table gauge is on the wire; with one request at a
            // time it reads 0 at completion.
            assert_eq!(v.get("active_tasks").unwrap().as_f64(), Some(0.0), "{sampler}: {resp}");
            assert!(v.get("flushed_batches").unwrap().as_f64().unwrap() > 0.0, "{sampler}: {resp}");
            assert!(v.get("pool_high_water").unwrap().as_f64().unwrap() > 0.0, "{sampler}: {resp}");
        }
    }

    #[test]
    fn submit_path_serves_mixed_fleet_without_request_threads() {
        // The serve loop's actual shape: submit_line_engine queues every
        // registry sampler concurrently with completion callbacks — no
        // thread blocks per request — and each response's sample is
        // bit-identical to the dedicated-backend run of the same line.
        let eng = engine();
        let be = backend();
        let (tx, rx) = std::sync::mpsc::channel::<PendingResponse>();
        let mut want: Vec<(u64, Value)> = Vec::new();
        for (i, sampler) in registry().list().iter().enumerate() {
            let line =
                format!(r#"{{"id":{i},"sampler":"{sampler}","n":16,"seed":{i},"tol":1e-6}}"#);
            let reference = json::parse(&handle_line(be.as_ref(), "gmm_toy2d", &line)).unwrap();
            want.push((i as u64, reference));
            let tx = tx.clone();
            submit_line_engine(&eng, "gmm_toy2d", &line, move |resp| {
                let _ = tx.send(resp);
            });
        }
        drop(tx);
        // Serialization runs receiver-side (the serve loop's writer
        // thread does the same via into_line).
        let got: Vec<Value> = rx.iter().map(|r| json::parse(&r.into_line()).unwrap()).collect();
        assert_eq!(got.len(), want.len(), "every callback fired exactly once");
        for (id, reference) in want {
            let g = got
                .iter()
                .find(|v| v.get("id").unwrap().as_f64() == Some(id as f64))
                .unwrap_or_else(|| panic!("no response for id {id}"));
            assert_eq!(g.get("ok").unwrap().as_bool(), Some(true), "{g:?}");
            assert_eq!(
                g.get("sampler").unwrap().as_str(),
                reference.get("sampler").unwrap().as_str()
            );
            // Engine task vs direct backend, through the full wire
            // serialization: bit-identical samples serialize identically.
            assert_eq!(
                g.get("sample").unwrap().as_f32_vec().unwrap(),
                reference.get("sample").unwrap().as_f32_vec().unwrap(),
                "id {id}: engine-native task vs direct run"
            );
            assert!(g.get("active_tasks").is_some());
        }
    }

    #[test]
    fn submit_path_reports_errors_through_the_callback() {
        let eng = engine();
        let (tx, rx) = std::sync::mpsc::channel::<PendingResponse>();
        for bad in [r#"{"id":9,"sampler":"ddim","n":16}"#, "{nope"] {
            let tx = tx.clone();
            submit_line_engine(&eng, "gmm_toy2d", bad, move |resp| {
                let _ = tx.send(resp);
            });
        }
        drop(tx);
        let got: Vec<Value> = rx.iter().map(|r| json::parse(&r.into_line()).unwrap()).collect();
        assert_eq!(got.len(), 2);
        for v in got {
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{v:?}");
        }
    }

    #[test]
    fn engine_path_matches_direct_backend_path() {
        // Same request line through the dedicated-backend path and the
        // multi-tenant engine path: identical samples (the serving-layer
        // face of the engine's equivalence invariant).
        let eng = engine();
        let be = backend();
        for line in [
            r#"{"id":1,"sampler":"srds","n":25,"seed":3,"tol":1e-4}"#,
            r#"{"id":2,"sampler":"sequential","n":25,"seed":3}"#,
            r#"{"id":3,"sampler":"paradigms","n":16,"seed":5,"tol":1e-6}"#,
        ] {
            let direct = json::parse(&handle_line(be.as_ref(), "gmm_toy2d", line)).unwrap();
            let engined = json::parse(&handle_line_engine(&eng, "gmm_toy2d", line)).unwrap();
            assert_eq!(engined.get("ok").unwrap().as_bool(), Some(true), "{line}");
            let a = direct.get("sample").unwrap().as_f32_vec().unwrap();
            let b = engined.get("sample").unwrap().as_f32_vec().unwrap();
            let d = ConvNorm::L1Mean.dist(&a, &b);
            assert!(d < 1e-6, "{line}: engine vs direct {d}");
            assert_eq!(
                direct.get("iters").unwrap().as_f64(),
                engined.get("iters").unwrap().as_f64(),
                "{line}"
            );
        }
    }

    #[test]
    fn engine_path_still_serves_srds_iterates() {
        // `iterates: true` is served natively by the SRDS task (its grid
        // retains every refinement's final state), so the wire contract
        // is unchanged on the engine path — no off-engine fallback.
        let eng = engine();
        let line = r#"{"id":4,"sampler":"srds","n":16,"seed":2,"tol":0.0,"iterates":true}"#;
        let v = json::parse(&handle_line_engine(&eng, "gmm_toy2d", line)).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{v:?}");
        let iters = v.get("iters").unwrap().as_f64().unwrap() as usize;
        let iterates = v.get("iterates").unwrap().as_arr().unwrap();
        assert_eq!(iterates.len(), iters + 1, "coarse init + one per refinement");
    }

    #[test]
    fn engine_path_rejects_bad_requests_like_direct_path() {
        let eng = engine();
        for bad in [
            r#"{"id":9,"sampler":"ddim","n":16}"#,
            r#"{"id":2,"n":16,"block":0}"#,
            r#"{"id":7,"n":16,"norm":"l7"}"#,
            "{nope",
        ] {
            let resp = handle_line_engine(&eng, "gmm_toy2d", bad);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{bad} -> {resp}");
        }
    }

    #[test]
    fn samplers_agree_on_sample() {
        // The registry-driven interchangeability check, over the wire
        // protocol: every registered sampler reproduces the sequential
        // sample at tight tolerance.
        let be = backend();
        let mk = |sampler: &str| {
            let line =
                format!(r#"{{"id":1,"sampler":"{sampler}","n":25,"seed":9,"tol":1e-6}}"#);
            let resp = handle_line(be.as_ref(), "gmm_toy2d", &line);
            json::parse(&resp).unwrap().get("sample").unwrap().as_f32_vec().unwrap()
        };
        let seq = mk("sequential");
        for sampler in registry().list() {
            let out = mk(sampler);
            let d = ConvNorm::L1Mean.dist(&out, &seq);
            assert!(d < 1e-2, "{sampler} vs sequential: {d}");
        }
    }

    #[test]
    fn error_frames_keep_legacy_shapes_at_v0_and_gain_the_envelope_at_v1() {
        // v0 parse error: the historical bare {ok, error} — no id, no
        // kind, no envelope.
        let v = error_frame(&WireError::parse("nope".into()), 0);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert!(v.get("error").is_some());
        assert!(v.get("id").is_none());
        assert!(v.get("kind").is_none() && v.get("error_kind").is_none());
        assert!(v.get("v").is_none() && v.get("frame").is_none());
        // v0 validation error: {id, ok, error}.
        let v = error_frame(&WireError::invalid(7, "bad".into()), 0);
        assert_eq!(v.get("id").unwrap().as_f64(), Some(7.0));
        assert!(v.get("error_kind").is_none(), "legacy validation errors carry no kind");
        // v0 structured kinds ride error_kind (timeout is new but
        // follows the overloaded precedent).
        let v = error_frame(&WireError::timeout(3, Some(250)), 0);
        assert_eq!(v.get("error_kind").unwrap().as_str(), Some("timeout"));
        assert!(v.get("frame").is_none());
        // v1: every error is a framed, typed line.
        let v = error_frame(&WireError::timeout(3, Some(250)), 1);
        assert_eq!(v.get("v").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("frame").unwrap().as_str(), Some("error"));
        assert_eq!(v.get("kind").unwrap().as_str(), Some("timeout"));
        let v = error_frame(&WireError::overloaded(9, 4, 25), 1);
        assert_eq!(v.get("kind").unwrap().as_str(), Some("overloaded"));
        assert_eq!(v.get("max_inflight").unwrap().as_f64(), Some(4.0));
        assert_eq!(v.get("retry_after_ms").unwrap().as_f64(), Some(25.0));
    }

    #[test]
    fn ack_and_iterate_frames_carry_the_envelope() {
        let a = ack_frame(5, "srds");
        assert_eq!(a.get("v").unwrap().as_f64(), Some(1.0));
        assert_eq!(a.get("frame").unwrap().as_str(), Some("ack"));
        assert_eq!(a.get("id").unwrap().as_f64(), Some(5.0));
        assert_eq!(a.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(a.get("sampler").unwrap().as_str(), Some("srds"));
        assert_eq!(a.get("stream").unwrap().as_bool(), Some(true));
        let it = iterate_frame(5, 2, 0.125, Some(&[1.0, 2.0]));
        assert_eq!(it.get("frame").unwrap().as_str(), Some("iterate"));
        assert_eq!(it.get("iter").unwrap().as_f64(), Some(2.0));
        assert_eq!(it.get("residual").unwrap().as_f64(), Some(0.125));
        assert_eq!(it.get("sample").unwrap().as_f32_vec().unwrap(), vec![1.0, 2.0]);
        // "sample": false requests get residual-only progress ticks.
        assert!(iterate_frame(5, 2, 0.125, None).get("sample").is_none());
    }

    #[test]
    fn protocol_version_gates_the_dialect() {
        // v1 requests get the framed final; v0 responses carry no
        // envelope keys at all (legacy byte-compatibility).
        let eng = engine();
        let legacy = json::parse(&handle_line_engine(
            &eng,
            "gmm_toy2d",
            r#"{"id":1,"sampler":"srds","n":16,"seed":3,"sample":false}"#,
        ))
        .unwrap();
        assert_eq!(legacy.get("ok").unwrap().as_bool(), Some(true), "{legacy:?}");
        assert!(legacy.get("v").is_none() && legacy.get("frame").is_none(), "{legacy:?}");
        // timed_out is the one new key legacy responses gain; it reads
        // false on an unbudgeted run.
        assert_eq!(legacy.get("timed_out").unwrap().as_bool(), Some(false));
        let framed = json::parse(&handle_line_engine(
            &eng,
            "gmm_toy2d",
            r#"{"v":1,"id":1,"sampler":"srds","n":16,"seed":3,"sample":false}"#,
        ))
        .unwrap();
        assert_eq!(framed.get("v").unwrap().as_f64(), Some(1.0), "{framed:?}");
        assert_eq!(framed.get("frame").unwrap().as_str(), Some("final"));
        assert_eq!(framed.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(
            framed.get("iters").unwrap().as_f64(),
            legacy.get("iters").unwrap().as_f64(),
            "the envelope is additive: same body either way"
        );
        // An unknown version is rejected up front, shaped as legacy
        // (that client can't be assumed to parse v1 frames).
        let bad = json::parse(&handle_line_engine(
            &eng,
            "gmm_toy2d",
            r#"{"v":2,"id":8,"sampler":"srds","n":16}"#,
        ))
        .unwrap();
        assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false), "{bad:?}");
        assert_eq!(bad.get("id").unwrap().as_f64(), Some(8.0));
        assert!(
            bad.get("error").unwrap().as_str().unwrap().contains("protocol version"),
            "{bad:?}"
        );
    }

    #[test]
    fn strict_mode_rejects_unknown_keys_only_behind_v1() {
        // v0: historical tolerance — a junk key is ignored.
        let o = LazyObj::parse(r#"{"id":1,"n":16,"timeout_millis":50}"#).unwrap();
        assert!(SampleRequest::from_json(&o).is_ok(), "v0 stays tolerant");
        // v1: the same typo is a typed unknown_field error.
        let o = LazyObj::parse(r#"{"v":1,"id":1,"n":16,"timeout_millis":50}"#).unwrap();
        let err = SampleRequest::from_json(&o).unwrap_err();
        assert_eq!(err.kind, ErrKind::UnknownField);
        assert_eq!(err.id, Some(1));
        assert!(err.detail.contains("timeout_millis"), "{}", err.detail);
        let wire = error_frame(&err, 1);
        assert_eq!(wire.get("kind").unwrap().as_str(), Some("unknown_field"));
        assert_eq!(wire.get("frame").unwrap().as_str(), Some("error"));
        // Every documented key passes strict mode.
        let o = LazyObj::parse(
            r#"{"v":1,"id":1,"kind":"sample","sampler":"srds","n":16,"class":0,
                "guidance":1.5,"seed":3,"tol":0.01,"norm":"l1_mean","max_iters":3,
                "block":4,"window":8,"history":2,"priority":"standard","deadline":100,
                "timeout_ms":500,"stream":false,"sample":true,"iterates":false}"#,
        )
        .unwrap();
        assert!(SampleRequest::from_json(&o).is_ok(), "the full schema is known to strict mode");
    }

    #[test]
    fn stream_requires_v1_and_an_anytime_sampler_and_a_serving_loop() {
        // v0 + stream: rejected at parse time.
        let o = LazyObj::parse(r#"{"id":1,"n":16,"stream":true}"#).unwrap();
        let err = SampleRequest::from_json(&o).unwrap_err();
        assert!(err.detail.contains("\"v\": 1"), "{}", err.detail);
        // v1 + stream on a non-anytime sampler: typed validation error
        // from spec resolution (the serving loop's pre-ack check).
        let o = LazyObj::parse(r#"{"v":1,"id":2,"sampler":"sequential","n":16,"stream":true}"#)
            .unwrap();
        let req = SampleRequest::from_json(&o).unwrap();
        let err = request_spec("gmm_toy2d", &req).unwrap_err();
        assert_eq!(err.kind, ErrKind::Invalid);
        assert!(err.detail.contains("anytime"), "{}", err.detail);
        // v1 + stream + srds on a single-response endpoint: rejected —
        // blocking paths have nowhere to put iterate frames.
        let eng = engine();
        let v = json::parse(&handle_line_engine(
            &eng,
            "gmm_toy2d",
            r#"{"v":1,"id":3,"sampler":"srds","n":16,"stream":true}"#,
        ))
        .unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{v:?}");
        assert!(v.get("error").unwrap().as_str().unwrap().contains("serving loop"), "{v:?}");
    }

    #[test]
    fn wall_clock_timeout_is_honest_over_the_wire() {
        // timeout_ms: 0 expires on the dispatcher's first sweep, before
        // any model eval. SRDS degrades to its newest (here: zeroth)
        // iterate and *succeeds* with timed_out: true — the anytime
        // property on the wire.
        let eng = engine();
        let line = r#"{"id":11,"sampler":"srds","n":16,"seed":4,"tol":0.0,"timeout_ms":0}"#;
        let v = json::parse(&handle_line_engine(&eng, "gmm_toy2d", line)).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("timed_out").unwrap().as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("converged").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("iters").unwrap().as_f64(), Some(0.0), "no refinement completed");
        let sample = v.get("sample").unwrap().as_f32_vec().unwrap();
        assert!(sample.iter().all(|x| x.is_finite()));
        // A sampler with no anytime iterate can't degrade: typed
        // timeout error (error_kind at v0, kind inside a frame at v1).
        let line = r#"{"id":12,"sampler":"sequential","n":16,"seed":4,"timeout_ms":0}"#;
        let v = json::parse(&handle_line_engine(&eng, "gmm_toy2d", line)).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{v:?}");
        assert_eq!(v.get("error_kind").unwrap().as_str(), Some("timeout"), "{v:?}");
        let line = r#"{"v":1,"id":13,"sampler":"sequential","n":16,"seed":4,"timeout_ms":0}"#;
        let v = json::parse(&handle_line_engine(&eng, "gmm_toy2d", line)).unwrap();
        assert_eq!(v.get("frame").unwrap().as_str(), Some("error"), "{v:?}");
        assert_eq!(v.get("kind").unwrap().as_str(), Some("timeout"), "{v:?}");
        // Negative is rejected at parse time, like deadline.
        let line = r#"{"id":14,"n":16,"timeout_ms":-5}"#;
        let v = json::parse(&handle_line_engine(&eng, "gmm_toy2d", line)).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{v:?}");
        assert!(v.get("error").unwrap().as_str().unwrap().contains("timeout_ms"), "{v:?}");
    }

    #[test]
    fn stats_probe_speaks_both_dialects() {
        let r = router(2);
        let legacy = json::parse(&handle_line_router(&r, "gmm_toy2d", r#"{"id":1,"kind":"stats"}"#))
            .unwrap();
        assert!(legacy.get("frame").is_none(), "{legacy:?}");
        assert_eq!(legacy.get("kind").unwrap().as_str(), Some("stats"));
        let framed = json::parse(&handle_line_router(
            &r,
            "gmm_toy2d",
            r#"{"v":1,"id":2,"kind":"stats"}"#,
        ))
        .unwrap();
        assert_eq!(framed.get("v").unwrap().as_f64(), Some(1.0), "{framed:?}");
        assert_eq!(framed.get("frame").unwrap().as_str(), Some("stats"));
        assert_eq!(framed.get("shards").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn non_object_lines_are_parse_errors_not_defaulted_requests() {
        // The lazy reader only accepts object lines; a bare scalar or
        // array must come back as a parse error, never run a sampler
        // with all-default knobs.
        let be = backend();
        for bad in ["5", "[1,2]", "\"srds\"", "true", "null"] {
            let resp = handle_line(be.as_ref(), "gmm_toy2d", bad);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{bad} -> {resp}");
            assert!(v.get("sampler").is_none(), "{bad} must not run: {resp}");
        }
    }
}
