//! JSON-line sampling server — the L3 request path.
//!
//! Protocol (one JSON object per line, over TCP; see DESIGN.md for the
//! full field table):
//!
//! ```json
//! {"id": 1, "sampler": "srds", "n": 25, "class": 2, "guidance": 7.5,
//!  "seed": 42, "tol": 0.0025, "max_iters": 3, "block": 5,
//!  "window": 32, "history": 2, "norm": "l1_mean"}
//! ```
//!
//! `sampler` must name an entry of [`registry`] — unknown names are
//! rejected with an `ok: false` error line rather than silently falling
//! back. The kind-specific knobs (`block` for SRDS, `window` for
//! ParaDiGMS, `history` for ParaTAA) are optional and ignored by
//! samplers they don't apply to.
//!
//! Response line:
//!
//! ```json
//! {"id": 1, "ok": true, "sampler": "srds", "iters": 2, "converged": true,
//!  "eff_serial_evals": 25, "eff_serial_evals_pipelined": 17,
//!  "total_evals": 74, "peak_states": 17, "wall_ms": 12.3, "sample": [...]}
//! ```
//!
//! Sampler workers each own a thread-bound backend (native or PJRT);
//! requests are dispatched over an mpsc queue and responses routed back
//! through per-request channels. Python is never involved.

use crate::coordinator::{
    prior_sample, registry, Conditioning, ConvNorm, SampleOutput, SamplerSpec,
};
use crate::data::make_gmm;
use crate::json::{self, Value};
use crate::solvers::{BackendFactory, StepBackend};
use crate::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

/// A parsed sampling request: the sampler name plus every
/// [`SamplerSpec`] knob the wire protocol exposes.
#[derive(Debug, Clone)]
pub struct SampleRequest {
    pub id: u64,
    pub sampler: String,
    pub n: usize,
    pub class: Option<u32>,
    pub guidance: f32,
    pub seed: u64,
    pub tol: f32,
    pub norm: ConvNorm,
    pub max_iters: Option<usize>,
    /// SRDS fine steps per block.
    pub block: Option<usize>,
    /// ParaDiGMS sliding window.
    pub window: Option<usize>,
    /// ParaTAA Anderson history depth.
    pub history: Option<usize>,
    pub return_sample: bool,
    /// Return the per-refinement final-sample iterates too.
    pub return_iterates: bool,
}

impl SampleRequest {
    pub fn from_json(v: &Value) -> Result<Self> {
        let num = |k: &str, default: f64| v.get(k).and_then(|x| x.as_f64()).unwrap_or(default);
        let norm = match v.get("norm").and_then(|x| x.as_str()) {
            None => ConvNorm::L1Mean,
            Some(s) => ConvNorm::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown norm {s:?} (l1_mean/l2_mean/linf)"))?,
        };
        Ok(SampleRequest {
            id: num("id", 0.0) as u64,
            sampler: v
                .get("sampler")
                .and_then(|x| x.as_str())
                .unwrap_or("srds")
                .to_string(),
            n: num("n", 25.0) as usize,
            class: v.get("class").and_then(|x| x.as_f64()).map(|c| c as u32),
            guidance: num("guidance", 0.0) as f32,
            seed: num("seed", 0.0) as u64,
            tol: num("tol", 2.5e-3) as f32,
            norm,
            max_iters: v.get("max_iters").and_then(|x| x.as_usize()),
            block: v.get("block").and_then(|x| x.as_usize()),
            window: v.get("window").and_then(|x| x.as_usize()),
            history: v.get("history").and_then(|x| x.as_usize()),
            return_sample: v.get("sample").and_then(|x| x.as_bool()).unwrap_or(true),
            return_iterates: v.get("iterates").and_then(|x| x.as_bool()).unwrap_or(false),
        })
    }

    /// Build the [`SamplerSpec`] this request describes, given the
    /// sampler's default kind and the request's conditioning.
    pub fn to_spec(&self, kind: crate::coordinator::SamplerKind, cond: Conditioning) -> SamplerSpec {
        let mut kind = kind;
        if let Some(w) = self.window {
            kind = kind.with_window(w);
        }
        if let Some(h) = self.history {
            kind = kind.with_history(h);
        }
        let mut spec = SamplerSpec::for_kind(self.n, kind)
            .with_tol(self.tol)
            .with_norm(self.norm)
            .with_seed(self.seed)
            .with_cond(cond);
        spec.block = self.block;
        spec.max_iters = self.max_iters;
        spec.keep_iterates = self.return_iterates;
        spec
    }
}

fn error_response(id: u64, msg: String) -> Value {
    json::obj(vec![
        ("id", Value::Num(id as f64)),
        ("ok", Value::Bool(false)),
        ("error", Value::Str(msg)),
    ])
}

/// Execute one request on a backend via the sampler registry. The
/// conditioning mask comes from the dataset zoo when the model is a
/// conditional GMM.
pub fn run_request(
    backend: &dyn StepBackend,
    model_name: &str,
    req: &SampleRequest,
) -> Value {
    let reg = registry();
    let Some(sampler) = reg.parse(&req.sampler) else {
        return error_response(
            req.id,
            format!(
                "unknown sampler {:?}; available: {}",
                req.sampler,
                reg.list().join(", ")
            ),
        );
    };
    let cond = match req.class {
        Some(c) if model_name.contains("latent_cond") => {
            let gmm = make_gmm("latent_cond");
            Conditioning::class(gmm.class_mask(c), req.guidance)
        }
        _ => Conditioning::none(),
    };
    let spec = req.to_spec(sampler.kind(), cond);
    // A range error must be an error line, not a worker-thread panic.
    if let Err(msg) = spec.validate() {
        return error_response(req.id, msg);
    }
    let x0 = prior_sample(backend.dim(), req.seed);
    let t0 = std::time::Instant::now();
    let out: SampleOutput = sampler.run(backend, &x0, &spec);
    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
    let mut pairs = vec![
        ("id", Value::Num(req.id as f64)),
        ("ok", Value::Bool(true)),
        ("sampler", Value::Str(sampler.name().to_string())),
        ("iters", Value::Num(out.stats.iters as f64)),
        ("converged", Value::Bool(out.stats.converged)),
        ("eff_serial_evals", Value::Num(out.stats.eff_serial_evals as f64)),
        (
            "eff_serial_evals_pipelined",
            Value::Num(out.stats.eff_serial_evals_pipelined as f64),
        ),
        ("total_evals", Value::Num(out.stats.total_evals as f64)),
        ("peak_states", Value::Num(out.stats.peak_states as f64)),
        ("wall_ms", Value::Num(wall_ms)),
    ];
    if req.return_sample {
        pairs.push(("sample", json::arr_f32(&out.sample)));
    }
    if req.return_iterates {
        pairs.push((
            "iterates",
            Value::Arr(out.iterates.iter().map(|v| json::arr_f32(v)).collect()),
        ));
    }
    json::obj(pairs)
}

/// Handle one raw request line (exposed for tests; no socket needed).
pub fn handle_line(backend: &dyn StepBackend, model_name: &str, line: &str) -> String {
    let resp = match json::parse(line) {
        Ok(v) => match SampleRequest::from_json(&v) {
            Ok(req) => run_request(backend, model_name, &req),
            // Request-level validation errors still echo the id so
            // pipelined clients can correlate them.
            Err(e) => {
                let id = v.get("id").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
                error_response(id, format!("{e:#}"))
            }
        },
        // Malformed JSON: no id to echo.
        Err(e) => json::obj(vec![
            ("ok", Value::Bool(false)),
            ("error", Value::Str(format!("{e:#}"))),
        ]),
    };
    json::to_string(&resp)
}

/// Server configuration.
pub struct ServeConfig {
    pub addr: String,
    /// Sampler worker threads (each owns one backend instance).
    pub workers: usize,
    pub model_name: String,
    pub factory: Arc<dyn BackendFactory>,
}

enum WorkItem {
    Line(String, Sender<String>),
}

/// Run the blocking accept loop. Each connection thread parses lines and
/// queues them for the sampler workers; responses stream back in
/// completion order per connection.
pub fn serve(cfg: ServeConfig) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)?;
    eprintln!(
        "srds-server listening on {} (model={}, workers={}, samplers={})",
        cfg.addr,
        cfg.model_name,
        cfg.workers,
        registry().list().join("/")
    );
    let (work_tx, work_rx) = channel::<WorkItem>();
    let work_rx = Arc::new(Mutex::new(work_rx));
    for w in 0..cfg.workers {
        let rx = work_rx.clone();
        let factory = cfg.factory.clone();
        let model_name = cfg.model_name.clone();
        std::thread::Builder::new()
            .name(format!("srds-sampler-{w}"))
            .spawn(move || {
                let backend = factory.create();
                loop {
                    let item = { rx.lock().unwrap().recv() };
                    let Ok(WorkItem::Line(line, resp_tx)) = item else { break };
                    let resp = handle_line(backend.as_ref(), &model_name, &line);
                    let _ = resp_tx.send(resp);
                }
            })?;
    }
    for stream in listener.incoming() {
        let stream = stream?;
        let work_tx = work_tx.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, work_tx) {
                eprintln!("connection error: {e:#}");
            }
        });
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, work_tx: Sender<WorkItem>) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let (resp_tx, resp_rx) = channel::<String>();
    // Dedicated writer thread: responses stream back the moment a sampler
    // worker finishes, independent of the (possibly idle) read side — a
    // blocked reader must never delay completed work.
    let writer_handle = std::thread::spawn(move || -> Result<()> {
        for resp in resp_rx {
            writeln!(writer, "{resp}")?;
        }
        Ok(())
    });
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        work_tx
            .send(WorkItem::Line(line, resp_tx.clone()))
            .map_err(|_| anyhow::anyhow!("server shutting down"))?;
    }
    // Reader EOF: drop our resp_tx; the writer exits once the in-flight
    // worker clones finish and the channel drains.
    drop(resp_tx);
    let _ = writer_handle.join();
    eprintln!("connection {peer} done");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ConvNorm;
    use crate::exec::NativeFactory;
    use crate::model::GmmEps;
    use crate::solvers::Solver;

    fn backend() -> Box<dyn StepBackend> {
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(make_gmm("toy2d")));
        NativeFactory::new(model, Solver::Ddim).create()
    }

    #[test]
    fn handle_line_srds() {
        let be = backend();
        let resp = handle_line(be.as_ref(), "gmm_toy2d", r#"{"id": 5, "n": 16, "tol": 0.001}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("id").unwrap().as_f64(), Some(5.0));
        assert_eq!(v.get("sampler").unwrap().as_str(), Some("srds"));
        assert_eq!(v.get("sample").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn handle_line_every_registered_sampler() {
        let be = backend();
        for sampler in registry().list() {
            let line = format!(r#"{{"id":1,"sampler":"{sampler}","n":16,"sample":false}}"#);
            let resp = handle_line(be.as_ref(), "gmm_toy2d", &line);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{sampler}: {resp}");
            assert_eq!(v.get("sampler").unwrap().as_str(), Some(sampler));
            assert!(v.get("sample").is_none());
            assert!(v.get("eff_serial_evals_pipelined").is_some(), "{sampler}: {resp}");
        }
    }

    #[test]
    fn handle_line_rejects_unknown_sampler() {
        // No silent SRDS fallback: unknown names are an explicit error.
        let be = backend();
        let resp =
            handle_line(be.as_ref(), "gmm_toy2d", r#"{"id": 9, "sampler": "ddim", "n": 16}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert_eq!(v.get("id").unwrap().as_f64(), Some(9.0), "error echoes the request id");
        let err = v.get("error").unwrap().as_str().unwrap().to_string();
        assert!(err.contains("unknown sampler"), "{err}");
        assert!(err.contains("srds"), "error lists the registry: {err}");
        assert!(v.get("sample").is_none());
    }

    #[test]
    fn handle_line_rejects_out_of_range_block() {
        // block is asserted deep inside Partition::with_block; the server
        // must reject it up front instead of panicking a worker thread.
        let be = backend();
        for bad in [r#"{"id":2,"n":16,"block":0}"#, r#"{"id":2,"n":16,"block":17}"#, r#"{"id":2,"n":0}"#] {
            let resp = handle_line(be.as_ref(), "gmm_toy2d", bad);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{bad} -> {resp}");
        }
        // Boundary values are fine: block == n is one block of n steps.
        let resp = handle_line(be.as_ref(), "gmm_toy2d", r#"{"id":3,"n":16,"block":16}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
    }

    #[test]
    fn handle_line_rejects_unknown_norm() {
        let be = backend();
        let resp = handle_line(be.as_ref(), "gmm_toy2d", r#"{"id":7,"n":16,"norm":"l7"}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        // Validation errors echo the id so pipelined clients can
        // correlate them with the failed request.
        assert_eq!(v.get("id").unwrap().as_f64(), Some(7.0), "{resp}");
    }

    #[test]
    fn paradigms_max_iters_zero_still_runs_one_sweep() {
        // max_iters is clamped to >= 1 in every sampler; a cap of 0 must
        // not return the untouched prior as a "sample".
        let be = backend();
        let resp = handle_line(
            be.as_ref(),
            "gmm_toy2d",
            r#"{"id":1,"sampler":"paradigms","n":16,"max_iters":0}"#,
        );
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert!(v.get("iters").unwrap().as_f64().unwrap() >= 1.0, "{resp}");
    }

    #[test]
    fn handle_line_bad_json() {
        let be = backend();
        let resp = handle_line(be.as_ref(), "gmm_toy2d", "{nope");
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn request_knobs_reach_the_spec() {
        let v = json::parse(
            r#"{"sampler":"paradigms","n":64,"window":16,"history":5,"block":4,
                "norm":"linf","max_iters":7,"tol":0.5,"iterates":true}"#,
        )
        .unwrap();
        let req = SampleRequest::from_json(&v).unwrap();
        let kind = registry().parse(&req.sampler).unwrap().kind();
        let spec = req.to_spec(kind, Conditioning::none());
        assert_eq!(spec.window(), Some(16), "window reaches ParaDiGMS");
        assert_eq!(spec.block, Some(4));
        assert_eq!(spec.norm, ConvNorm::LInf);
        assert_eq!(spec.max_iters, Some(7));
        assert!(spec.keep_iterates);
        // history is a ParaTAA knob; on a paradigms request it's ignored.
        assert_eq!(spec.history(), 2);
    }

    #[test]
    fn samplers_agree_on_sample() {
        // The registry-driven interchangeability check, over the wire
        // protocol: every registered sampler reproduces the sequential
        // sample at tight tolerance.
        let be = backend();
        let mk = |sampler: &str| {
            let line =
                format!(r#"{{"id":1,"sampler":"{sampler}","n":25,"seed":9,"tol":1e-6}}"#);
            let resp = handle_line(be.as_ref(), "gmm_toy2d", &line);
            json::parse(&resp).unwrap().get("sample").unwrap().as_f32_vec().unwrap()
        };
        let seq = mk("sequential");
        for sampler in registry().list() {
            let out = mk(sampler);
            let d = ConvNorm::L1Mean.dist(&out, &seq);
            assert!(d < 1e-2, "{sampler} vs sequential: {d}");
        }
    }
}
