//! JSON-line sampling server — the L3 request path.
//!
//! Protocol (one JSON object per line, over TCP):
//!
//! ```json
//! {"id": 1, "sampler": "srds", "n": 25, "class": 2, "guidance": 7.5,
//!  "seed": 42, "tol": 0.0025, "max_iters": 3}
//! ```
//!
//! Response line:
//!
//! ```json
//! {"id": 1, "ok": true, "iters": 2, "eff_serial_evals": 17,
//!  "total_evals": 74, "wall_ms": 12.3, "sample": [...]}
//! ```
//!
//! Sampler workers each own a thread-bound backend (native or PJRT);
//! requests are dispatched over an mpsc queue and responses routed back
//! through per-request channels. Python is never involved.

use crate::coordinator::{
    paradigms, parataa, prior_sample, sequential, srds, Conditioning, ParadigmsConfig,
    ParataaConfig, SrdsConfig,
};
use crate::data::make_gmm;
use crate::json::{self, Value};
use crate::solvers::{BackendFactory, StepBackend};
use crate::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

/// A parsed sampling request.
#[derive(Debug, Clone)]
pub struct SampleRequest {
    pub id: u64,
    pub sampler: String,
    pub n: usize,
    pub class: Option<u32>,
    pub guidance: f32,
    pub seed: u64,
    pub tol: f32,
    pub max_iters: Option<usize>,
    pub return_sample: bool,
}

impl SampleRequest {
    pub fn from_json(v: &Value) -> Result<Self> {
        let num = |k: &str, default: f64| v.get(k).and_then(|x| x.as_f64()).unwrap_or(default);
        Ok(SampleRequest {
            id: num("id", 0.0) as u64,
            sampler: v
                .get("sampler")
                .and_then(|x| x.as_str())
                .unwrap_or("srds")
                .to_string(),
            n: num("n", 25.0) as usize,
            class: v.get("class").and_then(|x| x.as_f64()).map(|c| c as u32),
            guidance: num("guidance", 0.0) as f32,
            seed: num("seed", 0.0) as u64,
            tol: num("tol", 2.5e-3) as f32,
            max_iters: v.get("max_iters").and_then(|x| x.as_usize()),
            return_sample: v.get("sample").and_then(|x| x.as_bool()).unwrap_or(true),
        })
    }
}

/// Execute one request on a backend. The conditioning mask comes from the
/// dataset zoo when the model is a conditional GMM.
pub fn run_request(
    backend: &dyn StepBackend,
    model_name: &str,
    req: &SampleRequest,
) -> Value {
    let dim = backend.dim();
    let cond = match req.class {
        Some(c) if model_name.contains("latent_cond") => {
            let gmm = make_gmm("latent_cond");
            Conditioning::class(gmm.class_mask(c), req.guidance)
        }
        _ => Conditioning::none(),
    };
    let x0 = prior_sample(dim, req.seed);
    let t0 = std::time::Instant::now();
    let (sample, iters, eff, total, converged) = match req.sampler.as_str() {
        "sequential" => {
            let (s, st) = sequential(backend, &x0, req.n, &cond, req.seed);
            (s, 0, st.eff_serial_evals, st.total_evals, true)
        }
        "paradigms" => {
            let mut cfg = ParadigmsConfig::new(req.n).with_tol(req.tol).with_seed(req.seed);
            cfg.cond = cond;
            let r = paradigms(backend, &x0, &cfg);
            (r.sample, r.stats.iters, r.stats.eff_serial_evals, r.stats.total_evals, r.stats.converged)
        }
        "parataa" => {
            let mut cfg = ParataaConfig::new(req.n).with_tol(req.tol).with_seed(req.seed);
            cfg.cond = cond;
            let r = parataa(backend, &x0, &cfg);
            (r.sample, r.stats.iters, r.stats.eff_serial_evals, r.stats.total_evals, r.stats.converged)
        }
        _ => {
            // srds (default)
            let mut cfg = SrdsConfig::new(req.n).with_tol(req.tol).with_seed(req.seed).with_cond(cond);
            if let Some(k) = req.max_iters {
                cfg = cfg.with_max_iters(k);
            }
            let r = srds(backend, &x0, &cfg);
            (
                r.sample,
                r.stats.iters,
                r.stats.eff_serial_evals_pipelined,
                r.stats.total_evals,
                r.stats.converged,
            )
        }
    };
    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
    let mut pairs = vec![
        ("id", Value::Num(req.id as f64)),
        ("ok", Value::Bool(true)),
        ("sampler", Value::Str(req.sampler.clone())),
        ("iters", Value::Num(iters as f64)),
        ("eff_serial_evals", Value::Num(eff as f64)),
        ("total_evals", Value::Num(total as f64)),
        ("converged", Value::Bool(converged)),
        ("wall_ms", Value::Num(wall_ms)),
    ];
    if req.return_sample {
        pairs.push(("sample", json::arr_f32(&sample)));
    }
    json::obj(pairs)
}

/// Handle one raw request line (exposed for tests; no socket needed).
pub fn handle_line(backend: &dyn StepBackend, model_name: &str, line: &str) -> String {
    let resp = match json::parse(line).and_then(|v| SampleRequest::from_json(&v)) {
        Ok(req) => run_request(backend, model_name, &req),
        Err(e) => json::obj(vec![
            ("ok", Value::Bool(false)),
            ("error", Value::Str(format!("{e:#}"))),
        ]),
    };
    json::to_string(&resp)
}

/// Server configuration.
pub struct ServeConfig {
    pub addr: String,
    /// Sampler worker threads (each owns one backend instance).
    pub workers: usize,
    pub model_name: String,
    pub factory: Arc<dyn BackendFactory>,
}

enum WorkItem {
    Line(String, Sender<String>),
}

/// Run the blocking accept loop. Each connection thread parses lines and
/// queues them for the sampler workers; responses stream back in
/// completion order per connection.
pub fn serve(cfg: ServeConfig) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)?;
    eprintln!(
        "srds-server listening on {} (model={}, workers={})",
        cfg.addr, cfg.model_name, cfg.workers
    );
    let (work_tx, work_rx) = channel::<WorkItem>();
    let work_rx = Arc::new(Mutex::new(work_rx));
    for w in 0..cfg.workers {
        let rx = work_rx.clone();
        let factory = cfg.factory.clone();
        let model_name = cfg.model_name.clone();
        std::thread::Builder::new()
            .name(format!("srds-sampler-{w}"))
            .spawn(move || {
                let backend = factory.create();
                loop {
                    let item = { rx.lock().unwrap().recv() };
                    let Ok(WorkItem::Line(line, resp_tx)) = item else { break };
                    let resp = handle_line(backend.as_ref(), &model_name, &line);
                    let _ = resp_tx.send(resp);
                }
            })?;
    }
    for stream in listener.incoming() {
        let stream = stream?;
        let work_tx = work_tx.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, work_tx) {
                eprintln!("connection error: {e:#}");
            }
        });
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, work_tx: Sender<WorkItem>) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let (resp_tx, resp_rx) = channel::<String>();
    // Dedicated writer thread: responses stream back the moment a sampler
    // worker finishes, independent of the (possibly idle) read side — a
    // blocked reader must never delay completed work.
    let writer_handle = std::thread::spawn(move || -> Result<()> {
        for resp in resp_rx {
            writeln!(writer, "{resp}")?;
        }
        Ok(())
    });
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        work_tx
            .send(WorkItem::Line(line, resp_tx.clone()))
            .map_err(|_| anyhow::anyhow!("server shutting down"))?;
    }
    // Reader EOF: drop our resp_tx; the writer exits once the in-flight
    // worker clones finish and the channel drains.
    drop(resp_tx);
    let _ = writer_handle.join();
    eprintln!("connection {peer} done");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::NativeFactory;
    use crate::model::GmmEps;
    use crate::solvers::Solver;

    fn backend() -> Box<dyn StepBackend> {
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(make_gmm("toy2d")));
        NativeFactory::new(model, Solver::Ddim).create()
    }

    #[test]
    fn handle_line_srds() {
        let be = backend();
        let resp = handle_line(be.as_ref(), "gmm_toy2d", r#"{"id": 5, "n": 16, "tol": 0.001}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("id").unwrap().as_f64(), Some(5.0));
        assert_eq!(v.get("sample").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn handle_line_all_samplers() {
        let be = backend();
        for sampler in ["sequential", "srds", "paradigms", "parataa"] {
            let line = format!(r#"{{"id":1,"sampler":"{sampler}","n":16,"sample":false}}"#);
            let resp = handle_line(be.as_ref(), "gmm_toy2d", &line);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{sampler}: {resp}");
            assert!(v.get("sample").is_none());
        }
    }

    #[test]
    fn handle_line_bad_json() {
        let be = backend();
        let resp = handle_line(be.as_ref(), "gmm_toy2d", "{nope");
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn samplers_agree_on_sample() {
        let be = backend();
        let mk = |sampler: &str| {
            let line =
                format!(r#"{{"id":1,"sampler":"{sampler}","n":25,"seed":9,"tol":1e-6}}"#);
            let resp = handle_line(be.as_ref(), "gmm_toy2d", &line);
            json::parse(&resp).unwrap().get("sample").unwrap().as_f32_vec().unwrap()
        };
        let seq = mk("sequential");
        let srds_s = mk("srds");
        for (a, b) in seq.iter().zip(&srds_s) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}
