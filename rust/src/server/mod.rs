//! JSON-line sampling server — the L3 request path.
//!
//! Protocol (one JSON object per line, over TCP; see DESIGN.md for the
//! full field table):
//!
//! ```json
//! {"id": 1, "sampler": "srds", "n": 25, "class": 2, "guidance": 7.5,
//!  "seed": 42, "tol": 0.0025, "max_iters": 3, "block": 5,
//!  "window": 32, "history": 2, "norm": "l1_mean",
//!  "priority": "interactive", "deadline": 120}
//! ```
//!
//! `sampler` must name an entry of [`registry`] — unknown names are
//! rejected with an `ok: false` error line rather than silently falling
//! back. The kind-specific knobs (`block` for SRDS, `window` for
//! ParaDiGMS, `history` for ParaTAA) are optional and ignored by
//! samplers they don't apply to. `priority`
//! (`interactive`/`standard`/`batch`, default `standard`) selects the
//! request's QoS lane in the engine's weighted-DRR batcher; `deadline`
//! is the anytime eval budget (model evals) after which SRDS finalizes
//! from its best completed iterate (`deadline_hit: true` in the
//! response) — unset requests inherit
//! [`ServeConfig::default_deadline`].
//!
//! Response line:
//!
//! ```json
//! {"id": 1, "ok": true, "sampler": "srds", "iters": 2, "converged": true,
//!  "deadline_hit": false, "priority": "interactive",
//!  "eff_serial_evals": 25, "eff_serial_evals_pipelined": 17,
//!  "total_evals": 74, "peak_states": 17, "wall_ms": 12.3,
//!  "batch_occupancy": 3.4, "engine_rows": 74,
//!  "queue_depth": 12, "active_tasks": 3, "flushed_batches": 210,
//!  "split_batches": 4,
//!  "classes": {"interactive": {"active": 1, "completed": 7, "rows": 310,
//!              "mean_wall_ms": 4.2, "deadline_hits": 0}, "standard": {},
//!              "batch": {}},
//!  "sample": [...]}
//! ```
//!
//! A request arriving while the connection is at its in-flight cap is
//! shed immediately with the structured admission error
//! (`{"id": …, "ok": false, "error_kind": "overloaded",
//! "retry_after_ms": …}` — see [`overloaded_response`]) instead of
//! stalling the read loop. A `{"kind": "stats"}` line is the
//! observability probe: it returns the fleet-aggregated engine snapshot
//! (including `shards` / `steals`) without running any sampler and
//! without taking an admission slot, so health checks work even on a
//! saturated connection.
//!
//! `batch_occupancy` / `engine_rows` are per-request fusion stats;
//! `queue_depth` / `active_tasks` / `flushed_batches` /
//! `split_batches` (flush fan-outs across idle workers) are engine-wide
//! snapshots taken at completion (absent when a request is executed
//! off-engine, e.g. via [`run_request`] in unit tests). `active_tasks`
//! is the depth of the engine's heterogeneous task table — how many
//! requests, of any sampler kind, were still resident when this one
//! finished.
//!
//! Every request is dispatched into the sharded engine fleet
//! ([`crate::exec::router`] fronting N [`crate::exec::engine`] shards)
//! as an engine-native [`crate::exec::task::SamplerTask`]: SRDS,
//! sequential, ParaDiGMS and ParaTAA all run as dependency-driven
//! state machines inside a shard's dispatcher, and each solver step
//! becomes a batch row that can fuse with co-tenant requests' rows
//! (`batch_occupancy` in the response reports how much fusion the
//! request actually saw). There are **no per-request threads and no
//! per-connection threads**: one nonblocking poll loop owns every
//! socket (accept, partial-line reassembly, write backpressure), the
//! router places each request onto a shard by load + QoS class, and
//! shard dispatchers steal queued rows from saturated siblings — the
//! process runs exactly `1 + shards × (1 + workers)` threads no matter
//! how many connections or requests are live. A connection that dies
//! flips its requests' liveness flags, and the owning dispatchers
//! abort them (queued rows purged, `aborted` counted) instead of
//! computing results nobody will read. Python is never involved.

use crate::batching::BatchPolicy;
use crate::coordinator::{
    prior_sample, registry, Conditioning, ConvNorm, QosClass, SampleOutput, SamplerSpec,
};
use crate::data::make_gmm;
use crate::exec::{Engine, EngineStats, Router, RouterConfig};
use crate::json::{self, Value};
use crate::solvers::{BackendFactory, StepBackend};
use crate::Result;
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// A parsed sampling request: the sampler name plus every
/// [`SamplerSpec`] knob the wire protocol exposes.
#[derive(Debug, Clone)]
pub struct SampleRequest {
    pub id: u64,
    pub sampler: String,
    pub n: usize,
    pub class: Option<u32>,
    pub guidance: f32,
    pub seed: u64,
    pub tol: f32,
    pub norm: ConvNorm,
    pub max_iters: Option<usize>,
    /// SRDS fine steps per block.
    pub block: Option<usize>,
    /// ParaDiGMS sliding window.
    pub window: Option<usize>,
    /// ParaTAA Anderson history depth.
    pub history: Option<usize>,
    /// QoS priority class (`"priority"` on the wire:
    /// `interactive`/`standard`/`batch`; default standard). Scheduling
    /// only — never changes the sample.
    pub priority: QosClass,
    /// Anytime eval budget (`"deadline"` on the wire, in model evals):
    /// SRDS finalizes from its best completed iterate once spent,
    /// reporting `deadline_hit: true` + `converged: false`. `None`
    /// (absent) falls back to [`ServeConfig::default_deadline`] on the
    /// serve loop; an explicit `Some(0)` means *unbudgeted* — the
    /// client's opt-out from the server default.
    pub deadline: Option<u64>,
    pub return_sample: bool,
    /// Return the per-refinement final-sample iterates too.
    pub return_iterates: bool,
}

impl SampleRequest {
    // lint: request-path
    pub fn from_json(v: &Value) -> Result<Self> {
        let num = |k: &str, default: f64| v.get(k).and_then(|x| x.as_f64()).unwrap_or(default);
        // "kind" selects the request flavor: absent or "sample" is a
        // sampling request (this parser); "stats" is the engine-snapshot
        // probe, which the serving entry points intercept *before*
        // from_json — one reaching here means the caller has no engine
        // to snapshot.
        match v.get("kind").and_then(|x| x.as_str()) {
            None | Some("sample") => {}
            Some(k) => {
                return Err(anyhow::anyhow!(
                    "unsupported kind {k:?} here (\"sample\"; \"stats\" is served by \
                     engine-backed endpoints)"
                ))
            }
        }
        let norm = match v.get("norm").and_then(|x| x.as_str()) {
            None => ConvNorm::L1Mean,
            Some(s) => ConvNorm::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown norm {s:?} (l1_mean/l2_mean/linf)"))?,
        };
        // Unknown priority names are an error, not a silent downgrade to
        // standard — a tenant must know its interactive flag didn't take.
        let priority = match v.get("priority").and_then(|x| x.as_str()) {
            None => QosClass::Standard,
            Some(s) => QosClass::parse(s).ok_or_else(|| {
                anyhow::anyhow!("unknown priority {s:?} (interactive/standard/batch)")
            })?,
        };
        // Budget semantics: absent → inherit the server's default;
        // explicit 0 → opt OUT of any budget (the escape hatch a
        // convergence-critical client needs when the operator set
        // --default-deadline); >= 1 → that many model evals. Negative
        // is rejected rather than degraded (the f64 → u64 cast would
        // saturate to a coarse-init-only run no client can have meant).
        let deadline = match v.get("deadline").and_then(|x| x.as_f64()) {
            None => None,
            Some(d) if d >= 0.0 => Some(d as u64),
            Some(d) => {
                return Err(anyhow::anyhow!(
                    "deadline must be >= 0 (0 = explicitly unbudgeted), got {d}"
                ))
            }
        };
        Ok(SampleRequest {
            id: num("id", 0.0) as u64,
            sampler: v
                .get("sampler")
                .and_then(|x| x.as_str())
                .unwrap_or("srds")
                .to_string(),
            n: num("n", 25.0) as usize,
            class: v.get("class").and_then(|x| x.as_f64()).map(|c| c as u32),
            guidance: num("guidance", 0.0) as f32,
            seed: num("seed", 0.0) as u64,
            tol: num("tol", 2.5e-3) as f32,
            norm,
            max_iters: v.get("max_iters").and_then(|x| x.as_usize()),
            block: v.get("block").and_then(|x| x.as_usize()),
            window: v.get("window").and_then(|x| x.as_usize()),
            history: v.get("history").and_then(|x| x.as_usize()),
            priority,
            deadline,
            return_sample: v.get("sample").and_then(|x| x.as_bool()).unwrap_or(true),
            return_iterates: v.get("iterates").and_then(|x| x.as_bool()).unwrap_or(false),
        })
    }

    /// Build the [`SamplerSpec`] this request describes, given the
    /// sampler's default kind and the request's conditioning.
    pub fn to_spec(&self, kind: crate::coordinator::SamplerKind, cond: Conditioning) -> SamplerSpec {
        let mut kind = kind;
        if let Some(w) = self.window {
            kind = kind.with_window(w);
        }
        if let Some(h) = self.history {
            kind = kind.with_history(h);
        }
        let mut spec = SamplerSpec::for_kind(self.n, kind)
            .with_tol(self.tol)
            .with_norm(self.norm)
            .with_seed(self.seed)
            .with_cond(cond);
        spec.block = self.block;
        spec.max_iters = self.max_iters;
        spec.keep_iterates = self.return_iterates;
        spec.priority = self.priority;
        // An explicit 0 is the opt-out: no budget, even when the serve
        // loop injected the server default into `deadline`.
        spec.deadline_evals = self.deadline.filter(|&d| d > 0);
        spec
    }
}

// lint: request-path
fn error_response(id: u64, msg: String) -> Value {
    json::obj(vec![
        ("id", Value::Num(id as f64)),
        ("ok", Value::Bool(false)),
        ("error", Value::Str(msg)),
    ])
}

/// Default backoff hint carried by [`overloaded_response`]
/// (`retry_after_ms`): a couple of typical small-request service times
/// — long enough that an immediate resend is unlikely to be shed
/// again, short enough not to idle an interactive client. A hint, not
/// a contract: clients may retry sooner and risk another shed.
pub const DEFAULT_RETRY_AFTER_MS: u64 = 25;

/// The structured admission-control error: sent the moment a request
/// would exceed the connection's in-flight cap, instead of stalling the
/// read loop. `error_kind: "overloaded"` is the machine-readable field
/// clients key their backoff on (the human-readable `error` text is not
/// a contract); `max_inflight` tells them the cap they hit, and
/// `retry_after_ms` is the server's backoff hint
/// ([`DEFAULT_RETRY_AFTER_MS`] from the serve loop).
// lint: request-path
pub fn overloaded_response(id: u64, max_inflight: usize, retry_after_ms: u64) -> Value {
    json::obj(vec![
        ("id", Value::Num(id as f64)),
        ("ok", Value::Bool(false)),
        ("error_kind", Value::Str("overloaded".into())),
        (
            "error",
            Value::Str(format!(
                "overloaded: connection already has {max_inflight} requests in flight; \
                 back off and retry"
            )),
        ),
        ("max_inflight", Value::Num(max_inflight as f64)),
        ("retry_after_ms", Value::Num(retry_after_ms as f64)),
    ])
}

/// Conditioning for a request: the mask comes from the dataset zoo when
/// the model is a conditional GMM.
// lint: request-path
fn request_cond(model_name: &str, req: &SampleRequest) -> Conditioning {
    match req.class {
        Some(c) if model_name.contains("latent_cond") => {
            let gmm = make_gmm("latent_cond");
            Conditioning::class(gmm.class_mask(c), req.guidance)
        }
        _ => Conditioning::none(),
    }
}

/// Resolve the request's sampler kind and build its validated spec, or
/// the error line to send back.
// lint: request-path
fn request_spec(model_name: &str, req: &SampleRequest) -> std::result::Result<SamplerSpec, Value> {
    let reg = registry();
    let Some(sampler) = reg.parse(&req.sampler) else {
        return Err(error_response(
            req.id,
            format!(
                "unknown sampler {:?}; available: {}",
                req.sampler,
                reg.list().join(", ")
            ),
        ));
    };
    let spec = req.to_spec(sampler.kind(), request_cond(model_name, req));
    // A range error must be an error line, not a worker-thread panic.
    if let Err(msg) = spec.validate() {
        return Err(error_response(req.id, msg));
    }
    Ok(spec)
}

/// Serialize a completed run; `engine` adds the engine-wide snapshot
/// fields next to the per-request ones in `out.stats` (the snapshot is
/// taken at completion — for callback-submitted requests the engine's
/// dispatcher provides it consistently at finalize time).
// lint: request-path
fn success_response(
    req: &SampleRequest,
    sampler_name: &str,
    out: &SampleOutput,
    wall_ms: f64,
    engine: Option<&EngineStats>,
) -> Value {
    let mut pairs = vec![
        ("id", Value::Num(req.id as f64)),
        ("ok", Value::Bool(true)),
        ("sampler", Value::Str(sampler_name.to_string())),
        ("iters", Value::Num(out.stats.iters as f64)),
        ("converged", Value::Bool(out.stats.converged)),
        ("deadline_hit", Value::Bool(out.stats.deadline_hit)),
        ("priority", Value::Str(req.priority.name().into())),
        ("eff_serial_evals", Value::Num(out.stats.eff_serial_evals as f64)),
        (
            "eff_serial_evals_pipelined",
            Value::Num(out.stats.eff_serial_evals_pipelined as f64),
        ),
        ("total_evals", Value::Num(out.stats.total_evals as f64)),
        ("peak_states", Value::Num(out.stats.peak_states as f64)),
        // State-buffer pool accounting (run-local for direct runs,
        // engine-pool snapshot for engine-resident tasks): steady-state
        // zero allocation shows up as flat pool_misses across responses.
        ("pool_hits", Value::Num(out.stats.pool_hits as f64)),
        ("pool_misses", Value::Num(out.stats.pool_misses as f64)),
        ("wall_ms", Value::Num(wall_ms)),
    ];
    if let Some(st) = engine {
        pairs.push(("batch_occupancy", Value::Num(out.stats.batch_occupancy)));
        pairs.push(("engine_rows", Value::Num(out.stats.engine_rows as f64)));
        pairs.push(("queue_depth", Value::Num(st.queue_depth as f64)));
        pairs.push(("active_tasks", Value::Num(st.active_tasks as f64)));
        pairs.push(("flushed_batches", Value::Num(st.flushed_batches as f64)));
        pairs.push(("split_batches", Value::Num(st.split_batches as f64)));
        // Fleet shape: shard count and cross-shard row migrations
        // (stolen rows execute on a sibling's workers — scheduling
        // only, never a value change).
        pairs.push(("shards", Value::Num(st.shards as f64)));
        pairs.push(("steals", Value::Num(st.steals as f64)));
        pairs.push(("pool_high_water", Value::Num(st.pool_high_water as f64)));
        // Shared-work layer: coarse-spine cache traffic and in-flight
        // coalesced duplicates, fleet-aggregated.
        pairs.push(("cache_hits", Value::Num(st.cache_hits as f64)));
        pairs.push(("cache_misses", Value::Num(st.cache_misses as f64)));
        pairs.push(("cache_evictions", Value::Num(st.cache_evictions as f64)));
        pairs.push(("coalesced", Value::Num(st.coalesced as f64)));
        // Per-QoS-class lanes (snapshot at completion): the operator's
        // starvation dashboard, one object per class. (stats_response
        // duplicates this block: the wire-schema lint reads the literal
        // keys out of *this* function's body, so they can't move into a
        // shared helper.)
        pairs.push((
            "classes",
            json::obj(
                QosClass::ALL
                    .into_iter()
                    .map(|c| {
                        let lane = st.class(c);
                        (
                            c.name(),
                            json::obj(vec![
                                ("active", Value::Num(lane.active() as f64)),
                                ("completed", Value::Num(lane.completed as f64)),
                                ("aborted", Value::Num(lane.aborted as f64)),
                                ("rows", Value::Num(lane.rows as f64)),
                                ("mean_wall_ms", Value::Num(lane.mean_wall_ms)),
                                ("deadline_hits", Value::Num(lane.deadline_hits as f64)),
                            ]),
                        )
                    })
                    .collect(),
            ),
        ));
    }
    if req.return_sample {
        pairs.push(("sample", json::arr_f32(&out.sample)));
    }
    if req.return_iterates {
        pairs.push((
            "iterates",
            Value::Arr(out.iterates.iter().map(|v| json::arr_f32(v)).collect()),
        ));
    }
    json::obj(pairs)
}

/// Detect the `{"kind": "stats"}` observability probe and return its
/// echoed id. Engine-backed entry points intercept this *before*
/// [`SampleRequest::from_json`]: the probe runs no sampler, takes no
/// admission slot, and must answer even on a saturated connection.
// lint: request-path
fn stats_probe_id(v: &Value) -> Option<u64> {
    match v.get("kind").and_then(|x| x.as_str()) {
        Some("stats") => Some(v.get("id").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64),
        _ => None,
    }
}

/// Serialize the `{"kind": "stats"}` probe response: the
/// fleet-aggregated engine snapshot with no sampler run attached
/// (documented in DESIGN.md under its own `wire-stats-fields` table —
/// the wire-schema lint scans `success_response`, not this fn).
// lint: request-path
pub fn stats_response(id: u64, st: &EngineStats) -> Value {
    json::obj(vec![
        ("id", Value::Num(id as f64)),
        ("ok", Value::Bool(true)),
        ("kind", Value::Str("stats".into())),
        ("shards", Value::Num(st.shards as f64)),
        ("steals", Value::Num(st.steals as f64)),
        ("workers", Value::Num(st.workers as f64)),
        ("queue_depth", Value::Num(st.queue_depth as f64)),
        ("active_tasks", Value::Num(st.active_tasks as f64)),
        ("flushed_batches", Value::Num(st.flushed_batches as f64)),
        ("flushed_rows", Value::Num(st.flushed_rows as f64)),
        ("split_batches", Value::Num(st.split_batches as f64)),
        ("mean_occupancy", Value::Num(st.mean_occupancy)),
        ("pool_hits", Value::Num(st.pool_hits as f64)),
        ("pool_misses", Value::Num(st.pool_misses as f64)),
        ("pool_high_water", Value::Num(st.pool_high_water as f64)),
        ("cache_hits", Value::Num(st.cache_hits as f64)),
        ("cache_misses", Value::Num(st.cache_misses as f64)),
        ("cache_evictions", Value::Num(st.cache_evictions as f64)),
        ("coalesced", Value::Num(st.coalesced as f64)),
        // Same lane shape as success_response's `classes` (that copy is
        // the lint-scanned one; see the note there).
        (
            "classes",
            json::obj(
                QosClass::ALL
                    .into_iter()
                    .map(|c| {
                        let lane = st.class(c);
                        (
                            c.name(),
                            json::obj(vec![
                                ("active", Value::Num(lane.active() as f64)),
                                ("completed", Value::Num(lane.completed as f64)),
                                ("aborted", Value::Num(lane.aborted as f64)),
                                ("rows", Value::Num(lane.rows as f64)),
                                ("mean_wall_ms", Value::Num(lane.mean_wall_ms)),
                                ("deadline_hits", Value::Num(lane.deadline_hits as f64)),
                            ]),
                        )
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Execute one request directly on a backend via the sampler registry —
/// the single-tenant path (unit tests, library callers without an
/// engine).
pub fn run_request(
    backend: &dyn StepBackend,
    model_name: &str,
    req: &SampleRequest,
) -> Value {
    let spec = match request_spec(model_name, req) {
        Ok(s) => s,
        Err(e) => return e,
    };
    let x0 = prior_sample(backend.dim(), req.seed);
    let t0 = std::time::Instant::now();
    // spec.run dispatches through the registry on spec.kind, which
    // request_spec resolved from the request's sampler name.
    let out: SampleOutput = spec.run(backend, &x0);
    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
    success_response(req, spec.kind.name(), &out, wall_ms, None)
}

/// Execute one request on the shared multi-tenant engine and block for
/// the result (tests, simple callers). Every sampler kind — SRDS,
/// sequential, ParaDiGMS, ParaTAA — runs as an engine-resident
/// [`crate::exec::task::SamplerTask`], cross-request batched; only this
/// caller's thread waits, nothing inside the engine blocks per request.
pub fn run_request_engine(engine: &Engine, model_name: &str, req: &SampleRequest) -> Value {
    let spec = match request_spec(model_name, req) {
        Ok(s) => s,
        Err(e) => return e,
    };
    let x0 = prior_sample(engine.dim(), req.seed);
    let t0 = std::time::Instant::now();
    let out: SampleOutput = engine.run(&x0, &spec);
    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
    success_response(req, spec.kind.name(), &out, wall_ms, Some(&engine.stats()))
}

/// Execute one request on a sharded fleet and block for the result
/// (tests, simple callers): the router places it by load + QoS class,
/// and the response carries the **fleet-aggregated** stats snapshot.
pub fn run_request_router(router: &Router, model_name: &str, req: &SampleRequest) -> Value {
    let spec = match request_spec(model_name, req) {
        Ok(s) => s,
        Err(e) => return e,
    };
    let x0 = prior_sample(router.dim(), req.seed);
    let t0 = std::time::Instant::now();
    let out: SampleOutput = router.run(&x0, &spec);
    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
    success_response(req, spec.kind.name(), &out, wall_ms, Some(&router.stats()))
}

/// Submit an already-parsed request onto the fleet without blocking —
/// the poll loop's shape. Validation errors invoke `done` inline;
/// otherwise the router places the request onto a shard and `done`
/// fires from that shard's completion callback with the
/// fleet-aggregated stats. `alive` is the dead-connection purge hook:
/// the poll loop flips it when the client goes away and the owning
/// dispatcher aborts the task instead of finishing it.
// lint: request-path
pub fn submit_request_router(
    router: &Router,
    model_name: &str,
    req: SampleRequest,
    alive: Arc<AtomicBool>,
    done: impl FnOnce(PendingResponse) + Send + 'static,
) {
    let spec = match request_spec(model_name, &req) {
        Ok(s) => s,
        Err(e) => return done(PendingResponse::Ready(json::to_string(&e))),
    };
    let x0 = prior_sample(router.dim(), req.seed);
    let t0 = std::time::Instant::now();
    let name = spec.kind.name();
    router.submit_with_alive(x0, spec, alive, move |out, stats| {
        let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
        done(PendingResponse::Finished(Box::new(FinishedResponse {
            req,
            name,
            out,
            stats,
            wall_ms,
        })));
    });
}

/// Handle one raw request line on the sharded fleet, blocking for the
/// response (tests, simple callers — the poll loop uses the
/// non-blocking [`submit_request_router`]). This is the one blocking
/// entry point that also answers the `{"kind": "stats"}` probe.
pub fn handle_line_router(router: &Router, model_name: &str, line: &str) -> String {
    let v = match json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            return json::to_string(&json::obj(vec![
                ("ok", Value::Bool(false)),
                ("error", Value::Str(format!("{e:#}"))),
            ]))
        }
    };
    if let Some(id) = stats_probe_id(&v) {
        return json::to_string(&stats_response(id, &router.stats()));
    }
    let resp = match SampleRequest::from_json(&v) {
        Ok(req) => run_request_router(router, model_name, &req),
        Err(e) => {
            let id = v.get("id").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
            error_response(id, format!("{e:#}"))
        }
    };
    json::to_string(&resp)
}

/// A response on its way out of [`submit_line_engine`]: either already
/// serialized (parse/validation errors) or *deferred* — the completed
/// run plus everything needed to serialize it. The engine invokes the
/// completion callback on its dispatcher thread, which must stay free
/// to form batches; deferring lets the receiver (the serve loop's poll
/// thread) pay for the JSON formatting of the
/// sample vector instead.
pub enum PendingResponse {
    /// Serialized eagerly (error lines — cheap, no sample payload).
    Ready(String),
    /// A completed run (boxed: the payload carries the whole sample);
    /// serialization deferred to [`PendingResponse::into_line`].
    Finished(Box<FinishedResponse>),
}

/// The deferred payload of [`PendingResponse::Finished`].
pub struct FinishedResponse {
    req: SampleRequest,
    name: &'static str,
    out: SampleOutput,
    stats: EngineStats,
    wall_ms: f64,
}

impl PendingResponse {
    /// Serialize to the wire line. For engine completions this is the
    /// heavy part (formatting `d` floats, plus iterates when requested)
    /// — call it off the dispatcher thread.
    pub fn into_line(self) -> String {
        match self {
            PendingResponse::Ready(s) => s,
            PendingResponse::Finished(f) => json::to_string(&success_response(
                &f.req,
                f.name,
                &f.out,
                f.wall_ms,
                Some(&f.stats),
            )),
        }
    }
}

/// Parse and submit one request line onto the engine **without
/// blocking**: `done` receives the [`PendingResponse`] when the request
/// completes (immediately, for parse/validation errors; otherwise from
/// the engine's completion callback). This is what the TCP read loop
/// calls — a request's whole lifetime lives inside the engine's
/// dispatcher + workers, and no per-request thread exists anywhere.
/// `done` may run on the dispatcher thread: it must be cheap and must
/// not block — the serve loop's forwards the still-unserialized
/// response to the connection's writer thread, which does the JSON
/// formatting via [`PendingResponse::into_line`].
// lint: request-path
pub fn submit_line_engine(
    engine: &Engine,
    model_name: &str,
    line: &str,
    done: impl FnOnce(PendingResponse) + Send + 'static,
) {
    let req = match line_to_request(line) {
        Ok(r) => r,
        Err(e) => return done(PendingResponse::Ready(json::to_string(&e))),
    };
    submit_request_engine(engine, model_name, req, done);
}

/// Submit an already-parsed request onto the engine without blocking —
/// the serve loop calls this after its admission check (so a shed
/// request never reaches the engine), [`submit_line_engine`] after
/// parsing. Validation errors invoke `done` inline; otherwise `done`
/// fires from the engine's completion callback.
// lint: request-path
pub fn submit_request_engine(
    engine: &Engine,
    model_name: &str,
    req: SampleRequest,
    done: impl FnOnce(PendingResponse) + Send + 'static,
) {
    let spec = match request_spec(model_name, &req) {
        Ok(s) => s,
        Err(e) => return done(PendingResponse::Ready(json::to_string(&e))),
    };
    let x0 = prior_sample(engine.dim(), req.seed);
    let t0 = std::time::Instant::now();
    let name = spec.kind.name();
    engine.submit_with(x0, spec, move |out, stats| {
        let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
        done(PendingResponse::Finished(Box::new(FinishedResponse {
            req,
            name,
            out,
            stats,
            wall_ms,
        })));
    });
}

// lint: request-path
fn line_to_request(line: &str) -> std::result::Result<SampleRequest, Value> {
    match json::parse(line) {
        Ok(v) => match SampleRequest::from_json(&v) {
            Ok(req) => Ok(req),
            // Request-level validation errors still echo the id so
            // pipelined clients can correlate them.
            Err(e) => {
                let id = v.get("id").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
                Err(error_response(id, format!("{e:#}")))
            }
        },
        // Malformed JSON: no id to echo.
        Err(e) => Err(json::obj(vec![
            ("ok", Value::Bool(false)),
            ("error", Value::Str(format!("{e:#}"))),
        ])),
    }
}

/// Handle one raw request line on a dedicated backend (exposed for
/// tests; no socket, no engine).
pub fn handle_line(backend: &dyn StepBackend, model_name: &str, line: &str) -> String {
    let resp = match line_to_request(line) {
        Ok(req) => run_request(backend, model_name, &req),
        Err(e) => e,
    };
    json::to_string(&resp)
}

/// Handle one raw request line on the shared engine, blocking for the
/// response (tests, simple callers — the TCP loop uses the non-blocking
/// [`submit_line_engine`] instead).
pub fn handle_line_engine(engine: &Engine, model_name: &str, line: &str) -> String {
    let resp = match line_to_request(line) {
        Ok(req) => run_request_engine(engine, model_name, &req),
        Err(e) => e,
    };
    json::to_string(&resp)
}

/// Default per-connection admission cap (see [`ServeConfig::max_inflight`]).
pub const DEFAULT_MAX_INFLIGHT: usize = 64;

/// Default per-shard coarse-spine cache capacity for the serving layer
/// (see [`ServeConfig::spine_cache_cap`]). The library-level
/// [`crate::exec::EngineConfig`] default is 0 (off); a server opts in
/// because repeat specs are the serving workload's common case.
pub const DEFAULT_SPINE_CACHE_CAP: usize = 64;

/// Server configuration.
pub struct ServeConfig {
    pub addr: String,
    /// Engine shards (`--shards` on the CLI; the default is one shard
    /// per `workers`-sized core group, see
    /// [`crate::exec::router::default_shards`]). Each shard is a full
    /// engine — dispatcher + `workers` worker threads + its own
    /// `BufPool` — behind the router's load/QoS placement, with
    /// cross-shard work stealing of queued rows. Placement and stealing
    /// are pure scheduling: outputs are bit-identical at any width.
    pub shards: usize,
    /// Engine worker threads *per shard* (each owns one backend
    /// instance).
    pub workers: usize,
    pub model_name: String,
    pub factory: Arc<dyn BackendFactory>,
    /// Cross-request batch assembly policy for the engine
    /// (`--batch-wait` / `--buckets` on the CLI).
    pub batch: BatchPolicy,
    /// Admission control: in-flight requests per connection
    /// (`--max-inflight` on the CLI, [`DEFAULT_MAX_INFLIGHT`] by
    /// default). A request arriving past the cap is **shed immediately**
    /// with the structured [`overloaded_response`] error line
    /// (`error_kind: "overloaded"`) so the client can back off — the
    /// read loop never stalls, and responses for in-flight work keep
    /// streaming while the connection is over cap.
    pub max_inflight: usize,
    /// Default anytime eval budget applied to requests that don't carry
    /// their own `"deadline"` field (`--default-deadline` on the CLI).
    /// `None` → no budget: requests refine to convergence/cap. Clients
    /// opt out per request with an explicit `"deadline": 0`.
    pub default_deadline: Option<u64>,
    /// Per-shard coarse-spine cache capacity (`--spine-cache-cap` on
    /// the CLI, [`DEFAULT_SPINE_CACHE_CAP`] by default, 0 disables): a
    /// repeat SRDS request warm-starts from the retained iteration-0
    /// boundary states and skips the serial coarse sweep entirely,
    /// bit-identically.
    pub spine_cache_cap: usize,
    /// In-flight coalescing (`--no-coalesce` turns it off): identical
    /// concurrent submissions share one resident task and fan out
    /// bit-identical responses.
    pub coalesce: bool,
}

/// Run the blocking accept loop on a fresh listener bound to `cfg.addr`.
pub fn serve(cfg: ServeConfig) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)?;
    serve_on(listener, cfg)
}

/// Write-backpressure bound: while a connection's pending response
/// bytes exceed this, the poll loop stops *reading* from it (already
/// queued responses keep draining) — a client that won't read its
/// responses can't balloon server memory by pipelining more work.
const MAX_OUTBUF: usize = 1 << 20;

/// How long the poll loop parks on the completion outbox when no socket
/// made progress. Engine completions notify the condvar, so a finished
/// request wakes the loop immediately; the timeout only bounds how
/// stale a WouldBlock retry can get.
const POLL_WAIT: Duration = Duration::from_millis(1);

/// Completed work on its way back to connections: shard dispatchers
/// push `(conn, response)` here from their completion callbacks (cheap
/// — no serialization), and the poll thread drains it, doing the heavy
/// JSON formatting off the dispatchers.
struct Outbox {
    queue: Mutex<Vec<(u64, PendingResponse)>>,
    cv: Condvar,
}

impl Outbox {
    fn new() -> Outbox {
        Outbox { queue: Mutex::new(Vec::new()), cv: Condvar::new() }
    }

    // lint: request-path
    fn push(&self, conn: u64, resp: PendingResponse) {
        // lint-allow(panic-policy): a poisoned outbox means a panicked poll thread — process-fatal, not request-controlled
        self.queue.lock().unwrap().push((conn, resp));
        self.cv.notify_one();
    }

    // lint: request-path
    fn drain(&self) -> Vec<(u64, PendingResponse)> {
        // lint-allow(panic-policy): poisoned outbox, see push
        std::mem::take(&mut *self.queue.lock().unwrap())
    }

    /// Park until either `timeout` passes or a completion lands.
    // lint: request-path
    fn wait(&self, timeout: Duration) {
        // lint-allow(panic-policy): poisoned outbox, see push
        let q = self.queue.lock().unwrap();
        if q.is_empty() {
            // lint-allow(panic-policy): poisoned outbox, see push
            let _ = self.cv.wait_timeout(q, timeout).unwrap();
        }
    }
}

/// Per-connection state in the poll loop: the nonblocking socket plus
/// read/write buffers and the liveness flag its in-flight tasks carry.
struct Conn {
    stream: TcpStream,
    peer: String,
    /// Bytes read but not yet terminated by `\n` (partial-line
    /// reassembly).
    inbuf: Vec<u8>,
    /// Serialized response bytes not yet accepted by the socket.
    outbuf: Vec<u8>,
    /// Requests handed to the router for this connection. Poll-thread
    /// local (only the poll thread submits), so the admission check and
    /// the drain-then-close decision are race-free by construction —
    /// no completion-side counter can be read at the wrong moment.
    submitted: u64,
    /// Router responses routed into `outbuf` so far. Every submission
    /// on a live connection produces exactly one outbox entry (inline
    /// validation errors included), so `submitted - delivered` is the
    /// connection's true in-flight count.
    delivered: u64,
    /// Flipped to `false` when the connection dies; every task
    /// submitted for it holds a clone, and the owning dispatcher aborts
    /// flagged tasks on its next sweep.
    alive: Arc<AtomicBool>,
    /// The peer half-closed its write side (EOF on read): accept no
    /// more requests, but keep draining responses for work already in
    /// flight, then close once everything submitted was delivered.
    read_closed: bool,
}

impl Conn {
    /// Requests submitted to the router and not yet answered.
    fn pending(&self) -> u64 {
        self.submitted - self.delivered
    }
}

/// Everything [`serve_on`]'s poll loop needs per event, bundled so the
/// per-connection handlers are methods instead of 8-argument functions.
struct PollLoop {
    router: Arc<Router>,
    model_name: String,
    default_deadline: Option<u64>,
    max_inflight: usize,
    outbox: Arc<Outbox>,
}

impl PollLoop {
    /// Flush this connection's pending response bytes. Returns `false`
    /// when the socket is dead.
    // lint: request-path
    fn write_side(&self, conn: &mut Conn, progress: &mut bool) -> bool {
        let mut wrote = 0;
        while wrote < conn.outbuf.len() {
            match conn.stream.write(&conn.outbuf[wrote..]) {
                Ok(0) => return false,
                Ok(n) => {
                    wrote += n;
                    *progress = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        conn.outbuf.drain(..wrote);
        true
    }

    /// Read whatever the socket has, reassemble complete lines, and
    /// dispatch each. Returns `false` when the socket is dead.
    // lint: request-path
    fn read_side(&self, id: u64, conn: &mut Conn, progress: &mut bool) -> bool {
        if conn.read_closed || conn.outbuf.len() >= MAX_OUTBUF {
            // Backpressure: a client that won't drain its responses
            // doesn't get to queue more work.
            return true;
        }
        let mut chunk = [0u8; 8192];
        loop {
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    // EOF. A trailing unterminated line still counts
                    // (matches the old BufRead::lines behavior), then
                    // the read side is done — responses keep flowing.
                    conn.read_closed = true;
                    *progress = true;
                    if !conn.inbuf.is_empty() {
                        let tail = std::mem::take(&mut conn.inbuf);
                        let line = String::from_utf8_lossy(&tail).to_string();
                        if !line.trim().is_empty() {
                            self.on_line(id, conn, line.trim());
                        }
                    }
                    return true;
                }
                Ok(n) => {
                    *progress = true;
                    conn.inbuf.extend_from_slice(&chunk[..n]);
                    self.drain_lines(id, conn);
                    if conn.outbuf.len() >= MAX_OUTBUF {
                        return true;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
    }

    /// Split the connection's read buffer on `\n` and dispatch every
    /// complete line; the tail stays buffered until its newline arrives.
    // lint: request-path
    fn drain_lines(&self, id: u64, conn: &mut Conn) {
        while let Some(pos) = conn.inbuf.iter().position(|&b| b == b'\n') {
            let raw: Vec<u8> = conn.inbuf.drain(..=pos).collect();
            let line = String::from_utf8_lossy(&raw[..raw.len() - 1]).to_string();
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            self.on_line(id, conn, line);
        }
    }

    /// One complete request line: parse errors and the stats probe are
    /// answered inline by the poll thread (straight into the write
    /// buffer); sampling requests pass admission and go to the router,
    /// whose completion callback posts to the outbox.
    // lint: request-path
    fn on_line(&self, id: u64, conn: &mut Conn, line: &str) {
        let v = match json::parse(line) {
            Ok(v) => v,
            Err(e) => {
                // Malformed JSON: no id to echo.
                let err = json::obj(vec![
                    ("ok", Value::Bool(false)),
                    ("error", Value::Str(format!("{e:#}"))),
                ]);
                return push_line(&mut conn.outbuf, &json::to_string(&err));
            }
        };
        // The stats probe runs no sampler and takes no admission slot —
        // it must answer even (especially) on a saturated connection.
        if let Some(pid) = stats_probe_id(&v) {
            let resp = stats_response(pid, &self.router.stats());
            return push_line(&mut conn.outbuf, &json::to_string(&resp));
        }
        let mut req = match SampleRequest::from_json(&v) {
            Ok(r) => r,
            Err(e) => {
                // Request-level validation errors still echo the id so
                // pipelined clients can correlate them.
                let rid = v.get("id").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
                return push_line(&mut conn.outbuf, &json::to_string(&error_response(rid, format!("{e:#}"))));
            }
        };
        if req.deadline.is_none() {
            req.deadline = self.default_deadline;
        }
        // Non-blocking admission: over the cap, shed with the
        // structured overloaded error (now carrying the retry_after_ms
        // backoff hint) instead of stalling the poll loop. The slot
        // frees when the response is routed back to this connection.
        if conn.pending() >= self.max_inflight as u64 {
            let shed = overloaded_response(req.id, self.max_inflight, DEFAULT_RETRY_AFTER_MS);
            return push_line(&mut conn.outbuf, &json::to_string(&shed));
        }
        conn.submitted += 1;
        // Submit and move on: the shard's completion callback posts the
        // still-unserialized response to the outbox; the poll thread
        // formats it (and releases the admission slot) next wake-up. No
        // thread exists for this request.
        let outbox = self.outbox.clone();
        submit_request_router(&self.router, &self.model_name, req, conn.alive.clone(), move |resp| {
            outbox.push(id, resp);
        });
    }
}

// lint: request-path
fn push_line(out: &mut Vec<u8>, line: &str) {
    out.extend_from_slice(line.as_bytes());
    out.push(b'\n');
}

/// Run the serve loop on an already-bound listener (tests bind an
/// ephemeral port first, then hand it over — no drop-and-rebind race).
///
/// One sharded engine fleet serves every connection through a **single
/// nonblocking poll loop** on the calling thread: nonblocking accept,
/// per-connection read/write buffers with partial-line reassembly,
/// write backpressure (a connection whose response backlog passes
/// [`MAX_OUTBUF`] is not read from until it drains), and a
/// dead-connection purge that flips the liveness flag carried by the
/// connection's in-flight tasks so shard dispatchers abort them. The
/// whole process runs `1 + shards × (1 + workers)` threads — connection
/// count and request count create none (the old design spent a reader
/// + writer thread pair per connection).
///
/// In-flight requests are capped at [`ServeConfig::max_inflight`] per
/// connection — a request past the cap is shed *immediately* with the
/// structured [`overloaded_response`] line (`error_kind: "overloaded"`,
/// `retry_after_ms` hint), never parked. `{"kind": "stats"}` probes are
/// answered inline from the fleet gauges without touching admission.
pub fn serve_on(listener: TcpListener, cfg: ServeConfig) -> Result<()> {
    let shards = cfg.shards.max(1);
    let router = Arc::new(Router::new(
        cfg.factory.clone(),
        RouterConfig {
            shards,
            workers: cfg.workers,
            batch: cfg.batch.clone(),
            steal: true,
            spine_cache_cap: cfg.spine_cache_cap,
            coalesce: cfg.coalesce,
        },
    ));
    eprintln!(
        "srds-server listening on {} (model={}, shards={}, workers/shard={}, buckets={:?}, \
         class-weights={:?}, max-inflight/conn={}, default-deadline={:?}, spine-cache-cap={}, \
         coalesce={}, samplers={})",
        listener.local_addr().map(|a| a.to_string()).unwrap_or_else(|_| cfg.addr.clone()),
        cfg.model_name,
        shards,
        cfg.workers,
        cfg.batch.buckets,
        cfg.batch.class_weights,
        cfg.max_inflight,
        cfg.default_deadline,
        cfg.spine_cache_cap,
        cfg.coalesce,
        registry().list().join("/")
    );
    listener.set_nonblocking(true)?;
    let lp = PollLoop {
        router,
        model_name: cfg.model_name.clone(),
        default_deadline: cfg.default_deadline,
        max_inflight: cfg.max_inflight.max(1),
        outbox: Arc::new(Outbox::new()),
    };
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_id: u64 = 0;
    let mut dead: Vec<u64> = Vec::new();
    loop {
        let mut progress = false;
        // 1. Accept every waiting connection.
        loop {
            match listener.accept() {
                Ok((stream, peer)) => {
                    if let Err(e) = stream.set_nonblocking(true) {
                        eprintln!("connection setup error: {e}");
                        continue;
                    }
                    conns.insert(
                        next_id,
                        Conn {
                            stream,
                            peer: peer.to_string(),
                            inbuf: Vec::new(),
                            outbuf: Vec::new(),
                            submitted: 0,
                            delivered: 0,
                            alive: Arc::new(AtomicBool::new(true)),
                            read_closed: false,
                        },
                    );
                    next_id += 1;
                    progress = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                // A broken listener can't be served around (matches the
                // old accept loop's `stream?`).
                Err(e) => return Err(e.into()),
            }
        }
        // 2. Route completed work into its connection's write buffer —
        // serialization happens here, on the poll thread, never on a
        // shard dispatcher. A completion for a vanished connection is
        // dropped (its client is gone; late results have no reader).
        for (conn_id, resp) in lp.outbox.drain() {
            if let Some(conn) = conns.get_mut(&conn_id) {
                conn.delivered += 1;
                push_line(&mut conn.outbuf, &resp.into_line());
                progress = true;
            }
        }
        // 3. Per-connection I/O: drain writes first (completed work
        // must stream out even if the client never sends another
        // byte), then read + dispatch new request lines.
        for (&id, conn) in conns.iter_mut() {
            let open = lp.write_side(conn, &mut progress)
                && lp.read_side(id, conn, &mut progress)
                && !(conn.read_closed && conn.outbuf.is_empty() && conn.pending() == 0);
            if !open {
                dead.push(id);
            }
        }
        // 4. Purge dead connections: dropping the socket closes it, and
        // flipping `alive` makes the dispatchers abort any of its
        // still-queued work instead of computing unread results.
        for id in dead.drain(..) {
            if let Some(conn) = conns.remove(&id) {
                conn.alive.store(false, Ordering::SeqCst);
                eprintln!("connection {} done", conn.peer);
            }
        }
        // 5. Nothing moved: park until a completion lands or the poll
        // interval elapses (bounds the WouldBlock retry latency).
        if !progress {
            lp.outbox.wait(POLL_WAIT);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ConvNorm;
    use crate::exec::{EngineConfig, NativeFactory};
    use crate::model::GmmEps;
    use crate::solvers::Solver;

    fn backend() -> Box<dyn StepBackend> {
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(make_gmm("toy2d")));
        NativeFactory::new(model, Solver::Ddim).create()
    }

    #[test]
    fn handle_line_srds() {
        let be = backend();
        let resp = handle_line(be.as_ref(), "gmm_toy2d", r#"{"id": 5, "n": 16, "tol": 0.001}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("id").unwrap().as_f64(), Some(5.0));
        assert_eq!(v.get("sampler").unwrap().as_str(), Some("srds"));
        assert_eq!(v.get("sample").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn handle_line_every_registered_sampler() {
        let be = backend();
        for sampler in registry().list() {
            let line = format!(r#"{{"id":1,"sampler":"{sampler}","n":16,"sample":false}}"#);
            let resp = handle_line(be.as_ref(), "gmm_toy2d", &line);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{sampler}: {resp}");
            assert_eq!(v.get("sampler").unwrap().as_str(), Some(sampler));
            assert!(v.get("sample").is_none());
            assert!(v.get("eff_serial_evals_pipelined").is_some(), "{sampler}: {resp}");
            // The zero-copy satellite: pool accounting is on the wire.
            assert!(v.get("pool_hits").is_some(), "{sampler}: {resp}");
            assert!(v.get("pool_misses").is_some(), "{sampler}: {resp}");
        }
    }

    #[test]
    fn handle_line_rejects_unknown_sampler() {
        // No silent SRDS fallback: unknown names are an explicit error.
        let be = backend();
        let resp =
            handle_line(be.as_ref(), "gmm_toy2d", r#"{"id": 9, "sampler": "ddim", "n": 16}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert_eq!(v.get("id").unwrap().as_f64(), Some(9.0), "error echoes the request id");
        let err = v.get("error").unwrap().as_str().unwrap().to_string();
        assert!(err.contains("unknown sampler"), "{err}");
        assert!(err.contains("srds"), "error lists the registry: {err}");
        assert!(v.get("sample").is_none());
    }

    #[test]
    fn handle_line_rejects_out_of_range_block() {
        // block is asserted deep inside Partition::with_block; the server
        // must reject it up front instead of panicking a worker thread.
        let be = backend();
        for bad in [r#"{"id":2,"n":16,"block":0}"#, r#"{"id":2,"n":16,"block":17}"#, r#"{"id":2,"n":0}"#] {
            let resp = handle_line(be.as_ref(), "gmm_toy2d", bad);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{bad} -> {resp}");
        }
        // Boundary values are fine: block == n is one block of n steps.
        let resp = handle_line(be.as_ref(), "gmm_toy2d", r#"{"id":3,"n":16,"block":16}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
    }

    #[test]
    fn handle_line_rejects_unknown_norm() {
        let be = backend();
        let resp = handle_line(be.as_ref(), "gmm_toy2d", r#"{"id":7,"n":16,"norm":"l7"}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        // Validation errors echo the id so pipelined clients can
        // correlate them with the failed request.
        assert_eq!(v.get("id").unwrap().as_f64(), Some(7.0), "{resp}");
    }

    #[test]
    fn paradigms_max_iters_zero_still_runs_one_sweep() {
        // max_iters is clamped to >= 1 in every sampler; a cap of 0 must
        // not return the untouched prior as a "sample".
        let be = backend();
        let resp = handle_line(
            be.as_ref(),
            "gmm_toy2d",
            r#"{"id":1,"sampler":"paradigms","n":16,"max_iters":0}"#,
        );
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert!(v.get("iters").unwrap().as_f64().unwrap() >= 1.0, "{resp}");
    }

    #[test]
    fn handle_line_bad_json() {
        let be = backend();
        let resp = handle_line(be.as_ref(), "gmm_toy2d", "{nope");
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn request_knobs_reach_the_spec() {
        let v = json::parse(
            r#"{"sampler":"paradigms","n":64,"window":16,"history":5,"block":4,
                "norm":"linf","max_iters":7,"tol":0.5,"iterates":true}"#,
        )
        .unwrap();
        let req = SampleRequest::from_json(&v).unwrap();
        let kind = registry().parse(&req.sampler).unwrap().kind();
        let spec = req.to_spec(kind, Conditioning::none());
        assert_eq!(spec.window(), Some(16), "window reaches ParaDiGMS");
        assert_eq!(spec.block, Some(4));
        assert_eq!(spec.norm, ConvNorm::LInf);
        assert_eq!(spec.max_iters, Some(7));
        assert!(spec.keep_iterates);
        // history is a ParaTAA knob; on a paradigms request it's ignored.
        assert_eq!(spec.history(), 2);
    }

    fn engine() -> Engine {
        let model: Arc<dyn crate::model::EpsModel> =
            Arc::new(GmmEps::new(make_gmm("toy2d")));
        Engine::new(
            Arc::new(NativeFactory::new(model, Solver::Ddim)),
            EngineConfig { workers: 2, ..EngineConfig::default() },
        )
    }

    fn router(shards: usize) -> Router {
        let model: Arc<dyn crate::model::EpsModel> =
            Arc::new(GmmEps::new(make_gmm("toy2d")));
        Router::new(
            Arc::new(NativeFactory::new(model, Solver::Ddim)),
            RouterConfig {
                shards,
                workers: 1,
                spine_cache_cap: DEFAULT_SPINE_CACHE_CAP,
                ..RouterConfig::default()
            },
        )
    }

    #[test]
    fn priority_and_deadline_reach_the_spec() {
        let v = json::parse(
            r#"{"sampler":"srds","n":36,"priority":"interactive","deadline":120}"#,
        )
        .unwrap();
        let req = SampleRequest::from_json(&v).unwrap();
        assert_eq!(req.priority, QosClass::Interactive);
        assert_eq!(req.deadline, Some(120));
        let kind = registry().parse(&req.sampler).unwrap().kind();
        let spec = req.to_spec(kind, Conditioning::none());
        assert_eq!(spec.priority, QosClass::Interactive);
        assert_eq!(spec.deadline_evals, Some(120));
        // Defaults: standard class, no budget.
        let v = json::parse(r#"{"sampler":"srds","n":36}"#).unwrap();
        let req = SampleRequest::from_json(&v).unwrap();
        assert_eq!(req.priority, QosClass::Standard);
        assert_eq!(req.deadline, None);
    }

    #[test]
    fn unknown_priority_is_rejected_not_downgraded() {
        let be = backend();
        let resp =
            handle_line(be.as_ref(), "gmm_toy2d", r#"{"id":4,"n":16,"priority":"urgent"}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert_eq!(v.get("id").unwrap().as_f64(), Some(4.0));
        assert!(
            v.get("error").unwrap().as_str().unwrap().contains("unknown priority"),
            "{resp}"
        );
    }

    #[test]
    fn deadline_zero_opts_out_and_negative_is_rejected() {
        // Explicit 0 is the client's escape hatch from a server-side
        // --default-deadline: it must parse as "unbudgeted", never as a
        // zero-eval budget. Negative would saturate to exactly that
        // coarse-init-only run, so it's rejected, not degraded.
        let v = json::parse(r#"{"sampler":"srds","n":16,"deadline":0}"#).unwrap();
        let req = SampleRequest::from_json(&v).unwrap();
        assert_eq!(req.deadline, Some(0), "explicit opt-out is preserved, not treated as absent");
        let kind = registry().parse(&req.sampler).unwrap().kind();
        assert_eq!(
            req.to_spec(kind, Conditioning::none()).deadline_evals,
            None,
            "0 reaches the sampler as 'no budget'"
        );
        let be = backend();
        let resp = handle_line(be.as_ref(), "gmm_toy2d", r#"{"id":6,"n":16,"deadline":-3}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{resp}");
        assert_eq!(v.get("id").unwrap().as_f64(), Some(6.0), "{resp}");
        assert!(v.get("error").unwrap().as_str().unwrap().contains("deadline"), "{resp}");
        // Boundary: 1 is a legal (if brutal) budget.
        let resp = handle_line(be.as_ref(), "gmm_toy2d", r#"{"id":7,"n":16,"deadline":1}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
    }

    #[test]
    fn engine_responses_carry_qos_fields() {
        let eng = engine();
        let line = r#"{"id":1,"sampler":"srds","n":16,"priority":"interactive","sample":false}"#;
        let v = json::parse(&handle_line_engine(&eng, "gmm_toy2d", line)).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("priority").unwrap().as_str(), Some("interactive"));
        assert_eq!(v.get("deadline_hit").unwrap().as_bool(), Some(false));
        let classes = v.get("classes").expect("per-class lanes on the wire");
        for c in QosClass::ALL {
            let lane = classes.get(c.name()).unwrap_or_else(|| panic!("{} lane", c.name()));
            assert!(lane.get("completed").is_some());
            assert!(lane.get("active").is_some());
            assert!(lane.get("aborted").is_some());
            assert!(lane.get("rows").is_some());
            assert!(lane.get("mean_wall_ms").is_some());
            assert!(lane.get("deadline_hits").is_some());
        }
        let inter = classes.get("interactive").unwrap();
        assert_eq!(inter.get("completed").unwrap().as_f64(), Some(1.0));
        assert!(inter.get("rows").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            classes.get("batch").unwrap().get("completed").unwrap().as_f64(),
            Some(0.0)
        );
    }

    #[test]
    fn deadline_truncation_is_honest_over_the_wire() {
        // tol 0 forces all iterations; a tiny eval budget must come back
        // as deadline_hit: true + converged: false, with a valid sample.
        let eng = engine();
        let line = r#"{"id":9,"sampler":"srds","n":36,"tol":0.0,"deadline":40,"seed":5}"#;
        let v = json::parse(&handle_line_engine(&eng, "gmm_toy2d", line)).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("deadline_hit").unwrap().as_bool(), Some(true), "{v:?}");
        assert_eq!(v.get("converged").unwrap().as_bool(), Some(false));
        let sample = v.get("sample").unwrap().as_f32_vec().unwrap();
        assert!(sample.iter().all(|x| x.is_finite()));
        let classes = v.get("classes").unwrap();
        assert_eq!(
            classes.get("standard").unwrap().get("deadline_hits").unwrap().as_f64(),
            Some(1.0)
        );
    }

    #[test]
    fn overloaded_response_is_structured() {
        let v = overloaded_response(42, 2, DEFAULT_RETRY_AFTER_MS);
        assert_eq!(v.get("id").unwrap().as_f64(), Some(42.0));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("error_kind").unwrap().as_str(), Some("overloaded"));
        assert_eq!(v.get("max_inflight").unwrap().as_f64(), Some(2.0));
        assert!(v.get("error").unwrap().as_str().unwrap().contains("overloaded"));
        // The backoff hint is structured, not prose (ROADMAP's
        // resilience edge): clients sleep retry_after_ms and resend.
        assert_eq!(
            v.get("retry_after_ms").unwrap().as_f64(),
            Some(DEFAULT_RETRY_AFTER_MS as f64)
        );
        // Round-trips through the wire serialization.
        let parsed = json::parse(&json::to_string(&v)).unwrap();
        assert_eq!(parsed.get("error_kind").unwrap().as_str(), Some("overloaded"));
        assert_eq!(
            parsed.get("retry_after_ms").unwrap().as_f64(),
            Some(DEFAULT_RETRY_AFTER_MS as f64)
        );
        // The hint is caller-controlled (an adaptive serve loop can
        // scale it with load without a schema change).
        let v = overloaded_response(1, 4, 250);
        assert_eq!(v.get("retry_after_ms").unwrap().as_f64(), Some(250.0));
    }

    #[test]
    fn stats_probe_answers_without_running_a_sampler() {
        // `{"kind": "stats"}` is the poll loop's health probe: it
        // reports the aggregated fleet snapshot (shards, steals, lanes)
        // and never touches the sampler registry or an admission slot.
        let r = router(2);
        // Warm the fleet so the probe has nonzero counters to show.
        let warm =
            handle_line_router(&r, "gmm_toy2d", r#"{"id":1,"sampler":"srds","n":16,"sample":false}"#);
        let wv = json::parse(&warm).unwrap();
        assert_eq!(wv.get("ok").unwrap().as_bool(), Some(true), "{warm}");
        let resp = handle_line_router(&r, "gmm_toy2d", r#"{"id":7,"kind":"stats"}"#);
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert_eq!(v.get("id").unwrap().as_f64(), Some(7.0));
        assert_eq!(v.get("kind").unwrap().as_str(), Some("stats"));
        assert_eq!(v.get("shards").unwrap().as_f64(), Some(2.0));
        assert!(v.get("steals").unwrap().as_f64().is_some(), "{resp}");
        assert_eq!(v.get("workers").unwrap().as_f64(), Some(2.0), "2 shards × 1 worker");
        assert!(v.get("flushed_rows").unwrap().as_f64().unwrap() > 0.0, "{resp}");
        assert_eq!(v.get("active_tasks").unwrap().as_f64(), Some(0.0));
        // No sampler ran: a stats line carries no sample payload.
        assert!(v.get("sample").is_none());
        assert!(v.get("sampler").is_none());
        let classes = v.get("classes").expect("per-class lanes ride the probe");
        let std_lane = classes.get("standard").unwrap();
        assert_eq!(std_lane.get("completed").unwrap().as_f64(), Some(1.0), "{resp}");
        assert_eq!(std_lane.get("aborted").unwrap().as_f64(), Some(0.0), "{resp}");
        // An explicit kind "sample" still parses as a normal request...
        let v = json::parse(r#"{"kind":"sample","n":16}"#).unwrap();
        assert!(SampleRequest::from_json(&v).is_ok());
        // ...while an unknown kind is rejected, not silently sampled.
        let v = json::parse(r#"{"kind":"metrics","n":16}"#).unwrap();
        assert!(SampleRequest::from_json(&v).is_err());
    }

    #[test]
    fn router_path_matches_engine_path_and_reports_fleet_fields() {
        // The serve loop's actual substrate is the sharded router; the
        // wire contract must be byte-compatible with the single-engine
        // path, plus the fleet fields (shards / steals).
        let eng = engine();
        let r = router(2);
        for line in [
            r#"{"id":1,"sampler":"srds","n":25,"seed":3,"tol":1e-5}"#,
            r#"{"id":2,"sampler":"sequential","n":25,"seed":3}"#,
        ] {
            let engined = json::parse(&handle_line_engine(&eng, "gmm_toy2d", line)).unwrap();
            let routed = json::parse(&handle_line_router(&r, "gmm_toy2d", line)).unwrap();
            assert_eq!(routed.get("ok").unwrap().as_bool(), Some(true), "{line}");
            assert_eq!(
                routed.get("sample").unwrap().as_f32_vec().unwrap(),
                engined.get("sample").unwrap().as_f32_vec().unwrap(),
                "{line}: sharded fleet vs single engine"
            );
            assert_eq!(routed.get("shards").unwrap().as_f64(), Some(2.0), "{line}");
            assert!(routed.get("steals").unwrap().as_f64().is_some(), "{line}");
            // The single-engine snapshot is a width-1 fleet on the wire.
            assert_eq!(engined.get("shards").unwrap().as_f64(), Some(1.0), "{line}");
            assert_eq!(engined.get("steals").unwrap().as_f64(), Some(0.0), "{line}");
        }
    }

    #[test]
    fn handle_line_engine_every_registered_sampler() {
        // The engine-dispatched serving path: every registry entry works
        // and reports the engine stats fields.
        let eng = engine();
        for sampler in registry().list() {
            let line = format!(r#"{{"id":1,"sampler":"{sampler}","n":16,"sample":false}}"#);
            let resp = handle_line_engine(&eng, "gmm_toy2d", &line);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{sampler}: {resp}");
            assert_eq!(v.get("sampler").unwrap().as_str(), Some(sampler));
            let occ = v.get("batch_occupancy").unwrap().as_f64().unwrap();
            assert!(occ >= 1.0, "{sampler} occupancy {occ}: {resp}");
            assert!(v.get("engine_rows").unwrap().as_f64().unwrap() > 0.0, "{sampler}: {resp}");
            assert!(v.get("queue_depth").is_some(), "{sampler}: {resp}");
            // The task-table gauge is on the wire; with one request at a
            // time it reads 0 at completion.
            assert_eq!(v.get("active_tasks").unwrap().as_f64(), Some(0.0), "{sampler}: {resp}");
            assert!(v.get("flushed_batches").unwrap().as_f64().unwrap() > 0.0, "{sampler}: {resp}");
            assert!(v.get("pool_high_water").unwrap().as_f64().unwrap() > 0.0, "{sampler}: {resp}");
        }
    }

    #[test]
    fn submit_path_serves_mixed_fleet_without_request_threads() {
        // The serve loop's actual shape: submit_line_engine queues every
        // registry sampler concurrently with completion callbacks — no
        // thread blocks per request — and each response's sample is
        // bit-identical to the dedicated-backend run of the same line.
        let eng = engine();
        let be = backend();
        let (tx, rx) = std::sync::mpsc::channel::<PendingResponse>();
        let mut want: Vec<(u64, Value)> = Vec::new();
        for (i, sampler) in registry().list().iter().enumerate() {
            let line =
                format!(r#"{{"id":{i},"sampler":"{sampler}","n":16,"seed":{i},"tol":1e-6}}"#);
            let reference = json::parse(&handle_line(be.as_ref(), "gmm_toy2d", &line)).unwrap();
            want.push((i as u64, reference));
            let tx = tx.clone();
            submit_line_engine(&eng, "gmm_toy2d", &line, move |resp| {
                let _ = tx.send(resp);
            });
        }
        drop(tx);
        // Serialization runs receiver-side (the serve loop's writer
        // thread does the same via into_line).
        let got: Vec<Value> = rx.iter().map(|r| json::parse(&r.into_line()).unwrap()).collect();
        assert_eq!(got.len(), want.len(), "every callback fired exactly once");
        for (id, reference) in want {
            let g = got
                .iter()
                .find(|v| v.get("id").unwrap().as_f64() == Some(id as f64))
                .unwrap_or_else(|| panic!("no response for id {id}"));
            assert_eq!(g.get("ok").unwrap().as_bool(), Some(true), "{g:?}");
            assert_eq!(
                g.get("sampler").unwrap().as_str(),
                reference.get("sampler").unwrap().as_str()
            );
            // Engine task vs direct backend, through the full wire
            // serialization: bit-identical samples serialize identically.
            assert_eq!(
                g.get("sample").unwrap().as_f32_vec().unwrap(),
                reference.get("sample").unwrap().as_f32_vec().unwrap(),
                "id {id}: engine-native task vs direct run"
            );
            assert!(g.get("active_tasks").is_some());
        }
    }

    #[test]
    fn submit_path_reports_errors_through_the_callback() {
        let eng = engine();
        let (tx, rx) = std::sync::mpsc::channel::<PendingResponse>();
        for bad in [r#"{"id":9,"sampler":"ddim","n":16}"#, "{nope"] {
            let tx = tx.clone();
            submit_line_engine(&eng, "gmm_toy2d", bad, move |resp| {
                let _ = tx.send(resp);
            });
        }
        drop(tx);
        let got: Vec<Value> = rx.iter().map(|r| json::parse(&r.into_line()).unwrap()).collect();
        assert_eq!(got.len(), 2);
        for v in got {
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{v:?}");
        }
    }

    #[test]
    fn engine_path_matches_direct_backend_path() {
        // Same request line through the dedicated-backend path and the
        // multi-tenant engine path: identical samples (the serving-layer
        // face of the engine's equivalence invariant).
        let eng = engine();
        let be = backend();
        for line in [
            r#"{"id":1,"sampler":"srds","n":25,"seed":3,"tol":1e-4}"#,
            r#"{"id":2,"sampler":"sequential","n":25,"seed":3}"#,
            r#"{"id":3,"sampler":"paradigms","n":16,"seed":5,"tol":1e-6}"#,
        ] {
            let direct = json::parse(&handle_line(be.as_ref(), "gmm_toy2d", line)).unwrap();
            let engined = json::parse(&handle_line_engine(&eng, "gmm_toy2d", line)).unwrap();
            assert_eq!(engined.get("ok").unwrap().as_bool(), Some(true), "{line}");
            let a = direct.get("sample").unwrap().as_f32_vec().unwrap();
            let b = engined.get("sample").unwrap().as_f32_vec().unwrap();
            let d = ConvNorm::L1Mean.dist(&a, &b);
            assert!(d < 1e-6, "{line}: engine vs direct {d}");
            assert_eq!(
                direct.get("iters").unwrap().as_f64(),
                engined.get("iters").unwrap().as_f64(),
                "{line}"
            );
        }
    }

    #[test]
    fn engine_path_still_serves_srds_iterates() {
        // `iterates: true` is served natively by the SRDS task (its grid
        // retains every refinement's final state), so the wire contract
        // is unchanged on the engine path — no off-engine fallback.
        let eng = engine();
        let line = r#"{"id":4,"sampler":"srds","n":16,"seed":2,"tol":0.0,"iterates":true}"#;
        let v = json::parse(&handle_line_engine(&eng, "gmm_toy2d", line)).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{v:?}");
        let iters = v.get("iters").unwrap().as_f64().unwrap() as usize;
        let iterates = v.get("iterates").unwrap().as_arr().unwrap();
        assert_eq!(iterates.len(), iters + 1, "coarse init + one per refinement");
    }

    #[test]
    fn engine_path_rejects_bad_requests_like_direct_path() {
        let eng = engine();
        for bad in [
            r#"{"id":9,"sampler":"ddim","n":16}"#,
            r#"{"id":2,"n":16,"block":0}"#,
            r#"{"id":7,"n":16,"norm":"l7"}"#,
            "{nope",
        ] {
            let resp = handle_line_engine(&eng, "gmm_toy2d", bad);
            let v = json::parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{bad} -> {resp}");
        }
    }

    #[test]
    fn samplers_agree_on_sample() {
        // The registry-driven interchangeability check, over the wire
        // protocol: every registered sampler reproduces the sequential
        // sample at tight tolerance.
        let be = backend();
        let mk = |sampler: &str| {
            let line =
                format!(r#"{{"id":1,"sampler":"{sampler}","n":25,"seed":9,"tol":1e-6}}"#);
            let resp = handle_line(be.as_ref(), "gmm_toy2d", &line);
            json::parse(&resp).unwrap().get("sample").unwrap().as_f32_vec().unwrap()
        };
        let seq = mk("sequential");
        for sampler in registry().list() {
            let out = mk(sampler);
            let d = ConvNorm::L1Mean.dist(&out, &seq);
            assert!(d < 1e-2, "{sampler} vs sequential: {d}");
        }
    }
}
