//! Time grids and coarse/fine partitions of the denoising interval.

/// The uniform `(n+1)`-point denoising grid `s_0 = 0, …, s_n = 1`.
///
/// Grid points are computed as `i / n` in f32 — identical to
/// `jnp.linspace(0, 1, n+1)` on the python side, so native and HLO solves
/// see the same times.
#[derive(Debug, Clone)]
pub struct Grid {
    n: usize,
    pts: Vec<f32>,
}

impl Grid {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "grid needs at least one step");
        let pts = (0..=n).map(|i| i as f32 / n as f32).collect();
        Grid { n, pts }
    }

    /// Number of fine steps `N`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// `s_i` for `i ∈ [0, n]`.
    #[inline]
    pub fn s(&self, i: usize) -> f32 {
        self.pts[i]
    }

    pub fn points(&self) -> &[f32] {
        &self.pts
    }
}

/// A two-level partition of an `N`-step grid into `num_blocks` blocks of
/// (up to) `block` fine steps each — the Parareal coarse discretization.
///
/// The paper uses `block ≈ √N` (App. B, Prop. 4); `N` need not be a
/// perfect square — the last block is simply smaller (paper footnote 2).
#[derive(Debug, Clone)]
pub struct Partition {
    grid: Grid,
    block: usize,
    /// Fine-grid index of each block boundary: `0 = b_0 < b_1 < … < b_M = N`.
    bounds: Vec<usize>,
}

impl Partition {
    /// Partition with an explicit block size `b` (fine steps per block).
    pub fn with_block(n: usize, block: usize) -> Self {
        assert!(block >= 1 && block <= n);
        let grid = Grid::new(n);
        let mut bounds = vec![0];
        let mut i = 0;
        while i < n {
            i = (i + block).min(n);
            bounds.push(i);
        }
        Partition { grid, block, bounds }
    }

    /// The paper's default: `block = ⌈√N⌉`.
    pub fn sqrt_n(n: usize) -> Self {
        let b = (n as f64).sqrt().ceil() as usize;
        Self::with_block(n, b.max(1))
    }

    pub fn grid(&self) -> &Grid {
        &self.grid
    }

    pub fn n(&self) -> usize {
        self.grid.n()
    }

    /// Nominal fine steps per block (last block may be smaller).
    pub fn block(&self) -> usize {
        self.block
    }

    /// Number of blocks `M = ⌈N / block⌉`.
    pub fn num_blocks(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Fine-grid index of coarse boundary `j ∈ [0, M]`.
    #[inline]
    pub fn bound(&self, j: usize) -> usize {
        self.bounds[j]
    }

    /// `s` at coarse boundary `j`.
    #[inline]
    pub fn s_bound(&self, j: usize) -> f32 {
        self.grid.s(self.bounds[j])
    }

    /// Fine steps inside block `j` (≥ 1).
    #[inline]
    pub fn block_len(&self, j: usize) -> usize {
        self.bounds[j + 1] - self.bounds[j]
    }

    /// Fine-grid `s` values covered by block `j`: `block_len + 1` points.
    pub fn block_points(&self, j: usize) -> &[f32] {
        &self.grid.points()[self.bounds[j]..=self.bounds[j + 1]]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_endpoints() {
        let g = Grid::new(25);
        assert_eq!(g.s(0), 0.0);
        assert_eq!(g.s(25), 1.0);
        assert_eq!(g.points().len(), 26);
    }

    #[test]
    fn perfect_square_partition() {
        let p = Partition::sqrt_n(25);
        assert_eq!(p.block(), 5);
        assert_eq!(p.num_blocks(), 5);
        for j in 0..5 {
            assert_eq!(p.block_len(j), 5);
        }
    }

    #[test]
    fn non_square_partition_last_block_smaller() {
        // Paper footnote 2: ⌈√N⌉ blocks with a smaller last interval.
        let p = Partition::sqrt_n(27); // block = 6 -> bounds 0,6,12,18,24,27
        assert_eq!(p.block(), 6);
        assert_eq!(p.num_blocks(), 5);
        assert_eq!(p.block_len(4), 3);
        let total: usize = (0..p.num_blocks()).map(|j| p.block_len(j)).sum();
        assert_eq!(total, 27);
    }

    #[test]
    fn block_points_are_contiguous() {
        let p = Partition::with_block(16, 4);
        let pts = p.block_points(2);
        assert_eq!(pts.len(), 5);
        assert_eq!(pts[0], p.s_bound(2));
        assert_eq!(pts[4], p.s_bound(3));
    }

    #[test]
    fn covers_every_fine_step() {
        for n in [1usize, 2, 3, 16, 25, 27, 100, 196, 961, 1024] {
            let p = Partition::sqrt_n(n);
            assert_eq!(p.bound(0), 0);
            assert_eq!(p.bound(p.num_blocks()), n);
            let total: usize = (0..p.num_blocks()).map(|j| p.block_len(j)).sum();
            assert_eq!(total, n, "n={n}");
        }
    }
}
