//! Continuous VP diffusion schedule — bit-compatible (f32) with
//! `python/compile/schedule.py`.
//!
//! Conventions (paper §2, reversed index): denoising progress `s ∈ [0,1]`
//! with `s = 0` pure noise and `s = 1` data; diffusion time `tau = 1 - s`.
//!
//! ```text
//! beta(tau)         = BETA_MIN + tau * (BETA_MAX - BETA_MIN)
//! log alpha_bar(tau)= -(BETA_MIN*tau + 0.5*(BETA_MAX-BETA_MIN)*tau^2)
//! ```

mod grid;
pub use grid::{Grid, Partition};

/// Schedule constants — MUST match `python/compile/schedule.py`.
pub const BETA_MIN: f32 = 0.1;
pub const BETA_MAX: f32 = 20.0;
pub const DBETA: f32 = BETA_MAX - BETA_MIN;
/// Floor on `sqrt(1 - alpha_bar)`; guards the score→eps conversion at
/// `s = 1` where `1 - alpha_bar = 0` (Euler/Heun/DPM evaluate there).
pub const SIGMA_FLOOR: f32 = 1e-4;

/// `beta(tau)`, the VP noise rate.
#[inline]
pub fn beta(tau: f32) -> f32 {
    BETA_MIN + tau * DBETA
}

/// `log alpha_bar` as a function of diffusion time `tau`.
#[inline]
pub fn log_alpha_bar(tau: f32) -> f32 {
    -(BETA_MIN * tau + 0.5 * DBETA * tau * tau)
}

/// `alpha_bar` as a function of denoising progress `s ∈ [0, 1]`.
#[inline]
pub fn alpha_bar(s: f32) -> f32 {
    log_alpha_bar(1.0 - s).exp()
}

/// `sqrt(alpha_bar(s))`.
#[inline]
pub fn sqrt_ab(s: f32) -> f32 {
    alpha_bar(s).sqrt()
}

/// `sqrt(1 - alpha_bar(s))`, floored away from zero (see [`SIGMA_FLOOR`]).
#[inline]
pub fn sigma(s: f32) -> f32 {
    (1.0 - alpha_bar(s)).max(0.0).sqrt().max(SIGMA_FLOOR)
}

/// Half log-SNR `lambda(s) = log(sqrt_ab / sigma)` (DPM-Solver space).
#[inline]
pub fn lam(s: f32) -> f32 {
    (sqrt_ab(s) / sigma(s)).ln()
}

/// Invert `lambda → s` in closed form (DPM-Solver-2 midpoints).
///
/// `alpha_bar = sigmoid(2 lambda)`, then solve the schedule quadratic for
/// `tau ≥ 0`. Mirrors `schedule.s_of_lam` in python (same float32 ops).
#[inline]
pub fn s_of_lam(l: f32) -> f32 {
    // log sigmoid(2l) = -log(1 + exp(-2l)) computed stably
    let log_ab = -log1p_exp(-2.0 * l);
    let disc = BETA_MIN * BETA_MIN - 2.0 * DBETA * log_ab;
    let tau = (-BETA_MIN + disc.sqrt()) / DBETA;
    1.0 - tau.clamp(0.0, 1.0)
}

/// Numerically stable `log(1 + exp(x))` (float32, matches jnp.logaddexp).
#[inline]
fn log1p_exp(x: f32) -> f32 {
    if x > 0.0 {
        x + (-x).exp().ln_1p()
    } else {
        x.exp().ln_1p()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints() {
        assert!((alpha_bar(1.0) - 1.0).abs() < 1e-7, "s=1 is clean data");
        let ab0 = alpha_bar(0.0);
        assert!(ab0 < 1e-4 && ab0 > 0.0, "s=0 is (almost) pure noise: {ab0}");
    }

    #[test]
    fn monotone() {
        let mut prev = alpha_bar(0.0);
        for i in 1..=100 {
            let ab = alpha_bar(i as f32 / 100.0);
            assert!(ab > prev, "alpha_bar must increase with s");
            prev = ab;
        }
    }

    #[test]
    fn sigma_floored_at_data() {
        assert_eq!(sigma(1.0), SIGMA_FLOOR);
    }

    #[test]
    fn lam_inverse_roundtrip() {
        for i in 1..100 {
            let s = i as f32 / 100.0;
            let back = s_of_lam(lam(s));
            assert!(
                (back - s).abs() < 2e-3,
                "s_of_lam(lam({s})) = {back}"
            );
        }
    }

    #[test]
    fn beta_positive() {
        for i in 0..=10 {
            assert!(beta(i as f32 / 10.0) > 0.0);
        }
    }
}
