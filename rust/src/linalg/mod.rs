//! Dense symmetric linear algebra for the metrics layer (no external
//! deps in this offline environment): Jacobi eigendecomposition, PSD
//! matrix square root — sized for `d ≤ 256` covariance work.

/// Jacobi eigenvalue iteration for a symmetric matrix (row-major `n×n`).
///
/// Returns `(eigenvalues, eigenvectors)` with eigenvectors in columns of
/// the returned row-major matrix `v` (i.e. `A = V diag(w) Vᵀ`).
/// Cyclic-by-row sweeps; converges quadratically — ~8 sweeps at d=256.
pub fn jacobi_eigh(a_in: &[f64], n: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a_in.len(), n * n);
    let mut a = a_in.to_vec();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0f64;
        for i in 0..n {
            for j in i + 1..n {
                off += a[i * n + j] * a[i * n + j];
            }
        }
        if off.sqrt() < 1e-12 * (1.0 + frob(&a, n)) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = a[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q of A.
                for k in 0..n {
                    let akp = a[k * n + p];
                    let akq = a[k * n + q];
                    a[k * n + p] = c * akp - s * akq;
                    a[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[p * n + k];
                    let aqk = a[q * n + k];
                    a[p * n + k] = c * apk - s * aqk;
                    a[q * n + k] = s * apk + c * aqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let w: Vec<f64> = (0..n).map(|i| a[i * n + i]).collect();
    (w, v)
}

fn frob(a: &[f64], n: usize) -> f64 {
    a.iter().take(n * n).map(|x| x * x).sum::<f64>().sqrt()
}

/// Symmetric PSD matrix square root via eigendecomposition (negative
/// eigenvalues from numerical noise are clamped to zero).
pub fn sqrtm_psd(a: &[f64], n: usize) -> Vec<f64> {
    let (w, v) = jacobi_eigh(a, n);
    let sw: Vec<f64> = w.iter().map(|&x| x.max(0.0).sqrt()).collect();
    // V diag(sw) Vᵀ
    let mut out = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0;
            for k in 0..n {
                acc += v[i * n + k] * sw[k] * v[j * n + k];
            }
            out[i * n + j] = acc;
        }
    }
    out
}

/// `C = A · B` for row-major `n×n` matrices.
pub fn matmul(a: &[f64], b: &[f64], n: usize) -> Vec<f64> {
    let mut c = vec![0.0f64; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            if aik == 0.0 {
                continue;
            }
            let br = &b[k * n..(k + 1) * n];
            let cr = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                cr[j] += aik * br[j];
            }
        }
    }
    c
}

/// Trace of a row-major `n×n` matrix.
pub fn trace(a: &[f64], n: usize) -> f64 {
    (0..n).map(|i| a[i * n + i]).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::SplitMix64;

    fn random_psd(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = SplitMix64::new(seed);
        let b: Vec<f64> = (0..n * n).map(|_| rng.next_normal()).collect();
        // A = B Bᵀ / n + 0.1 I (strictly PD)
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0;
                for k in 0..n {
                    acc += b[i * n + k] * b[j * n + k];
                }
                a[i * n + j] = acc / n as f64;
            }
            a[i * n + i] += 0.1;
        }
        a
    }

    #[test]
    fn eigh_diagonal_matrix() {
        let a = vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0];
        let (mut w, _) = jacobi_eigh(&a, 3);
        w.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((w[0] - 1.0).abs() < 1e-12);
        assert!((w[1] - 2.0).abs() < 1e-12);
        assert!((w[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn eigh_reconstructs() {
        let n = 16;
        let a = random_psd(n, 7);
        let (w, v) = jacobi_eigh(&a, n);
        // A ≈ V diag(w) Vᵀ
        let mut rec = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0;
                for k in 0..n {
                    acc += v[i * n + k] * w[k] * v[j * n + k];
                }
                rec[i * n + j] = acc;
            }
        }
        for t in 0..n * n {
            assert!((rec[t] - a[t]).abs() < 1e-8, "elem {t}");
        }
    }

    #[test]
    fn sqrtm_squares_back() {
        let n = 12;
        let a = random_psd(n, 3);
        let s = sqrtm_psd(&a, n);
        let ss = matmul(&s, &s, n);
        for t in 0..n * n {
            assert!((ss[t] - a[t]).abs() < 1e-8, "elem {t}: {} vs {}", ss[t], a[t]);
        }
    }

    #[test]
    fn trace_and_matmul() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![0.0, 1.0, 1.0, 0.0];
        let c = matmul(&a, &b, 2);
        assert_eq!(c, vec![2.0, 1.0, 4.0, 3.0]);
        assert_eq!(trace(&a, 2), 5.0);
    }

    #[test]
    fn eigenvalues_of_psd_are_nonnegative() {
        let a = random_psd(24, 11);
        let (w, _) = jacobi_eigh(&a, 24);
        assert!(w.iter().all(|&x| x > -1e-10));
    }
}
