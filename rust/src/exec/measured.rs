//! Measured executor: real worker threads, each owning its own
//! (thread-bound) backend instance, running the **pipelined SRDS**
//! dataflow of Fig. 4 with true concurrency.
//!
//! The main thread is a dependency-driven dispatcher: it releases a fine
//! solve `F(p, i)` the moment `x^{p-1}_{i-1}` materializes and a coarse
//! step `G(p, i)` the moment `x^p_{i-1}` does — no iteration barrier, as
//! in the paper's pipelined implementation (which it improves on: the
//! paper's §4.2 footnote notes their torch.multiprocessing version still
//! round-trips through a coordinator device; here workers stay hot and
//! only states cross threads).

use crate::coordinator::{Conditioning, IterStat, RunStats, SampleOutput, SamplerSpec};
use crate::solvers::{BackendFactory, Solver, StepBackend, StepRequest};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// What a worker executes: a full fine block solve or one coarse step.
#[derive(Debug)]
pub enum JobKind {
    /// `block_points` fine steps from `s[0]` to `s[last]`.
    Fine { points: Vec<f32> },
    /// One coarse step from `s_from` to `s_to`.
    Coarse { s_from: f32, s_to: f32 },
}

/// A unit of work dispatched to the pool.
#[derive(Debug)]
pub struct Job {
    /// (iteration p, block i, is_fine) — the dispatcher's bookkeeping key.
    pub key: (usize, usize, bool),
    pub kind: JobKind,
    pub x: Vec<f32>,
    pub mask: Option<Vec<f32>>,
    pub guidance: f32,
    pub seed: u64,
}

impl Job {
    /// Critical-path priority: earlier iterations first, then earlier
    /// blocks, with coarse steps ahead of fine solves at equal (p, i) —
    /// the G chain is the serial spine of the schedule (Prop. 2 proof).
    fn priority(&self) -> u64 {
        let (p, i, is_fine) = self.key;
        ((p as u64) << 32) | ((i as u64) << 1) | is_fine as u64
    }
}

/// Completed work.
pub struct JobDone {
    pub key: (usize, usize, bool),
    pub out: Vec<f32>,
    /// Model evaluations this job burned.
    pub evals: u64,
}

/// Priority entry (min-heap by `prio` via reversed Ord).
struct QJob {
    prio: u64,
    seq: u64,
    job: Job,
}

impl PartialEq for QJob {
    fn eq(&self, other: &Self) -> bool {
        (self.prio, self.seq) == (other.prio, other.seq)
    }
}
impl Eq for QJob {}
impl PartialOrd for QJob {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QJob {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap is a max-heap, we want the smallest prio.
        (other.prio, other.seq).cmp(&(self.prio, self.seq))
    }
}

struct PoolState {
    queue: std::collections::BinaryHeap<QJob>,
    closed: bool,
    seq: u64,
}

/// Fixed pool of worker threads, one backend instance each, pulling from
/// a shared **priority** queue (critical-path-first; speculative work
/// from later iterations never delays the serial spine).
pub struct WorkerPool {
    state: Arc<(Mutex<PoolState>, std::sync::Condvar)>,
    done_rx: Receiver<JobDone>,
    stop: Arc<AtomicBool>,
    handles: Vec<std::thread::JoinHandle<()>>,
    workers: usize,
}

impl WorkerPool {
    /// Spawn `workers` threads; each calls `factory.create()` locally
    /// (PJRT clients are `Rc`-based and cannot cross threads).
    pub fn new(factory: Arc<dyn BackendFactory>, workers: usize) -> Self {
        let state = Arc::new((
            Mutex::new(PoolState { queue: std::collections::BinaryHeap::new(), closed: false, seq: 0 }),
            std::sync::Condvar::new(),
        ));
        let (done_tx, done_rx) = channel::<JobDone>();
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for w in 0..workers {
            let state = state.clone();
            let done_tx = done_tx.clone();
            let factory = factory.clone();
            let stop = stop.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("srds-worker-{w}"))
                    .spawn(move || {
                        let backend = factory.create();
                        loop {
                            let job = {
                                let (lock, cv) = &*state;
                                let mut st = lock.lock().unwrap();
                                loop {
                                    if let Some(qj) = st.queue.pop() {
                                        break Some(qj.job);
                                    }
                                    if st.closed {
                                        break None;
                                    }
                                    st = cv.wait(st).unwrap();
                                }
                            };
                            let Some(job) = job else { break };
                            if stop.load(Ordering::Relaxed) {
                                break;
                            }
                            let done = run_job(backend.as_ref(), job);
                            if done_tx.send(done).is_err() {
                                break;
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        WorkerPool { state, done_rx, stop, handles, workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn submit(&self, job: Job) {
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap();
        let prio = job.priority();
        let seq = st.seq;
        st.seq += 1;
        st.queue.push(QJob { prio, seq, job });
        cv.notify_one();
    }

    pub fn recv(&self) -> JobDone {
        self.done_rx.recv().expect("pool alive")
    }

    /// Remove every job still queued (not yet started). Returns how many
    /// were dropped — the dispatcher subtracts them from its in-flight
    /// count. Used when SRDS converges early and the speculative tail of
    /// the schedule becomes garbage.
    pub fn purge_queued(&self) -> usize {
        let (lock, _) = &*self.state;
        let mut st = lock.lock().unwrap();
        let n = st.queue.len();
        st.queue.clear();
        n
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        {
            let (lock, cv) = &*self.state;
            let mut st = lock.lock().unwrap();
            st.closed = true;
            st.queue.clear();
            cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn run_job(backend: &dyn StepBackend, job: Job) -> JobDone {
    match job.kind {
        JobKind::Coarse { s_from, s_to } => {
            let out = backend.step(&StepRequest {
                x: &job.x,
                s_from: &[s_from],
                s_to: &[s_to],
                mask: job.mask.as_deref(),
                guidance: job.guidance,
                seeds: &[job.seed],
            });
            JobDone { key: job.key, out, evals: backend.evals_per_step() as u64 }
        }
        JobKind::Fine { points } => {
            let mut x = job.x;
            let mut evals = 0u64;
            for w in points.windows(2) {
                x = backend.step(&StepRequest {
                    x: &x,
                    s_from: &[w[0]],
                    s_to: &[w[1]],
                    mask: job.mask.as_deref(),
                    guidance: job.guidance,
                    seeds: &[job.seed],
                });
                evals += backend.evals_per_step() as u64;
            }
            JobDone { key: job.key, out: x, evals }
        }
    }
}

/// Factory producing native backends (each worker gets a cheap clone of
/// the shared model Arc).
pub struct NativeFactory {
    model: Arc<dyn crate::model::EpsModel>,
    solver: Solver,
}

impl NativeFactory {
    pub fn new(model: Arc<dyn crate::model::EpsModel>, solver: Solver) -> Self {
        NativeFactory { model, solver }
    }
}

impl BackendFactory for NativeFactory {
    fn create(&self) -> Box<dyn StepBackend> {
        Box::new(crate::solvers::NativeBackend::new(self.model.clone(), self.solver))
    }

    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn solver(&self) -> Solver {
        self.solver
    }
}

/// Pipelined SRDS over a worker pool (Fig. 4), dependency-driven.
///
/// Produces the same iterates as [`crate::coordinator::srds`] (pinned by
/// the integration tests) while overlapping iterations across devices;
/// `stats.wall` is a real measurement.
///
/// The dispatcher is fully event-driven: each job completion touches only
/// the O(1) cells it can unblock (corrector at its own cell, the fine /
/// coarse jobs downstream of a newly-materialized state) instead of
/// rescanning the whole (iteration × block) grid — see EXPERIMENTS.md
/// §Perf L3 for the before/after.
pub fn measured_pipelined_srds(
    pool: &WorkerPool,
    x0: &[f32],
    spec: &SamplerSpec,
) -> SampleOutput {
    let t0 = Instant::now();
    let part = spec.partition();
    let m = part.num_blocks();
    let cond = &spec.cond;
    let max_iters = spec.max_iters.unwrap_or(m).max(1).min(m);

    // Grid state, indexed [p][i].
    let mut x_state: Vec<Vec<Option<Vec<f32>>>> = vec![vec![None; m + 1]; max_iters + 1];
    let mut g: Vec<Vec<Option<Vec<f32>>>> = vec![vec![None; m + 1]; max_iters + 1];
    let mut y: Vec<Vec<Option<Vec<f32>>>> = vec![vec![None; m + 1]; max_iters + 1];
    let mut submitted = vec![vec![[false; 2]; m + 1]; max_iters + 1];
    let mut total_evals = 0u64;
    let mut per_iter: Vec<IterStat> = Vec::new();
    let mut stop_at_iter: Option<usize> = None;
    let mut inflight = 0usize;

    // Submit helpers (closures can't borrow everything mutably; keep as
    // small fns taking the needed state).
    struct Ctx<'a> {
        pool: &'a WorkerPool,
        part: &'a crate::schedule::Partition,
        cond: &'a Conditioning,
        seed: u64,
    }
    let ctx = Ctx { pool, part: &part, cond, seed: spec.seed };
    let submit_fine = |ctx: &Ctx, p: usize, i: usize, x: Vec<f32>, inflight: &mut usize| {
        *inflight += 1;
        ctx.pool.submit(Job {
            key: (p, i, true),
            kind: JobKind::Fine { points: ctx.part.block_points(i - 1).to_vec() },
            x,
            mask: ctx.cond.mask.clone(),
            guidance: ctx.cond.guidance,
            seed: ctx.seed,
        });
    };
    let submit_coarse = |ctx: &Ctx, p: usize, i: usize, x: Vec<f32>, inflight: &mut usize| {
        *inflight += 1;
        ctx.pool.submit(Job {
            key: (p, i, false),
            kind: JobKind::Coarse {
                s_from: ctx.part.s_bound(i - 1),
                s_to: ctx.part.s_bound(i),
            },
            x,
            mask: ctx.cond.mask.clone(),
            guidance: ctx.cond.guidance,
            seed: ctx.seed,
        });
    };

    // Seed the prior states and kick off everything x0 unblocks:
    // G(p, 1) for every p (their input never changes) and F(1, 1).
    for p in 0..=max_iters {
        x_state[p][0] = Some(x0.to_vec());
    }
    for p in 0..=max_iters {
        if !submitted[p][1][0] {
            submitted[p][1][0] = true;
            submit_coarse(&ctx, p, 1, x0.to_vec(), &mut inflight);
        }
        // F(p, 1) for every refinement: its input x^{p-1}_0 = x0 is
        // already final (block 1's fine solve is identical across
        // iterations — recomputed here; the vanilla path caches it).
        if p >= 1 && !submitted[p][1][1] {
            submitted[p][1][1] = true;
            submit_fine(&ctx, p, 1, x0.to_vec(), &mut inflight);
        }
    }

    // Newly-materialized states to propagate.
    let mut ready: Vec<(usize, usize)> = Vec::new();

    while inflight > 0 {
        let done = pool.recv();
        inflight -= 1;
        total_evals += done.evals;
        let (p, i, is_fine) = done.key;
        if is_fine {
            y[p][i] = Some(done.out);
        } else {
            g[p][i] = Some(done.out);
        }
        // Corrector attempts unblocked by this result: cell (p, i) and —
        // when a coarse result acts as `prev` — cell (p+1, i).
        let mut attempts = vec![(p, i)];
        if !is_fine && p + 1 <= max_iters {
            attempts.push((p + 1, i));
        }
        for (ap, ai) in attempts {
            if x_state[ap][ai].is_some() {
                continue;
            }
            let materialized = if ap == 0 {
                g[0][ai].clone()
            } else if let (Some(yi), Some(cur), Some(prev)) =
                (&y[ap][ai], &g[ap][ai], &g[ap - 1][ai])
            {
                Some(
                    yi.iter()
                        .zip(cur.iter().zip(prev))
                        .map(|(a, (b, c))| a + (b - c))
                        .collect(),
                )
            } else {
                None
            };
            if let Some(v) = materialized {
                x_state[ap][ai] = Some(v);
                ready.push((ap, ai));
            }
        }
        // Propagate each new state to the jobs it unblocks.
        while let Some((sp, si)) = ready.pop() {
            let past_stop = |p: usize| stop_at_iter.map(|s| p > s).unwrap_or(false);
            // F(sp+1, si+1) needs x^{sp}_{si}.
            if si + 1 <= m && sp + 1 <= max_iters && !submitted[sp + 1][si + 1][1] && !past_stop(sp + 1) {
                submitted[sp + 1][si + 1][1] = true;
                submit_fine(&ctx, sp + 1, si + 1, x_state[sp][si].clone().unwrap(), &mut inflight);
            }
            // G(sp, si+1) needs x^{sp}_{si}.
            if si + 1 <= m && !submitted[sp][si + 1][0] && !past_stop(sp) {
                submitted[sp][si + 1][0] = true;
                submit_coarse(&ctx, sp, si + 1, x_state[sp][si].clone().unwrap(), &mut inflight);
            }
            // Convergence: strictly in iteration order (a later final
            // state can exist before an earlier one, see the while-let
            // ordering note in the history of this file).
            if si == m {
                while stop_at_iter.is_none() {
                    let pp = per_iter.len() + 1;
                    if pp > max_iters {
                        break;
                    }
                    let (Some(curf), Some(prevf)) = (&x_state[pp][m], &x_state[pp - 1][m]) else {
                        break;
                    };
                    let residual = spec.norm.dist(curf, prevf);
                    per_iter.push(IterStat { iter: pp, residual, evals: 0 });
                    if residual < spec.tol || pp >= m {
                        stop_at_iter = Some(pp);
                    }
                }
            }
        }
        if let Some(s) = stop_at_iter {
            if x_state[s][m].is_some() {
                // Converged: purge the speculative queued tail outright
                // and only wait out the ≤ workers jobs already running.
                inflight -= pool.purge_queued();
                while inflight > 0 {
                    let d = pool.recv();
                    total_evals += d.evals;
                    inflight -= 1;
                }
                break;
            }
        }
    }

    let final_iter = stop_at_iter.unwrap_or_else(|| {
        (1..=max_iters).rev().find(|&p| x_state[p][m].is_some()).unwrap_or(0)
    });
    let sample = x_state[final_iter][m].clone().expect("final state");
    let converged = per_iter
        .iter()
        .find(|s| s.iter == final_iter)
        .map(|s| s.residual < spec.tol || final_iter >= m)
        .unwrap_or(false);
    let b = part.block();
    let stats = RunStats {
        iters: final_iter,
        converged,
        eff_serial_evals: 0, // accounting comes from the simclock path
        eff_serial_evals_pipelined: if final_iter == 0 {
            m as u64
        } else {
            (m * final_iter + b).saturating_sub(final_iter) as u64
        },
        total_evals,
        wall: t0.elapsed(),
        // The dispatcher materializes the full (iterations × blocks) grid
        // of x/G/F states — wall-clock-optimal, not memory-optimal.
        peak_states: 3 * (max_iters + 1) * (m + 1),
        per_iter,
    };
    SampleOutput { sample, stats, iterates: vec![] }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{prior_sample, srds, SamplerSpec};
    use crate::data::make_gmm;
    use crate::model::GmmEps;

    fn pool(workers: usize) -> WorkerPool {
        let model: Arc<dyn crate::model::EpsModel> =
            Arc::new(GmmEps::new(make_gmm("church")));
        WorkerPool::new(Arc::new(NativeFactory::new(model, Solver::Ddim)), workers)
    }

    #[test]
    fn pipelined_matches_vanilla_srds_output() {
        let p = pool(4);
        let x0 = prior_sample(64, 42);
        let spec = SamplerSpec::srds(64).with_tol(1e-4).with_seed(42);
        let measured = measured_pipelined_srds(&p, &x0, &spec);

        let model: Arc<dyn crate::model::EpsModel> =
            Arc::new(GmmEps::new(make_gmm("church")));
        let be = crate::solvers::NativeBackend::new(model, Solver::Ddim);
        let vanilla = srds(&be, &x0, &spec);
        assert_eq!(measured.stats.iters, vanilla.stats.iters);
        let d = spec.norm.dist(&measured.sample, &vanilla.sample);
        assert!(d < 1e-6, "measured vs vanilla {d}");
    }

    #[test]
    fn single_worker_still_completes() {
        let p = pool(1);
        let x0 = prior_sample(64, 7);
        let spec = SamplerSpec::srds(25).with_tol(1e-3).with_seed(7);
        let res = measured_pipelined_srds(&p, &x0, &spec);
        assert!(res.stats.converged);
        assert!(!res.sample.iter().any(|v| v.is_nan()));
    }

    #[test]
    fn worst_case_equals_sequential_native() {
        let p = pool(6);
        let x0 = prior_sample(64, 5);
        let n = 16;
        let spec = SamplerSpec::srds(n).with_tol(0.0).with_seed(5);
        let res = measured_pipelined_srds(&p, &x0, &spec);
        let model: Arc<dyn crate::model::EpsModel> =
            Arc::new(GmmEps::new(make_gmm("church")));
        let be = crate::solvers::NativeBackend::new(model, Solver::Ddim);
        let (seq, _) =
            crate::coordinator::sequential(&be, &x0, n, &Conditioning::none(), 5);
        assert_eq!(res.sample, seq);
    }

    #[test]
    fn pool_shuts_down_cleanly() {
        let p = pool(2);
        drop(p); // must not hang
    }
}
