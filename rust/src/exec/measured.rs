//! Measured executor: real worker threads with thread-bound backends
//! running the **pipelined SRDS** dataflow of Fig. 4 with true
//! concurrency — wall-clock numbers come from here.
//!
//! Since the multi-tenant rework this module is a thin veneer over
//! [`crate::exec::engine`]: [`WorkerPool`] owns an [`Engine`] configured
//! with [`BatchPolicy::immediate`] (flush eagerly, never hold a row
//! waiting for co-tenants — the right policy when one benchmark request
//! owns the pool), and [`measured_pipelined_srds`] submits one request
//! and blocks. The dependency-driven dispatcher that used to live here
//! — release `F(p, i)` the moment `x^{p-1}_{i-1}` materializes, `G(p, i)`
//! the moment `x^p_{i-1}` does, no iteration barrier — is now the
//! engine-native SRDS [`crate::exec::task::SamplerTask`], shared by
//! every tenant.

use crate::batching::BatchPolicy;
use crate::coordinator::{SampleOutput, SamplerSpec};
use crate::exec::engine::{Engine, EngineConfig};
use crate::solvers::{BackendFactory, Solver};
use std::sync::Arc;

/// Fixed pool of worker threads, one backend instance each. Kept as the
/// single-request face of the engine for the benches and tests that
/// measure one sampler at a time.
pub struct WorkerPool {
    engine: Engine,
}

impl WorkerPool {
    /// Spawn `workers` threads; each calls `factory.create()` locally
    /// (PJRT clients are `Rc`-based and cannot cross threads).
    pub fn new(factory: Arc<dyn BackendFactory>, workers: usize) -> Self {
        WorkerPool {
            engine: Engine::new(factory, EngineConfig { workers, batch: BatchPolicy::immediate(), ..EngineConfig::default() }),
        }
    }

    pub fn workers(&self) -> usize {
        self.engine.workers()
    }

    /// The underlying multi-tenant engine.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

/// Pipelined SRDS over a worker pool (Fig. 4), dependency-driven.
///
/// Produces the same iterates as [`crate::coordinator::srds`] (pinned by
/// the integration tests) while overlapping iterations across devices;
/// `stats.wall` is a real measurement.
pub fn measured_pipelined_srds(
    pool: &WorkerPool,
    x0: &[f32],
    spec: &SamplerSpec,
) -> SampleOutput {
    pool.engine.run(x0, spec)
}

/// Factory producing native backends (each worker gets a cheap clone of
/// the shared model Arc).
pub struct NativeFactory {
    model: Arc<dyn crate::model::EpsModel>,
    solver: Solver,
}

impl NativeFactory {
    pub fn new(model: Arc<dyn crate::model::EpsModel>, solver: Solver) -> Self {
        NativeFactory { model, solver }
    }
}

impl BackendFactory for NativeFactory {
    fn create(&self) -> Box<dyn crate::solvers::StepBackend> {
        Box::new(crate::solvers::NativeBackend::new(self.model.clone(), self.solver))
    }

    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn solver(&self) -> Solver {
        self.solver
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{prior_sample, srds, Conditioning, SamplerSpec};
    use crate::data::make_gmm;
    use crate::model::GmmEps;

    fn pool(workers: usize) -> WorkerPool {
        let model: Arc<dyn crate::model::EpsModel> =
            Arc::new(GmmEps::new(make_gmm("church")));
        WorkerPool::new(Arc::new(NativeFactory::new(model, Solver::Ddim)), workers)
    }

    #[test]
    fn pipelined_matches_vanilla_srds_output() {
        let p = pool(4);
        let x0 = prior_sample(64, 42);
        let spec = SamplerSpec::srds(64).with_tol(1e-4).with_seed(42);
        let measured = measured_pipelined_srds(&p, &x0, &spec);

        let model: Arc<dyn crate::model::EpsModel> =
            Arc::new(GmmEps::new(make_gmm("church")));
        let be = crate::solvers::NativeBackend::new(model, Solver::Ddim);
        let vanilla = srds(&be, &x0, &spec);
        assert_eq!(measured.stats.iters, vanilla.stats.iters);
        let d = spec.norm.dist(&measured.sample, &vanilla.sample);
        assert!(d < 1e-6, "measured vs vanilla {d}");
        // Satellite of the engine rework: the measured path reports the
        // vanilla-schedule eval count instead of a 0 placeholder.
        assert_eq!(measured.stats.eff_serial_evals, vanilla.stats.eff_serial_evals);
    }

    #[test]
    fn single_worker_still_completes() {
        let p = pool(1);
        let x0 = prior_sample(64, 7);
        let spec = SamplerSpec::srds(25).with_tol(1e-3).with_seed(7);
        let res = measured_pipelined_srds(&p, &x0, &spec);
        assert!(res.stats.converged);
        assert!(!res.sample.iter().any(|v| v.is_nan()));
    }

    #[test]
    fn worst_case_equals_sequential_native() {
        let p = pool(6);
        let x0 = prior_sample(64, 5);
        let n = 16;
        let spec = SamplerSpec::srds(n).with_tol(0.0).with_seed(5);
        let res = measured_pipelined_srds(&p, &x0, &spec);
        let model: Arc<dyn crate::model::EpsModel> =
            Arc::new(GmmEps::new(make_gmm("church")));
        let be = crate::solvers::NativeBackend::new(model, Solver::Ddim);
        let (seq, _) =
            crate::coordinator::sequential(&be, &x0, n, &Conditioning::none(), 5);
        assert_eq!(res.sample, seq);
    }

    #[test]
    fn pool_shuts_down_cleanly() {
        let p = pool(2);
        drop(p); // must not hang
    }
}
