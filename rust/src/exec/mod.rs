//! Execution backends realizing the samplers' parallelism:
//!
//! * [`simclock`] — deterministic discrete-event simulator: schedules the
//!   SRDS dependency graph (and the baselines' sweeps) onto `D` devices
//!   with a fixed per-eval cost. This reproduces the paper's
//!   effective-serial-eval and device-scaling tables exactly,
//!   independent of host hardware.
//! * [`task`] — engine-native sampler tasks: every registry sampler as
//!   an object-safe [`task::SamplerTask`] state machine (SRDS's
//!   dependency grid, the sequential one-row chain, ParaDiGMS's and
//!   ParaTAA's whole-sweep batched rows) that emits step rows and
//!   absorbs completions — the per-request unit the engine schedules.
//! * [`engine`] — the multi-tenant step-level engine: many concurrent
//!   sampling requests share one worker pool, each request is exactly
//!   one dispatcher-resident `SamplerTask` (no per-request threads of
//!   any kind), every fine/coarse step becomes a
//!   [`crate::batching::PendingRow`], and rows are fused into multi-row
//!   [`crate::solvers::StepRequest`] batches across requests (§3.4's
//!   batched inference, applied to serving). Rows drain through
//!   per-QoS-class lanes under weighted deficit round robin
//!   ([`crate::coordinator::QosClass`]), deadline-budgeted SRDS
//!   requests degrade to their best completed Parareal iterate, and
//!   per-class occupancy/latency lanes ride [`engine::EngineStats`].
//!   Serving submissions ([`engine::Engine::submit_serving`]) can
//!   stream: each completed anytime iterate fans out through an
//!   [`engine::ProgressSink`] as a refcount share (the wire's
//!   `iterate` frames), and a per-request wall-clock timeout
//!   finalizes SRDS from its newest iterate — or resolves
//!   [`engine::TaskReply::TimedOut`] for kinds with no anytime
//!   anchor.
//!   Determinism makes work sharing legal: identical in-flight
//!   submissions coalesce into one resident task (fanned-out
//!   bit-identical replies), and a QoS-aware LRU of finished coarse
//!   spines lets repeat SRDS requests warm-start past the serial
//!   sweep (`cache_hits`/`coalesced` counters).
//!   All request state rides in
//!   pooled [`crate::buf::StateBuf`]s from one engine-wide slab pool — a
//!   warm engine allocates no state buffers. The serving loop dispatches
//!   into this.
//! * [`router`] — the horizontal-scale front: N independent engine
//!   shards (each with its own dispatcher, worker set, and `BufPool`)
//!   behind one load/QoS-aware placement function, with queued batch
//!   rows *work-stolen* between shards over [`engine::StealMesh`] when
//!   a shard's lanes run dry. Per-shard [`engine::EngineStats`]
//!   aggregate into one fleet snapshot (`shards` / `steals` on the
//!   wire). Placement and stealing move rows, never values: a request's
//!   output is bit-identical whichever shard runs it.
//! * [`measured`] — the single-request veneer over the engine (one OS
//!   thread per simulated device, each owning its own thread-bound PJRT
//!   or native backend) running the *pipelined* SRDS dataflow of Fig. 4
//!   with true concurrency; wall-clock numbers come from here.

pub mod engine;
pub mod measured;
pub mod router;
pub mod simclock;
pub mod task;

pub use engine::{
    ClassLane, Engine, EngineConfig, EngineStats, LoadGauge, ProgressSink, StatsHandle, StealMesh,
    TaskReply,
};
pub use router::{default_shards, Router, RouterConfig};
pub use measured::{measured_pipelined_srds, NativeFactory, WorkerPool};
pub use simclock::{schedule_tasks, simulate_paradigms, simulate_sequential, simulate_srds, SimReport, SimTask};
pub use task::{new_task, new_warm_task, Completion, IterateEvent, SamplerTask, TaskRow};
