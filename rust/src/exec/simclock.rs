//! Discrete-event simulated-clock executor.
//!
//! Greedy non-preemptive list scheduling of a dependency DAG onto `D`
//! identical devices: a task becomes ready when all dependencies finish;
//! among ready tasks the earliest-ready (FIFO tie-break) runs on the
//! earliest-free device. Zero-duration tasks are synchronization events
//! and occupy no device.
//!
//! Time is measured in *model evaluations* (the unit of every latency
//! table in the paper); multiply by a per-eval cost to get seconds.

use crate::schedule::Partition;
use std::collections::BinaryHeap;

/// One task in the DAG.
#[derive(Debug, Clone)]
pub struct SimTask {
    /// Dependencies: indices of tasks that must finish first.
    pub deps: Vec<usize>,
    /// Duration in eval units (0 = pure synchronization event).
    pub dur: u64,
    /// Display label (used by the Fig. 4 gantt example).
    pub label: String,
}

/// Scheduling outcome.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Completion time of the last task (eval units).
    pub makespan: u64,
    /// Busy time per device.
    pub device_busy: Vec<u64>,
    /// Mean device utilization over the makespan.
    pub utilization: f64,
    /// Peak number of simultaneously-running (non-event) tasks.
    pub peak_concurrency: usize,
    /// (task index, device, start, end) for every non-event task.
    pub spans: Vec<(usize, usize, u64, u64)>,
}

/// List-schedule `tasks` onto `devices` identical devices.
pub fn schedule_tasks(tasks: &[SimTask], devices: usize) -> SimReport {
    assert!(devices >= 1);
    let n = tasks.len();
    let mut indeg = vec![0usize; n];
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, t) in tasks.iter().enumerate() {
        indeg[i] = t.deps.len();
        for &d in &t.deps {
            assert!(d < i, "deps must point backwards (task {i} dep {d})");
            out[d].push(i);
        }
    }
    // ready heap: (ready_time, seq) min-heap via Reverse.
    use std::cmp::Reverse;
    let mut ready: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let mut ready_time = vec![0u64; n];
    for i in 0..n {
        if indeg[i] == 0 {
            ready.push(Reverse((0, i)));
        }
    }
    let mut dev_free = vec![0u64; devices];
    let mut finish = vec![0u64; n];
    let mut spans = Vec::new();
    let mut device_busy = vec![0u64; devices];
    let mut done = 0usize;
    while let Some(Reverse((rt, i))) = ready.pop() {
        let t = &tasks[i];
        let (start, end, dev) = if t.dur == 0 {
            (rt, rt, usize::MAX)
        } else {
            // earliest-free device
            let dev = (0..devices).min_by_key(|&d| dev_free[d]).unwrap();
            let start = rt.max(dev_free[dev]);
            let end = start + t.dur;
            dev_free[dev] = end;
            device_busy[dev] += t.dur;
            spans.push((i, dev, start, end));
            (start, end, dev)
        };
        let _ = (start, dev);
        finish[i] = end;
        done += 1;
        for &j in &out[i] {
            indeg[j] -= 1;
            ready_time[j] = ready_time[j].max(end);
            if indeg[j] == 0 {
                ready.push(Reverse((ready_time[j], j)));
            }
        }
    }
    assert_eq!(done, n, "cycle in task graph");
    let makespan = finish.iter().copied().max().unwrap_or(0);
    // Peak concurrency over real spans.
    let mut events: Vec<(u64, i32)> = Vec::with_capacity(spans.len() * 2);
    for &(_, _, s, e) in &spans {
        events.push((s, 1));
        events.push((e, -1));
    }
    events.sort();
    let (mut cur, mut peak) = (0i32, 0i32);
    for (_, d) in events {
        cur += d;
        peak = peak.max(cur);
    }
    let busy: u64 = device_busy.iter().sum();
    let utilization = if makespan == 0 {
        0.0
    } else {
        busy as f64 / (makespan * devices as u64) as f64
    };
    SimReport { makespan, device_busy, utilization, peak_concurrency: peak.max(0) as usize, spans }
}

/// Build the SRDS task DAG (pipelined or with per-iteration barriers)
/// and schedule it onto `devices`.
///
/// `pipelined = false` inserts a synchronization event after each
/// refinement (the vanilla Alg. 1 loop); `pipelined = true` keeps only
/// the true data dependencies (Fig. 3/4).
pub fn simulate_srds(
    part: &Partition,
    iters: usize,
    epc: u64,
    devices: usize,
    pipelined: bool,
) -> SimReport {
    let m = part.num_blocks();
    let mut tasks: Vec<SimTask> = Vec::new();
    // ev[i] = task index whose completion means "x^p_i ready" (current p).
    // Init sweep: coarse chain.
    let mut ev: Vec<usize> = Vec::with_capacity(m + 1);
    tasks.push(SimTask { deps: vec![], dur: 0, label: "x0".into() });
    ev.push(0);
    for i in 1..=m {
        let t = tasks.len();
        tasks.push(SimTask { deps: vec![ev[i - 1]], dur: epc, label: format!("G0,{i}") });
        ev.push(t);
    }
    let mut prev_ev = ev.clone();
    let mut barrier: Option<usize> = None;
    for p in 1..=iters {
        let mut cur_ev = vec![0usize; m + 1];
        cur_ev[0] = prev_ev[0];
        let mut iter_tasks = Vec::new();
        for i in 1..=m {
            if i < p {
                // Prefix already exact: no recomputation (cached).
                cur_ev[i] = prev_ev[i];
                continue;
            }
            // Fine solve F(p, i): needs x^{p-1}_{i-1} (+ barrier if vanilla).
            let mut fdeps = vec![prev_ev[i - 1]];
            if let Some(b) = barrier {
                fdeps.push(b);
            }
            let f = tasks.len();
            tasks.push(SimTask {
                deps: fdeps,
                dur: part.block_len(i - 1) as u64 * epc,
                label: format!("F{p},{i}"),
            });
            iter_tasks.push(f);
            // Coarse G(p, i): needs x^p_{i-1}; skipped for i == p where
            // the correction cancels (see coordinator::pipeline docs).
            let mut deps = vec![f, prev_ev[i]];
            if i > p {
                let mut gdeps = vec![cur_ev[i - 1]];
                if let Some(b) = barrier {
                    gdeps.push(b);
                }
                let g = tasks.len();
                tasks.push(SimTask { deps: gdeps, dur: epc, label: format!("G{p},{i}") });
                iter_tasks.push(g);
                deps.push(g);
            }
            // x^p_i ready event (corrector is free).
            let e = tasks.len();
            tasks.push(SimTask { deps, dur: 0, label: format!("x{p},{i}") });
            cur_ev[i] = e;
        }
        if !pipelined {
            // Barrier after the full iteration (vanilla main loop).
            let b = tasks.len();
            tasks.push(SimTask { deps: iter_tasks, dur: 0, label: format!("barrier{p}") });
            barrier = Some(b);
        }
        prev_ev = cur_ev;
    }
    schedule_tasks(&tasks, devices)
}

/// Sequential baseline on the sim clock: `n` chained steps.
pub fn simulate_sequential(n: usize, epc: u64, _devices: usize) -> SimReport {
    let mut tasks = Vec::with_capacity(n);
    for i in 0..n {
        let deps = if i == 0 { vec![] } else { vec![i - 1] };
        tasks.push(SimTask { deps, dur: epc, label: format!("S{i}") });
    }
    schedule_tasks(&tasks, 1)
}

/// ParaDiGMS on the sim clock: each sweep evaluates `window` points in
/// parallel across `devices × batch_per_device` eval slots, then a
/// (serial) prefix-sum + AllReduce-style sync charged as `sync_cost`.
pub fn simulate_paradigms(
    sweeps: usize,
    window: usize,
    devices: usize,
    batch_per_device: usize,
    epc: u64,
    sync_cost: u64,
) -> SimReport {
    let cap = devices * batch_per_device;
    let mut tasks = Vec::new();
    let mut last: Option<usize> = None;
    for s in 0..sweeps {
        // Window evaluation: ceil(window/cap) serialized batched rounds
        // per device-group; modeled as `rounds` chained eval tasks per
        // device, all fanned out from the previous sync.
        let rounds = window.div_ceil(cap).max(1);
        let mut round_tasks = Vec::new();
        for d in 0..devices {
            let mut dep = last;
            for r in 0..rounds {
                let t = tasks.len();
                tasks.push(SimTask {
                    deps: dep.into_iter().collect(),
                    dur: epc,
                    label: format!("W{s},{d},{r}"),
                });
                dep = Some(t);
            }
            round_tasks.push(dep.unwrap());
        }
        // Cross-device sync (prefix sum / AllReduce).
        let t = tasks.len();
        tasks.push(SimTask { deps: round_tasks, dur: sync_cost, label: format!("sync{s}") });
        last = Some(t);
    }
    schedule_tasks(&tasks, devices + 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::pipeline_schedule;

    #[test]
    fn unbounded_devices_match_ideal_pipeline() {
        // With ≥ 2M+1 devices the bounded scheduler reproduces the
        // Prop. 2 recurrence finish times.
        for (n, iters) in [(25usize, 2usize), (196, 3), (961, 1)] {
            let part = Partition::sqrt_n(n);
            let m = part.num_blocks();
            let ideal = pipeline_schedule(&part, iters, 1).finish;
            let sim = simulate_srds(&part, iters, 1, 2 * m + 2, true);
            assert_eq!(sim.makespan, ideal, "n={n} iters={iters}");
        }
    }

    #[test]
    fn pipelined_beats_vanilla_on_same_devices() {
        let part = Partition::sqrt_n(196);
        let d = part.num_blocks() + 1;
        let v = simulate_srds(&part, 3, 1, d, false);
        let p = simulate_srds(&part, 3, 1, d, true);
        assert!(
            p.makespan < v.makespan,
            "pipelined {} !< vanilla {}",
            p.makespan,
            v.makespan
        );
    }

    #[test]
    fn single_device_degenerates_to_total_work() {
        let part = Partition::sqrt_n(25);
        let r = simulate_srds(&part, 1, 1, 1, true);
        // All work serialized: init 5 + fine 25 + coarse 4 = 34.
        assert_eq!(r.makespan, 34);
        assert!((r.utilization - 1.0).abs() < 1e-9);
    }

    #[test]
    fn more_devices_never_slower() {
        let part = Partition::sqrt_n(100);
        let mut prev = u64::MAX;
        for d in [1usize, 2, 4, 8, 16] {
            let r = simulate_srds(&part, 2, 1, d, true);
            assert!(r.makespan <= prev, "devices {d}");
            prev = r.makespan;
        }
    }

    #[test]
    fn sequential_sim_is_n_steps() {
        let r = simulate_sequential(100, 2, 4);
        assert_eq!(r.makespan, 200);
    }

    #[test]
    fn paradigms_sim_scales_with_devices() {
        let a = simulate_paradigms(16, 100, 1, 8, 1, 0);
        let b = simulate_paradigms(16, 100, 4, 8, 1, 0);
        assert!(b.makespan < a.makespan);
        // With sync cost the gap narrows (the App. D observation).
        let c = simulate_paradigms(16, 100, 4, 8, 1, 4);
        assert!(c.makespan > b.makespan);
    }

    #[test]
    fn zero_duration_events_use_no_device() {
        let tasks = vec![
            SimTask { deps: vec![], dur: 5, label: "a".into() },
            SimTask { deps: vec![], dur: 5, label: "b".into() },
            SimTask { deps: vec![0, 1], dur: 0, label: "join".into() },
            SimTask { deps: vec![2], dur: 1, label: "c".into() },
        ];
        let r = schedule_tasks(&tasks, 2);
        assert_eq!(r.makespan, 6);
        assert_eq!(r.peak_concurrency, 2);
    }
}
