//! Multi-tenant step-level execution engine.
//!
//! Generalizes the pipelined-SRDS dispatcher of Fig. 4 (previously a
//! one-request-at-a-time loop in `exec::measured`) so **many concurrent
//! sampling requests share one worker pool**: every fine/coarse solver
//! step any request needs becomes a [`PendingRow`], rows are coalesced
//! by [`Batcher`] into multi-row [`StepRequest`] batches, and workers
//! execute whole batches in one backend call — the cross-request face of
//! the paper's §3.4 batched-inference observation (one model evaluation
//! serves rows from *different* users, not just different blocks of one
//! trajectory).
//!
//! Two entry paths share the pool:
//!
//! * [`Engine::run_srds`] / [`Engine::submit_srds`] — SRDS requests run
//!   as dependency-driven state machines *inside* the dispatcher thread
//!   (the direct generalization of `measured_pipelined_srds`): a fine
//!   block solve is a chain of single-step rows, a coarse step is one
//!   row, and each completion unblocks exactly the O(1) cells it can.
//! * [`Engine::backend`] — an adapter [`StepBackend`] for everything
//!   else (sequential / ParaDiGMS / ParaTAA registry entries): the
//!   sampler runs unchanged on its own thread, but every `step()` call
//!   is decomposed into rows and funneled through the same batchers, so
//!   baseline traffic fuses with SRDS traffic too.
//!
//! **Flush policy** (vLLM-style, adapted to a CPU/PJRT pool): the
//! dispatcher is *work-conserving with spread-first sizing* — a row
//! never waits while enough workers are idle. With `I` idle workers and
//! `P` pending rows it dispatches batches of `ceil(P / I)` rows
//! (bucket-quantized by [`Batcher::take_up_to`]), so a lone request's
//! independent rows still fan out across the pool, while under load —
//! all workers busy — rows accumulate and flush as large fused batches
//! the moment a worker frees up. When *fewer rows than idle workers*
//! are pending and work is already in flight, the dispatcher may hold
//! them up to `BatchPolicy::max_wait` hoping co-tenant rows arrive
//! (`max_wait == 0` disables holding entirely — the measured executor's
//! configuration). SRDS coarse rows enter their batcher at the head
//! ([`Batcher::push_urgent`]): the G chain is the schedule's serial
//! spine (Prop. 2), and speculative fine work must not delay it — the
//! FIFO analogue of the old worker pool's critical-path priority heap.
//!
//! **Invariant (pinned by tests):** a request's output is identical to a
//! solo vanilla [`crate::coordinator::srds`] run with the same spec and
//! seed, regardless of what else is in flight — every backend computes
//! batch rows independently, so fusing a row with strangers never
//! changes its value.
//!
//! **Zero-copy state:** every state the engine touches is a pooled
//! refcounted [`StateBuf`] from one engine-wide [`BufPool`] — task grid
//! cells, queued row states (a queued row *shares* its producer's
//! buffer), and worker batch outputs. Batch assembly runs through one
//! persistent [`BatchStage`] per worker, and backends write results in
//! place via [`StepBackend::step_into`]. After warm-up a steady request
//! stream allocates no fresh state buffers; `pool_hits`/`pool_misses`
//! (in [`EngineStats`] and every response's `RunStats`) make that
//! observable.

use crate::batching::{stage_rows, BatchPolicy, Batcher, PendingRow};
use crate::buf::{BatchStage, BufPool, StateBuf};
use crate::coordinator::{IterStat, RunStats, SampleOutput, SamplerSpec};
use crate::schedule::Partition;
use crate::solvers::{BackendFactory, Solver, StepBackend, StepRequest};
use std::cell::Cell;
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Free-list cap per dim bucket for the engine's shared [`BufPool`].
/// Sized for the multi-tenant working set: admission control allows 64
/// in-flight requests per connection and each SRDS task retains its
/// full iteration × block grid until finalize (~200 buffers at n=1024),
/// so a serving burst legitimately parks thousands of slabs. At dim 64
/// the fully-parked worst case is 4 MiB per bucket.
const ENGINE_POOL_MAX_FREE: usize = 16 * 1024;

/// Engine construction knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads (one thread-bound backend instance each).
    pub workers: usize,
    /// Cross-request batch assembly policy.
    pub batch: BatchPolicy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { workers: 4, batch: BatchPolicy::default() }
    }
}

/// Rows may only share a [`StepRequest`] when the request-wide scalar
/// fields agree: one guidance weight and one mask shape per batch.
type BatchKey = (u32, bool, usize);

fn batch_key(row: &PendingRow) -> BatchKey {
    (
        row.guidance.to_bits(),
        row.mask.is_some(),
        row.mask.as_ref().map(|m| m.len()).unwrap_or(0),
    )
}

/// Where a completed row's output must be routed.
enum RowOrigin {
    /// Engine-resident SRDS state machine: request id + (p, i, is_fine).
    Srds { req: u64, key: (usize, usize, bool) },
    /// Blocking adapter call: call id + row slot within the call.
    Call { call: u64, slot: usize },
}

enum Msg {
    Srds { x0: Vec<f32>, spec: SamplerSpec, reply: Sender<SampleOutput> },
    Call { rows: Vec<PendingRow>, reply: Sender<(usize, StateBuf, usize)> },
    BatchDone { outs: Vec<(u64, StateBuf)> },
    Shutdown,
}

/// One batch handed to a worker. Tags are engine row ids.
struct ExecBatch {
    rows: Vec<PendingRow>,
}

#[derive(Default)]
struct WorkState {
    queue: VecDeque<ExecBatch>,
    closed: bool,
}

type WorkQueue = (Mutex<WorkState>, Condvar);

/// Aggregate engine counters, published by the dispatcher.
#[derive(Debug, Clone, Copy, Default)]
struct Counters {
    flushed_batches: u64,
    flushed_rows: u64,
    queue_depth: usize,
    inflight_requests: usize,
}

/// A point-in-time view of the engine's batching behavior.
#[derive(Debug, Clone, Copy)]
pub struct EngineStats {
    /// Batches dispatched to workers since engine start.
    pub flushed_batches: u64,
    /// Rows those batches carried.
    pub flushed_rows: u64,
    /// `flushed_rows / flushed_batches` — > 1.0 means step fusion is
    /// actually happening.
    pub mean_occupancy: f64,
    /// Rows currently waiting in the batchers.
    pub queue_depth: usize,
    /// Requests (SRDS tasks + blocked adapter calls) currently open.
    pub inflight_requests: usize,
    /// Pool size.
    pub workers: usize,
    /// Shared state-buffer pool: requests served from the free lists.
    /// After warm-up, `pool_misses` stops growing while `pool_hits`
    /// climbs — the steady-state-zero-allocation invariant.
    pub pool_hits: u64,
    /// Shared state-buffer pool: requests that allocated fresh slabs.
    pub pool_misses: u64,
    /// Peak simultaneously-live state buffers (the leak detector).
    pub pool_high_water: usize,
}

/// The multi-tenant execution engine. See the module docs.
pub struct Engine {
    tx: Mutex<Sender<Msg>>,
    counters: Arc<Mutex<Counters>>,
    /// Shared state-buffer slab pool: SRDS task grids, queued row
    /// states, and worker batch outputs all draw from (and recycle
    /// into) it.
    pool: BufPool,
    dim: usize,
    solver: Solver,
    workers: usize,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    worker_handles: Vec<std::thread::JoinHandle<()>>,
}

impl Engine {
    /// Spawn the dispatcher plus `cfg.workers` worker threads; each
    /// worker calls `factory.create()` locally (PJRT clients are
    /// `Rc`-based and cannot cross threads).
    pub fn new(factory: Arc<dyn BackendFactory>, cfg: EngineConfig) -> Engine {
        let workers = cfg.workers.max(1);
        let (tx, rx) = channel::<Msg>();
        let work: Arc<WorkQueue> = Arc::new((Mutex::new(WorkState::default()), Condvar::new()));
        let counters = Arc::new(Mutex::new(Counters::default()));
        // The engine's working set is many concurrent tasks' full
        // x/G/F grids (O(M²) buffers per request, retained until
        // finalize), so the free lists must park far more slabs than
        // the run-local default or every request wave would mass-drop
        // and re-allocate its grid — the cap only bounds *retention*
        // (never exceeds the observed peak), not allocation.
        let pool = BufPool::with_max_free(ENGINE_POOL_MAX_FREE);
        let mut worker_handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let work = work.clone();
            let factory = factory.clone();
            let done_tx = tx.clone();
            let pool = pool.clone();
            worker_handles.push(
                std::thread::Builder::new()
                    .name(format!("srds-engine-worker-{w}"))
                    .spawn(move || {
                        let backend = factory.create();
                        worker_loop(backend.as_ref(), &work, &done_tx, &pool);
                    })
                    .expect("spawn engine worker"),
            );
        }
        let dim = factory.dim();
        let solver = factory.solver();
        let epc = solver.evals_per_step() as u64;
        let d_work = work.clone();
        let d_counters = counters.clone();
        // The dispatcher is the only producer into its batchers, so the
        // queue cap is not a back-pressure point here (admission control
        // belongs above the engine); an overflow would tear down every
        // tenant at once, so disable it.
        let mut policy = cfg.batch.clone();
        policy.max_queue = usize::MAX;
        let d_pool = pool.clone();
        let dispatcher = std::thread::Builder::new()
            .name("srds-engine-dispatcher".into())
            .spawn(move || {
                Dispatcher::new(rx, d_work, d_counters, workers, policy, epc, d_pool).run();
            })
            .expect("spawn engine dispatcher");
        Engine {
            tx: Mutex::new(tx),
            counters,
            pool,
            dim,
            solver,
            workers,
            dispatcher: Some(dispatcher),
            worker_handles,
        }
    }

    /// The engine's shared state-buffer pool (observability / tests).
    pub fn pool(&self) -> &BufPool {
        &self.pool
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn solver(&self) -> Solver {
        self.solver
    }

    fn send(&self, msg: Msg) {
        self.tx.lock().unwrap().send(msg).expect("engine dispatcher alive");
    }

    /// Queue an SRDS request; the returned channel yields its
    /// [`SampleOutput`] when the state machine finishes.
    pub fn submit_srds(&self, x0: Vec<f32>, spec: SamplerSpec) -> Receiver<SampleOutput> {
        let (reply, rx) = channel();
        self.send(Msg::Srds { x0, spec, reply });
        rx
    }

    /// Run one SRDS request to completion (blocking). Other requests may
    /// be in flight concurrently; per-request output is unaffected.
    pub fn run_srds(&self, x0: &[f32], spec: &SamplerSpec) -> SampleOutput {
        self.submit_srds(x0.to_vec(), spec.clone())
            .recv()
            .expect("engine dropped mid-request")
    }

    /// A [`StepBackend`] whose every `step()` is decomposed into rows
    /// and batched with whatever else the engine is running. One handle
    /// per request thread; not `Sync`.
    pub fn backend(&self) -> EngineBackend {
        EngineBackend {
            tx: self.tx.lock().unwrap().clone(),
            pool: self.pool.clone(),
            dim: self.dim,
            solver: self.solver,
            rows_done: Cell::new(0),
            occ_sum: Cell::new(0),
        }
    }

    /// Snapshot the engine counters.
    pub fn stats(&self) -> EngineStats {
        let c = *self.counters.lock().unwrap();
        let ps = self.pool.stats();
        EngineStats {
            flushed_batches: c.flushed_batches,
            flushed_rows: c.flushed_rows,
            mean_occupancy: c.flushed_rows as f64 / c.flushed_batches.max(1) as f64,
            queue_depth: c.queue_depth,
            inflight_requests: c.inflight_requests,
            workers: self.workers,
            pool_hits: ps.hits,
            pool_misses: ps.misses,
            pool_high_water: ps.high_water,
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(Msg::Shutdown);
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
        for h in self.worker_handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Adapter backend: decomposes each [`StepRequest`] into engine rows and
/// blocks until all of them complete. Tracks the batch occupancy its
/// rows observed so serving can report per-request fusion. Row states
/// are pooled [`StateBuf`]s and a uniform request mask is shared as one
/// `Arc` across all rows — decomposition allocates nothing after
/// warm-up.
pub struct EngineBackend {
    tx: Sender<Msg>,
    pool: BufPool,
    dim: usize,
    solver: Solver,
    rows_done: Cell<u64>,
    occ_sum: Cell<u64>,
}

impl EngineBackend {
    /// `(rows executed, mean batch occupancy)` over this handle's calls.
    pub fn occupancy(&self) -> (u64, f64) {
        let rows = self.rows_done.get();
        (rows, self.occ_sum.get() as f64 / rows.max(1) as f64)
    }
}

impl StepBackend for EngineBackend {
    fn dim(&self) -> usize {
        self.dim
    }

    fn solver(&self) -> Solver {
        self.solver
    }

    fn step_into(&self, req: &StepRequest, out: &mut [f32]) {
        let b = req.rows();
        let d = self.dim;
        let mask_k = req.mask.map(|m| m.len() / b);
        // Samplers tile one sample mask across their batch rows; detect
        // that and share a single Arc instead of copying k floats per
        // row (heterogeneous masks fall back to per-row Arcs).
        let shared_mask: Option<Arc<[f32]>> = req.mask.and_then(|m| {
            let k = mask_k.unwrap();
            if k == 0 {
                return None;
            }
            let first = &m[..k];
            m.chunks_exact(k).all(|c| c == first).then(|| first.into())
        });
        let rows: Vec<PendingRow> = (0..b)
            .map(|i| PendingRow {
                tag: i as u64,
                x: self.pool.take(&req.x[i * d..(i + 1) * d]),
                s_from: req.s_from[i],
                s_to: req.s_to[i],
                mask: req.mask.map(|m| {
                    let k = mask_k.unwrap();
                    shared_mask
                        .clone()
                        .unwrap_or_else(|| m[i * k..(i + 1) * k].into())
                }),
                guidance: req.guidance,
                seed: req.seeds[i],
            })
            .collect();
        let (reply, rx) = channel();
        self.tx.send(Msg::Call { rows, reply }).expect("engine dispatcher alive");
        for _ in 0..b {
            let (slot, y, batch_rows) = rx.recv().expect("engine dropped mid-call");
            out[slot * d..(slot + 1) * d].copy_from_slice(&y);
            self.rows_done.set(self.rows_done.get() + 1);
            self.occ_sum.set(self.occ_sum.get() + batch_rows as u64);
        }
    }
}

fn worker_loop(backend: &dyn StepBackend, work: &WorkQueue, done_tx: &Sender<Msg>, pool: &BufPool) {
    let d = backend.dim();
    // One persistent staging buffer per worker: batch assembly reuses it
    // for the whole thread lifetime (no flat-vector churn per flush).
    let mut stage = BatchStage::new();
    loop {
        let batch = {
            let (lock, cv) = work;
            let mut st = lock.lock().unwrap();
            loop {
                if let Some(b) = st.queue.pop_front() {
                    break Some(b);
                }
                if st.closed {
                    break None;
                }
                st = cv.wait(st).unwrap();
            }
        };
        let Some(batch) = batch else { break };
        stage_rows(&batch.rows, &mut stage);
        let out = stage.step(backend);
        // De-batch into pooled per-row buffers: tasks receive refcounted
        // StateBufs they can store and re-share without further copies.
        let outs = batch
            .rows
            .iter()
            .enumerate()
            .map(|(i, r)| (r.tag, pool.take(&out[i * d..(i + 1) * d])))
            .collect();
        if done_tx.send(Msg::BatchDone { outs }).is_err() {
            break;
        }
    }
}

/// A fine block solve in flight: the chain of single-step rows walking
/// `points`. `next` is the window index of the row currently queued or
/// executing.
struct FineChain {
    points: Vec<f32>,
    next: usize,
}

/// A step to enqueue, produced by a task while it holds `&mut self`
/// (rows are materialized into the batchers afterwards, avoiding a
/// simultaneous borrow of the task map and the batcher map). `x` is a
/// refcounted share of the task-resident state, not a copy.
struct Emit {
    key: (usize, usize, bool),
    x: StateBuf,
    s_from: f32,
    s_to: f32,
}

/// Dependency-driven SRDS state machine for one request — the Fig. 4
/// pipelined dataflow of `measured_pipelined_srds`, re-expressed as
/// event handlers so the dispatcher can interleave many of them.
///
/// Every cell of the `x`/`g`/`y` grids is a pooled [`StateBuf`]; cells
/// are written once (by a worker or the corrector) and shared read-only
/// from then on — emitting a follow-up row or reusing a coarse result
/// as the next iteration's `prev` is a refcount bump.
struct SrdsTask {
    spec: SamplerSpec,
    part: Partition,
    m: usize,
    max_iters: usize,
    x: Vec<Vec<Option<StateBuf>>>,
    g: Vec<Vec<Option<StateBuf>>>,
    y: Vec<Vec<Option<StateBuf>>>,
    submitted: Vec<Vec<[bool; 2]>>,
    fines: HashMap<(usize, usize), FineChain>,
    per_iter: Vec<IterStat>,
    stop_at_iter: Option<usize>,
    inflight_rows: usize,
    total_evals: u64,
    rows_done: u64,
    occ_sum: u64,
    t0: Instant,
    reply: Sender<SampleOutput>,
}

impl SrdsTask {
    fn new(
        x0: &[f32],
        spec: SamplerSpec,
        reply: Sender<SampleOutput>,
        pool: &BufPool,
    ) -> (SrdsTask, Vec<Emit>) {
        let part = spec.partition();
        let m = part.num_blocks();
        let max_iters = spec.max_iters.unwrap_or(m).max(1).min(m);
        let mut task = SrdsTask {
            spec,
            part,
            m,
            max_iters,
            x: vec![vec![None; m + 1]; max_iters + 1],
            g: vec![vec![None; m + 1]; max_iters + 1],
            y: vec![vec![None; m + 1]; max_iters + 1],
            submitted: vec![vec![[false; 2]; m + 1]; max_iters + 1],
            fines: HashMap::new(),
            per_iter: Vec::new(),
            stop_at_iter: None,
            inflight_rows: 0,
            total_evals: 0,
            rows_done: 0,
            occ_sum: 0,
            t0: Instant::now(),
            reply,
        };
        // Seed the prior states and kick off everything x0 unblocks:
        // G(p, 1) for every p (their input never changes) and F(p, 1) for
        // every refinement (its input x^{p-1}_0 = x0 is already final).
        // One pooled buffer, shared by refcount across every iteration's
        // x[p][0] and every seeded row.
        let x0 = pool.take(x0);
        let mut emits = Vec::new();
        for p in 0..=task.max_iters {
            task.x[p][0] = Some(x0.clone());
        }
        for p in 0..=task.max_iters {
            task.submitted[p][1][0] = true;
            emits.push(task.emit_coarse(p, 1, x0.clone()));
            if p >= 1 {
                task.submitted[p][1][1] = true;
                emits.push(task.emit_fine_start(p, 1, x0.clone()));
            }
        }
        (task, emits)
    }

    fn emit_coarse(&mut self, p: usize, i: usize, x: StateBuf) -> Emit {
        self.inflight_rows += 1;
        Emit {
            key: (p, i, false),
            x,
            s_from: self.part.s_bound(i - 1),
            s_to: self.part.s_bound(i),
        }
    }

    fn emit_fine_start(&mut self, p: usize, i: usize, x: StateBuf) -> Emit {
        let points = self.part.block_points(i - 1).to_vec();
        let (s_from, s_to) = (points[0], points[1]);
        self.fines.insert((p, i), FineChain { points, next: 0 });
        self.inflight_rows += 1;
        Emit { key: (p, i, true), x, s_from, s_to }
    }

    /// Handle one completed row; returns follow-up rows to enqueue.
    /// `epc` is the backend's evals per step; corrector states
    /// materialize out of `pool`.
    fn on_row(
        &mut self,
        key: (usize, usize, bool),
        out: StateBuf,
        batch_rows: usize,
        epc: u64,
        pool: &BufPool,
    ) -> Vec<Emit> {
        self.inflight_rows -= 1;
        self.total_evals += epc;
        self.rows_done += 1;
        self.occ_sum += batch_rows as u64;
        let (p, i, is_fine) = key;
        let mut emits = Vec::new();
        if is_fine {
            let chain = self.fines.get_mut(&(p, i)).expect("live fine chain");
            let last_window = chain.points.len() - 2;
            if chain.next < last_window {
                chain.next += 1;
                let (s_from, s_to) = (chain.points[chain.next], chain.points[chain.next + 1]);
                self.inflight_rows += 1;
                emits.push(Emit { key, x: out, s_from, s_to });
                return emits;
            }
            self.fines.remove(&(p, i));
            self.y[p][i] = Some(out);
        } else {
            self.g[p][i] = Some(out);
        }
        // Corrector attempts unblocked by this result: cell (p, i) and —
        // when a coarse result acts as `prev` — cell (p+1, i).
        let mut attempts = vec![(p, i)];
        if !is_fine && p + 1 <= self.max_iters {
            attempts.push((p + 1, i));
        }
        let mut ready: Vec<(usize, usize)> = Vec::new();
        for (ap, ai) in attempts {
            if self.x[ap][ai].is_some() {
                continue;
            }
            let materialized = if ap == 0 {
                // The init boundary IS the coarse result — share it.
                self.g[0][ai].clone()
            } else if let (Some(yi), Some(cur), Some(prev)) =
                (&self.y[ap][ai], &self.g[ap][ai], &self.g[ap - 1][ai])
            {
                // Eq. 6's parenthesization y + (G_new − G_old) is
                // load-bearing for Prop. 1's bitwise collapse.
                let mut v = pool.get(yi.len());
                let vs = v.as_mut_slice();
                for (t, a) in yi.iter().enumerate() {
                    vs[t] = a + (cur[t] - prev[t]);
                }
                Some(v)
            } else {
                None
            };
            if let Some(v) = materialized {
                self.x[ap][ai] = Some(v);
                ready.push((ap, ai));
            }
        }
        // Propagate each new state to the jobs it unblocks.
        while let Some((sp, si)) = ready.pop() {
            let stop = self.stop_at_iter;
            let past_stop = move |p: usize| stop.map(|s| p > s).unwrap_or(false);
            if si + 1 <= self.m
                && sp + 1 <= self.max_iters
                && !self.submitted[sp + 1][si + 1][1]
                && !past_stop(sp + 1)
            {
                self.submitted[sp + 1][si + 1][1] = true;
                let x = self.x[sp][si].clone().unwrap();
                emits.push(self.emit_fine_start(sp + 1, si + 1, x));
            }
            if si + 1 <= self.m && !self.submitted[sp][si + 1][0] && !past_stop(sp) {
                self.submitted[sp][si + 1][0] = true;
                let x = self.x[sp][si].clone().unwrap();
                emits.push(self.emit_coarse(sp, si + 1, x));
            }
            // Convergence: strictly in iteration order (a later final
            // state can exist before an earlier one).
            if si == self.m {
                while self.stop_at_iter.is_none() {
                    let pp = self.per_iter.len() + 1;
                    if pp > self.max_iters {
                        break;
                    }
                    let (Some(curf), Some(prevf)) = (&self.x[pp][self.m], &self.x[pp - 1][self.m])
                    else {
                        break;
                    };
                    let residual = self.spec.norm.dist(curf, prevf);
                    self.per_iter.push(IterStat { iter: pp, residual, evals: 0 });
                    if residual < self.spec.tol || pp >= self.m {
                        self.stop_at_iter = Some(pp);
                    }
                }
            }
        }
        emits
    }

    /// Whether the request can produce its final answer now: either the
    /// convergence test fired and the winning iterate exists, or no rows
    /// remain in flight (the speculative frontier ran dry).
    fn finished(&self) -> bool {
        match self.stop_at_iter {
            Some(s) => self.x[s][self.m].is_some(),
            None => self.inflight_rows == 0,
        }
    }

    fn finalize(self, epc: u64, pool: &BufPool) {
        let final_iter = self.stop_at_iter.unwrap_or_else(|| {
            (1..=self.max_iters).rev().find(|&p| self.x[p][self.m].is_some()).unwrap_or(0)
        });
        // Copy the winning state out (one d-sized copy per request, at
        // egress) — deliberately NOT into_vec(): stealing the slab would
        // shrink the engine-wide pool by one buffer per completed
        // request and make pool_misses drift upward forever. Every grid
        // cell, this one included, recycles when the task drops below.
        let sample = self.x[final_iter][self.m].as_ref().expect("final state").to_vec();
        let converged = self
            .per_iter
            .iter()
            .find(|s| s.iter == final_iter)
            .map(|s| s.residual < self.spec.tol || final_iter >= self.m)
            .unwrap_or(false);
        let m = self.m as u64;
        let b = self.part.block() as u64;
        // Vanilla-schedule accounting, same formula as coordinator::srds:
        // the coarse init sweep (M), then per iteration the longest fine
        // block plus the sequential coarse sweep.
        let b_max = (0..self.m).map(|j| self.part.block_len(j)).max().unwrap_or(0) as u64;
        let iters = final_iter as u64;
        let eff_serial = (m + iters * (b_max + m)) * epc;
        let eff_pipelined =
            if final_iter == 0 { m * epc } else { (m * iters + b).saturating_sub(iters) * epc };
        let ps = pool.stats();
        let stats = RunStats {
            iters: final_iter,
            converged,
            eff_serial_evals: eff_serial,
            eff_serial_evals_pipelined: eff_pipelined,
            total_evals: self.total_evals,
            wall: self.t0.elapsed(),
            // The dispatcher materializes the full (iterations × blocks)
            // grid of x/G/F states — wall-clock-optimal, not
            // memory-optimal.
            peak_states: 3 * (self.max_iters + 1) * (self.m + 1),
            batch_occupancy: self.occ_sum as f64 / self.rows_done.max(1) as f64,
            engine_rows: self.rows_done,
            // Engine-wide pool snapshot at completion: across a steady
            // request stream, successive responses show flat misses.
            pool_hits: ps.hits,
            pool_misses: ps.misses,
            per_iter: self.per_iter,
        };
        // A dropped receiver (client went away) is not an engine error.
        let _ = self.reply.send(SampleOutput { sample, stats, iterates: vec![] });
    }
}

struct CallTask {
    reply: Sender<(usize, StateBuf, usize)>,
    remaining: usize,
}

struct Dispatcher {
    rx: Receiver<Msg>,
    work: Arc<WorkQueue>,
    counters: Arc<Mutex<Counters>>,
    workers: usize,
    policy: BatchPolicy,
    epc: u64,
    pool: BufPool,
    batchers: HashMap<BatchKey, Batcher>,
    origins: HashMap<u64, RowOrigin>,
    tasks: HashMap<u64, SrdsTask>,
    calls: HashMap<u64, CallTask>,
    next_row: u64,
    next_id: u64,
    in_flight: usize,
    flushed_batches: u64,
    flushed_rows: u64,
}

impl Dispatcher {
    #[allow(clippy::too_many_arguments)]
    fn new(
        rx: Receiver<Msg>,
        work: Arc<WorkQueue>,
        counters: Arc<Mutex<Counters>>,
        workers: usize,
        policy: BatchPolicy,
        epc: u64,
        pool: BufPool,
    ) -> Dispatcher {
        Dispatcher {
            rx,
            work,
            counters,
            workers,
            policy,
            epc,
            pool,
            batchers: HashMap::new(),
            origins: HashMap::new(),
            tasks: HashMap::new(),
            calls: HashMap::new(),
            next_row: 0,
            next_id: 0,
            in_flight: 0,
            flushed_batches: 0,
            flushed_rows: 0,
        }
    }

    fn run(mut self) {
        loop {
            // Park on the inbox. While rows are being held back (linger:
            // idle capacity exists but we are waiting for co-tenants) the
            // park is bounded so the max_wait flush fires on time.
            let lingering =
                self.in_flight < self.workers && self.batchers.values().any(|b| b.pending() > 0);
            let msg = if lingering {
                match self.rx.recv_timeout(self.policy.max_wait.max(Duration::from_micros(200))) {
                    Ok(m) => Some(m),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            } else {
                match self.rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => break,
                }
            };
            let mut shutdown = false;
            if let Some(m) = msg {
                shutdown = self.handle(m);
                // Drain whatever else arrived before deciding batches —
                // concurrent submitters' rows should co-batch.
                while !shutdown {
                    match self.rx.try_recv() {
                        Ok(m) => shutdown = self.handle(m),
                        Err(_) => break,
                    }
                }
            }
            if shutdown {
                break;
            }
            self.flush();
            self.publish();
        }
        // Close the worker queue; workers drain what is queued and exit.
        let (lock, cv) = &*self.work;
        lock.lock().unwrap().closed = true;
        cv.notify_all();
    }

    /// Returns `true` on shutdown.
    fn handle(&mut self, msg: Msg) -> bool {
        match msg {
            Msg::Shutdown => return true,
            Msg::Srds { x0, spec, reply } => {
                let id = self.next_id;
                self.next_id += 1;
                let (task, emits) = SrdsTask::new(&x0, spec, reply, &self.pool);
                self.tasks.insert(id, task);
                self.enqueue_srds_rows(id, emits);
                self.maybe_finalize(id);
            }
            Msg::Call { rows, reply } => {
                let id = self.next_id;
                self.next_id += 1;
                self.calls.insert(id, CallTask { reply, remaining: rows.len() });
                for mut row in rows {
                    let slot = row.tag as usize;
                    row.tag = self.next_row;
                    self.next_row += 1;
                    self.origins.insert(row.tag, RowOrigin::Call { call: id, slot });
                    self.push_row(row, false);
                }
            }
            Msg::BatchDone { outs } => {
                self.in_flight -= 1;
                let batch_rows = outs.len();
                let epc = self.epc;
                for (tag, out) in outs {
                    match self.origins.remove(&tag) {
                        Some(RowOrigin::Srds { req, key }) => {
                            let Some(task) = self.tasks.get_mut(&req) else { continue };
                            let emits = task.on_row(key, out, batch_rows, epc, &self.pool);
                            self.enqueue_srds_rows(req, emits);
                            self.maybe_finalize(req);
                        }
                        Some(RowOrigin::Call { call, slot }) => {
                            let Some(c) = self.calls.get_mut(&call) else { continue };
                            c.remaining -= 1;
                            let gone = c.reply.send((slot, out, batch_rows)).is_err();
                            if gone || c.remaining == 0 {
                                self.calls.remove(&call);
                            }
                        }
                        // Row of a request that already finalized.
                        None => {}
                    }
                }
            }
        }
        false
    }

    fn enqueue_srds_rows(&mut self, req: u64, emits: Vec<Emit>) {
        // Borrow the task immutably for the shared row fields.
        let (mask, guidance, seed) = {
            let t = &self.tasks[&req];
            (t.spec.cond.mask.clone(), t.spec.cond.guidance, t.spec.seed)
        };
        for e in emits {
            let tag = self.next_row;
            self.next_row += 1;
            // Coarse steps are the schedule's serial spine (Prop. 2) —
            // queue them ahead of speculative fine work.
            let urgent = !e.key.2;
            self.origins.insert(tag, RowOrigin::Srds { req, key: e.key });
            self.push_row(
                PendingRow {
                    tag,
                    x: e.x,
                    s_from: e.s_from,
                    s_to: e.s_to,
                    mask: mask.clone(),
                    guidance,
                    seed,
                },
                urgent,
            );
        }
    }

    fn push_row(&mut self, row: PendingRow, urgent: bool) {
        let key = batch_key(&row);
        let batcher = self
            .batchers
            .entry(key)
            .or_insert_with(|| Batcher::new(self.policy.clone()));
        // The dispatcher is the only producer; queue overflow here means
        // admission control above the engine failed, not a row to drop.
        let pushed = if urgent { batcher.push_urgent(row) } else { batcher.push(row) };
        assert!(pushed, "engine batcher overflow (raise BatchPolicy::max_queue)");
    }

    fn maybe_finalize(&mut self, req: u64) {
        let done = self.tasks.get(&req).map(|t| t.finished()).unwrap_or(false);
        if done {
            if let Some(mut task) = self.tasks.remove(&req) {
                // Eagerly purge this request's still-queued speculative
                // rows — they will never run, and leaving them in place
                // would inflate queue_depth and the spread-cap math until
                // the lazy flush filter got to them.
                let origins = &mut self.origins;
                let mut queued = 0usize;
                for b in self.batchers.values_mut() {
                    let dead = b.purge(|r| {
                        !matches!(origins.get(&r.tag),
                                  Some(RowOrigin::Srds { req: rr, .. }) if *rr == req)
                    });
                    for row in dead {
                        origins.remove(&row.tag);
                        queued += 1;
                    }
                }
                // Rows already handed to workers still execute and burn
                // model evals; attribute them now (the old measured
                // executor drained and counted them the same way). Their
                // results are discarded on arrival via the origin map.
                let executing = task.inflight_rows.saturating_sub(queued) as u64;
                task.total_evals += executing * self.epc;
                // Publish counters before the reply unblocks the caller,
                // so a stats() read right after completion is current.
                self.publish();
                task.finalize(self.epc, &self.pool);
            }
        }
    }

    /// Work-conserving, spread-first flush. See the module docs.
    fn flush(&mut self) {
        loop {
            let idle = self.workers.saturating_sub(self.in_flight);
            if idle == 0 {
                return;
            }
            let key = self.batchers.iter().find_map(|(k, b)| {
                if b.pending() == 0 {
                    return None;
                }
                let eager = self.in_flight == 0 || b.pending() >= idle || b.should_flush();
                eager.then_some(*k)
            });
            let Some(key) = key else { return };
            let batcher = self.batchers.get_mut(&key).unwrap();
            let cap = batcher.pending().div_ceil(idle);
            let mut rows = batcher.take_up_to(cap);
            // Drop rows whose owner finished already (the lazy purge).
            let (origins, tasks, calls) = (&mut self.origins, &self.tasks, &self.calls);
            rows.retain(|r| {
                let live = match origins.get(&r.tag) {
                    Some(RowOrigin::Srds { req, .. }) => tasks.contains_key(req),
                    Some(RowOrigin::Call { call, .. }) => calls.contains_key(call),
                    None => false,
                };
                if !live {
                    origins.remove(&r.tag);
                }
                live
            });
            if rows.is_empty() {
                continue;
            }
            self.flushed_batches += 1;
            self.flushed_rows += rows.len() as u64;
            self.in_flight += 1;
            let (lock, cv) = &*self.work;
            lock.lock().unwrap().queue.push_back(ExecBatch { rows });
            cv.notify_one();
        }
    }

    fn publish(&self) {
        let mut c = self.counters.lock().unwrap();
        c.flushed_batches = self.flushed_batches;
        c.flushed_rows = self.flushed_rows;
        c.queue_depth = self.batchers.values().map(|b| b.pending()).sum();
        c.inflight_requests = self.tasks.len() + self.calls.len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{prior_sample, registry, srds, Conditioning, SamplerSpec};
    use crate::data::make_gmm;
    use crate::exec::NativeFactory;
    use crate::model::GmmEps;

    fn engine(workers: usize, batch: BatchPolicy) -> Engine {
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(make_gmm("church")));
        Engine::new(
            Arc::new(NativeFactory::new(model, Solver::Ddim)),
            EngineConfig { workers, batch },
        )
    }

    fn vanilla(x0: &[f32], spec: &SamplerSpec) -> SampleOutput {
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(make_gmm("church")));
        let be = crate::solvers::NativeBackend::new(model, Solver::Ddim);
        srds(&be, x0, spec)
    }

    #[test]
    fn concurrent_requests_match_solo_vanilla_srds() {
        // The headline multi-tenant invariant: ≥4 requests in flight at
        // once, each one's sample identical to a solo vanilla srds() run
        // with the same spec and seed.
        let eng = Arc::new(engine(3, BatchPolicy::default()));
        let specs: Vec<(Vec<f32>, SamplerSpec)> = (0..5u64)
            .map(|s| {
                let spec = SamplerSpec::srds(36 + 9 * s as usize)
                    .with_tol(1e-4)
                    .with_seed(s);
                (prior_sample(64, s), spec)
            })
            .collect();
        let handles: Vec<_> = specs
            .iter()
            .map(|(x0, spec)| eng.submit_srds(x0.clone(), spec.clone()))
            .collect();
        for ((x0, spec), rx) in specs.iter().zip(handles) {
            let got = rx.recv().expect("engine reply");
            let want = vanilla(x0, spec);
            assert_eq!(got.stats.iters, want.stats.iters, "seed {}", spec.seed);
            let d = spec.norm.dist(&got.sample, &want.sample);
            assert!(d < 1e-6, "engine vs vanilla (seed {}): {d}", spec.seed);
        }
    }

    #[test]
    fn engine_reports_vanilla_eff_serial_evals() {
        // No more `eff_serial_evals: 0` placeholder: the engine computes
        // the vanilla-schedule count with coordinator::srds's formula.
        let eng = engine(2, BatchPolicy::immediate());
        let x0 = prior_sample(64, 1);
        let spec = SamplerSpec::srds(25).with_tol(0.0).with_max_iters(1).with_seed(1);
        let res = eng.run_srds(&x0, &spec);
        let want = vanilla(&x0, &spec);
        assert_eq!(res.stats.eff_serial_evals, want.stats.eff_serial_evals);
        assert_eq!(
            res.stats.eff_serial_evals_pipelined,
            want.stats.eff_serial_evals_pipelined
        );
        assert!(res.stats.eff_serial_evals > 0);
    }

    #[test]
    fn adapter_backend_runs_every_registered_sampler() {
        let eng = engine(2, BatchPolicy::default());
        let reg = registry();
        let x0 = prior_sample(64, 9);
        let reference = {
            let model: Arc<dyn crate::model::EpsModel> =
                Arc::new(GmmEps::new(make_gmm("church")));
            let be = crate::solvers::NativeBackend::new(model, Solver::Ddim);
            let (seq, _) =
                crate::coordinator::sequential(&be, &x0, 25, &Conditioning::none(), 9);
            seq
        };
        for name in reg.list() {
            let s = reg.parse(name).unwrap();
            let spec = SamplerSpec::for_kind(25, s.kind()).with_tol(1e-6).with_seed(9);
            let be = eng.backend();
            let out = s.run(&be, &x0, &spec);
            let d = spec.norm.dist(&out.sample, &reference);
            assert!(d < 1e-2, "{name} via engine adapter vs sequential: {d}");
            let (rows, occ) = be.occupancy();
            assert!(rows > 0, "{name} executed no engine rows");
            assert!(occ >= 1.0, "{name} occupancy {occ}");
        }
    }

    #[test]
    fn fused_batches_preserve_per_request_outputs() {
        // Saturate a 1-worker engine so rows MUST fuse across requests,
        // then check nothing leaked between tenants. All six requests
        // are enqueued before the first reply is awaited, so their rows
        // demonstrably share the pool.
        let eng = engine(1, BatchPolicy::default());
        let reqs: Vec<(Vec<f32>, SamplerSpec)> = (0..6u64)
            .map(|s| {
                let x0 = prior_sample(64, 100 + s);
                let spec = SamplerSpec::srds(25).with_tol(1e-4).with_seed(100 + s);
                (x0, spec)
            })
            .collect();
        let handles: Vec<_> = reqs
            .iter()
            .map(|(x0, spec)| eng.submit_srds(x0.clone(), spec.clone()))
            .collect();
        let mut saw_fusion = false;
        for ((x0, spec), rx) in reqs.iter().zip(handles) {
            let got = rx.recv().expect("engine reply");
            let want = vanilla(x0, spec);
            let d = spec.norm.dist(&got.sample, &want.sample);
            assert!(d < 1e-6, "seed {}: {d}", spec.seed);
            saw_fusion |= got.stats.batch_occupancy > 1.0;
        }
        let stats = eng.stats();
        assert!(stats.flushed_batches > 0);
        // With 6 concurrent requests on one worker, fusion must occur.
        assert!(saw_fusion, "no request ever rode a multi-row batch");
        assert!(stats.mean_occupancy > 1.0, "engine never fused rows");
    }

    #[test]
    fn engine_stats_snapshot_is_consistent() {
        let eng = engine(2, BatchPolicy::immediate());
        let x0 = prior_sample(64, 3);
        let spec = SamplerSpec::srds(25).with_tol(1e-4).with_seed(3);
        let res = eng.run_srds(&x0, &spec);
        assert!(res.stats.engine_rows > 0);
        assert!(res.stats.batch_occupancy >= 1.0);
        let st = eng.stats();
        assert!(st.flushed_rows >= res.stats.engine_rows);
        assert_eq!(st.inflight_requests, 0);
        assert_eq!(st.workers, 2);
    }

    #[test]
    fn engine_shuts_down_cleanly() {
        let eng = engine(3, BatchPolicy::default());
        drop(eng); // must not hang
    }

    #[test]
    fn steady_request_stream_stops_missing_the_pool() {
        // The engine-wide zero-copy claim: once a few identical requests
        // have warmed the pool, further requests are served from the
        // free lists. (A straggler row finishing after its request's
        // finalize can check a buffer out at an unlucky moment, so we
        // allow a few residual misses rather than exactly zero.)
        let eng = engine(2, BatchPolicy::default());
        let run = |seed: u64| {
            let x0 = prior_sample(64, seed);
            eng.run_srds(&x0, &SamplerSpec::srds(25).with_tol(1e-4).with_seed(seed))
        };
        for s in 0..3 {
            run(s);
        }
        let warm = eng.stats();
        assert!(warm.pool_misses > 0, "states do come from the pool");
        let mut last = run(3);
        for s in 4..9 {
            last = run(s);
        }
        let end = eng.stats();
        let fresh = end.pool_misses - warm.pool_misses;
        assert!(fresh <= 8, "steady-state requests allocated {fresh} fresh buffers");
        assert!(end.pool_hits > warm.pool_hits, "recycling is happening");
        assert!(end.pool_high_water >= warm.pool_high_water);
        // Responses carry the engine pool snapshot, so the flat-misses
        // trend is visible over the wire too. (Snapshot at finalize, so
        // a straggler row finishing afterwards may add a miss before the
        // eng.stats() read — monotone, not exactly equal.)
        assert!(last.stats.pool_misses <= end.pool_misses);
        assert!(last.stats.pool_misses >= warm.pool_misses);
        assert!(last.stats.pool_hits > 0);
    }
}
