//! Multi-tenant step-level execution engine.
//!
//! Generalizes the pipelined-SRDS dispatcher of Fig. 4 (previously a
//! one-request-at-a-time loop in `exec::measured`) so **many concurrent
//! sampling requests share one worker pool**: every fine/coarse solver
//! step any request needs becomes a [`PendingRow`], rows are coalesced
//! by [`Batcher`] into multi-row [`StepRequest`](crate::solvers::StepRequest)
//! batches, and workers
//! execute whole batches in one backend call — the cross-request face of
//! the paper's §3.4 batched-inference observation (one model evaluation
//! serves rows from *different* users, not just different blocks of one
//! trajectory).
//!
//! **Every request is a [`SamplerTask`]** (`exec::task`): an
//! engine-resident state machine the dispatcher drives by event —
//! SRDS's dependency grid, the sequential one-row chain, ParaDiGMS's
//! whole-window sweeps and ParaTAA's whole-trajectory sweeps all live in
//! one heterogeneous task table. There are no per-request threads
//! anywhere: [`Engine::submit`] hands the dispatcher a spec, and the
//! request's entire lifetime is event handling on the dispatcher thread
//! plus batched solver steps on the workers. (The previous adapter
//! `StepBackend`, which parked one blocking OS thread per non-SRDS
//! request, is gone.) A ParaDiGMS sweep's N rows fill worker batches
//! alongside SRDS fine blocks and sequential chain steps — baseline
//! traffic fuses with everything else.
//!
//! **Flush policy** (vLLM-style, adapted to a CPU/PJRT pool): the
//! dispatcher is *work-conserving with spread-first sizing* — a row
//! never waits while enough workers are idle. With `I` idle workers it
//! drains the longest-waiting eligible batcher whole (bucket-quantized
//! by [`Batcher::take_up_to`]) and *splits* the drain into `min(I, rows)`
//! contiguous row-chunk sub-batches, one per idle worker
//! ([`EngineStats::split_batches`] counts these fan-outs) — so a lone
//! request's wide sweep still spreads across the pool instead of
//! pinning one worker, while under load — all workers busy — rows
//! accumulate and flush as large fused batches the moment a worker
//! frees up. Splitting is free of numerical consequence: batch rows
//! never interact, so a row's output is bit-identical whatever chunk it
//! lands in (the batch-shape property tests pin this). When *fewer rows than idle workers*
//! are pending and work is already in flight, the dispatcher may hold
//! them up to `BatchPolicy::max_wait` hoping co-tenant rows arrive
//! (`max_wait == 0` disables holding entirely — the measured executor's
//! configuration). SRDS coarse rows enter their batcher at the head
//! ([`Batcher::push_urgent`]): the G chain is the schedule's serial
//! spine (Prop. 2), and speculative fine work must not delay it — the
//! FIFO analogue of the old worker pool's critical-path priority heap.
//!
//! **QoS scheduling:** every request carries a
//! [`QosClass`] (`interactive` / `standard` / `batch`) and every row it
//! emits drains from that class's lane under weighted deficit round
//! robin ([`Batcher`], [`crate::batching::BatchPolicy::class_weights`]) —
//! under contention the classes' service shares track the weight ratio
//! and no class (hence no tenant) can be starved by another's flood.
//! Deadline-budgeted SRDS requests
//! ([`SamplerSpec::deadline_evals`](crate::coordinator::SamplerSpec::deadline_evals))
//! additionally degrade *gracefully*: when the budget fires the task
//! finalizes from its best completed Parareal iterate (honest
//! `converged: false` + achieved residual), trading refinement quality
//! for latency exactly as the paper's §4 early-convergence property
//! licenses. Per-class occupancy/latency lanes ride [`EngineStats`].
//!
//! **Invariant (pinned by tests):** a request's output is identical to a
//! solo vanilla run of its registry sampler with the same spec and seed,
//! regardless of what else is in flight or which QoS class it rides —
//! every backend computes batch rows independently, so fusing a row with
//! strangers never changes its value, and class selection reorders rows
//! without touching them.
//!
//! **Shared work:** the same determinism that makes the fused-batch
//! invariant checkable makes whole runs *reusable*. A canonical
//! identity —
//! [`SamplerSpec::cache_key`](crate::coordinator::SamplerSpec::cache_key)
//! over the numerics fields plus
//! [`state_hash`](crate::coordinator::state_hash) over `x0` — names a
//! run's entire output, and the dispatcher shares at two levels:
//! *in-flight coalescing* (an identical concurrent submission joins the
//! resident task as one more follower — N duplicates cost one run and
//! each gets its own bit-identical reply, its own latency accounting,
//! and its own cancellation flag; the task aborts only when the last
//! follower's client dies) and the *coarse-spine cache* (at finalize,
//! refcount shares of an SRDS task's iteration-0 boundary states are
//! retained in a capacity-bounded QoS-aware LRU; a repeat request
//! warm-starts at iteration 1, emitting zero coarse-spine rows and
//! dropping `eff_serial_evals` by the skipped sweep). Both are pure
//! work-sharing — `rust/tests/cache_identity.rs` pins bit-identity of
//! shared vs solo output — observable via
//! `cache_hits`/`cache_misses`/`cache_evictions`/`coalesced`.
//!
//! **Zero-copy state:** every state the engine touches is a pooled
//! refcounted [`StateBuf`] from one engine-wide [`BufPool`] — task grid
//! cells, queued row states (a queued row *shares* its producer's
//! buffer), and worker batch outputs. Batch assembly runs through one
//! persistent [`BatchStage`] per worker, and backends write results in
//! place via [`StepBackend::step_into`]. After warm-up a steady request
//! stream allocates no fresh state buffers; `pool_hits`/`pool_misses`
//! (in [`EngineStats`] and every response's `RunStats`) make that
//! observable.

use crate::batching::{stage_rows, BatchPolicy, Batcher, PendingRow};
use crate::buf::{BatchStage, BufPool, StateBuf};
use crate::coordinator::{state_hash, QosClass, SampleOutput, SamplerKind, SamplerSpec};
use crate::exec::task::{new_task, new_warm_task, Completion, IterateEvent, SamplerTask, TaskRow};
use crate::solvers::{BackendFactory, Solver, StepBackend};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Free-list cap per dim bucket for the engine's shared [`BufPool`].
/// Sized for the multi-tenant working set: per-connection admission
/// control defaults to 64 in-flight requests
/// (`crate::server::DEFAULT_MAX_INFLIGHT`; operators can raise it with
/// `--max-inflight`) and each SRDS task retains its full iteration ×
/// block grid until finalize (~200 buffers at n=1024), so a serving
/// burst legitimately parks thousands of slabs. At dim 64 the
/// fully-parked worst case is 4 MiB per bucket; much larger configured
/// caps may see extra pool misses under burst, never unbounded growth.
const ENGINE_POOL_MAX_FREE: usize = 16 * 1024;

/// How often an idle sharded dispatcher re-checks sibling load gauges
/// for steal candidates. Only dispatchers with a [`StealMesh`] pay this
/// wake-up (an unsharded engine still parks indefinitely on its inbox);
/// 1 ms bounds the steal reaction latency at far below any batch
/// execution time while costing an idle shard ~a microsecond of work
/// per tick.
const STEAL_POLL: Duration = Duration::from_millis(1);

/// Engine construction knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads (one thread-bound backend instance each).
    pub workers: usize,
    /// Cross-request batch assembly policy.
    pub batch: BatchPolicy,
    /// This engine's slot in `mesh` (ignored when `mesh` is `None`).
    pub shard_id: usize,
    /// The cross-shard steal fabric shared by every engine of a
    /// [`Router`](crate::exec::router::Router) fleet. `None` (the
    /// default) makes a standalone, mesh-free engine — exactly the
    /// pre-sharding behavior.
    pub mesh: Option<Arc<StealMesh>>,
    /// Whether this shard's dispatcher *steals* queued rows from
    /// saturated siblings when its own lanes run dry. Donating is not
    /// gated — an overloaded shard always answers a `StealRequest`.
    pub steal: bool,
    /// Coarse-spine cache capacity: how many finished SRDS spines this
    /// engine retains (refcount shares of the iteration-0 boundary
    /// states) for warm-starting repeat requests. `0` — the library
    /// default — disables the cache entirely, keeping a bare engine's
    /// buffer liveness exactly its working set; the serving layer turns
    /// it on (`--spine-cache-cap`). Retention is bounded by
    /// `cap × M` buffers and surfaces in `pool` liveness by design —
    /// cached spines are *supposed* to stay live.
    pub spine_cache_cap: usize,
    /// Coalesce identical concurrent submissions — same
    /// [`SamplerSpec::cache_key`](crate::coordinator::SamplerSpec::cache_key),
    /// initial state, QoS class, deadline and payload shape — into one
    /// resident task with fanned-out bit-identical replies. On by
    /// default (`--no-coalesce` on the CLI): distinct requests are
    /// never merged — the dedupe identity includes the wall-clock
    /// timeout, and streaming requests opt out entirely — so the only
    /// observable effect is N identical requests costing one run.
    pub coalesce: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 4,
            batch: BatchPolicy::default(),
            shard_id: 0,
            mesh: None,
            steal: true,
            spine_cache_cap: 0,
            coalesce: true,
        }
    }
}

/// One shard's published load: queued rows and resident tasks,
/// maintained by its dispatcher at every publish and read lock-free by
/// sibling dispatchers picking steal victims and by the router placing
/// requests.
#[derive(Debug, Default)]
pub struct LoadGauge {
    rows: AtomicU64,
    tasks: AtomicU64,
}

impl LoadGauge {
    /// Rows currently queued in the shard's batchers.
    pub fn rows(&self) -> u64 {
        self.rows.load(Ordering::Relaxed)
    }

    /// Requests currently resident in the shard's task table.
    pub fn tasks(&self) -> u64 {
        self.tasks.load(Ordering::Relaxed)
    }
}

/// The cross-shard steal fabric. Each sharded engine registers its
/// dispatcher inbox and [`LoadGauge`] here at construction; thief
/// dispatchers use the gauges to pick the most-loaded sibling and the
/// senders to address [`Msg::StealRequest`] / [`Msg::StolenRows`]
/// transfers. All cross-shard traffic rides the ordinary per-shard
/// dispatcher inboxes — there is no shared work queue and no lock is
/// ever held across shards (the slot table's own mutex guards only
/// sender/gauge lookups).
pub struct StealMesh {
    slots: Mutex<Vec<Option<(Sender<Msg>, Arc<LoadGauge>)>>>,
}

impl std::fmt::Debug for StealMesh {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StealMesh").field("shards", &self.shards()).finish()
    }
}

impl StealMesh {
    /// A mesh with `shards` empty slots; each engine of the fleet fills
    /// its own slot from [`Engine::new`].
    pub fn new(shards: usize) -> Arc<StealMesh> {
        Arc::new(StealMesh { slots: Mutex::new((0..shards.max(1)).map(|_| None).collect()) })
    }

    /// Fleet width (slot count, registered or not).
    pub fn shards(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    /// Published `(queued rows, resident tasks)` of one shard — the
    /// router's lock-free placement view. Zeros until registered.
    pub fn load(&self, shard: usize) -> (u64, u64) {
        match self.slots.lock().unwrap().get(shard).and_then(|s| s.as_ref()) {
            Some((_, g)) => (g.rows(), g.tasks()),
            None => (0, 0),
        }
    }

    fn register(&self, shard: usize, tx: Sender<Msg>, gauge: Arc<LoadGauge>) {
        let mut slots = self.slots.lock().unwrap();
        assert!(shard < slots.len(), "shard id {shard} outside mesh of {}", slots.len());
        assert!(slots[shard].is_none(), "shard {shard} registered twice");
        slots[shard] = Some((tx, gauge));
    }

    fn sender(&self, shard: usize) -> Option<Sender<Msg>> {
        self.slots.lock().unwrap().get(shard).and_then(|s| s.as_ref()).map(|(tx, _)| tx.clone())
    }

    /// The sibling with the deepest published row queue (`None` when
    /// every other shard is idle) — who a dry dispatcher asks for work.
    fn pick_victim(&self, thief: usize) -> Option<Sender<Msg>> {
        let slots = self.slots.lock().unwrap();
        let mut best: Option<(u64, &Sender<Msg>)> = None;
        for (i, slot) in slots.iter().enumerate() {
            if i == thief {
                continue;
            }
            if let Some((tx, g)) = slot {
                let rows = g.rows();
                if rows > 0 && best.map(|(b, _)| rows > b).unwrap_or(true) {
                    best = Some((rows, tx));
                }
            }
        }
        best.map(|(_, tx)| tx.clone())
    }
}

/// Rows may only share a [`StepRequest`] when the request-wide scalar
/// fields agree: one guidance weight and one mask shape per batch.
type BatchKey = (u32, bool, usize);

fn batch_key(row: &PendingRow) -> BatchKey {
    (
        row.guidance.to_bits(),
        row.mask.is_some(),
        row.mask.as_ref().map(|m| m.len()).unwrap_or(0),
    )
}

/// Where a completed engine row routes back to: the owning task and the
/// task-local row key it echoed.
struct RowOrigin {
    req: u64,
    key: u64,
}

/// What a serving submission ([`Engine::submit_serving`]) resolves to.
pub enum TaskReply {
    /// The run finished. Under a wall-clock timeout an SRDS run may be
    /// truncated to its newest completed iterate — still a valid
    /// anytime sample, with `stats.timed_out` reporting the truncation
    /// honestly.
    Done(SampleOutput),
    /// The wall-clock timeout expired on a sampler kind with no anytime
    /// iterate to finalize from; the run was aborted with no sample.
    TimedOut,
}

/// Streaming hook attached to a serving submission: invoked on the
/// dispatcher thread once per completed Parareal iterate, with a
/// refcount share of the iterate's sample (never a copy). Must be cheap
/// and must not block — it runs inside the engine's event loop.
pub type ProgressSink = Box<dyn FnMut(IterateEvent) + Send>;

/// How a finished task's [`SampleOutput`] leaves the engine.
enum ReplySink {
    /// Blocking callers ([`Engine::submit`] / [`Engine::run`]).
    Channel(Sender<SampleOutput>),
    /// Non-blocking callers ([`Engine::submit_with`]): invoked on the
    /// dispatcher thread with a consistent [`EngineStats`] snapshot
    /// taken at completion. Must not block.
    Callback(Box<dyn FnOnce(SampleOutput, EngineStats) + Send>),
    /// Serving callers ([`Engine::submit_serving`]): like `Callback`,
    /// but the reply distinguishes a finished run from a timed-out one
    /// that had no anytime iterate to finalize from.
    Serving(Box<dyn FnOnce(TaskReply, EngineStats) + Send>),
}

impl ReplySink {
    fn send(self, out: SampleOutput, stats: EngineStats) {
        match self {
            // A dropped receiver (client went away) is not an engine
            // error.
            ReplySink::Channel(tx) => {
                let _ = tx.send(out);
            }
            ReplySink::Callback(f) => f(out, stats),
            ReplySink::Serving(f) => f(TaskReply::Done(out), stats),
        }
    }

    /// Terminal failure: the wall-clock timeout expired and the task
    /// could not finalize early. Serving callers get an explicit
    /// [`TaskReply::TimedOut`]; blocking channels are dropped (the
    /// receiver sees a disconnect instead of hanging forever), and
    /// fire-and-forget callbacks are simply never invoked.
    fn fail(self, stats: EngineStats) {
        match self {
            ReplySink::Channel(_) | ReplySink::Callback(_) => {}
            ReplySink::Serving(f) => f(TaskReply::TimedOut, stats),
        }
    }
}

enum Msg {
    Submit {
        x0: Vec<f32>,
        spec: SamplerSpec,
        /// Liveness flag owned by the serving layer: flipped to `false`
        /// when the client connection dies, aborting the task on the
        /// dispatcher's next sweep. `None` = uncancellable.
        alive: Option<Arc<AtomicBool>>,
        /// Streaming sink for completed anytime iterates (`None` for
        /// non-streaming submissions).
        progress: Option<ProgressSink>,
        reply: ReplySink,
    },
    BatchDone {
        outs: Vec<(u64, StateBuf)>,
    },
    /// A dry sibling shard asks for queued rows (thief-initiated; the
    /// victim always answers with [`Msg::StolenRows`], possibly empty,
    /// so the thief's outstanding-steal latch clears).
    StealRequest {
        thief: usize,
    },
    /// A victim's donation. `home` is the victim's own inbox: the thief
    /// executes the rows on its workers and routes the results back via
    /// [`Msg::StolenDone`] — row tags only mean something in the
    /// victim's origin map.
    StolenRows {
        rows: Vec<PendingRow>,
        home: Sender<Msg>,
    },
    /// Results of stolen rows arriving back at their home shard. Like
    /// [`Msg::BatchDone`] but without an `in_flight` slot to release —
    /// the execution happened on the thief's workers.
    StolenDone {
        outs: Vec<(u64, StateBuf)>,
    },
    Shutdown,
}

/// One batch handed to a worker. Tags are engine row ids. `home` is
/// `None` for the shard's own rows; for stolen rows it is the victim
/// shard's inbox, where the results must be routed.
struct ExecBatch {
    rows: Vec<PendingRow>,
    home: Option<Sender<Msg>>,
}

#[derive(Default)]
struct WorkState {
    queue: VecDeque<ExecBatch>,
    closed: bool,
}

type WorkQueue = (Mutex<WorkState>, Condvar);

/// Aggregate engine counters, published by the dispatcher.
#[derive(Debug, Clone, Copy, Default)]
struct Counters {
    flushed_batches: u64,
    flushed_rows: u64,
    split_batches: u64,
    steals: u64,
    queue_depth: usize,
    active_tasks: usize,
    cache_hits: u64,
    cache_misses: u64,
    cache_evictions: u64,
    coalesced: u64,
    per_class: [ClassLane; 3],
}

/// Per-QoS-class occupancy and latency counters, one per
/// [`QosClass`] in [`QosClass::ALL`] order inside
/// [`EngineStats::per_class`]. The operator's starvation dashboard: a
/// healthy engine under mixed load shows every class's `completed`
/// climbing and `mean_wall_ms` tracking its weight share, never a flat
/// lane.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClassLane {
    /// Requests of this class admitted into the task table since start.
    pub submitted: u64,
    /// Requests of this class finalized since start.
    pub completed: u64,
    /// Step rows of this class flushed to workers since start.
    pub rows: u64,
    /// Mean request latency (submit → finalize) over this class's
    /// completed requests, milliseconds.
    pub mean_wall_ms: f64,
    /// Completed requests whose anytime eval budget fired
    /// ([`crate::coordinator::RunStats::deadline_hit`]) — how often this
    /// class is being served degraded-but-valid samples under load.
    pub deadline_hits: u64,
    /// Requests of this class aborted before finalize because their
    /// client went away ([`Engine::submit_with_alive`]'s liveness flag
    /// flipped): queued rows purged, no reply built, no completion
    /// counted.
    pub aborted: u64,
}

impl ClassLane {
    /// Requests of this class currently resident
    /// (submitted − completed − aborted).
    pub fn active(&self) -> u64 {
        self.submitted - self.completed - self.aborted
    }
}

/// A point-in-time view of the engine's batching behavior.
#[derive(Debug, Clone, Copy)]
pub struct EngineStats {
    /// Batches dispatched to workers since engine start.
    pub flushed_batches: u64,
    /// Rows those batches carried.
    pub flushed_rows: u64,
    /// `flushed_rows / flushed_batches` — > 1.0 means step fusion is
    /// actually happening.
    pub mean_occupancy: f64,
    /// Flush cycles whose drained batch fanned out to several idle
    /// workers as contiguous row-chunk sub-batches (each sub-batch also
    /// counts in `flushed_batches`). Rows are split-invariant — chunk
    /// boundaries never change a row's value — so this is purely a
    /// load-balance/latency lever, observable here.
    pub split_batches: u64,
    /// Engine shards in the fleet this engine belongs to (1 for a
    /// standalone engine; the mesh width for every member of a
    /// [`Router`](crate::exec::router::Router) fleet, and for the
    /// router's aggregated snapshot).
    pub shards: usize,
    /// Rows this shard's workers executed *on behalf of a sibling
    /// shard* (work stealing): counted on the thief at absorb time, so
    /// the fleet-wide sum equals total migrated rows. Stolen rows also
    /// count in the thief's `flushed_rows` / `flushed_batches` /
    /// `per_class[].rows` — all three are execution-side counters.
    /// Stealing never changes a row's value (rows never interact), only
    /// where it runs.
    pub steals: u64,
    /// Rows currently waiting in the batchers.
    pub queue_depth: usize,
    /// Tasks currently resident in the dispatcher's heterogeneous task
    /// table — every in-flight request of every sampler kind is exactly
    /// one entry here (there is no other request state anywhere).
    pub active_tasks: usize,
    /// Pool size.
    pub workers: usize,
    /// Shared state-buffer pool: requests served from the free lists.
    /// After warm-up, `pool_misses` stops growing while `pool_hits`
    /// climbs — the steady-state-zero-allocation invariant.
    pub pool_hits: u64,
    /// Shared state-buffer pool: requests that allocated fresh slabs.
    pub pool_misses: u64,
    /// Peak simultaneously-live state buffers (the leak detector).
    pub pool_high_water: usize,
    /// SRDS submissions warm-started from a cached coarse spine: the
    /// repeat request skipped the serial init sweep entirely (its
    /// `eff_serial_evals` drops by `M × epc`) while staying
    /// bit-identical to a fresh run. Only counted when the spine cache
    /// is enabled (`spine_cache_cap > 0`).
    pub cache_hits: u64,
    /// SRDS submissions that ran a fresh spine because no cached one
    /// matched `(cache_key, state_hash)`. `hits / (hits + misses)` is
    /// the spine-cache hit rate the `repeat` bench section gates.
    pub cache_misses: u64,
    /// Cached spines dropped by the QoS-aware LRU to stay within
    /// `spine_cache_cap` (lowest class first, oldest within a class).
    pub cache_evictions: u64,
    /// Submissions absorbed as followers of an identical in-flight
    /// request instead of becoming their own task: each one still
    /// counts in `per_class[].submitted`/`completed` and receives its
    /// own bit-identical reply, but cost zero extra rows.
    pub coalesced: u64,
    /// Per-QoS-class occupancy/latency lanes, in [`QosClass::ALL`] order
    /// (`[interactive, standard, batch]`); index with
    /// [`QosClass::index`].
    pub per_class: [ClassLane; 3],
}

impl EngineStats {
    /// The lane for one class.
    pub fn class(&self, c: QosClass) -> &ClassLane {
        &self.per_class[c.index()]
    }
}

/// The multi-tenant execution engine. See the module docs.
pub struct Engine {
    tx: Mutex<Sender<Msg>>,
    counters: Arc<Mutex<Counters>>,
    /// Shared state-buffer slab pool: task grids, queued row states, and
    /// worker batch outputs all draw from (and recycle into) it.
    pool: BufPool,
    dim: usize,
    solver: Solver,
    workers: usize,
    shards: usize,
    gauge: Arc<LoadGauge>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    worker_handles: Vec<std::thread::JoinHandle<()>>,
}

impl Engine {
    /// Spawn the dispatcher plus `cfg.workers` worker threads; each
    /// worker calls `factory.create()` locally (PJRT clients are
    /// `Rc`-based and cannot cross threads).
    pub fn new(factory: Arc<dyn BackendFactory>, cfg: EngineConfig) -> Engine {
        let workers = cfg.workers.max(1);
        let (tx, rx) = channel::<Msg>();
        let work: Arc<WorkQueue> = Arc::new((Mutex::new(WorkState::default()), Condvar::new()));
        let counters = Arc::new(Mutex::new(Counters::default()));
        // The engine's working set is many concurrent tasks' full
        // x/G/F grids (O(M²) buffers per request, retained until
        // finalize), so the free lists must park far more slabs than
        // the run-local default or every request wave would mass-drop
        // and re-allocate its grid — the cap only bounds *retention*
        // (never exceeds the observed peak), not allocation.
        let pool = BufPool::with_max_free(ENGINE_POOL_MAX_FREE);
        let mut worker_handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let work = work.clone();
            let factory = factory.clone();
            let done_tx = tx.clone();
            let pool = pool.clone();
            worker_handles.push(
                std::thread::Builder::new()
                    .name(format!("srds-engine-worker-{w}"))
                    .spawn(move || {
                        let backend = factory.create();
                        worker_loop(backend.as_ref(), &work, &done_tx, &pool);
                    })
                    .expect("spawn engine worker"),
            );
        }
        let dim = factory.dim();
        let solver = factory.solver();
        let epc = solver.evals_per_step() as u64;
        let d_work = work.clone();
        let d_counters = counters.clone();
        // The dispatcher is the only producer into its batchers, so the
        // queue cap is not a back-pressure point here (admission control
        // belongs above the engine); an overflow would tear down every
        // tenant at once, so disable it.
        let mut policy = cfg.batch.clone();
        policy.max_queue = usize::MAX;
        let d_pool = pool.clone();
        // Join the steal fabric before the dispatcher starts: a sibling
        // must never observe a registered-then-running shard whose own
        // slot (its StolenRows reply address) is still empty.
        let gauge = Arc::new(LoadGauge::default());
        let shards = cfg.mesh.as_ref().map(|m| m.shards()).unwrap_or(1);
        if let Some(mesh) = &cfg.mesh {
            mesh.register(cfg.shard_id, tx.clone(), gauge.clone());
        }
        let shard = ShardCtx {
            id: cfg.shard_id,
            shards,
            mesh: cfg.mesh.clone(),
            steal: cfg.steal,
            gauge: gauge.clone(),
        };
        let (cache_cap, coalesce) = (cfg.spine_cache_cap, cfg.coalesce);
        let dispatcher = std::thread::Builder::new()
            .name(format!("srds-engine-dispatcher-{}", cfg.shard_id))
            .spawn(move || {
                Dispatcher::new(
                    rx, d_work, d_counters, workers, policy, epc, d_pool, shard, cache_cap,
                    coalesce,
                )
                .run();
            })
            .expect("spawn engine dispatcher");
        Engine {
            tx: Mutex::new(tx),
            counters,
            pool,
            dim,
            solver,
            workers,
            shards,
            gauge,
            dispatcher: Some(dispatcher),
            worker_handles,
        }
    }

    /// The engine's shared state-buffer pool (observability / tests).
    pub fn pool(&self) -> &BufPool {
        &self.pool
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Fleet width this engine was built into (1 when standalone).
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// This shard's published load gauge (what the mesh and router see).
    pub fn gauge(&self) -> &Arc<LoadGauge> {
        &self.gauge
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn solver(&self) -> Solver {
        self.solver
    }

    // lint: request-path
    fn send(&self, msg: Msg) {
        // lint-allow(panic-policy): a poisoned sender mutex or dead dispatcher is process-fatal, not request-controlled
        self.tx.lock().unwrap().send(msg).expect("engine dispatcher alive");
    }

    /// Queue a request of any registered sampler kind (the dispatcher
    /// builds the matching [`SamplerTask`] from `spec.kind`); the
    /// returned channel yields its [`SampleOutput`] when the state
    /// machine finishes.
    pub fn submit(&self, x0: Vec<f32>, spec: SamplerSpec) -> Receiver<SampleOutput> {
        let (reply, rx) = channel();
        self.send(Msg::Submit {
            x0,
            spec,
            alive: None,
            progress: None,
            reply: ReplySink::Channel(reply),
        });
        rx
    }

    /// [`Engine::submit`] with a completion callback instead of a
    /// channel: `done` runs on the dispatcher thread the moment the task
    /// finalizes, with an [`EngineStats`] snapshot taken at that instant.
    /// This is the serving path's shape — no thread ever blocks waiting
    /// for a request. The callback must be cheap and must not block (it
    /// runs inside the engine's event loop).
    // lint: request-path
    pub fn submit_with<F>(&self, x0: Vec<f32>, spec: SamplerSpec, done: F)
    where
        F: FnOnce(SampleOutput, EngineStats) + Send + 'static,
    {
        self.send(Msg::Submit {
            x0,
            spec,
            alive: None,
            progress: None,
            reply: ReplySink::Callback(Box::new(done)),
        });
    }

    /// [`Engine::submit_with`] plus a liveness flag: the serving layer
    /// flips `alive` to `false` when the client connection dies, and the
    /// dispatcher aborts the task on its next sweep — queued rows
    /// purged, rows already on workers discarded on arrival, no reply
    /// built ([`ClassLane::aborted`] counts these). The poll loop's
    /// dead-connection purge rides this.
    // lint: request-path
    pub fn submit_with_alive<F>(
        &self,
        x0: Vec<f32>,
        spec: SamplerSpec,
        alive: Arc<AtomicBool>,
        done: F,
    ) where
        F: FnOnce(SampleOutput, EngineStats) + Send + 'static,
    {
        self.send(Msg::Submit {
            x0,
            spec,
            alive: Some(alive),
            progress: None,
            reply: ReplySink::Callback(Box::new(done)),
        });
    }

    /// The serving layer's full-featured entry point: a completion
    /// callback that distinguishes a finished run ([`TaskReply::Done`])
    /// from a timed-out one with nothing to finalize
    /// ([`TaskReply::TimedOut`]), an optional client-liveness flag (see
    /// [`Engine::submit_with_alive`]), and an optional streaming sink
    /// that receives one [`IterateEvent`] per completed anytime iterate
    /// — SRDS publishes them, other kinds simply never call the sink.
    /// Both callbacks run on the dispatcher thread and must not block.
    // lint: request-path
    pub fn submit_serving<F>(
        &self,
        x0: Vec<f32>,
        spec: SamplerSpec,
        alive: Option<Arc<AtomicBool>>,
        progress: Option<ProgressSink>,
        done: F,
    ) where
        F: FnOnce(TaskReply, EngineStats) + Send + 'static,
    {
        self.send(Msg::Submit { x0, spec, alive, progress, reply: ReplySink::Serving(Box::new(done)) });
    }

    /// Run one request to completion (blocking). Other requests may be
    /// in flight concurrently; per-request output is unaffected.
    pub fn run(&self, x0: &[f32], spec: &SamplerSpec) -> SampleOutput {
        self.submit(x0.to_vec(), spec.clone())
            .recv()
            .expect("engine dropped mid-request")
    }

    /// Snapshot the engine counters.
    pub fn stats(&self) -> EngineStats {
        let c = *self.counters.lock().unwrap();
        let ps = self.pool.stats();
        EngineStats {
            flushed_batches: c.flushed_batches,
            flushed_rows: c.flushed_rows,
            mean_occupancy: c.flushed_rows as f64 / c.flushed_batches.max(1) as f64,
            split_batches: c.split_batches,
            shards: self.shards,
            steals: c.steals,
            queue_depth: c.queue_depth,
            active_tasks: c.active_tasks,
            workers: self.workers,
            pool_hits: ps.hits,
            pool_misses: ps.misses,
            pool_high_water: ps.high_water,
            cache_hits: c.cache_hits,
            cache_misses: c.cache_misses,
            cache_evictions: c.cache_evictions,
            coalesced: c.coalesced,
            per_class: c.per_class,
        }
    }
}

/// A cheap, cloneable, thread-safe view of one engine's counters that
/// does **not** keep the engine alive. The router's completion wrappers
/// aggregate fleet stats from inside dispatcher threads; capturing the
/// engines themselves there would let the last in-flight callback drop
/// an [`Engine`] *on its own dispatcher thread* (a self-join deadlock).
#[derive(Clone)]
pub struct StatsHandle {
    counters: Arc<Mutex<Counters>>,
    pool: BufPool,
    workers: usize,
    shards: usize,
}

impl StatsHandle {
    /// Same view as [`Engine::stats`].
    pub fn stats(&self) -> EngineStats {
        let c = *self.counters.lock().unwrap();
        let ps = self.pool.stats();
        EngineStats {
            flushed_batches: c.flushed_batches,
            flushed_rows: c.flushed_rows,
            mean_occupancy: c.flushed_rows as f64 / c.flushed_batches.max(1) as f64,
            split_batches: c.split_batches,
            shards: self.shards,
            steals: c.steals,
            queue_depth: c.queue_depth,
            active_tasks: c.active_tasks,
            workers: self.workers,
            pool_hits: ps.hits,
            pool_misses: ps.misses,
            pool_high_water: ps.high_water,
            cache_hits: c.cache_hits,
            cache_misses: c.cache_misses,
            cache_evictions: c.cache_evictions,
            coalesced: c.coalesced,
            per_class: c.per_class,
        }
    }
}

impl Engine {
    /// A detached stats view for this engine (see [`StatsHandle`]).
    pub fn stats_handle(&self) -> StatsHandle {
        StatsHandle {
            counters: self.counters.clone(),
            pool: self.pool.clone(),
            workers: self.workers,
            shards: self.shards,
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(Msg::Shutdown);
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
        for h in self.worker_handles.drain(..) {
            let _ = h.join();
        }
    }
}

// lint: hot-path
fn worker_loop(backend: &dyn StepBackend, work: &WorkQueue, done_tx: &Sender<Msg>, pool: &BufPool) {
    let d = backend.dim();
    // One persistent staging buffer per worker: batch assembly reuses it
    // for the whole thread lifetime (no flat-vector churn per flush).
    let mut stage = BatchStage::new();
    loop {
        let batch = {
            let (lock, cv) = work;
            let mut st = lock.lock().unwrap();
            loop {
                if let Some(b) = st.queue.pop_front() {
                    break Some(b);
                }
                if st.closed {
                    break None;
                }
                st = cv.wait(st).unwrap();
            }
        };
        let Some(batch) = batch else { break };
        stage_rows(&batch.rows, &mut stage);
        let out = stage.execute(backend);
        // De-batch into pooled per-row buffers: tasks receive refcounted
        // StateBufs they can store and re-share without further copies.
        let outs = batch
            .rows
            .iter()
            .enumerate()
            .map(|(i, r)| (r.tag, pool.take(&out[i * d..(i + 1) * d])))
            // lint-allow(hot-path-alloc): O(batch) channel payload of pooled bufs; pool.take recycles the slabs
            .collect();
        match batch.home {
            // Stolen rows: results route to the victim shard's inbox
            // (only its origin map knows these tags); the local
            // dispatcher still gets an empty BatchDone to release the
            // worker's in-flight slot.
            Some(home) => {
                let _ = home.send(Msg::StolenDone { outs });
                // lint-allow(hot-path-alloc): Vec::new of an empty slot-release message, no buffer behind it
                if done_tx.send(Msg::BatchDone { outs: Vec::new() }).is_err() {
                    break;
                }
            }
            None => {
                if done_tx.send(Msg::BatchDone { outs }).is_err() {
                    break;
                }
            }
        }
    }
}

/// One requester attached to a resident task. A task is born with one
/// follower (its submitter); in-flight coalescing appends more — each
/// an independent request with its own reply sink, submit instant (for
/// honest per-request latency) and client-liveness flag. The task stays
/// alive while *any* follower's client is, and every live follower
/// receives its own bit-identical copy of the output at finalize.
struct Follower {
    reply: ReplySink,
    /// Submit instant (the per-class latency counters).
    t_submit: Instant,
    /// Client liveness; `false` means detach on the next sweep (and
    /// abort the task when the last follower detaches).
    alive: Option<Arc<AtomicBool>>,
    /// Streaming sink: completed anytime iterates fan out here as
    /// refcount shares (`None` for non-streaming requests).
    progress: Option<ProgressSink>,
}

/// The in-flight dedupe identity: everything that must match for two
/// submissions to legally share one task. The numerics pair
/// `(cache_key, state_hash)` guarantees bit-identical output; the
/// scheduling/payload tail (`keep_iterates`, `deadline_evals`,
/// `priority`, `timeout_ms`) is re-added here — [`SamplerSpec::cache_key`]
/// excludes it on purpose — because requests that truncate at different
/// budgets or wall-clock limits, want different payloads, or ride
/// different QoS lanes cannot share a run even though their numerics
/// agree. Streaming requests opt out of coalescing entirely (see
/// [`Dispatcher::handle`]), so `stream` needs no slot here.
type CoalesceKey = (u64, u64, bool, Option<u64>, u8, Option<u64>);

/// One resident request: its state machine plus the request-wide row
/// fields the dispatcher attaches to every row the task emits, and the
/// count of rows currently queued or executing (for stray-eval
/// accounting at finalize).
struct TaskEntry {
    task: Box<dyn SamplerTask>,
    /// Everyone awaiting this task's output — the submitter plus any
    /// coalesced duplicates. Never empty while the entry is resident.
    followers: Vec<Follower>,
    mask: Option<Arc<[f32]>>,
    guidance: f32,
    seed: u64,
    /// QoS lane every row of this request drains from (all followers
    /// share it — the coalesce key includes the class).
    class: QosClass,
    inflight: usize,
    /// This task's slot in the dispatcher's in-flight dedupe table
    /// (`None` when coalescing is off), cleared when the task leaves
    /// the table so a later identical submission starts fresh.
    coalesce_key: Option<CoalesceKey>,
    /// The spine-cache key `(cache_key, state_hash)` — `Some` only for
    /// SRDS requests while the cache is enabled; where the harvested
    /// spine is filed at finalize.
    spine_key: Option<(u64, u64)>,
    /// Wall-clock expiry armed from `spec.timeout_ms` at admission;
    /// cleared when it fires so the timeout triggers exactly once.
    deadline: Option<Instant>,
}

/// Capacity-bounded, QoS-aware LRU of finished coarse spines. Values
/// are refcount shares of the donor task's iteration-0 grid row —
/// retaining or handing out a spine never copies a buffer, so the
/// cache's entire cost is `cap × M` pooled slabs staying checked out.
/// Eviction is class-then-recency: a Batch tenant's spine never
/// displaces an Interactive one, and within a class the
/// least-recently-touched entry goes first.
struct SpineCache {
    cap: usize,
    /// Monotone touch counter backing recency (no clocks on the
    /// dispatcher thread).
    tick: u64,
    map: HashMap<(u64, u64), SpineEntry>,
}

struct SpineEntry {
    spine: Vec<StateBuf>,
    class: QosClass,
    tick: u64,
}

impl SpineCache {
    fn new(cap: usize) -> SpineCache {
        SpineCache { cap, tick: 0, map: HashMap::new() }
    }

    /// Look up a spine; a hit refreshes recency and returns refcount
    /// shares of the stored buffers.
    // lint: hot-path
    fn get(&mut self, key: &(u64, u64)) -> Option<Vec<StateBuf>> {
        self.tick += 1;
        let tick = self.tick;
        let e = self.map.get_mut(key)?;
        e.tick = tick;
        // lint-allow(hot-path-alloc): Arc refcount bumps of the cached bufs, not buffer copies
        Some(e.spine.clone())
    }

    /// Insert (or refresh) a spine; returns the number of entries
    /// evicted to stay within `cap` (0 or 1).
    fn insert(&mut self, key: (u64, u64), spine: Vec<StateBuf>, class: QosClass) -> u64 {
        if self.cap == 0 {
            return 0;
        }
        self.tick += 1;
        let tick = self.tick;
        let mut evicted = 0;
        if !self.map.contains_key(&key) && self.map.len() >= self.cap {
            // QoS-aware LRU victim: highest class index first
            // (`QosClass::ALL` orders interactive < standard < batch),
            // oldest tick within a class.
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, e)| (std::cmp::Reverse(e.class.index()), e.tick))
                .map(|(k, _)| *k);
            if let Some(k) = victim {
                self.map.remove(&k);
                evicted = 1;
            }
        }
        self.map.insert(key, SpineEntry { spine, class, tick });
        evicted
    }
}

/// The sharding face of one dispatcher: its identity in the fleet plus
/// the steal fabric (all `None`/trivial for a standalone engine).
struct ShardCtx {
    id: usize,
    shards: usize,
    mesh: Option<Arc<StealMesh>>,
    steal: bool,
    gauge: Arc<LoadGauge>,
}

struct Dispatcher {
    rx: Receiver<Msg>,
    work: Arc<WorkQueue>,
    counters: Arc<Mutex<Counters>>,
    workers: usize,
    policy: BatchPolicy,
    epc: u64,
    pool: BufPool,
    shard: ShardCtx,
    /// Thief latch: a `StealRequest` is outstanding and the sibling's
    /// `StolenRows` answer (possibly empty) has not arrived yet. At most
    /// one steal conversation per thief keeps the fabric chatter
    /// row-bounded.
    steal_outstanding: bool,
    batchers: HashMap<BatchKey, Batcher>,
    origins: HashMap<u64, RowOrigin>,
    /// The heterogeneous task table: every in-flight request, whatever
    /// its sampler kind.
    tasks: HashMap<u64, TaskEntry>,
    next_row: u64,
    next_id: u64,
    in_flight: usize,
    flushed_batches: u64,
    flushed_rows: u64,
    split_batches: u64,
    steals: u64,
    /// Per-class lanes (the public [`EngineStats::per_class`] view),
    /// maintained incrementally: `submitted` at submit, `rows` after the
    /// dead-row filter in [`Dispatcher::flush`] (so it stays consistent
    /// with `flushed_rows` — the batchers' own per-class counters run at
    /// drain time and would overcount purged rows), the rest at
    /// finalize. `class_wall_ms_sum` backs the running `mean_wall_ms`.
    per_class: [ClassLane; 3],
    class_wall_ms_sum: [f64; 3],
    /// In-flight dedupe table: coalesce identity → resident task id.
    /// Entries are removed when their task finalizes or aborts, so a
    /// lookup hit is always a live task to follow.
    inflight_by_key: HashMap<CoalesceKey, u64>,
    coalesce: bool,
    spine_cache: SpineCache,
    cache_hits: u64,
    cache_misses: u64,
    cache_evictions: u64,
    coalesced: u64,
}

impl Dispatcher {
    #[allow(clippy::too_many_arguments)]
    fn new(
        rx: Receiver<Msg>,
        work: Arc<WorkQueue>,
        counters: Arc<Mutex<Counters>>,
        workers: usize,
        policy: BatchPolicy,
        epc: u64,
        pool: BufPool,
        shard: ShardCtx,
        spine_cache_cap: usize,
        coalesce: bool,
    ) -> Dispatcher {
        Dispatcher {
            rx,
            work,
            counters,
            workers,
            policy,
            epc,
            pool,
            shard,
            steal_outstanding: false,
            batchers: HashMap::new(),
            origins: HashMap::new(),
            tasks: HashMap::new(),
            next_row: 0,
            next_id: 0,
            in_flight: 0,
            flushed_batches: 0,
            flushed_rows: 0,
            split_batches: 0,
            steals: 0,
            per_class: [ClassLane::default(); 3],
            class_wall_ms_sum: [0.0; 3],
            inflight_by_key: HashMap::new(),
            coalesce,
            spine_cache: SpineCache::new(spine_cache_cap),
            cache_hits: 0,
            cache_misses: 0,
            cache_evictions: 0,
            coalesced: 0,
        }
    }

    fn run(mut self) {
        loop {
            // Park on the inbox. While rows are being held back (linger:
            // idle capacity exists but we are waiting for co-tenants) the
            // park is bounded so the max_wait flush fires on time. A
            // steal-eligible sharded dispatcher (idle capacity, dry
            // lanes, no outstanding request) also bounds its park so it
            // keeps re-checking sibling gauges; an unsharded engine
            // still parks indefinitely.
            let lingering =
                self.in_flight < self.workers && self.batchers.values().any(|b| b.pending() > 0);
            let timeout = if lingering {
                Some(self.policy.max_wait.max(Duration::from_micros(200)))
            } else if self.steal_eligible() {
                Some(STEAL_POLL)
            } else {
                None
            };
            // An armed per-request timeout also bounds the park: the
            // dispatcher must wake at the nearest deadline even if no
            // message ever arrives.
            let nearest_deadline = self
                .tasks
                .values()
                .filter_map(|e| e.deadline)
                .min()
                .map(|dl| dl.saturating_duration_since(Instant::now()));
            let timeout = match (timeout, nearest_deadline) {
                (Some(t), Some(d)) => Some(t.min(d)),
                (None, Some(d)) => Some(d),
                (t, None) => t,
            };
            let msg = match timeout {
                Some(t) => match self.rx.recv_timeout(t) {
                    Ok(m) => Some(m),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => break,
                },
                None => match self.rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => break,
                },
            };
            let mut shutdown = false;
            if let Some(m) = msg {
                shutdown = self.handle(m);
                // Drain whatever else arrived before deciding batches —
                // concurrent submitters' rows should co-batch.
                while !shutdown {
                    match self.rx.try_recv() {
                        Ok(m) => shutdown = self.handle(m),
                        Err(_) => break,
                    }
                }
            }
            if shutdown {
                break;
            }
            // Abort tasks whose client died before flushing: their
            // queued rows must not reach a worker (or a thief).
            self.reap_cancelled();
            // Then enforce wall-clock timeouts, so an expired task never
            // flushes more speculative rows.
            self.reap_timeouts();
            self.flush();
            self.maybe_steal();
            self.publish();
        }
        // Close the worker queue; workers drain what is queued and exit.
        let (lock, cv) = &*self.work;
        lock.lock().unwrap().closed = true;
        cv.notify_all();
    }

    /// Returns `true` on shutdown.
    // lint: hot-path
    // lint: request-path
    fn handle(&mut self, msg: Msg) -> bool {
        match msg {
            Msg::Shutdown => return true,
            Msg::Submit { x0, spec, alive, progress, reply } => {
                let class = spec.priority;
                self.per_class[class.index()].submitted += 1;
                let follower = Follower { reply, t_submit: Instant::now(), alive, progress };
                // Shared-work identity, computed once per request (not
                // per row) and only when a feature that uses it is on.
                let shared = self.coalesce || self.spine_cache.cap > 0;
                let keys = shared.then(|| (spec.cache_key(), state_hash(&x0)));
                // (a) In-flight coalescing: an identical concurrent
                // submission rides the resident task as one more
                // follower — zero extra rows, one more bit-identical
                // reply at finalize. Streaming requests never coalesce
                // (in either direction): each stream owns its delivery
                // cadence, and a non-streaming duplicate riding a
                // streaming task (or vice versa) would entangle them.
                if let (true, false, Some((sk, xk))) = (self.coalesce, spec.stream, keys) {
                    let ckey: CoalesceKey = (
                        sk,
                        xk,
                        spec.keep_iterates,
                        spec.deadline_evals,
                        class.index() as u8,
                        spec.timeout_ms,
                    );
                    if let Some(&resident) = self.inflight_by_key.get(&ckey) {
                        if let Some(entry) = self.tasks.get_mut(&resident) {
                            entry.followers.push(follower);
                            self.coalesced += 1;
                            return false;
                        }
                    }
                    let id = self.admit(x0, spec, follower, Some(ckey), keys);
                    // Only a still-resident task can absorb followers (an
                    // instantly-finished one already cleaned its slot).
                    if self.tasks.contains_key(&id) {
                        self.inflight_by_key.insert(ckey, id);
                    }
                } else {
                    self.admit(x0, spec, follower, None, keys);
                }
            }
            Msg::BatchDone { outs } => {
                self.in_flight -= 1;
                self.route_completions(outs);
            }
            // Results of this shard's rows executed on a thief's
            // workers: same routing as BatchDone, but no local worker
            // slot to release.
            Msg::StolenDone { outs } => self.route_completions(outs),
            Msg::StealRequest { thief } => self.donate(thief),
            Msg::StolenRows { rows, home } => self.absorb_stolen(rows, home),
        }
        false
    }

    /// Admit one submission as a new resident task: spine-cache lookup
    /// (warm-start on a hit), task construction, start, row enqueue.
    /// Returns the task id — the entry may already be gone if the task
    /// finished during admission.
    // lint: hot-path
    // lint: request-path
    fn admit(
        &mut self,
        x0: Vec<f32>,
        spec: SamplerSpec,
        follower: Follower,
        coalesce_key: Option<CoalesceKey>,
        keys: Option<(u64, u64)>,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        // lint-allow(hot-path-alloc): Arc refcount bump, not a buffer copy
        let mask = spec.cond.mask.clone();
        let guidance = spec.cond.guidance;
        let seed = spec.seed;
        let class = spec.priority;
        // (b) Coarse-spine cache: a repeat SRDS request warm-starts from
        // the retained iteration-0 boundary states and skips the one
        // serial sweep Parareal cannot parallelize.
        let spine_key =
            if self.spine_cache.cap > 0 && matches!(spec.kind, SamplerKind::Srds) {
                keys
            } else {
                None
            };
        let warm = spine_key.and_then(|k| {
            let hit = self.spine_cache.get(&k);
            match hit.is_some() {
                true => self.cache_hits += 1,
                false => self.cache_misses += 1,
            }
            hit
        });
        // Arm the wall-clock timeout before the task runs a single row,
        // so `timeout_ms: 0` deterministically expires on the first
        // reap sweep (finalizing SRDS from its iteration-0 spine).
        let deadline = spec.timeout_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
        let mut task = match warm {
            Some(spine) => new_warm_task(&x0, &spec, &self.pool, self.epc, spine),
            None => new_task(&x0, &spec, &self.pool, self.epc),
        };
        let rows = task.start();
        self.tasks.insert(
            id,
            TaskEntry {
                task,
                // lint-allow(hot-path-alloc): one single-element followers vec per admitted request
                followers: vec![follower],
                mask,
                guidance,
                seed,
                class,
                inflight: 0,
                coalesce_key,
                spine_key,
                deadline,
            },
        );
        self.enqueue_rows(id, rows);
        self.maybe_finalize(id);
        id
    }

    /// De-multiplex a batch's results to their owning tasks and drive
    /// each task forward — shared by [`Msg::BatchDone`] (this shard's
    /// workers) and [`Msg::StolenDone`] (a thief's workers).
    // lint: hot-path
    // lint: request-path
    fn route_completions(&mut self, outs: Vec<(u64, StateBuf)>) {
        let batch_rows = outs.len();
        // Group completions per owning task (preserving
        // first-seen order) so a sweep task absorbs a whole
        // batch's worth of its rows in one poll.
        // lint-allow(hot-path-alloc): O(batch) per-task grouping scratch, amortized across a whole batch
        let mut grouped: Vec<(u64, Vec<Completion>)> = Vec::new();
        for (tag, out) in outs {
            // Rows of already-finalized requests have no origin
            // left; their results are discarded here.
            let Some(origin) = self.origins.remove(&tag) else { continue };
            if !self.tasks.contains_key(&origin.req) {
                continue;
            }
            let done = Completion { key: origin.key, out, batch_rows };
            match grouped.iter_mut().find(|(r, _)| *r == origin.req) {
                Some((_, v)) => v.push(done),
                // lint-allow(hot-path-alloc): one short completion vector per distinct task in the batch
                None => grouped.push((origin.req, vec![done])),
            }
        }
        for (req, completions) in grouped {
            let Some(entry) = self.tasks.get_mut(&req) else { continue };
            entry.inflight -= completions.len();
            let rows = entry.task.poll(completions);
            // Streaming fan-out before the next wave of rows goes out:
            // iterates the poll just completed reach clients while the
            // refinement keeps running.
            Self::drain_progress(entry);
            self.enqueue_rows(req, rows);
            self.maybe_finalize(req);
        }
    }

    /// Fan the task's newly completed anytime iterates out to every
    /// follower that asked for a stream. Each event hands the sink a
    /// refcount share of the iterate's grid cell — no buffer copies on
    /// the dispatcher thread.
    // lint: hot-path
    // lint: request-path
    fn drain_progress(entry: &mut TaskEntry) {
        let events = entry.task.take_progress();
        if events.is_empty() {
            return;
        }
        for f in entry.followers.iter_mut() {
            if let Some(sink) = f.progress.as_mut() {
                for ev in &events {
                    // lint-allow(hot-path-alloc): StateBuf refcount bump, not a buffer copy
                    sink(ev.clone());
                }
            }
        }
    }

    // lint: hot-path
    // lint: request-path
    fn enqueue_rows(&mut self, req: u64, rows: Vec<TaskRow>) {
        if rows.is_empty() {
            return;
        }
        // lint-allow(panic-policy): invariant — rows only come out of a task that is still in the map
        let entry = self.tasks.get_mut(&req).expect("rows from a live task");
        entry.inflight += rows.len();
        let (mask, guidance, seed, class) =
            // lint-allow(hot-path-alloc): Arc refcount bump, not a buffer copy
            (entry.mask.clone(), entry.guidance, entry.seed, entry.class);
        for row in rows {
            let tag = self.next_row;
            self.next_row += 1;
            self.origins.insert(tag, RowOrigin { req, key: row.key });
            self.push_row(
                PendingRow {
                    tag,
                    x: row.x,
                    s_from: row.s_from,
                    s_to: row.s_to,
                    // lint-allow(hot-path-alloc): Arc refcount bump, not a buffer copy
                    mask: mask.clone(),
                    guidance,
                    seed,
                    class,
                },
                row.urgent,
            );
        }
    }

    // lint: hot-path
    // lint: request-path
    fn push_row(&mut self, row: PendingRow, urgent: bool) {
        let key = batch_key(&row);
        let batcher = self
            .batchers
            .entry(key)
            .or_insert_with(|| Batcher::new(self.policy.clone())); // lint-allow(hot-path-alloc): once per new batch key, not per row
        // The dispatcher is the only producer; queue overflow here means
        // admission control above the engine failed, not a row to drop.
        let pushed = if urgent { batcher.push_urgent(row) } else { batcher.push(row) };
        assert!(pushed, "engine batcher overflow (raise BatchPolicy::max_queue)");
    }

    // lint: request-path
    fn maybe_finalize(&mut self, req: u64) {
        let done = self.tasks.get(&req).map(|e| e.task.finished()).unwrap_or(false);
        if !done {
            return;
        }
        let Some(mut entry) = self.tasks.remove(&req) else { return };
        self.forget_inflight_key(req, &entry);
        // Flush any still-undelivered iterates first: a stream's Final
        // frame must never overtake its last Iterate.
        Self::drain_progress(&mut entry);
        // Eagerly purge this request's still-queued speculative rows —
        // they will never run, and leaving them in place would inflate
        // queue_depth and the spread-cap math until the lazy flush
        // filter got to them.
        let origins = &mut self.origins;
        let mut queued = 0usize;
        for b in self.batchers.values_mut() {
            let dead = b.purge(|r| !matches!(origins.get(&r.tag), Some(o) if o.req == req));
            for row in dead {
                origins.remove(&row.tag);
                queued += 1;
            }
        }
        // Rows already handed to workers still execute and burn model
        // evals; attribute them now. Their results are discarded on
        // arrival via the origin map.
        let executing = entry.inflight.saturating_sub(queued) as u64;
        entry.task.charge_stray_rows(executing);
        // Spine harvest, before finalize consumes the task: refcount
        // shares of the iteration-0 boundary states go into the cache
        // (for a warm task these are the cached buffers themselves, so
        // re-stocking is a pure recency refresh).
        if let Some(key) = entry.spine_key {
            if let Some(spine) = entry.task.take_spine() {
                self.cache_evictions += self.spine_cache.insert(key, spine, entry.class);
            }
        }
        let out = entry.task.finalize();
        // Per-class latency/deadline accounting — one completion per
        // follower, each with its *own* submit instant, so coalesced
        // requests report honest per-request latency — folded in before
        // the publish so the reply's stats snapshot already includes
        // this request's own completion.
        let c = entry.class.index();
        for f in &entry.followers {
            let lane = &mut self.per_class[c];
            lane.completed += 1;
            self.class_wall_ms_sum[c] += f.t_submit.elapsed().as_secs_f64() * 1000.0;
            lane.mean_wall_ms = self.class_wall_ms_sum[c] / lane.completed as f64;
            if out.stats.deadline_hit {
                lane.deadline_hits += 1;
            }
        }
        // Publish counters before the replies unblock callers, so a
        // stats() read right after completion is current.
        self.publish();
        let stats = self.snapshot_stats();
        // Fan out: every follower gets a bit-identical output (the
        // sample vector clones; the run happened once).
        let mut followers = entry.followers;
        let last = followers.pop();
        for f in followers {
            f.reply.send(out.clone(), stats);
        }
        if let Some(f) = last {
            f.reply.send(out, stats);
        }
    }

    /// Clear a departing task's slot in the in-flight dedupe table (if
    /// it still points at this task — a stale slot may already have
    /// been reclaimed by a later identical submission).
    fn forget_inflight_key(&mut self, req: u64, entry: &TaskEntry) {
        if let Some(ckey) = entry.coalesce_key {
            if self.inflight_by_key.get(&ckey) == Some(&req) {
                self.inflight_by_key.remove(&ckey);
            }
        }
    }

    /// Work-conserving, spread-first flush. See the module docs.
    // lint: hot-path
    // lint: request-path
    fn flush(&mut self) {
        loop {
            let idle = self.workers.saturating_sub(self.in_flight);
            if idle == 0 {
                return;
            }
            // Among the eager batchers, drain the one whose head row has
            // waited longest — HashMap iteration order must never decide
            // who gets served, or a flooding tenant in one batch key
            // (guidance / mask shape) could starve every other key.
            let key = self
                .batchers
                .iter()
                .filter(|(_, b)| {
                    b.pending() > 0
                        && (self.in_flight == 0 || b.pending() >= idle || b.should_flush())
                })
                .min_by_key(|(_, b)| b.oldest_since())
                .map(|(k, _)| *k);
            let Some(key) = key else { return };
            // lint-allow(panic-policy): the key was just selected from this very map
            let batcher = self.batchers.get_mut(&key).unwrap();
            let mut rows = batcher.take_up_to(batcher.pending());
            // Drop rows whose owner finished already (the lazy purge).
            let (origins, tasks) = (&mut self.origins, &self.tasks);
            rows.retain(|r| {
                let live = origins
                    .get(&r.tag)
                    .map(|o| tasks.contains_key(&o.req))
                    .unwrap_or(false);
                if !live {
                    origins.remove(&r.tag);
                }
                live
            });
            if rows.is_empty() {
                continue;
            }
            self.flushed_rows += rows.len() as u64;
            // Per-class dispatch counters, taken after the dead-row
            // filter so `classes[].rows` on the wire never counts work
            // that was purged instead of executed.
            for r in &rows {
                self.per_class[r.class.index()].rows += 1;
            }
            // Data-parallel batch split: batch rows are independent (the
            // module invariant), so one oversized drain fans out across
            // every idle worker as contiguous row chunks instead of
            // pinning the whole batch on one. Chunk boundaries cannot
            // change any row's value — a worker stages and steps its
            // chunk exactly as the fused batch would have.
            let chunks = idle.min(rows.len());
            let per = rows.len().div_ceil(chunks);
            if chunks > 1 {
                self.split_batches += 1;
            }
            let (lock, cv) = &*self.work;
            // lint-allow(panic-policy): a poisoned work queue means a panicked worker — process-fatal, not request-controlled
            let mut st = lock.lock().unwrap();
            while !rows.is_empty() {
                let rest = rows.split_off(per.min(rows.len()));
                self.in_flight += 1;
                self.flushed_batches += 1;
                st.queue.push_back(ExecBatch { rows, home: None });
                rows = rest;
            }
            drop(st);
            cv.notify_all();
        }
    }

    /// Whether this dispatcher should be probing siblings for work:
    /// sharded, stealing enabled, no conversation outstanding, idle
    /// worker capacity, and nothing queued locally (local rows always
    /// run here first — stealing is strictly a dry-lane move).
    fn steal_eligible(&self) -> bool {
        self.shard.steal
            && self.shard.mesh.is_some()
            && !self.steal_outstanding
            && self.in_flight < self.workers
            && !self.batchers.values().any(|b| b.pending() > 0)
    }

    /// Thief side: ask the most-loaded sibling for queued rows. At most
    /// one request is ever outstanding; the latch clears when the
    /// (possibly empty) [`Msg::StolenRows`] answer arrives.
    fn maybe_steal(&mut self) {
        if !self.steal_eligible() {
            return;
        }
        let Some(mesh) = &self.shard.mesh else { return };
        if let Some(victim) = mesh.pick_victim(self.shard.id) {
            if victim.send(Msg::StealRequest { thief: self.shard.id }).is_ok() {
                self.steal_outstanding = true;
            }
        }
    }

    /// Victim side of a steal: donate up to half of the deepest
    /// batcher's queue — but only while genuinely saturated (every
    /// worker busy; with an idle local worker the next flush would run
    /// these rows right here). One batcher per transfer keeps the
    /// donation a single [`BatchKey`], so the thief can execute it as
    /// one fused batch. The answer is always sent, even empty, to clear
    /// the thief's latch. Donated rows keep their origin entries: the
    /// results come home via [`Msg::StolenDone`] and route exactly like
    /// local completions.
    fn donate(&mut self, thief: usize) {
        let Some(mesh) = self.shard.mesh.clone() else { return };
        let (Some(reply_to), Some(home)) = (mesh.sender(thief), mesh.sender(self.shard.id)) else {
            return;
        };
        let rows = self.donatable_rows();
        let _ = reply_to.send(Msg::StolenRows { rows, home });
    }

    // lint: request-path
    fn donatable_rows(&mut self) -> Vec<PendingRow> {
        if self.in_flight < self.workers {
            return Vec::new();
        }
        let Some(key) = self
            .batchers
            .iter()
            .filter(|(_, b)| b.pending() > 0)
            .max_by_key(|(_, b)| b.pending())
            .map(|(k, _)| *k)
        else {
            return Vec::new();
        };
        // lint-allow(panic-policy): the key was just selected from this very map
        let batcher = self.batchers.get_mut(&key).unwrap();
        let mut rows = batcher.steal_tail(batcher.pending() / 2);
        // Never export rows of already-finished requests (the same
        // dead-row filter a local flush applies).
        let (origins, tasks) = (&mut self.origins, &self.tasks);
        rows.retain(|r| {
            let live = origins.get(&r.tag).map(|o| tasks.contains_key(&o.req)).unwrap_or(false);
            if !live {
                origins.remove(&r.tag);
            }
            live
        });
        rows
    }

    /// Thief side: queue a sibling's donated rows straight onto this
    /// shard's workers. Stolen rows bypass the local batchers and origin
    /// map entirely — their tags only mean something to the victim, and
    /// mixing them into local lanes could collide with this shard's own
    /// row ids. Like a local flush, the donation fans out across every
    /// idle worker as contiguous row chunks (chunk boundaries never
    /// change a row's value).
    // lint: request-path
    fn absorb_stolen(&mut self, mut rows: Vec<PendingRow>, home: Sender<Msg>) {
        self.steal_outstanding = false;
        if rows.is_empty() {
            return;
        }
        self.steals += rows.len() as u64;
        self.flushed_rows += rows.len() as u64;
        for r in &rows {
            self.per_class[r.class.index()].rows += 1;
        }
        let idle = self.workers.saturating_sub(self.in_flight).max(1);
        let chunks = idle.min(rows.len());
        let per = rows.len().div_ceil(chunks);
        if chunks > 1 {
            self.split_batches += 1;
        }
        let (lock, cv) = &*self.work;
        // lint-allow(panic-policy): a poisoned work queue means a panicked worker — process-fatal, not request-controlled
        let mut st = lock.lock().unwrap();
        while !rows.is_empty() {
            let rest = rows.split_off(per.min(rows.len()));
            self.in_flight += 1;
            self.flushed_batches += 1;
            st.queue.push_back(ExecBatch { rows, home: Some(home.clone()) });
            rows = rest;
        }
        drop(st);
        cv.notify_all();
    }

    /// Detach every follower whose client liveness flag went false
    /// (dead-connection purge from the serving layer's poll loop), and
    /// abort a task only when its *last* follower detaches. This is the
    /// coalesced-cancellation contract: one dying duplicate must never
    /// kill a run other clients are still waiting on — the task keeps
    /// computing for the survivors, and only the dead request's reply
    /// is dropped (counted on its class's `aborted` lane).
    fn reap_cancelled(&mut self) {
        if self.tasks.is_empty() {
            return;
        }
        let per_class = &mut self.per_class;
        let mut orphaned: Vec<u64> = Vec::new();
        for (id, e) in self.tasks.iter_mut() {
            let before = e.followers.len();
            e.followers
                .retain(|f| !f.alive.as_ref().is_some_and(|a| !a.load(Ordering::Relaxed)));
            per_class[e.class.index()].aborted += (before - e.followers.len()) as u64;
            if e.followers.is_empty() {
                orphaned.push(*id);
            }
        }
        for req in orphaned {
            self.abort(req);
        }
    }

    /// Drop one task without finalizing: purge its queued rows and
    /// forget its dedupe slot — every follower is gone and nobody is
    /// listening (abort accounting already ran per follower in
    /// [`Dispatcher::reap_cancelled`]). Rows already on workers (local
    /// or stolen) finish and are discarded on arrival via the origin
    /// map.
    fn abort(&mut self, req: u64) {
        let Some(entry) = self.tasks.remove(&req) else { return };
        self.forget_inflight_key(req, &entry);
        let origins = &mut self.origins;
        for b in self.batchers.values_mut() {
            for row in b.purge(|r| !matches!(origins.get(&r.tag), Some(o) if o.req == req)) {
                origins.remove(&row.tag);
            }
        }
    }

    /// Enforce per-request wall-clock timeouts. An expired SRDS task
    /// finalizes from its newest completed iterate — the anytime
    /// property makes that a valid (honestly flagged) sample, delivered
    /// through the normal finalize path. Kinds without an anytime
    /// anchor refuse [`SamplerTask::force_finish`] and are failed
    /// instead: rows purged, followers told [`TaskReply::TimedOut`].
    /// Each deadline fires exactly once (it is cleared here), so a task
    /// whose truncated finalize needs further polls is not re-reaped.
    fn reap_timeouts(&mut self) {
        if self.tasks.is_empty() {
            return;
        }
        let now = Instant::now();
        let mut expired: Vec<(u64, bool)> = Vec::new();
        for (id, e) in self.tasks.iter_mut() {
            if e.deadline.is_some_and(|dl| now >= dl) {
                e.deadline = None;
                expired.push((*id, e.task.force_finish()));
            }
        }
        for (req, finalized) in expired {
            if finalized {
                self.maybe_finalize(req);
            } else {
                self.fail_task(req);
            }
        }
    }

    /// Drop one timed-out task that could not finalize early: purge its
    /// queued rows, forget its dedupe slot, count every follower on its
    /// class's `aborted` lane, and tell each reply sink the request
    /// timed out (serving callers get [`TaskReply::TimedOut`]; blocking
    /// channels disconnect). Rows already on workers finish and are
    /// discarded on arrival via the origin map.
    fn fail_task(&mut self, req: u64) {
        let Some(mut entry) = self.tasks.remove(&req) else { return };
        self.forget_inflight_key(req, &entry);
        let origins = &mut self.origins;
        for b in self.batchers.values_mut() {
            for row in b.purge(|r| !matches!(origins.get(&r.tag), Some(o) if o.req == req)) {
                origins.remove(&row.tag);
            }
        }
        self.per_class[entry.class.index()].aborted += entry.followers.len() as u64;
        self.publish();
        let stats = self.snapshot_stats();
        for f in entry.followers.drain(..) {
            f.reply.fail(stats);
        }
    }

    /// The full public stats view, built dispatcher-side (no lock on the
    /// shared counters needed) — what completion callbacks receive.
    fn snapshot_stats(&self) -> EngineStats {
        let ps = self.pool.stats();
        EngineStats {
            flushed_batches: self.flushed_batches,
            flushed_rows: self.flushed_rows,
            mean_occupancy: self.flushed_rows as f64 / self.flushed_batches.max(1) as f64,
            split_batches: self.split_batches,
            shards: self.shard.shards,
            steals: self.steals,
            queue_depth: self.batchers.values().map(|b| b.pending()).sum(),
            active_tasks: self.tasks.len(),
            workers: self.workers,
            pool_hits: ps.hits,
            pool_misses: ps.misses,
            pool_high_water: ps.high_water,
            cache_hits: self.cache_hits,
            cache_misses: self.cache_misses,
            cache_evictions: self.cache_evictions,
            coalesced: self.coalesced,
            per_class: self.per_class,
        }
    }

    fn publish(&self) {
        let queue_depth: usize = self.batchers.values().map(|b| b.pending()).sum();
        {
            let mut c = self.counters.lock().unwrap();
            c.flushed_batches = self.flushed_batches;
            c.flushed_rows = self.flushed_rows;
            c.split_batches = self.split_batches;
            c.steals = self.steals;
            c.queue_depth = queue_depth;
            c.active_tasks = self.tasks.len();
            c.cache_hits = self.cache_hits;
            c.cache_misses = self.cache_misses;
            c.cache_evictions = self.cache_evictions;
            c.coalesced = self.coalesced;
            c.per_class = self.per_class;
        }
        // The mesh/router view: updated after every handled event, read
        // lock-free by sibling thieves and the placement loop.
        self.shard.gauge.rows.store(queue_depth as u64, Ordering::Relaxed);
        self.shard.gauge.tasks.store(self.tasks.len() as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{prior_sample, registry, srds, SamplerSpec};
    use crate::data::make_gmm;
    use crate::exec::NativeFactory;
    use crate::model::GmmEps;
    use crate::solvers::NativeBackend;

    fn engine(workers: usize, batch: BatchPolicy) -> Engine {
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(make_gmm("church")));
        Engine::new(
            Arc::new(NativeFactory::new(model, Solver::Ddim)),
            EngineConfig { workers, batch, ..EngineConfig::default() },
        )
    }

    fn sharded_pair(workers: usize) -> (Engine, Engine, Arc<StealMesh>) {
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(make_gmm("church")));
        let factory: Arc<dyn crate::solvers::BackendFactory> =
            Arc::new(NativeFactory::new(model, Solver::Ddim));
        let mesh = StealMesh::new(2);
        let mk = |id: usize| {
            Engine::new(
                factory.clone(),
                EngineConfig {
                    workers,
                    shard_id: id,
                    mesh: Some(mesh.clone()),
                    steal: true,
                    ..EngineConfig::default()
                },
            )
        };
        (mk(0), mk(1), mesh)
    }

    fn native_backend() -> NativeBackend {
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(make_gmm("church")));
        NativeBackend::new(model, Solver::Ddim)
    }

    fn vanilla(x0: &[f32], spec: &SamplerSpec) -> SampleOutput {
        srds(&native_backend(), x0, spec)
    }

    #[test]
    fn concurrent_requests_match_solo_vanilla_srds() {
        // The headline multi-tenant invariant: ≥4 requests in flight at
        // once, each one's sample identical to a solo vanilla srds() run
        // with the same spec and seed.
        let eng = Arc::new(engine(3, BatchPolicy::default()));
        let specs: Vec<(Vec<f32>, SamplerSpec)> = (0..5u64)
            .map(|s| {
                let spec = SamplerSpec::srds(36 + 9 * s as usize)
                    .with_tol(1e-4)
                    .with_seed(s);
                (prior_sample(64, s), spec)
            })
            .collect();
        let handles: Vec<_> = specs
            .iter()
            .map(|(x0, spec)| eng.submit(x0.clone(), spec.clone()))
            .collect();
        for ((x0, spec), rx) in specs.iter().zip(handles) {
            let got = rx.recv().expect("engine reply");
            let want = vanilla(x0, spec);
            assert_eq!(got.stats.iters, want.stats.iters, "seed {}", spec.seed);
            let d = spec.norm.dist(&got.sample, &want.sample);
            assert!(d < 1e-6, "engine vs vanilla (seed {}): {d}", spec.seed);
        }
    }

    #[test]
    fn engine_reports_vanilla_eff_serial_evals() {
        // No more `eff_serial_evals: 0` placeholder: the engine computes
        // the vanilla-schedule count with coordinator::srds's formula.
        let eng = engine(2, BatchPolicy::immediate());
        let x0 = prior_sample(64, 1);
        let spec = SamplerSpec::srds(25).with_tol(0.0).with_max_iters(1).with_seed(1);
        let res = eng.run(&x0, &spec);
        let want = vanilla(&x0, &spec);
        assert_eq!(res.stats.eff_serial_evals, want.stats.eff_serial_evals);
        assert_eq!(
            res.stats.eff_serial_evals_pipelined,
            want.stats.eff_serial_evals_pipelined
        );
        assert!(res.stats.eff_serial_evals > 0);
    }

    #[test]
    fn mixed_fleet_is_bit_identical_with_cross_request_fusion() {
        // The tentpole acceptance test: all four registry samplers in
        // flight through one engine simultaneously (two requests each,
        // submitted before any reply is awaited), every request's output
        // bit-identical to its solo vanilla run on a dedicated backend,
        // and at least one request demonstrably riding fused batches.
        let eng = engine(2, BatchPolicy::default());
        let reg = registry();
        let mut reqs: Vec<(Vec<f32>, SamplerSpec)> = Vec::new();
        for (i, name) in reg.list().iter().enumerate() {
            let kind = reg.parse(name).unwrap().kind();
            for rep in 0..2u64 {
                let seed = 40 + 2 * i as u64 + rep;
                let spec = SamplerSpec::for_kind(25, kind).with_tol(1e-5).with_seed(seed);
                reqs.push((prior_sample(64, seed), spec));
            }
        }
        let handles: Vec<_> = reqs
            .iter()
            .map(|(x0, spec)| eng.submit(x0.clone(), spec.clone()))
            .collect();
        let be = native_backend();
        let mut saw_fusion = false;
        for ((x0, spec), rx) in reqs.iter().zip(handles) {
            let got = rx.recv().expect("engine reply");
            let want = spec.run(&be, x0);
            let name = spec.kind.name();
            assert_eq!(got.sample, want.sample, "{name} seed {}: engine vs solo", spec.seed);
            assert_eq!(got.stats.iters, want.stats.iters, "{name} seed {}", spec.seed);
            assert!(got.stats.engine_rows > 0, "{name} executed no engine rows");
            assert!(got.stats.batch_occupancy >= 1.0, "{name} occupancy");
            saw_fusion |= got.stats.batch_occupancy > 1.0;
        }
        assert!(saw_fusion, "no request of the mixed fleet ever rode a multi-row batch");
        let stats = eng.stats();
        assert!(stats.mean_occupancy > 1.0, "mixed fleet never fused rows");
        assert_eq!(stats.active_tasks, 0, "task table drains");
    }

    #[test]
    fn fused_batches_preserve_per_request_outputs() {
        // Saturate a 1-worker engine so rows MUST fuse across requests,
        // then check nothing leaked between tenants. All six requests
        // are enqueued before the first reply is awaited, so their rows
        // demonstrably share the pool.
        let eng = engine(1, BatchPolicy::default());
        let reqs: Vec<(Vec<f32>, SamplerSpec)> = (0..6u64)
            .map(|s| {
                let x0 = prior_sample(64, 100 + s);
                let spec = SamplerSpec::srds(25).with_tol(1e-4).with_seed(100 + s);
                (x0, spec)
            })
            .collect();
        let handles: Vec<_> = reqs
            .iter()
            .map(|(x0, spec)| eng.submit(x0.clone(), spec.clone()))
            .collect();
        let mut saw_fusion = false;
        for ((x0, spec), rx) in reqs.iter().zip(handles) {
            let got = rx.recv().expect("engine reply");
            let want = vanilla(x0, spec);
            let d = spec.norm.dist(&got.sample, &want.sample);
            assert!(d < 1e-6, "seed {}: {d}", spec.seed);
            saw_fusion |= got.stats.batch_occupancy > 1.0;
        }
        let stats = eng.stats();
        assert!(stats.flushed_batches > 0);
        // With 6 concurrent requests on one worker, fusion must occur.
        assert!(saw_fusion, "no request ever rode a multi-row batch");
        assert!(stats.mean_occupancy > 1.0, "engine never fused rows");
    }

    #[test]
    fn submit_with_callback_runs_on_completion_with_stats() {
        // The serving path's shape: no thread blocks on the reply; the
        // callback fires on the dispatcher with a consistent snapshot.
        let eng = engine(2, BatchPolicy::default());
        let (tx, rx) = channel();
        let x0 = prior_sample(64, 9);
        let spec = SamplerSpec::sequential(16).with_seed(9);
        eng.submit_with(x0.clone(), spec, move |out, stats| {
            let _ = tx.send((out, stats));
        });
        let (out, stats) = rx.recv().expect("callback fired");
        let be = native_backend();
        let want = SamplerSpec::sequential(16).with_seed(9).run(&be, &x0);
        assert_eq!(out.sample, want.sample);
        assert!(stats.flushed_batches > 0);
        assert_eq!(stats.active_tasks, 0, "snapshot taken after table removal");
    }

    #[test]
    fn engine_stats_snapshot_is_consistent() {
        let eng = engine(2, BatchPolicy::immediate());
        let x0 = prior_sample(64, 3);
        let spec = SamplerSpec::srds(25).with_tol(1e-4).with_seed(3);
        let res = eng.run(&x0, &spec);
        assert!(res.stats.engine_rows > 0);
        assert!(res.stats.batch_occupancy >= 1.0);
        let st = eng.stats();
        assert!(st.flushed_rows >= res.stats.engine_rows);
        assert_eq!(st.active_tasks, 0);
        assert_eq!(st.workers, 2);
    }

    #[test]
    fn active_tasks_gauge_tracks_the_table() {
        // Four requests submitted before any completes (each takes many
        // worker round trips, so all four Submit messages sit in the
        // dispatcher inbox ahead of the first request's completions):
        // the first callback to fire must observe the other tasks still
        // resident, and the table must drain to zero at the end.
        let eng = engine(1, BatchPolicy::default());
        let (tx, rx) = channel();
        for s in 0..4u64 {
            let tx = tx.clone();
            eng.submit_with(
                prior_sample(64, s),
                SamplerSpec::srds(100).with_tol(1e-4).with_seed(s),
                move |_, stats| {
                    let _ = tx.send(stats.active_tasks);
                },
            );
        }
        drop(tx);
        let seen: Vec<usize> = rx.iter().collect();
        assert_eq!(seen.len(), 4);
        assert!(
            seen.iter().any(|&a| a > 0),
            "no completion ever observed a co-resident task: {seen:?}"
        );
        assert_eq!(eng.stats().active_tasks, 0, "table drains to zero");
    }

    #[test]
    fn large_sweeps_split_across_idle_workers() {
        // The data-parallel split: one request's wide sweep must fan
        // out over several idle workers as row-chunk sub-batches — and
        // because chunk boundaries never change a row's math, the
        // output stays bit-identical to the solo vanilla run.
        let eng = engine(4, BatchPolicy::immediate());
        let x0 = prior_sample(64, 21);
        let spec = SamplerSpec::paradigms(48).with_seed(21);
        let got = eng.run(&x0, &spec);
        let want = spec.run(&native_backend(), &x0);
        assert_eq!(got.sample, want.sample, "split batches changed the output");
        assert_eq!(got.stats.iters, want.stats.iters);
        let st = eng.stats();
        assert!(st.split_batches > 0, "a 48-row sweep on 4 idle workers never split");
        assert!(
            st.flushed_batches > st.split_batches,
            "each split fan-out must emit several sub-batches"
        );
    }

    #[test]
    fn engine_shuts_down_cleanly() {
        let eng = engine(3, BatchPolicy::default());
        drop(eng); // must not hang
    }

    #[test]
    fn interactive_tenant_is_never_starved_by_a_batch_flood() {
        // The ISSUE's fairness property, end to end: one tenant floods
        // batch-class requests through a 1-worker engine; another then
        // submits interactive requests. Weighted DRR must (a) complete
        // every interactive request before the flood's tail (bounded
        // queue age — pure FIFO would finish the entire flood first),
        // and (b) leave every output bit-identical to a solo vanilla
        // run: classes shape scheduling, never numerics.
        let eng = engine(1, BatchPolicy::default());
        let (tx, rx) = channel::<(&'static str, u64)>();
        for s in 0..6u64 {
            let x0 = prior_sample(64, 200 + s);
            let spec = SamplerSpec::srds(36)
                .with_tol(1e-4)
                .with_seed(200 + s)
                .with_priority(QosClass::Batch);
            let tx = tx.clone();
            eng.submit_with(x0, spec, move |out, _| {
                let _ = tx.send(("batch", out.stats.engine_rows));
            });
        }
        let mut inter = Vec::new();
        for s in 0..2u64 {
            let x0 = prior_sample(64, 300 + s);
            let spec = SamplerSpec::srds(25)
                .with_tol(1e-4)
                .with_seed(300 + s)
                .with_priority(QosClass::Interactive);
            let tx = tx.clone();
            let (otx, orx) = channel::<SampleOutput>();
            eng.submit_with(x0.clone(), spec.clone(), move |out, _| {
                let _ = tx.send(("interactive", out.stats.engine_rows));
                let _ = otx.send(out);
            });
            inter.push((x0, spec, orx));
        }
        drop(tx);
        let order: Vec<&'static str> = rx.iter().map(|(c, _)| c).collect();
        assert_eq!(order.len(), 8, "every request completed");
        let last_interactive = order.iter().rposition(|&c| c == "interactive").unwrap();
        let last_batch = order.iter().rposition(|&c| c == "batch").unwrap();
        assert!(
            last_interactive < last_batch,
            "an interactive request outlived the whole batch flood: {order:?}"
        );
        // Bit-identical despite priority scheduling.
        for (x0, spec, orx) in inter {
            let got = orx.recv().expect("interactive output");
            let want = vanilla(&x0, &spec);
            assert_eq!(got.sample, want.sample, "seed {}: class changed numerics", spec.seed);
            assert_eq!(got.stats.iters, want.stats.iters);
        }
        // Per-class lanes saw the traffic and drained fully.
        let st = eng.stats();
        let i = st.class(QosClass::Interactive);
        let b = st.class(QosClass::Batch);
        assert_eq!(i.submitted, 2);
        assert_eq!(i.completed, 2);
        assert_eq!(i.active(), 0);
        assert_eq!(b.submitted, 6);
        assert_eq!(b.completed, 6);
        assert!(i.rows > 0 && b.rows > 0, "both lanes flushed rows");
        assert!(i.mean_wall_ms > 0.0 && b.mean_wall_ms > 0.0);
        assert_eq!(st.class(QosClass::Standard).submitted, 0);
    }

    #[test]
    fn deadline_requests_degrade_gracefully_on_the_engine() {
        // An eval-budgeted SRDS request through the full engine path:
        // the response is an early iterate with honest reporting, and
        // the per-class deadline_hits counter ticks.
        let eng = engine(2, BatchPolicy::default());
        let x0 = prior_sample(64, 77);
        let spec = SamplerSpec::srds(36)
            .with_tol(0.0)
            .with_max_iters(6)
            .with_deadline_evals(60)
            .with_seed(77)
            .with_priority(QosClass::Interactive);
        let out = eng.run(&x0, &spec);
        assert!(out.stats.deadline_hit, "a 60-eval budget must fire at tol 0");
        assert!(!out.stats.converged);
        assert!(out.sample.iter().all(|v| v.is_finite()));
        // The truncated sample is the exact early iterate of the full run.
        let full = vanilla(
            &x0,
            &SamplerSpec::srds(36).with_tol(0.0).with_max_iters(6).with_iterates().with_seed(77),
        );
        assert_eq!(out.sample, full.iterates[out.stats.iters]);
        let st = eng.stats();
        assert_eq!(st.class(QosClass::Interactive).deadline_hits, 1);
        assert_eq!(st.class(QosClass::Interactive).completed, 1);
    }

    #[test]
    fn steady_request_stream_stops_missing_the_pool() {
        // The engine-wide zero-copy claim: once a few identical requests
        // have warmed the pool, further requests are served from the
        // free lists. (A straggler row finishing after its request's
        // finalize can check a buffer out at an unlucky moment, so we
        // allow a few residual misses rather than exactly zero.)
        let eng = engine(2, BatchPolicy::default());
        let run = |seed: u64| {
            let x0 = prior_sample(64, seed);
            eng.run(&x0, &SamplerSpec::srds(25).with_tol(1e-4).with_seed(seed))
        };
        for s in 0..3 {
            run(s);
        }
        let warm = eng.stats();
        assert!(warm.pool_misses > 0, "states do come from the pool");
        let mut last = run(3);
        for s in 4..9 {
            last = run(s);
        }
        let end = eng.stats();
        let fresh = end.pool_misses - warm.pool_misses;
        assert!(fresh <= 8, "steady-state requests allocated {fresh} fresh buffers");
        assert!(end.pool_hits > warm.pool_hits, "recycling is happening");
        assert!(end.pool_high_water >= warm.pool_high_water);
        // Responses carry the engine pool snapshot, so the flat-misses
        // trend is visible over the wire too. (Snapshot at finalize, so
        // a straggler row finishing afterwards may add a miss before the
        // eng.stats() read — monotone, not exactly equal.)
        assert!(last.stats.pool_misses <= end.pool_misses);
        assert!(last.stats.pool_misses >= warm.pool_misses);
        assert!(last.stats.pool_hits > 0);
    }

    #[test]
    fn steal_mesh_picks_the_most_loaded_sibling() {
        let mesh = StealMesh::new(3);
        assert_eq!(mesh.shards(), 3);
        let gauges: Vec<Arc<LoadGauge>> =
            (0..3).map(|_| Arc::new(LoadGauge::default())).collect();
        let mut rxs = Vec::new();
        for (i, g) in gauges.iter().enumerate() {
            let (tx, rx) = channel::<Msg>();
            mesh.register(i, tx, g.clone());
            rxs.push(rx);
        }
        // All idle: no victim for anyone.
        assert!(mesh.pick_victim(0).is_none());
        gauges[1].rows.store(4, Ordering::Relaxed);
        gauges[2].rows.store(9, Ordering::Relaxed);
        // Thief 0 must pick shard 2 (deepest queue), never itself.
        let victim = mesh.pick_victim(0).expect("loaded sibling");
        victim.send(Msg::StealRequest { thief: 0 }).unwrap();
        assert!(matches!(rxs[2].try_recv(), Ok(Msg::StealRequest { thief: 0 })));
        // Thief 2 must pick shard 1 even though 2 itself is deepest.
        let victim = mesh.pick_victim(2).expect("loaded sibling");
        victim.send(Msg::StealRequest { thief: 2 }).unwrap();
        assert!(matches!(rxs[1].try_recv(), Ok(Msg::StealRequest { thief: 2 })));
        assert_eq!(mesh.load(2), (9, 0));
        assert_eq!(mesh.load(7), (0, 0), "out-of-range shard reads as idle");
    }

    #[test]
    fn dead_client_tasks_are_aborted_not_finalized() {
        // A request whose liveness flag is already false must be reaped
        // before any of its rows run: no reply callback, aborted lane
        // ticks, active() drains to zero, and later requests are
        // unaffected.
        let eng = engine(1, BatchPolicy::default());
        let alive = Arc::new(AtomicBool::new(false));
        let (dead_tx, dead_rx) = channel::<()>();
        eng.submit_with_alive(
            prior_sample(64, 50),
            SamplerSpec::srds(36).with_tol(1e-4).with_seed(50),
            alive,
            move |_, _| {
                let _ = dead_tx.send(());
            },
        );
        // A live request through the same engine completes normally.
        let x0 = prior_sample(64, 51);
        let spec = SamplerSpec::srds(25).with_tol(1e-4).with_seed(51);
        let got = eng.run(&x0, &spec);
        assert_eq!(got.sample, vanilla(&x0, &spec).sample);
        assert!(
            dead_rx.try_recv().is_err(),
            "aborted task must never build a reply"
        );
        let st = eng.stats();
        let lane = st.class(QosClass::Standard);
        assert_eq!(lane.aborted, 1);
        assert_eq!(lane.submitted, 2);
        assert_eq!(lane.completed, 1);
        assert_eq!(lane.active(), 0, "aborted tasks leave the table");
        assert_eq!(st.active_tasks, 0);
    }

    #[test]
    fn cancel_mid_flight_aborts_on_a_later_sweep() {
        // Flip the flag while the task is running: the dispatcher reaps
        // it at the next event, queued rows are purged, and in-flight
        // row results are discarded through the origin map (no panic,
        // no leak into other tenants).
        let eng = engine(1, BatchPolicy::default());
        let alive = Arc::new(AtomicBool::new(true));
        let (dead_tx, dead_rx) = channel::<()>();
        eng.submit_with_alive(
            prior_sample(64, 60),
            SamplerSpec::srds(100).with_tol(0.0).with_max_iters(24).with_seed(60),
            alive.clone(),
            move |_, _| {
                let _ = dead_tx.send(());
            },
        );
        alive.store(false, Ordering::Relaxed);
        // Churn the loop with live traffic until the abort lands.
        let mut aborted = 0;
        for s in 0..20u64 {
            let x0 = prior_sample(64, 70 + s);
            let spec = SamplerSpec::sequential(8).with_seed(70 + s);
            let got = eng.run(&x0, &spec);
            let want = spec.run(&native_backend(), &x0);
            assert_eq!(got.sample, want.sample, "co-tenant unaffected by the abort");
            aborted = eng.stats().class(QosClass::Standard).aborted;
            if aborted == 1 {
                break;
            }
        }
        assert_eq!(aborted, 1, "mid-flight cancel never reaped");
        assert!(dead_rx.try_recv().is_err());
        assert_eq!(eng.stats().active_tasks, 0);
    }

    #[test]
    fn streaming_requests_deliver_every_iterate_then_the_final() {
        // The anytime stream through the full engine path: one
        // IterateEvent per completed Parareal iterate, every event's
        // sample bit-identical to the vanilla run's recorded iterate,
        // all events delivered before the final reply, and the final
        // sample untouched by streaming.
        let eng = engine(2, BatchPolicy::default());
        let x0 = prior_sample(64, 23);
        let spec = SamplerSpec::srds(25).with_tol(0.0).with_max_iters(4).with_seed(23);
        let (ev_tx, ev_rx) = channel::<IterateEvent>();
        let (done_tx, done_rx) = channel();
        eng.submit_serving(
            x0.clone(),
            spec.clone().with_stream(),
            None,
            Some(Box::new(move |ev| {
                let _ = ev_tx.send(ev);
            })),
            move |reply, _| {
                let _ = done_tx.send(reply);
            },
        );
        let TaskReply::Done(out) = done_rx.recv().expect("serving reply") else {
            panic!("streamed run must finish, not time out");
        };
        // The final reply is sent after the last drain, so every event
        // is already in the channel here.
        let events: Vec<IterateEvent> = ev_rx.try_iter().collect();
        let full = vanilla(&x0, &spec.clone().with_iterates());
        assert_eq!(events.len(), out.stats.iters, "one event per completed iterate");
        for (k, ev) in events.iter().enumerate() {
            assert_eq!(ev.iter, k + 1, "events arrive in iterate order");
            assert_eq!(ev.sample.to_vec(), full.iterates[k + 1], "iterate {} sample", ev.iter);
            assert!(ev.residual.is_finite());
        }
        assert_eq!(out.sample, full.sample, "streaming must not change the final sample");
        assert_eq!(
            events.last().expect("at least one iterate").sample.to_vec(),
            out.sample,
            "the last streamed iterate IS the final sample"
        );
    }

    #[test]
    fn wall_clock_timeout_finalizes_srds_from_the_newest_iterate() {
        // timeout_ms: 0 expires on the dispatcher's first reap sweep,
        // before any parallel row has completed — the reply must be the
        // iteration-0 coarse spine endpoint with honest flags, counted
        // as a completion (not an abort).
        let eng = engine(2, BatchPolicy::default());
        let x0 = prior_sample(64, 31);
        let spec = SamplerSpec::srds(25).with_tol(0.0).with_max_iters(4).with_seed(31);
        let (done_tx, done_rx) = channel();
        eng.submit_serving(x0.clone(), spec.clone().with_timeout_ms(0), None, None, move |r, s| {
            let _ = done_tx.send((r, s));
        });
        let (reply, stats) = done_rx.recv().expect("serving reply");
        let TaskReply::Done(out) = reply else {
            panic!("SRDS must finalize from its newest iterate, not fail");
        };
        assert!(out.stats.timed_out, "truncation must be reported");
        assert!(!out.stats.converged, "a truncated run never claims convergence");
        assert_eq!(out.stats.iters, 0, "no parallel iterate completed before expiry");
        let full = vanilla(&x0, &spec.with_iterates());
        assert_eq!(out.sample, full.iterates[0], "the newest iterate is the coarse spine");
        let lane = stats.class(QosClass::Standard);
        assert_eq!(lane.completed, 1, "a timed-out SRDS run still completes");
        assert_eq!(lane.aborted, 0);
    }

    #[test]
    fn wall_clock_timeout_fails_kinds_without_anytime_samples() {
        // A sequential run has no intermediate iterate to fall back on:
        // the timeout aborts it with an explicit TimedOut reply, the
        // aborted lane ticks, and the engine keeps serving co-tenants.
        let eng = engine(2, BatchPolicy::default());
        let (done_tx, done_rx) = channel();
        eng.submit_serving(
            prior_sample(64, 41),
            SamplerSpec::sequential(64).with_seed(41).with_timeout_ms(0),
            None,
            None,
            move |r, s| {
                let _ = done_tx.send((r, s));
            },
        );
        let (reply, stats) = done_rx.recv().expect("serving reply");
        assert!(matches!(reply, TaskReply::TimedOut), "sequential cannot finalize early");
        let lane = stats.class(QosClass::Standard);
        assert_eq!(lane.aborted, 1);
        assert_eq!(lane.completed, 0);
        // The engine is still healthy: a live request completes.
        let x0 = prior_sample(64, 42);
        let spec = SamplerSpec::srds(25).with_tol(1e-4).with_seed(42);
        let got = eng.run(&x0, &spec);
        assert_eq!(got.sample, vanilla(&x0, &spec).sample);
        assert_eq!(eng.stats().active_tasks, 0, "the failed task left the table");
    }

    #[test]
    fn streaming_requests_are_never_coalesced() {
        // Two bit-identical streaming submissions: each must own its
        // task and its full event stream (coalescing a stream would
        // entangle delivery cadences), so `coalesced` stays zero and
        // both sinks see every iterate.
        let eng = engine(1, BatchPolicy::default());
        let x0 = prior_sample(64, 53);
        let spec =
            SamplerSpec::srds(25).with_tol(0.0).with_max_iters(3).with_seed(53).with_stream();
        let mut dones = Vec::new();
        let mut streams = Vec::new();
        for _ in 0..2 {
            let (ev_tx, ev_rx) = channel::<IterateEvent>();
            let (done_tx, done_rx) = channel();
            eng.submit_serving(
                x0.clone(),
                spec.clone(),
                None,
                Some(Box::new(move |ev| {
                    let _ = ev_tx.send(ev);
                })),
                move |reply, _| {
                    let _ = done_tx.send(reply);
                },
            );
            dones.push(done_rx);
            streams.push(ev_rx);
        }
        let mut finals = Vec::new();
        for done_rx in dones {
            let TaskReply::Done(out) = done_rx.recv().expect("serving reply") else {
                panic!("streamed run must finish");
            };
            finals.push(out);
        }
        assert_eq!(finals[0].sample, finals[1].sample, "identical requests, identical output");
        for (out, ev_rx) in finals.iter().zip(streams) {
            let events: Vec<IterateEvent> = ev_rx.try_iter().collect();
            assert_eq!(events.len(), out.stats.iters, "each stream gets its own full fan-out");
        }
        assert_eq!(eng.stats().coalesced, 0, "streams must never share a task");
    }

    #[test]
    fn work_stealing_preserves_outputs_and_counts() {
        // Two 1-worker shards on one mesh. Everything is pinned to
        // shard 0, so shard 0 saturates with deep queues while shard 1
        // idles — its thief must lift queued rows across, and every
        // output must stay bit-identical to the solo vanilla run
        // (stealing moves rows, never changes them). Steal timing is
        // load-dependent, so the liveness half retries a few rounds;
        // the bit-identity half is asserted on every attempt.
        let mut stole = 0u64;
        for _attempt in 0..5 {
            let (eng0, eng1, _mesh) = sharded_pair(1);
            let reqs: Vec<(Vec<f32>, SamplerSpec)> = (0..6u64)
                .map(|s| {
                    let spec = SamplerSpec::paradigms(64).with_seed(400 + s);
                    (prior_sample(64, 400 + s), spec)
                })
                .collect();
            let handles: Vec<_> = reqs
                .iter()
                .map(|(x0, spec)| eng0.submit(x0.clone(), spec.clone()))
                .collect();
            let be = native_backend();
            for ((x0, spec), rx) in reqs.iter().zip(handles) {
                let got = rx.recv().expect("engine reply");
                let want = spec.run(&be, x0);
                assert_eq!(got.sample, want.sample, "seed {}: stealing changed a row", spec.seed);
                assert_eq!(got.stats.iters, want.stats.iters, "seed {}", spec.seed);
            }
            let (s0, s1) = (eng0.stats(), eng1.stats());
            assert_eq!(s0.shards, 2);
            assert_eq!(s1.shards, 2);
            assert_eq!(s0.steals, 0, "the loaded shard had nothing to steal");
            assert_eq!(s0.active_tasks, 0);
            stole = s1.steals;
            if stole > 0 {
                // Stolen rows count as executed work on the thief.
                assert!(s1.flushed_rows >= stole);
                break;
            }
        }
        assert!(stole > 0, "an idle sibling never stole from a saturated shard");
    }

    #[test]
    fn stealing_disabled_keeps_every_row_home() {
        // steal: false on both shards — the victim-side gate alone
        // would donate (donating is always on), but no thief ever asks.
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(make_gmm("church")));
        let factory: Arc<dyn crate::solvers::BackendFactory> =
            Arc::new(NativeFactory::new(model, Solver::Ddim));
        let mesh = StealMesh::new(2);
        let mk = |id: usize| {
            Engine::new(
                factory.clone(),
                EngineConfig {
                    workers: 1,
                    shard_id: id,
                    mesh: Some(mesh.clone()),
                    steal: false,
                    ..EngineConfig::default()
                },
            )
        };
        let (eng0, eng1) = (mk(0), mk(1));
        let x0 = prior_sample(64, 90);
        let spec = SamplerSpec::paradigms(48).with_seed(90);
        let got = eng0.run(&x0, &spec);
        assert_eq!(got.sample, spec.run(&native_backend(), &x0).sample);
        assert_eq!(eng1.stats().steals, 0);
        assert_eq!(eng1.stats().flushed_rows, 0, "idle shard executed foreign rows");
        assert_eq!(eng0.stats().steals, 0);
    }
}
