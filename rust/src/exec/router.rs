//! Sharded engine fleet: N independent [`Engine`]s behind one
//! load/QoS-aware placement function, with cross-shard work stealing.
//!
//! **Why shard at all?** One engine has exactly one dispatcher thread —
//! a single-core ceiling on event handling no matter how many workers
//! execute batches. The router splits the machine into *core groups*:
//! each shard owns its dispatcher, its worker set, and its own
//! [`BufPool`](crate::buf::BufPool) (so slab recycling stays NUMA/cache
//! local and shard dispatchers never contend on an allocator lock).
//! This is the serving-level face of the paper's thesis — throughput
//! comes from keeping every device busy on *independent* rows — and the
//! placement interface is deliberately the seam a multi-node router
//! would plug into later.
//!
//! **Placement** ([`Router::submit_with_alive`]) scores every shard by
//! its published [`LoadGauge`] (queued rows + resident tasks, read
//! lock-free) with QoS-class-dependent weights: an interactive request
//! penalizes queue depth hardest (it wants the emptiest lanes *now*),
//! a batch request mostly balances resident-task count. Ties rotate
//! round-robin so an idle fleet stripes instead of piling on shard 0.
//!
//! **Work stealing** rebalances *after* placement mistakes or skewed
//! request widths: when a shard's lanes run dry while a sibling is
//! saturated, its dispatcher lifts the tail of the sibling's deepest
//! batcher over the [`StealMesh`] and executes those rows on its own
//! workers, routing results home (thief-initiated, message-passing
//! only — no shared queue, no cross-shard lock). `steals` counts
//! migrated rows on the thief's [`EngineStats`].
//!
//! **The invariant that makes all of this legal:** batch rows never
//! interact, and every backend computes rows independently, so *where*
//! a row executes — which shard, which worker, stolen or home — can
//! never change its value. A request's output is bit-identical on any
//! shard of any fleet width, with stealing on or off
//! (`rust/tests/shard_determinism.rs` pins this).

use crate::batching::BatchPolicy;
use crate::coordinator::{state_hash, QosClass, SampleOutput, SamplerKind, SamplerSpec};
use crate::exec::engine::{
    ClassLane, Engine, EngineConfig, EngineStats, ProgressSink, StatsHandle, StealMesh, TaskReply,
};
use crate::solvers::{BackendFactory, Solver};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};

/// Fleet construction knobs.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Engine shards (each one dispatcher + `workers` worker threads +
    /// one `BufPool`). 1 gives exactly the single-engine behavior.
    pub shards: usize,
    /// Worker threads *per shard*.
    pub workers: usize,
    /// Batch assembly policy, applied per shard.
    pub batch: BatchPolicy,
    /// Enable cross-shard work stealing (on by default; the
    /// determinism tests run both ways).
    pub steal: bool,
    /// Per-shard coarse-spine cache capacity (entries). 0 — the library
    /// default — disables the cache; the serving layer turns it on.
    /// When enabled, placement gains a spec-affinity hint: a repeat
    /// SRDS request prefers the shard whose cache holds its spine.
    pub spine_cache_cap: usize,
    /// Per-shard in-flight request coalescing (on by default).
    pub coalesce: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            shards: default_shards(4),
            workers: 4,
            batch: BatchPolicy::default(),
            steal: true,
            spine_cache_cap: 0,
            coalesce: true,
        }
    }
}

/// Default fleet width: one shard per `workers_per_shard`-sized core
/// group of the machine, at least 1 (a 16-core host with 4-worker
/// shards gets 4 shards). Callers override with `--shards`.
pub fn default_shards(workers_per_shard: usize) -> usize {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    (cores / workers_per_shard.max(1)).max(1)
}

/// What the router captures into completion wrappers: stats handles
/// only — never the engines, or the last in-flight callback could drop
/// an engine on its own dispatcher thread (self-join deadlock).
struct FleetView {
    handles: Vec<StatsHandle>,
}

impl FleetView {
    fn aggregate(&self) -> EngineStats {
        aggregate(self.handles.iter().map(|h| h.stats()))
    }
}

/// Fold per-shard snapshots into one fleet view: counters sum,
/// occupancy re-derives from the summed rows/batches, per-class
/// `mean_wall_ms` is completed-weighted, and `workers` becomes the
/// fleet's total execution width. `shards` is the fleet width (every
/// member snapshot already carries it; the fold keeps the max so an
/// empty iterator degrades to 0 rather than lying).
pub fn aggregate<I: IntoIterator<Item = EngineStats>>(shards: I) -> EngineStats {
    let mut n = 0usize;
    let mut acc = EngineStats {
        flushed_batches: 0,
        flushed_rows: 0,
        mean_occupancy: 0.0,
        split_batches: 0,
        shards: 0,
        steals: 0,
        queue_depth: 0,
        active_tasks: 0,
        workers: 0,
        pool_hits: 0,
        pool_misses: 0,
        pool_high_water: 0,
        cache_hits: 0,
        cache_misses: 0,
        cache_evictions: 0,
        coalesced: 0,
        per_class: [ClassLane::default(); 3],
    };
    let mut wall_sums = [0.0f64; 3];
    for s in shards {
        n += 1;
        acc.flushed_batches += s.flushed_batches;
        acc.flushed_rows += s.flushed_rows;
        acc.split_batches += s.split_batches;
        acc.shards = acc.shards.max(s.shards).max(n);
        acc.steals += s.steals;
        acc.queue_depth += s.queue_depth;
        acc.active_tasks += s.active_tasks;
        acc.workers += s.workers;
        acc.pool_hits += s.pool_hits;
        acc.pool_misses += s.pool_misses;
        acc.pool_high_water += s.pool_high_water;
        acc.cache_hits += s.cache_hits;
        acc.cache_misses += s.cache_misses;
        acc.cache_evictions += s.cache_evictions;
        acc.coalesced += s.coalesced;
        for ((lane, w), sl) in acc.per_class.iter_mut().zip(wall_sums.iter_mut()).zip(s.per_class.iter()) {
            lane.submitted += sl.submitted;
            lane.completed += sl.completed;
            lane.rows += sl.rows;
            lane.deadline_hits += sl.deadline_hits;
            lane.aborted += sl.aborted;
            *w += sl.mean_wall_ms * sl.completed as f64;
        }
    }
    for (lane, w) in acc.per_class.iter_mut().zip(wall_sums) {
        if lane.completed > 0 {
            lane.mean_wall_ms = w / lane.completed as f64;
        }
    }
    acc.mean_occupancy = acc.flushed_rows as f64 / acc.flushed_batches.max(1) as f64;
    acc
}

/// The sharded fleet front. See the module docs.
pub struct Router {
    engines: Vec<Engine>,
    mesh: Arc<StealMesh>,
    view: Arc<FleetView>,
    /// Tie-break rotation for placement, so an idle fleet stripes.
    rr: AtomicUsize,
    /// Per-shard spine-cache capacity (0 = caches off, no affinity).
    spine_cache_cap: usize,
    /// Spec-affinity placement hints: shared-work identity → the shard
    /// whose spine cache (probably) holds that spine. Per-shard caches
    /// make a spine hit shard-local, so repeats must land where the
    /// first run did or the retained spine is wasted. Advisory only —
    /// a stale hint just means a cache miss on a fresh shard, never a
    /// wrong answer. Bounded at fleet cache capacity by wholesale
    /// clear (entries outliving the LRU they point into are already
    /// stale). This is the router's only interior lock; it never nests
    /// inside or around another.
    affinity: Mutex<HashMap<(u64, u64), usize>>,
}

impl Router {
    /// Build `cfg.shards` engines on one steal mesh. Every shard calls
    /// `factory.create()` per worker exactly as a standalone engine
    /// does — the model weights behind the factory are shared, the
    /// execution state is not.
    pub fn new(factory: Arc<dyn BackendFactory>, cfg: RouterConfig) -> Router {
        let shards = cfg.shards.max(1);
        let mesh = StealMesh::new(shards);
        let engines: Vec<Engine> = (0..shards)
            .map(|id| {
                Engine::new(
                    factory.clone(),
                    EngineConfig {
                        workers: cfg.workers,
                        batch: cfg.batch.clone(),
                        shard_id: id,
                        mesh: Some(mesh.clone()),
                        steal: cfg.steal,
                        spine_cache_cap: cfg.spine_cache_cap,
                        coalesce: cfg.coalesce,
                    },
                )
            })
            .collect();
        let view = Arc::new(FleetView { handles: engines.iter().map(|e| e.stats_handle()).collect() });
        Router {
            engines,
            mesh,
            view,
            rr: AtomicUsize::new(0),
            spine_cache_cap: cfg.spine_cache_cap,
            affinity: Mutex::new(HashMap::new()),
        }
    }

    pub fn shards(&self) -> usize {
        self.engines.len()
    }

    /// Total worker threads across the fleet.
    pub fn total_workers(&self) -> usize {
        self.engines.iter().map(|e| e.workers()).sum()
    }

    pub fn dim(&self) -> usize {
        self.engines[0].dim()
    }

    pub fn solver(&self) -> Solver {
        self.engines[0].solver()
    }

    /// The shared steal fabric (observability / tests).
    pub fn mesh(&self) -> &Arc<StealMesh> {
        &self.mesh
    }

    /// Score-based placement: pick the shard whose published load is
    /// lightest under this class's weights. Queue depth dominates for
    /// interactive traffic (latency: emptiest lanes now), resident
    /// tasks dominate for batch traffic (long-horizon balance). Reads
    /// only lock-free gauges; ties rotate round-robin.
    // lint: request-path
    pub fn place(&self, class: QosClass) -> usize {
        let n = self.engines.len();
        if n == 1 {
            return 0;
        }
        let (w_rows, w_tasks) = match class {
            QosClass::Interactive => (4u64, 1u64),
            QosClass::Standard => (2, 1),
            QosClass::Batch => (1, 2),
        };
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let mut best = start;
        let mut best_score = u64::MAX;
        for k in 0..n {
            let i = (start + k) % n;
            let (rows, tasks) = self.mesh.load(i);
            let score = rows.saturating_mul(w_rows).saturating_add(tasks.saturating_mul(w_tasks));
            if score < best_score {
                best_score = score;
                best = i;
            }
        }
        best
    }

    /// [`Router::place`] with the spec-affinity hint layered on top: a
    /// repeat SRDS request prefers the shard that (probably) retained
    /// its coarse spine — a warm start beats an emptier queue, because
    /// it deletes the one serial sweep instead of merely waiting less.
    /// First-seen specs fall through to the load score and record the
    /// choice. No-op unless the spine cache is enabled.
    // lint: request-path
    fn place_affine(&self, x0: &[f32], spec: &SamplerSpec) -> usize {
        if self.spine_cache_cap == 0
            || self.engines.len() == 1
            || !matches!(spec.kind, SamplerKind::Srds)
        {
            return self.place(spec.priority);
        }
        let key = (spec.cache_key(), state_hash(x0));
        let Ok(mut hints) = self.affinity.lock() else { return self.place(spec.priority) };
        if let Some(&shard) = hints.get(&key) {
            return shard;
        }
        let shard = self.place(spec.priority);
        // Bound the hint table at the fleet's total cache capacity;
        // beyond that, hints point at entries the per-shard LRUs have
        // started evicting anyway, so a wholesale reset is honest.
        if hints.len() >= self.engines.len() * self.spine_cache_cap {
            hints.clear();
        }
        hints.insert(key, shard);
        shard
    }

    /// Place and submit; returns the chosen shard. `done` receives the
    /// **fleet-aggregated** [`EngineStats`] (what the wire `engine`
    /// snapshot shows), not the executing shard's local view.
    // lint: request-path
    pub fn submit_with_alive<F>(
        &self,
        x0: Vec<f32>,
        spec: SamplerSpec,
        alive: Arc<AtomicBool>,
        done: F,
    ) -> usize
    where
        F: FnOnce(SampleOutput, EngineStats) + Send + 'static,
    {
        let shard = self.place_affine(&x0, &spec);
        self.submit_to_with_alive(shard, x0, spec, alive, done);
        shard
    }

    /// [`Router::submit_with_alive`] pinned to one shard — the
    /// cross-shard determinism tests' entry point (placement must be a
    /// pure scheduling choice, so pinning must never change an output).
    // lint: request-path
    pub fn submit_to_with_alive<F>(
        &self,
        shard: usize,
        x0: Vec<f32>,
        spec: SamplerSpec,
        alive: Arc<AtomicBool>,
        done: F,
    ) where
        F: FnOnce(SampleOutput, EngineStats) + Send + 'static,
    {
        let view = self.view.clone();
        self.engines[shard].submit_with_alive(x0, spec, alive, move |out, _local| {
            done(out, view.aggregate())
        });
    }

    /// The serving layer's streaming/timeout-aware submit: places like
    /// [`Router::submit_with_alive`], forwards the optional
    /// [`ProgressSink`] (one call per completed anytime iterate, on the
    /// executing shard's dispatcher thread), and resolves with a
    /// [`TaskReply`] so a wall-clock timeout on a kind with no anytime
    /// iterate surfaces as [`TaskReply::TimedOut`] instead of silence.
    /// `done` receives the fleet-aggregated [`EngineStats`]; returns
    /// the chosen shard.
    // lint: request-path
    pub fn submit_serving<F>(
        &self,
        x0: Vec<f32>,
        spec: SamplerSpec,
        alive: Option<Arc<AtomicBool>>,
        progress: Option<ProgressSink>,
        done: F,
    ) -> usize
    where
        F: FnOnce(TaskReply, EngineStats) + Send + 'static,
    {
        let shard = self.place_affine(&x0, &spec);
        let view = self.view.clone();
        self.engines[shard].submit_serving(x0, spec, alive, progress, move |reply, _local| {
            done(reply, view.aggregate())
        });
        shard
    }

    /// Blocking pinned submit (tests / CLI): the reply channel yields
    /// the output when the shard finalizes the task.
    pub fn submit_to(&self, shard: usize, x0: Vec<f32>, spec: SamplerSpec) -> Receiver<SampleOutput> {
        self.engines[shard].submit(x0, spec)
    }

    /// Run one request to completion on the placed shard (blocking).
    pub fn run(&self, x0: &[f32], spec: &SamplerSpec) -> SampleOutput {
        let shard = self.place_affine(x0, spec);
        self.submit_to(shard, x0.to_vec(), spec.clone())
            .recv()
            .expect("engine dropped mid-request")
    }

    /// The fleet-aggregated stats snapshot (the wire view).
    pub fn stats(&self) -> EngineStats {
        self.view.aggregate()
    }

    /// Per-shard snapshots, shard-id order (observability / tests).
    pub fn shard_stats(&self) -> Vec<EngineStats> {
        self.engines.iter().map(|e| e.stats()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{prior_sample, SamplerSpec};
    use crate::data::make_gmm;
    use crate::exec::NativeFactory;
    use crate::model::GmmEps;
    use crate::solvers::{NativeBackend, Solver};
    use std::sync::mpsc::channel;

    fn factory() -> Arc<dyn BackendFactory> {
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(make_gmm("church")));
        Arc::new(NativeFactory::new(model, Solver::Ddim))
    }

    fn native_backend() -> NativeBackend {
        let model: Arc<dyn crate::model::EpsModel> = Arc::new(GmmEps::new(make_gmm("church")));
        NativeBackend::new(model, Solver::Ddim)
    }

    fn router(shards: usize, workers: usize, steal: bool) -> Router {
        Router::new(
            factory(),
            RouterConfig { shards, workers, steal, ..RouterConfig::default() },
        )
    }

    #[test]
    fn routed_requests_match_solo_vanilla_runs() {
        // A mixed-class fleet of requests through a 3-shard router:
        // wherever placement lands them, outputs are bit-identical to
        // solo vanilla runs and the fleet aggregate adds up.
        let r = router(3, 1, true);
        let classes = [QosClass::Interactive, QosClass::Standard, QosClass::Batch];
        let reqs: Vec<(Vec<f32>, SamplerSpec)> = (0..9u64)
            .map(|s| {
                let spec = SamplerSpec::srds(25 + 9 * (s as usize % 3))
                    .with_tol(1e-4)
                    .with_seed(500 + s)
                    .with_priority(classes[s as usize % 3]);
                (prior_sample(64, 500 + s), spec)
            })
            .collect();
        let (tx, rx) = channel();
        let mut shards_used = Vec::new();
        for (i, (x0, spec)) in reqs.iter().enumerate() {
            let tx = tx.clone();
            let alive = Arc::new(AtomicBool::new(true));
            let shard = r.submit_with_alive(x0.clone(), spec.clone(), alive, move |out, agg| {
                let _ = tx.send((i, out, agg));
            });
            shards_used.push(shard);
        }
        drop(tx);
        let be = native_backend();
        let mut got = 0;
        for (i, out, agg) in rx.iter() {
            let (x0, spec) = &reqs[i];
            let want = spec.run(&be, x0);
            assert_eq!(out.sample, want.sample, "req {i}: placement changed numerics");
            assert_eq!(agg.shards, 3, "callbacks see the fleet aggregate");
            got += 1;
        }
        assert_eq!(got, 9);
        assert!(shards_used.iter().any(|&s| s != shards_used[0]), "placement never spread");
        let agg = r.stats();
        assert_eq!(agg.shards, 3);
        assert_eq!(agg.active_tasks, 0, "fleet drains");
        let by_class: u64 = agg.per_class.iter().map(|l| l.completed).sum();
        assert_eq!(by_class, 9);
        assert_eq!(
            agg.flushed_rows,
            r.shard_stats().iter().map(|s| s.flushed_rows).sum::<u64>(),
            "aggregate is the per-shard sum"
        );
    }

    #[test]
    fn placement_prefers_the_lighter_shard() {
        // Saturate shard 0 via pinned submits, then place: the router
        // must send the newcomer elsewhere while shard 0's gauge is hot.
        let r = router(2, 1, false);
        let mut handles = Vec::new();
        for s in 0..4u64 {
            let x0 = prior_sample(64, 600 + s);
            let spec = SamplerSpec::srds(48).with_tol(1e-4).with_seed(600 + s);
            handles.push((r.submit_to(0, x0.clone(), spec.clone()), x0, spec));
        }
        // Wait until shard 0's dispatcher has published a nonzero load
        // (placement reads the gauges, which update per event).
        let t0 = std::time::Instant::now();
        while r.mesh().load(0) == (0, 0) && t0.elapsed().as_secs() < 5 {
            std::thread::yield_now();
        }
        assert_eq!(r.place(QosClass::Interactive), 1, "hot shard 0 must repel placement");
        let be = native_backend();
        for (rx, x0, spec) in handles {
            let out = rx.recv().expect("reply");
            assert_eq!(out.sample, spec.run(&be, &x0).sample);
        }
    }

    #[test]
    fn aggregate_folds_counters_and_weighted_latency() {
        let mut a = EngineStats {
            flushed_batches: 10,
            flushed_rows: 40,
            mean_occupancy: 0.0,
            split_batches: 1,
            shards: 2,
            steals: 3,
            queue_depth: 2,
            active_tasks: 1,
            workers: 4,
            pool_hits: 100,
            pool_misses: 10,
            pool_high_water: 50,
            cache_hits: 4,
            cache_misses: 6,
            cache_evictions: 1,
            coalesced: 3,
            per_class: [ClassLane::default(); 3],
        };
        let mut b = a;
        b.flushed_batches = 30;
        b.flushed_rows = 60;
        a.per_class[0] = ClassLane {
            submitted: 3,
            completed: 2,
            rows: 20,
            mean_wall_ms: 10.0,
            deadline_hits: 1,
            aborted: 1,
        };
        b.per_class[0] = ClassLane {
            submitted: 8,
            completed: 8,
            rows: 40,
            mean_wall_ms: 40.0,
            deadline_hits: 0,
            aborted: 0,
        };
        let agg = aggregate([a, b]);
        assert_eq!(agg.flushed_batches, 40);
        assert_eq!(agg.flushed_rows, 100);
        assert_eq!(agg.shards, 2);
        assert_eq!(agg.steals, 6);
        assert_eq!(agg.workers, 8);
        assert_eq!(agg.cache_hits, 8);
        assert_eq!(agg.cache_misses, 12);
        assert_eq!(agg.cache_evictions, 2);
        assert_eq!(agg.coalesced, 6);
        assert!((agg.mean_occupancy - 2.5).abs() < 1e-12);
        let lane = &agg.per_class[0];
        assert_eq!(lane.submitted, 11);
        assert_eq!(lane.completed, 10);
        assert_eq!(lane.aborted, 1);
        assert_eq!(lane.deadline_hits, 1);
        // (2×10 + 8×40) / 10 = 34: completed-weighted, not averaged.
        assert!((lane.mean_wall_ms - 34.0).abs() < 1e-12, "{}", lane.mean_wall_ms);
        assert_eq!(lane.active(), 0);
    }

    #[test]
    fn repeat_requests_prefer_the_shard_holding_their_spine() {
        // With the spine cache on, a repeat SRDS request must follow
        // its first run's shard (that cache holds the spine), hit the
        // cache there, and still answer bit-identically.
        let r = Router::new(
            factory(),
            RouterConfig { shards: 2, workers: 1, spine_cache_cap: 8, ..RouterConfig::default() },
        );
        let x0 = prior_sample(64, 800);
        let spec = SamplerSpec::srds(25).with_tol(1e-4).with_seed(800);
        let (tx, rx) = channel();
        let send_to = |tx: std::sync::mpsc::Sender<SampleOutput>| {
            move |out: SampleOutput, _agg: EngineStats| {
                let _ = tx.send(out);
            }
        };
        let first = r.submit_with_alive(
            x0.clone(),
            spec.clone(),
            Arc::new(AtomicBool::new(true)),
            send_to(tx.clone()),
        );
        let fresh = rx.recv().expect("fresh reply");
        let second = r.submit_with_alive(
            x0.clone(),
            spec.clone(),
            Arc::new(AtomicBool::new(true)),
            send_to(tx),
        );
        assert_eq!(second, first, "the repeat must land where the spine lives");
        let warm = rx.recv().expect("warm reply");
        assert_eq!(warm.sample, fresh.sample, "warm start changed the answer");
        assert!(
            warm.stats.eff_serial_evals < fresh.stats.eff_serial_evals,
            "the cached spine must shorten the serial path ({} vs {})",
            warm.stats.eff_serial_evals,
            fresh.stats.eff_serial_evals
        );
        let agg = r.stats();
        assert_eq!(agg.cache_hits, 1, "exactly the repeat hits");
        assert_eq!(agg.cache_misses, 1, "exactly the first run misses");
        // A different spec must not be hijacked by the hint table.
        let other = SamplerSpec::srds(34).with_tol(1e-4).with_seed(801);
        let out = r.run(&prior_sample(64, 801), &other);
        assert_eq!(out.sample, other.run(&native_backend(), &prior_sample(64, 801)).sample);
    }

    #[test]
    fn serving_submits_stream_and_time_out_through_placement() {
        // submit_serving through a 2-shard fleet: a streamed SRDS run
        // fans out its iterates and finishes bit-identically to the
        // vanilla run, and a timed-out sequential run resolves with an
        // explicit TimedOut against the fleet-aggregated stats.
        let r = router(2, 1, true);
        let x0 = prior_sample(64, 900);
        let spec = SamplerSpec::srds(25).with_tol(0.0).with_max_iters(3).with_seed(900);
        let (ev_tx, ev_rx) = channel();
        let (tx, rx) = channel();
        r.submit_serving(
            x0.clone(),
            spec.clone().with_stream(),
            None,
            Some(Box::new(move |ev| {
                let _ = ev_tx.send(ev);
            })),
            move |reply, agg| {
                let _ = tx.send((reply, agg));
            },
        );
        let (reply, agg) = rx.recv().expect("serving reply");
        let TaskReply::Done(out) = reply else { panic!("streamed run must finish") };
        assert_eq!(out.sample, spec.run(&native_backend(), &x0).sample);
        assert_eq!(ev_rx.try_iter().count(), out.stats.iters, "one event per iterate");
        assert_eq!(agg.shards, 2, "callback sees the fleet aggregate");
        let (tx, rx) = channel();
        r.submit_serving(
            prior_sample(64, 901),
            SamplerSpec::sequential(64).with_seed(901).with_timeout_ms(0),
            None,
            None,
            move |reply, agg| {
                let _ = tx.send((reply, agg));
            },
        );
        let (reply, agg) = rx.recv().expect("serving reply");
        assert!(matches!(reply, TaskReply::TimedOut));
        assert_eq!(agg.per_class.iter().map(|l| l.aborted).sum::<u64>(), 1);
    }

    #[test]
    fn single_shard_router_is_a_plain_engine() {
        let r = router(1, 2, true);
        let x0 = prior_sample(64, 700);
        let spec = SamplerSpec::srds(25).with_tol(1e-4).with_seed(700);
        let out = r.run(&x0, &spec);
        let want = {
            let model: Arc<dyn crate::model::EpsModel> =
                Arc::new(GmmEps::new(make_gmm("church")));
            let eng = Engine::new(
                Arc::new(NativeFactory::new(model, Solver::Ddim)),
                EngineConfig { workers: 2, ..EngineConfig::default() },
            );
            eng.run(&x0, &spec)
        };
        assert_eq!(out.sample, want.sample);
        let st = r.stats();
        assert_eq!(st.shards, 1);
        assert_eq!(st.steals, 0, "a 1-shard mesh has nobody to steal from");
        assert_eq!(st.workers, 2);
    }
}
