//! Engine-native sampler tasks: every registered sampler as a
//! dependency-driven state machine the multi-tenant engine can drive.
//!
//! The paper's Parareal framing treats SRDS, ParaDiGMS and ParaTAA as
//! interchangeable trajectory-parallel iterations over the same ODE, and
//! its §3.4/§3.5 pipelining argument applies to any of them. This module
//! is that framing on the serving path: a [`SamplerTask`] is an
//! object-safe state machine that *emits* step rows ([`TaskRow`]) and
//! *absorbs* their results ([`Completion`]), so the engine's dispatcher
//! can interleave many heterogeneous requests over one worker pool — no
//! sampler ever occupies a thread of its own. (Before this layer
//! existed, only SRDS ran dispatcher-resident; sequential / ParaDiGMS /
//! ParaTAA each blocked a dedicated OS thread inside an adapter
//! `StepBackend`, which capped concurrency at thread-spawn scale.)
//!
//! The four registry samplers map onto the trait naturally:
//!
//! * [`SrdsTask`] — the reference implementation: the Fig. 4 pipelined
//!   dataflow as event handlers over the iteration × block grid (a fine
//!   block solve is a chain of single-step rows, a coarse step one
//!   urgent row, each completion unblocks exactly the O(1) cells it
//!   can).
//! * [`SeqTask`] — a trivial one-row chain: emit step `i+1` when step
//!   `i` lands.
//! * [`ParadigmsTask`] — the windowed Picard sweep emits a whole
//!   window's rows at once (its natural parallel shape); when the last
//!   row of the sweep lands it runs the prefix-sum rebuild and emits the
//!   next window.
//! * [`ParataaTask`] — the Anderson fixed-point emits one full
//!   trajectory sweep per iteration and mixes via the shared
//!   [`AndersonMixer`] when the sweep completes.
//!
//! Each task owns its pooled [`StateBuf`] state (grids, trajectories,
//! sweep staging) and its `RunStats` accounting; emitted rows *share*
//! task-resident buffers by refcount, never copy them. The numerical
//! kernels (SRDS's Eq. 6 corrector, ParaDiGMS's Picard point update,
//! ParaTAA's Anderson mix) are the same functions the vanilla
//! coordinator samplers call, so a task's output is bit-identical to its
//! solo vanilla run — pinned by the drive-harness tests below and the
//! engine's mixed-fleet tests.

use crate::buf::{BufPool, StateBuf};
use crate::coordinator::paradigms::picard_point_update;
use crate::coordinator::parataa::AndersonMixer;
use crate::coordinator::sequential::chain_stats;
use crate::coordinator::srds::corrector;
use crate::coordinator::{IterStat, RunStats, SampleOutput, SamplerKind, SamplerSpec};
use crate::schedule::{Grid, Partition};
use std::collections::HashMap;
use std::time::Instant;

/// One row of step work a task wants executed. `key` is task-local (the
/// engine echoes it back in the matching [`Completion`]); `x` is a
/// refcounted share of task-resident state, not a copy. `urgent` rows
/// enter their batcher's head region (the SRDS coarse spine, Prop. 2).
/// The request-wide mask / guidance / seed are attached by the engine
/// from the task's spec.
pub struct TaskRow {
    pub key: u64,
    pub x: StateBuf,
    pub s_from: f32,
    pub s_to: f32,
    pub urgent: bool,
}

/// One completed row, handed back to the task that emitted it.
/// `batch_rows` is the size of the fused batch the row rode in (the
/// per-request `batch_occupancy` accounting).
pub struct Completion {
    pub key: u64,
    pub out: StateBuf,
    pub batch_rows: usize,
}

/// One completed refinement iterate, published while the task is still
/// running — the paper's §4 anytime property on the wire: every Parareal
/// iterate is a valid approximate sample, so a streaming client can use
/// `sample` the moment it lands. `sample` is a refcount share of the
/// task's grid cell (grid cells are written once, then read-only), never
/// a copy; serializing or dropping it on another thread is safe and
/// recycles into the engine pool as usual.
#[derive(Clone)]
pub struct IterateEvent {
    /// Refinement iteration this sample belongs to (1-based, Alg. 1 `p`).
    pub iter: usize,
    /// Convergence-norm distance to the previous iterate.
    pub residual: f32,
    /// The iterate's final state `x^p(s = 1)`.
    pub sample: StateBuf,
}

/// A sampling request as a dependency-driven state machine. The engine's
/// dispatcher drives the lifecycle: [`SamplerTask::start`] once, then
/// [`SamplerTask::poll`] with each batch of completed rows until
/// [`SamplerTask::finished`], then [`SamplerTask::finalize`] for the
/// [`SampleOutput`]. Hooks run on the dispatcher thread and must not
/// block; heavy lifting belongs in the rows they emit.
pub trait SamplerTask: Send {
    /// Emit the rows the initial state unblocks. Called exactly once.
    fn start(&mut self) -> Vec<TaskRow>;

    /// Absorb completed rows and emit the follow-up rows they unblock.
    /// An empty return with [`SamplerTask::finished`] still false means
    /// other rows of this task are still in flight.
    fn poll(&mut self, done: Vec<Completion>) -> Vec<TaskRow>;

    /// Whether the task can produce its final answer now.
    fn finished(&self) -> bool;

    /// Rows already handed to workers when the task finished (possible
    /// only for speculative samplers); their model evals are attributed
    /// to this request even though the results will be discarded.
    fn charge_stray_rows(&mut self, _rows: u64) {}

    /// Drain iterate-completion events recorded since the last drain.
    /// The dispatcher calls this after every [`SamplerTask::poll`] and
    /// fans the events out to streaming followers. Only kinds with the
    /// anytime anchor publish anything; the default is no progress.
    fn take_progress(&mut self) -> Vec<IterateEvent> {
        Vec::new()
    }

    /// Wall-clock timeout (`SamplerSpec::timeout_ms`) fired: if this
    /// task can finalize early from already-completed work — SRDS
    /// truncating to its newest completed iterate, exactly like the
    /// `deadline_evals` path — it arranges that and returns `true`; the
    /// dispatcher then lets the chosen iterate's in-flight rows land and
    /// finalizes normally. `false` (the default) means the kind has no
    /// valid early answer and the dispatcher must fail the request
    /// instead.
    fn force_finish(&mut self) -> bool {
        false
    }

    /// Harvest the reusable serial prefix of a finished task: for SRDS,
    /// the iteration-0 coarse boundary states `G(x_0), …, G(x_{M-1})` —
    /// refcount shares of the grid cells, never copies. The engine calls
    /// this right before [`SamplerTask::finalize`] to stock its
    /// coarse-spine cache; a later identical request hands the vector to
    /// [`new_warm_task`] and skips the one serial sweep Parareal cannot
    /// parallelize. Kinds with no cacheable spine return `None` (the
    /// default).
    fn take_spine(&mut self) -> Option<Vec<StateBuf>> {
        None
    }

    /// Consume the task into its output. Only called after
    /// [`SamplerTask::finished`] returns true.
    fn finalize(self: Box<Self>) -> SampleOutput;
}

/// Build the engine-resident task for `spec.kind` — the task-table
/// analogue of [`crate::coordinator::registry`]. `pool` is the engine's
/// shared slab pool (the task's grids and sweep rows draw from and
/// recycle into it) and `epc` the backend's evals per step.
pub fn new_task(x0: &[f32], spec: &SamplerSpec, pool: &BufPool, epc: u64) -> Box<dyn SamplerTask> {
    match spec.kind {
        SamplerKind::Sequential => Box::new(SeqTask::new(x0, spec.clone(), pool.clone(), epc)),
        SamplerKind::Srds => Box::new(SrdsTask::new(x0, spec.clone(), pool.clone(), epc)),
        SamplerKind::Paradigms { .. } => {
            Box::new(ParadigmsTask::new(x0, spec.clone(), pool.clone(), epc))
        }
        SamplerKind::Parataa { .. } => {
            Box::new(ParataaTask::new(x0, spec.clone(), pool.clone(), epc))
        }
    }
}

/// [`new_task`], warm-started from a cached coarse spine (the vector a
/// previous identical run returned from [`SamplerTask::take_spine`]).
/// The spine's `StateBuf`s are shared by refcount into the new task's
/// iteration-0 grid row, so the task emits **zero** coarse-spine rows
/// and opens the full iteration-1 wavefront immediately; its
/// `eff_serial_evals` drops by the skipped sweep. Bit-identity with a
/// fresh run holds because the cached states are exactly the values the
/// fresh spine computes. Falls back to a cold [`new_task`] when the
/// spine does not fit the spec (wrong kind, wrong block count) — the
/// caller's cache key should make that unreachable, but a stale entry
/// must degrade to a correct fresh run, never a wrong warm one.
pub fn new_warm_task(
    x0: &[f32],
    spec: &SamplerSpec,
    pool: &BufPool,
    epc: u64,
    spine: Vec<StateBuf>,
) -> Box<dyn SamplerTask> {
    if matches!(spec.kind, SamplerKind::Srds)
        && spine.len() == spec.partition().num_blocks()
        && spine.iter().all(|b| b.len() == x0.len())
    {
        Box::new(SrdsTask::new(x0, spec.clone(), pool.clone(), epc).with_spine(spine))
    } else {
        new_task(x0, spec, pool, epc)
    }
}

/// Per-request fusion accounting every task keeps: rows completed and
/// the mean batch occupancy they rode in.
#[derive(Default)]
struct RowMeter {
    rows: u64,
    occ_sum: u64,
}

impl RowMeter {
    fn note(&mut self, batch_rows: usize) {
        self.rows += 1;
        self.occ_sum += batch_rows as u64;
    }

    fn occupancy(&self) -> f64 {
        self.occ_sum as f64 / self.rows.max(1) as f64
    }
}

// ---------------------------------------------------------------------
// Sequential: a one-row chain.
// ---------------------------------------------------------------------

/// The `N`-step baseline as a task: one row in flight at any moment,
/// each completion feeding the next step — the engine-native form of
/// [`crate::coordinator::sequential`]. Its rows still fuse into
/// co-tenant batches, so even baseline traffic fills worker batches.
struct SeqTask {
    spec: SamplerSpec,
    pool: BufPool,
    epc: u64,
    grid: Grid,
    n: usize,
    x0: Option<StateBuf>,
    last: Option<StateBuf>,
    step: usize,
    meter: RowMeter,
    t0: Instant,
}

impl SeqTask {
    fn new(x0: &[f32], spec: SamplerSpec, pool: BufPool, epc: u64) -> SeqTask {
        let n = spec.n;
        let x0 = pool.take(x0);
        SeqTask {
            spec,
            pool,
            epc,
            grid: Grid::new(n),
            n,
            x0: Some(x0),
            last: None,
            step: 0,
            meter: RowMeter::default(),
            t0: Instant::now(),
        }
    }
}

impl SamplerTask for SeqTask {
    fn start(&mut self) -> Vec<TaskRow> {
        // n >= 1 is a Grid invariant, so the chain always has a head.
        let x0 = self.x0.take().expect("start called once");
        vec![TaskRow {
            key: 0,
            x: x0,
            s_from: self.grid.s(0),
            s_to: self.grid.s(1),
            urgent: false,
        }]
    }

    fn poll(&mut self, done: Vec<Completion>) -> Vec<TaskRow> {
        let mut rows = Vec::new();
        for c in done {
            self.meter.note(c.batch_rows);
            self.step += 1;
            if self.step < self.n {
                rows.push(TaskRow {
                    key: self.step as u64,
                    x: c.out,
                    s_from: self.grid.s(self.step),
                    s_to: self.grid.s(self.step + 1),
                    urgent: false,
                });
            } else {
                self.last = Some(c.out);
            }
        }
        rows
    }

    fn finished(&self) -> bool {
        self.last.is_some()
    }

    fn finalize(self: Box<Self>) -> SampleOutput {
        // Copy the final state out (never steal the slab — see
        // SrdsTask::finalize on why egress copies keep the engine pool
        // steady-state-allocation-free).
        let sample = self.last.as_ref().expect("chain complete").to_vec();
        let ps = self.pool.stats();
        let mut stats = chain_stats(self.n, self.epc);
        stats.wall = self.t0.elapsed();
        stats.batch_occupancy = self.meter.occupancy();
        stats.engine_rows = self.meter.rows;
        stats.pool_hits = ps.hits;
        stats.pool_misses = ps.misses;
        let iterates = if self.spec.keep_iterates { vec![sample.clone()] } else { vec![] };
        SampleOutput { sample, stats, iterates }
    }
}

// ---------------------------------------------------------------------
// SRDS: the dependency-driven grid state machine (the reference task).
// ---------------------------------------------------------------------

/// A fine block solve in flight: the chain of single-step rows walking
/// `points`. `next` is the window index of the row currently queued or
/// executing.
struct FineChain {
    points: Vec<f32>,
    next: usize,
}

/// Row keys pack the grid cell: `(p, i, is_fine)` as
/// `(p << 33) | (i << 1) | is_fine`. The packing is a stable contract —
/// `tests/cache_identity.rs` decodes emitted keys to count coarse-spine
/// rows (`p == 0`, `is_fine == false`) and pin that warm starts emit
/// none.
fn srds_key(p: usize, i: usize, fine: bool) -> u64 {
    ((p as u64) << 33) | ((i as u64) << 1) | fine as u64
}

fn srds_key_parts(key: u64) -> (usize, usize, bool) {
    ((key >> 33) as usize, ((key >> 1) & 0xFFFF_FFFF) as usize, key & 1 == 1)
}

/// Dependency-driven SRDS state machine for one request — the Fig. 4
/// pipelined dataflow of `measured_pipelined_srds`, expressed as event
/// handlers so the dispatcher can interleave many of them.
///
/// Every cell of the `x`/`g`/`y` grids is a pooled [`StateBuf`]; cells
/// are written once (by a worker or the corrector) and shared read-only
/// from then on — emitting a follow-up row or reusing a coarse result
/// as the next iteration's `prev` is a refcount bump.
struct SrdsTask {
    spec: SamplerSpec,
    pool: BufPool,
    epc: u64,
    part: Partition,
    m: usize,
    max_iters: usize,
    x0: Option<StateBuf>,
    x: Vec<Vec<Option<StateBuf>>>,
    g: Vec<Vec<Option<StateBuf>>>,
    y: Vec<Vec<Option<StateBuf>>>,
    submitted: Vec<Vec<[bool; 2]>>,
    /// Iteration-0 grid row was prefilled from a cached spine: `start`
    /// emits no `p = 0` coarse rows and `finalize` drops the skipped
    /// sweep from the serial-work accounting.
    warm: bool,
    fines: HashMap<(usize, usize), FineChain>,
    per_iter: Vec<IterStat>,
    stop_at_iter: Option<usize>,
    /// The anytime eval budget fired: refinement was truncated to the
    /// best completed iterate (see [`SrdsTask::check_deadline`]).
    deadline_hit: bool,
    /// The wall-clock timeout fired and actually truncated refinement
    /// (see [`SamplerTask::force_finish`]).
    timed_out: bool,
    /// Iterate completions recorded since the last `take_progress` drain
    /// — only populated when `spec.stream` asks for them.
    progress: Vec<IterateEvent>,
    inflight_rows: usize,
    total_evals: u64,
    meter: RowMeter,
    t0: Instant,
}

impl SrdsTask {
    fn new(x0: &[f32], spec: SamplerSpec, pool: BufPool, epc: u64) -> SrdsTask {
        let part = spec.partition();
        let m = part.num_blocks();
        let max_iters = spec.max_iters.unwrap_or(m).max(1).min(m);
        let x0 = pool.take(x0);
        SrdsTask {
            spec,
            pool,
            epc,
            part,
            m,
            max_iters,
            x0: Some(x0),
            x: vec![vec![None; m + 1]; max_iters + 1],
            g: vec![vec![None; m + 1]; max_iters + 1],
            y: vec![vec![None; m + 1]; max_iters + 1],
            submitted: vec![vec![[false; 2]; m + 1]; max_iters + 1],
            warm: false,
            fines: HashMap::new(),
            per_iter: Vec::new(),
            stop_at_iter: None,
            deadline_hit: false,
            timed_out: false,
            progress: Vec::new(),
            inflight_rows: 0,
            total_evals: 0,
            meter: RowMeter::default(),
            t0: Instant::now(),
        }
    }

    /// Prefill the iteration-0 grid row from a cached coarse spine:
    /// `g[0][i]` (and therefore `x[0][i]` — the init boundary IS the
    /// coarse result) for every block, each a refcount share of the
    /// cached buffer. The cells are marked submitted so no `p = 0`
    /// coarse row is ever emitted for them. Caller guarantees
    /// `spine.len() == m` (checked in [`new_warm_task`]).
    fn with_spine(mut self, spine: Vec<StateBuf>) -> SrdsTask {
        debug_assert_eq!(spine.len(), self.m);
        for (j, s) in spine.into_iter().enumerate() {
            self.submitted[0][j + 1][0] = true;
            self.g[0][j + 1] = Some(s);
        }
        self.warm = true;
        self
    }

    /// Anytime refinement (the QoS deadline): once the request has spent
    /// its eval budget, stop refining and converge on the **newest
    /// iterate whose residual is already known** — iterations
    /// `1..=per_iter.len()` are recorded contiguously, so that is
    /// `per_iter.len()` (or 0, the coarse init, when no refinement has
    /// completed yet). Every Parareal iterate is a valid approximate
    /// sample that only improves with `p` (paper §4), so truncation
    /// degrades quality gracefully rather than failing the request; the
    /// response stays honest via `converged: false` + the achieved
    /// residual + `deadline_hit`. Setting `stop_at_iter` both gates any
    /// further row emission (`past_stop`) and lets the engine purge this
    /// request's still-queued speculative rows at finalize. The chosen
    /// iterate's remaining rows (possibly the whole coarse spine, for a
    /// budget smaller than one sweep) still run: the budget is a target,
    /// not a hard wall — the request always returns a *valid* iterate.
    ///
    /// Runs after convergence bookkeeping, so a budget that fires on the
    /// same completion that reaches tolerance reports the genuine
    /// convergence, not a truncation.
    fn check_deadline(&mut self) {
        if self.stop_at_iter.is_some() || self.deadline_hit {
            return;
        }
        let Some(budget) = self.spec.deadline_evals else { return };
        if self.total_evals >= budget {
            // Only a real truncation is a hit: when every refinement
            // this run was going to do has already recorded its residual
            // (the budget expired during the speculative tail), stopping
            // changes nothing about the returned sample, and the
            // response must not claim degradation that never happened.
            if self.per_iter.len() < self.max_iters {
                self.deadline_hit = true;
            }
            self.stop_at_iter = Some(self.per_iter.len());
        }
    }

    fn emit_coarse(&mut self, p: usize, i: usize, x: StateBuf) -> TaskRow {
        self.inflight_rows += 1;
        TaskRow {
            key: srds_key(p, i, false),
            x,
            s_from: self.part.s_bound(i - 1),
            s_to: self.part.s_bound(i),
            // Coarse steps are the schedule's serial spine (Prop. 2) —
            // queued ahead of speculative fine work.
            urgent: true,
        }
    }

    fn emit_fine_start(&mut self, p: usize, i: usize, x: StateBuf) -> TaskRow {
        let points = self.part.block_points(i - 1).to_vec();
        let (s_from, s_to) = (points[0], points[1]);
        self.fines.insert((p, i), FineChain { points, next: 0 });
        self.inflight_rows += 1;
        TaskRow { key: srds_key(p, i, true), x, s_from, s_to, urgent: false }
    }

    /// Handle one completed row; pushes follow-up rows into `emits`.
    fn on_row(&mut self, c: Completion, emits: &mut Vec<TaskRow>) {
        self.inflight_rows -= 1;
        self.total_evals += self.epc;
        self.meter.note(c.batch_rows);
        let (p, i, is_fine) = srds_key_parts(c.key);
        let out = c.out;
        if is_fine {
            let chain = self.fines.get_mut(&(p, i)).expect("live fine chain");
            let last_window = chain.points.len() - 2;
            if chain.next < last_window {
                chain.next += 1;
                let (s_from, s_to) = (chain.points[chain.next], chain.points[chain.next + 1]);
                self.inflight_rows += 1;
                emits.push(TaskRow { key: c.key, x: out, s_from, s_to, urgent: false });
                return;
            }
            self.fines.remove(&(p, i));
            self.y[p][i] = Some(out);
        } else {
            self.g[p][i] = Some(out);
        }
        // Corrector attempts unblocked by this result: cell (p, i) and —
        // when a coarse result acts as `prev` — cell (p+1, i).
        let mut attempts = vec![(p, i)];
        if !is_fine && p + 1 <= self.max_iters {
            attempts.push((p + 1, i));
        }
        let mut ready: Vec<(usize, usize)> = Vec::new();
        for (ap, ai) in attempts {
            if self.x[ap][ai].is_some() {
                continue;
            }
            let materialized = if ap == 0 {
                // The init boundary IS the coarse result — share it.
                self.g[0][ai].clone()
            } else if let (Some(yi), Some(cur), Some(prev)) =
                (&self.y[ap][ai], &self.g[ap][ai], &self.g[ap - 1][ai])
            {
                // Eq. 6, via the same corrector the vanilla loop uses.
                let mut v = self.pool.get(yi.len());
                corrector(yi, cur, prev, v.as_mut_slice());
                Some(v)
            } else {
                None
            };
            if let Some(v) = materialized {
                self.x[ap][ai] = Some(v);
                ready.push((ap, ai));
            }
        }
        // Propagate each new state to the jobs it unblocks.
        while let Some((sp, si)) = ready.pop() {
            let stop = self.stop_at_iter;
            let past_stop = move |p: usize| stop.map(|s| p > s).unwrap_or(false);
            if si + 1 <= self.m
                && sp + 1 <= self.max_iters
                && !self.submitted[sp + 1][si + 1][1]
                && !past_stop(sp + 1)
            {
                self.submitted[sp + 1][si + 1][1] = true;
                let x = self.x[sp][si].clone().unwrap();
                emits.push(self.emit_fine_start(sp + 1, si + 1, x));
            }
            if si + 1 <= self.m && !self.submitted[sp][si + 1][0] && !past_stop(sp) {
                self.submitted[sp][si + 1][0] = true;
                let x = self.x[sp][si].clone().unwrap();
                emits.push(self.emit_coarse(sp, si + 1, x));
            }
            // Convergence: strictly in iteration order (a later final
            // state can exist before an earlier one).
            if si == self.m {
                while self.stop_at_iter.is_none() {
                    let pp = self.per_iter.len() + 1;
                    if pp > self.max_iters {
                        break;
                    }
                    let (Some(curf), Some(prevf)) = (&self.x[pp][self.m], &self.x[pp - 1][self.m])
                    else {
                        break;
                    };
                    let residual = self.spec.norm.dist(curf, prevf);
                    // Streaming: publish the iterate as a refcount share
                    // of the grid cell — the anytime sample, zero copies.
                    if self.spec.stream {
                        self.progress.push(IterateEvent { iter: pp, residual, sample: curf.clone() });
                    }
                    self.per_iter.push(IterStat { iter: pp, residual, evals: 0 });
                    if residual < self.spec.tol || pp >= self.m {
                        self.stop_at_iter = Some(pp);
                    }
                }
            }
        }
        // After convergence bookkeeping: genuine convergence on this
        // very completion wins over a simultaneous budget expiry.
        self.check_deadline();
    }
}

impl SamplerTask for SrdsTask {
    fn start(&mut self) -> Vec<TaskRow> {
        // Seed the prior states and kick off everything x0 unblocks:
        // G(p, 1) for every p (their input never changes) and F(p, 1) for
        // every refinement (its input x^{p-1}_0 = x0 is already final).
        // One pooled buffer, shared by refcount across every iteration's
        // x[p][0] and every seeded row.
        let x0 = self.x0.take().expect("start called once");
        for p in 0..=self.max_iters {
            self.x[p][0] = Some(x0.clone());
        }
        let mut emits = Vec::new();
        if self.warm {
            // Warm start: iteration 0 is already fully materialized from
            // the cached spine (`with_spine` filled `g[0][*]`), so the
            // init boundaries are final *now* — share them into `x[0][*]`
            // and emit no `p = 0` row at all. What a fresh run unlocks
            // one spine step at a time opens here all at once: the whole
            // iteration-1 fine wavefront plus each refinement's head.
            for i in 1..=self.m {
                self.x[0][i] = self.g[0][i].clone();
            }
            for p in 1..=self.max_iters {
                self.submitted[p][1][0] = true;
                let row = self.emit_coarse(p, 1, x0.clone());
                emits.push(row);
                self.submitted[p][1][1] = true;
                let row = self.emit_fine_start(p, 1, x0.clone());
                emits.push(row);
            }
            for i in 2..=self.m {
                self.submitted[1][i][1] = true;
                let x = self.x[0][i - 1].clone().expect("warm spine boundary");
                let row = self.emit_fine_start(1, i, x);
                emits.push(row);
            }
        } else {
            for p in 0..=self.max_iters {
                self.submitted[p][1][0] = true;
                let row = self.emit_coarse(p, 1, x0.clone());
                emits.push(row);
                if p >= 1 {
                    self.submitted[p][1][1] = true;
                    let row = self.emit_fine_start(p, 1, x0.clone());
                    emits.push(row);
                }
            }
        }
        emits
    }

    fn poll(&mut self, done: Vec<Completion>) -> Vec<TaskRow> {
        let mut emits = Vec::new();
        for c in done {
            self.on_row(c, &mut emits);
        }
        emits
    }

    /// Either the convergence test fired and the winning iterate exists,
    /// or no rows remain in flight (the speculative frontier ran dry).
    fn finished(&self) -> bool {
        match self.stop_at_iter {
            Some(s) => self.x[s][self.m].is_some(),
            None => self.inflight_rows == 0,
        }
    }

    fn charge_stray_rows(&mut self, rows: u64) {
        self.total_evals += rows * self.epc;
    }

    fn take_progress(&mut self) -> Vec<IterateEvent> {
        std::mem::take(&mut self.progress)
    }

    /// Wall-clock analogue of [`SrdsTask::check_deadline`]: converge on
    /// the newest iterate whose residual is already recorded (possibly
    /// the coarse init). Same honesty rule — `timed_out` is only set
    /// when the timeout actually truncated refinement; expiring during
    /// the speculative tail, or after convergence already fired, reports
    /// nothing. Always returns `true`: SRDS can finalize from any
    /// completed iterate.
    fn force_finish(&mut self) -> bool {
        if self.stop_at_iter.is_none() {
            if self.per_iter.len() < self.max_iters {
                self.timed_out = true;
            }
            self.stop_at_iter = Some(self.per_iter.len());
        }
        true
    }

    /// The iteration-0 boundary states, shared by refcount — for a warm
    /// task these are the very buffers the cache handed in, so
    /// re-stocking the cache refreshes recency without duplicating a
    /// single slab. `None` if the spine never completed (a task that
    /// finished without filling row 0 has nothing reusable).
    fn take_spine(&mut self) -> Option<Vec<StateBuf>> {
        (1..=self.m).map(|i| self.g[0][i].clone()).collect()
    }

    fn finalize(self: Box<Self>) -> SampleOutput {
        let final_iter = self.stop_at_iter.unwrap_or_else(|| {
            (1..=self.max_iters).rev().find(|&p| self.x[p][self.m].is_some()).unwrap_or(0)
        });
        // Copy the winning state out (one d-sized copy per request, at
        // egress) — deliberately NOT into_vec(): stealing the slab would
        // shrink the engine-wide pool by one buffer per completed
        // request and make pool_misses drift upward forever. Every grid
        // cell, this one included, recycles when the task drops below.
        let sample = self.x[final_iter][self.m].as_ref().expect("final state").to_vec();
        // The grid retains every iteration's final state, so iterates
        // cost nothing extra: the coarse init at index 0 plus one entry
        // per refinement — the same contract as the vanilla sampler.
        let iterates = if self.spec.keep_iterates {
            (0..=final_iter)
                .map(|p| {
                    self.x[p][self.m]
                        .as_ref()
                        .expect("grid filled through the final iterate")
                        .to_vec()
                })
                .collect()
        } else {
            vec![]
        };
        // Honest reporting under anytime truncation: a deadline-chosen
        // iterate keeps its recorded residual in `per_iter`, and the
        // flag below tells the client *why* `converged` is false.
        let converged = self
            .per_iter
            .iter()
            .find(|s| s.iter == final_iter)
            .map(|s| s.residual < self.spec.tol || final_iter >= self.m)
            .unwrap_or(false);
        let m = self.m as u64;
        let b = self.part.block() as u64;
        // Vanilla-schedule accounting, same formula as coordinator::srds:
        // the coarse init sweep (M), then per iteration the longest fine
        // block plus the sequential coarse sweep.
        let b_max = (0..self.m).map(|j| self.part.block_len(j)).max().unwrap_or(0) as u64;
        let iters = final_iter as u64;
        let epc = self.epc;
        // A warm start consumed a cached spine instead of running the
        // init sweep, so the leading M drops out of the serial-work
        // account (and a converged-at-init warm run did no evals at
        // all). The per-iteration terms are identical: refinement work
        // does not change, only the serial prefix is skipped.
        let spine = if self.warm { 0 } else { m };
        let eff_serial = (spine + iters * (b_max + m)) * epc;
        let eff_pipelined = if final_iter == 0 {
            spine * epc
        } else {
            (m * iters + b).saturating_sub(iters) * epc
        };
        let ps = self.pool.stats();
        let stats = RunStats {
            iters: final_iter,
            converged,
            deadline_hit: self.deadline_hit,
            timed_out: self.timed_out,
            eff_serial_evals: eff_serial,
            eff_serial_evals_pipelined: eff_pipelined,
            total_evals: self.total_evals,
            wall: self.t0.elapsed(),
            // The task materializes the full (iterations × blocks) grid
            // of x/G/F states — wall-clock-optimal, not memory-optimal.
            peak_states: 3 * (self.max_iters + 1) * (self.m + 1),
            batch_occupancy: self.meter.occupancy(),
            engine_rows: self.meter.rows,
            // Engine-wide pool snapshot at completion: across a steady
            // request stream, successive responses show flat misses.
            pool_hits: ps.hits,
            pool_misses: ps.misses,
            per_iter: self.per_iter,
        };
        SampleOutput { sample, stats, iterates }
    }
}

// ---------------------------------------------------------------------
// ParaDiGMS: whole-window Picard sweeps.
// ---------------------------------------------------------------------

/// The windowed Picard sweep as a task: each sweep emits every window
/// point's row at once — the sampler's natural parallel shape, which the
/// retired adapter used to serialize through blocking `step()` calls.
/// When the last row of the sweep lands, the prefix-sum rebuild runs
/// (via the shared [`picard_point_update`]) and the next window is
/// emitted.
struct ParadigmsTask {
    spec: SamplerSpec,
    pool: BufPool,
    epc: u64,
    grid: Grid,
    n: usize,
    window: usize,
    max_sweeps: usize,
    /// Trajectory x[0..=n]; ParaDiGMS initializes every point to x0.
    x: Vec<StateBuf>,
    acc: Vec<f32>,
    lo: usize,
    sweeps: usize,
    sweep_lo: usize,
    sweep_hi: usize,
    /// Pre-sweep window inputs (refcount shares — the drift rebuild
    /// needs them after the grid slots are replaced).
    sweep_in: Vec<StateBuf>,
    sweep_out: Vec<Option<StateBuf>>,
    remaining: usize,
    total_evals: u64,
    per_iter: Vec<IterStat>,
    iterates: Vec<Vec<f32>>,
    done: bool,
    meter: RowMeter,
    t0: Instant,
}

impl ParadigmsTask {
    fn new(x0: &[f32], spec: SamplerSpec, pool: BufPool, epc: u64) -> ParadigmsTask {
        let n = spec.n;
        let window = spec.window().unwrap_or(n).max(1);
        let max_sweeps = spec.max_iters.unwrap_or(8 * n).max(1);
        let x: Vec<StateBuf> = (0..=n).map(|_| pool.take(x0)).collect();
        ParadigmsTask {
            spec,
            pool,
            epc,
            grid: Grid::new(n),
            n,
            window,
            max_sweeps,
            x,
            acc: vec![0.0f32; x0.len()],
            lo: 0,
            sweeps: 0,
            sweep_lo: 0,
            sweep_hi: 0,
            sweep_in: Vec::new(),
            sweep_out: Vec::new(),
            remaining: 0,
            total_evals: 0,
            per_iter: Vec::new(),
            iterates: Vec::new(),
            done: false,
            meter: RowMeter::default(),
            t0: Instant::now(),
        }
    }

    fn emit_sweep(&mut self) -> Vec<TaskRow> {
        self.sweep_lo = self.lo;
        self.sweep_hi = (self.lo + self.window).min(self.n);
        let count = self.sweep_hi - self.sweep_lo;
        self.sweep_in.clear();
        self.sweep_out.clear();
        self.sweep_out.resize_with(count, || None);
        self.remaining = count;
        let mut rows = Vec::with_capacity(count);
        for j in self.sweep_lo..self.sweep_hi {
            // Two refcount shares of the grid cell: one pinned as the
            // pre-sweep input for the drift rebuild, one riding the row.
            self.sweep_in.push(self.x[j].clone());
            rows.push(TaskRow {
                key: j as u64,
                x: self.x[j].clone(),
                s_from: self.grid.s(j),
                s_to: self.grid.s(j + 1),
                urgent: false,
            });
        }
        rows
    }

    fn process_sweep(&mut self) -> Vec<TaskRow> {
        let (lo, hi) = (self.sweep_lo, self.sweep_hi);
        let rows = hi - lo;
        self.total_evals += rows as u64 * self.epc;
        self.sweeps += 1;
        let tol2 = self.spec.tol; // squared-error threshold (module docs)

        // Prefix-sum rebuild + per-point error, exactly the vanilla
        // sweep: drift reads the staged pre-sweep inputs, the error
        // compares against the not-yet-replaced x[j+1], and replaced
        // slots are fresh pooled buffers (grid cells may still be shared
        // with in-flight row copies, so they are replaced, not mutated).
        self.acc.copy_from_slice(&self.sweep_in[0]);
        let mut first_unconverged = hi;
        let mut max_err = 0.0f32;
        for j in lo..hi {
            let slot = j - lo;
            let phi = self.sweep_out[slot].as_ref().expect("sweep complete");
            let err = picard_point_update(&mut self.acc, phi, &self.sweep_in[slot], &self.x[j + 1]);
            max_err = max_err.max(err);
            self.x[j + 1] = self.pool.take(&self.acc);
            if err > tol2 && first_unconverged == hi {
                first_unconverged = j;
            }
        }
        // Advance past converged prefix (always ≥ 1 to guarantee
        // progress, mirroring the vanilla sampler).
        let stride = (first_unconverged - lo).max(1);
        self.per_iter.push(IterStat {
            iter: self.sweeps,
            residual: max_err.sqrt(),
            evals: rows as u64 * self.epc,
        });
        if self.spec.keep_iterates {
            self.iterates.push(self.x[self.n].to_vec());
        }
        self.lo += stride;
        self.sweep_in.clear();
        self.sweep_out.clear();
        if self.lo < self.n && self.sweeps < self.max_sweeps {
            self.emit_sweep()
        } else {
            self.done = true;
            vec![]
        }
    }
}

impl SamplerTask for ParadigmsTask {
    fn start(&mut self) -> Vec<TaskRow> {
        // n >= 1 (Grid invariant) and lo starts at 0, so the first
        // window is never empty.
        self.emit_sweep()
    }

    fn poll(&mut self, done: Vec<Completion>) -> Vec<TaskRow> {
        for c in done {
            self.meter.note(c.batch_rows);
            let slot = c.key as usize - self.sweep_lo;
            self.sweep_out[slot] = Some(c.out);
            self.remaining -= 1;
        }
        if self.remaining == 0 && !self.done {
            self.process_sweep()
        } else {
            vec![]
        }
    }

    fn finished(&self) -> bool {
        self.done
    }

    fn finalize(self: Box<Self>) -> SampleOutput {
        let sample = self.x[self.n].to_vec();
        let ps = self.pool.stats();
        let stats = RunStats {
            iters: self.sweeps,
            converged: self.lo >= self.n,
            // ParaDiGMS ignores the anytime budget: its sliding-window
            // Picard truncation has no serial-equivalence anchor — a
            // half-converged window is not a valid sample of anything.
            // (Same for the wall-clock timeout: the dispatcher fails the
            // request instead of truncating, see `force_finish`.)
            deadline_hit: false,
            timed_out: false,
            eff_serial_evals: self.sweeps as u64 * self.epc,
            eff_serial_evals_pipelined: self.sweeps as u64 * self.epc,
            total_evals: self.total_evals,
            wall: self.t0.elapsed(),
            // The window of live trajectory states plus the window
            // anchor — the O(window) memory of the §3.6 comparison.
            peak_states: self.window.min(self.n) + 1,
            batch_occupancy: self.meter.occupancy(),
            engine_rows: self.meter.rows,
            pool_hits: ps.hits,
            pool_misses: ps.misses,
            per_iter: self.per_iter,
        };
        SampleOutput { sample, stats, iterates: self.iterates }
    }
}

// ---------------------------------------------------------------------
// ParaTAA: whole-trajectory fixed-point sweeps with Anderson mixing.
// ---------------------------------------------------------------------

/// The Anderson fixed-point as a task: each iteration emits one full
/// trajectory sweep (`n` rows at once); when the sweep completes, the
/// residual check and the shared [`AndersonMixer`] update run, and the
/// next sweep is emitted.
struct ParataaTask {
    spec: SamplerSpec,
    pool: BufPool,
    epc: u64,
    n: usize,
    d: usize,
    max_iters: usize,
    s_from: Vec<f32>,
    s_to: Vec<f32>,
    /// Stacked trajectory iterate (n+1, d), flat.
    x: Vec<f32>,
    tx: Vec<f32>,
    r: Vec<f32>,
    mixer: AndersonMixer,
    /// 1-based iteration currently in flight.
    k: usize,
    sweep_out: Vec<Option<StateBuf>>,
    remaining: usize,
    total_evals: u64,
    per_iter: Vec<IterStat>,
    iterates: Vec<Vec<f32>>,
    converged: bool,
    iters: usize,
    done: bool,
    meter: RowMeter,
    t0: Instant,
}

impl ParataaTask {
    fn new(x0: &[f32], spec: SamplerSpec, pool: BufPool, epc: u64) -> ParataaTask {
        let n = spec.n;
        let d = x0.len();
        let len = (n + 1) * d;
        let grid = Grid::new(n);
        let max_iters = spec.max_iters.unwrap_or(2 * n).max(1);
        let history = spec.history();
        // Initialize the trajectory at the prior (as ParaDiGMS does).
        let mut x = vec![0.0f32; len];
        for i in 0..=n {
            x[i * d..(i + 1) * d].copy_from_slice(x0);
        }
        ParataaTask {
            spec,
            pool,
            epc,
            n,
            d,
            max_iters,
            s_from: (0..n).map(|i| grid.s(i)).collect(),
            s_to: (0..n).map(|i| grid.s(i + 1)).collect(),
            x,
            tx: vec![0.0f32; len],
            r: vec![0.0f32; len],
            mixer: AndersonMixer::new(history, len),
            k: 1,
            sweep_out: Vec::new(),
            remaining: 0,
            total_evals: 0,
            per_iter: Vec::new(),
            iterates: Vec::new(),
            converged: false,
            iters: 0,
            done: false,
            meter: RowMeter::default(),
            t0: Instant::now(),
        }
    }

    fn emit_sweep(&mut self) -> Vec<TaskRow> {
        let d = self.d;
        self.sweep_out.clear();
        self.sweep_out.resize_with(self.n, || None);
        self.remaining = self.n;
        (0..self.n)
            .map(|j| TaskRow {
                key: j as u64,
                // The trajectory is one flat vector; each emitted row
                // takes a pooled d-sized copy of its point (recycled
                // every sweep once the pool is warm).
                x: self.pool.take(&self.x[j * d..(j + 1) * d]),
                s_from: self.s_from[j],
                s_to: self.s_to[j],
                urgent: false,
            })
            .collect()
    }

    fn process_sweep(&mut self) -> Vec<TaskRow> {
        let (n, d) = (self.n, self.d);
        // Assemble T(X): T(X)_0 = x_0, T(X)_{j+1} = Φ(X_j).
        self.tx[..d].copy_from_slice(&self.x[..d]);
        for (j, out) in self.sweep_out.drain(..).enumerate() {
            let out = out.expect("sweep complete");
            self.tx[(j + 1) * d..(j + 2) * d].copy_from_slice(&out);
        }
        self.total_evals += n as u64 * self.epc;
        for t in 0..self.x.len() {
            self.r[t] = self.tx[t] - self.x[t];
        }

        // Residual on the final sample only (the SRDS criterion).
        let final_res = self.spec.norm.dist(&self.tx[n * d..], &self.x[n * d..]);
        self.iters = self.k;
        self.per_iter.push(IterStat {
            iter: self.k,
            residual: final_res,
            evals: n as u64 * self.epc,
        });

        if final_res < self.spec.tol {
            self.x.copy_from_slice(&self.tx);
            if self.spec.keep_iterates {
                self.iterates.push(self.x[n * d..].to_vec());
            }
            self.converged = true;
            self.done = true;
            return vec![];
        }

        self.mixer.advance(self.k, n, d, &mut self.x, &self.tx, &self.r, &self.pool);
        if self.spec.keep_iterates {
            self.iterates.push(self.x[n * d..].to_vec());
        }
        self.k += 1;
        if self.k <= self.max_iters {
            self.emit_sweep()
        } else {
            self.done = true;
            vec![]
        }
    }
}

impl SamplerTask for ParataaTask {
    fn start(&mut self) -> Vec<TaskRow> {
        // n >= 1 is a Grid invariant; the first sweep is never empty.
        self.emit_sweep()
    }

    fn poll(&mut self, done: Vec<Completion>) -> Vec<TaskRow> {
        for c in done {
            self.meter.note(c.batch_rows);
            self.sweep_out[c.key as usize] = Some(c.out);
            self.remaining -= 1;
        }
        if self.remaining == 0 && !self.done {
            self.process_sweep()
        } else {
            vec![]
        }
    }

    fn finished(&self) -> bool {
        self.done
    }

    fn finalize(self: Box<Self>) -> SampleOutput {
        let (n, d) = (self.n, self.d);
        let sample = self.x[n * d..].to_vec();
        let ps = self.pool.stats();
        let stats = RunStats {
            iters: self.iters,
            converged: self.converged,
            // Like ParaDiGMS, ParaTAA has no Parareal anytime guarantee
            // to truncate onto (an Anderson-mixed iterate is a solver
            // accelerant, not a serial-equivalent sample).
            deadline_hit: false,
            timed_out: false,
            eff_serial_evals: self.iters as u64 * self.epc,
            eff_serial_evals_pipelined: self.iters as u64 * self.epc,
            total_evals: self.total_evals,
            wall: self.t0.elapsed(),
            // Whole-trajectory iterate, its T-image, the residual, and
            // the Anderson history pairs — the O(N·history) memory of
            // §3.6.
            peak_states: (n + 1) * (3 + 2 * self.spec.history()),
            batch_occupancy: self.meter.occupancy(),
            engine_rows: self.meter.rows,
            pool_hits: ps.hits,
            pool_misses: ps.misses,
            per_iter: self.per_iter,
        };
        SampleOutput { sample, stats, iterates: self.iterates }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{prior_sample, registry, Conditioning};
    use crate::data::make_gmm;
    use crate::model::GmmEps;
    use crate::solvers::{NativeBackend, Solver, StepBackend, StepRequest};
    use std::sync::Arc;

    /// Synchronous single-row driver: exactly what the engine dispatcher
    /// does, minus threads and batching — every emitted row executes
    /// immediately, one backend call per row. Any interleaving the real
    /// dispatcher produces yields the same per-cell values (rows compute
    /// independently), so this is a valid execution of the task.
    fn drive(backend: &dyn StepBackend, x0: &[f32], spec: &SamplerSpec) -> SampleOutput {
        let pool = BufPool::new();
        let mut task = new_task(x0, spec, &pool, backend.evals_per_step() as u64);
        let mut rows = task.start();
        let mut steps = 0u64;
        while !rows.is_empty() {
            let done: Vec<Completion> = rows
                .drain(..)
                .map(|r| {
                    steps += 1;
                    assert!(steps < 2_000_000, "task runaway");
                    let mut out = pool.get(r.x.len());
                    backend.step_into(
                        &StepRequest {
                            x: &r.x,
                            s_from: &[r.s_from],
                            s_to: &[r.s_to],
                            mask: spec.cond.mask_slice(),
                            guidance: spec.cond.guidance,
                            seeds: &[spec.seed],
                        },
                        out.as_mut_slice(),
                    );
                    Completion { key: r.key, out, batch_rows: 1 }
                })
                .collect();
            rows = task.poll(done);
        }
        assert!(task.finished(), "no rows in flight but task not finished");
        task.finalize()
    }

    fn backend() -> NativeBackend {
        NativeBackend::new(Arc::new(GmmEps::new(make_gmm("church"))), Solver::Ddim)
    }

    #[test]
    fn every_task_is_bit_identical_to_its_vanilla_sampler() {
        // The tentpole invariant at its root: for each registry entry,
        // the engine-native task produces the exact sample, iteration
        // count and eval accounting of the direct coordinator run.
        let be = backend();
        let reg = registry();
        let x0 = prior_sample(64, 11);
        for name in reg.list() {
            let s = reg.parse(name).unwrap();
            let spec = SamplerSpec::for_kind(25, s.kind()).with_tol(1e-5).with_seed(11);
            let want = s.run(&be, &x0, &spec);
            let got = drive(&be, &x0, &spec);
            assert_eq!(got.sample, want.sample, "{name}: task vs vanilla sample");
            assert_eq!(got.stats.iters, want.stats.iters, "{name}: iters");
            assert_eq!(got.stats.converged, want.stats.converged, "{name}: converged");
            assert_eq!(
                got.stats.eff_serial_evals, want.stats.eff_serial_evals,
                "{name}: eff serial evals"
            );
            assert!(got.stats.engine_rows > 0, "{name}: no engine rows metered");
            assert!(got.stats.batch_occupancy >= 1.0, "{name}: occupancy");
        }
    }

    #[test]
    fn srds_task_exact_after_worst_case_iterations() {
        // Prop. 1 through the task path: τ = 0 forces all M iterations
        // and the result equals the sequential solve bit-for-bit.
        let be = backend();
        let x0 = prior_sample(64, 3);
        let n = 16;
        let (seq, _) =
            crate::coordinator::sequential(&be, &x0, n, &Conditioning::none(), 3);
        let spec = SamplerSpec::srds(n).with_tol(0.0).with_max_iters(4).with_seed(3);
        let got = drive(&be, &x0, &spec);
        assert_eq!(got.sample, seq);
        assert_eq!(got.stats.iters, 4);
    }

    #[test]
    fn srds_task_records_iterates_natively() {
        // keep_iterates no longer needs an off-engine fallback: the task
        // grid already retains every refinement's final state.
        let be = backend();
        let x0 = prior_sample(64, 21);
        let spec = SamplerSpec::srds(36)
            .with_tol(0.0)
            .with_max_iters(6)
            .with_iterates()
            .with_seed(21);
        let want = crate::coordinator::srds(&be, &x0, &spec);
        let got = drive(&be, &x0, &spec);
        assert_eq!(got.iterates.len(), got.stats.iters + 1, "coarse init + one per refinement");
        assert_eq!(got.iterates, want.iterates, "same iterate trail as vanilla");
        assert_eq!(got.iterates.last().unwrap(), &got.sample);
    }

    #[test]
    fn srds_deadline_truncates_to_last_completed_iterate() {
        // The anytime contract: a deadline-truncated SRDS run returns
        // exactly the iterate a full run would have produced at the same
        // refinement depth (the grid values are schedule-independent),
        // with honest converged/residual/deadline_hit reporting.
        let be = backend();
        let x0 = prior_sample(64, 13);
        let full_spec = SamplerSpec::srds(36)
            .with_tol(0.0)
            .with_max_iters(6)
            .with_iterates()
            .with_seed(13);
        let full = crate::coordinator::srds(&be, &x0, &full_spec);
        assert_eq!(full.iterates.len(), full.stats.iters + 1);

        let spec = SamplerSpec::srds(36)
            .with_tol(0.0)
            .with_max_iters(6)
            .with_deadline_evals(80)
            .with_seed(13);
        let got = drive(&be, &x0, &spec);
        assert!(got.stats.deadline_hit, "an 80-eval budget must fire on a tol=0 n=36 run");
        assert!(!got.stats.converged, "truncation is never reported as convergence");
        assert!(got.stats.iters < full.stats.iters, "refinement was actually cut short");
        // The returned sample IS iterate `iters` of the untruncated run.
        assert_eq!(
            got.sample, full.iterates[got.stats.iters],
            "anytime sample must be the exact early iterate"
        );
        // Residual honesty: the last recorded per-iter entry belongs to
        // the returned iterate and matches the full run's residual.
        if got.stats.iters > 0 {
            let last = got.stats.per_iter.last().unwrap();
            assert_eq!(last.iter, got.stats.iters);
            let want = &full.stats.per_iter[got.stats.iters - 1];
            assert_eq!(last.residual, want.residual, "achieved residual reported verbatim");
        }
    }

    #[test]
    fn srds_minimal_deadline_still_returns_the_coarse_init() {
        // A budget smaller than anything useful: the task still finishes
        // the coarse init sweep (iterate 0 — the smallest valid Parareal
        // sample) rather than returning garbage or hanging.
        let be = backend();
        let x0 = prior_sample(64, 17);
        let full = crate::coordinator::srds(
            &be,
            &x0,
            &SamplerSpec::srds(25).with_tol(0.0).with_max_iters(4).with_iterates().with_seed(17),
        );
        let spec = SamplerSpec::srds(25)
            .with_tol(0.0)
            .with_max_iters(4)
            .with_deadline_evals(1)
            .with_seed(17);
        let got = drive(&be, &x0, &spec);
        assert!(got.stats.deadline_hit);
        assert_eq!(got.stats.iters, 0, "nothing beyond the coarse init fits in 1 eval");
        assert!(!got.stats.converged);
        assert_eq!(got.sample, full.iterates[0], "iterate 0 is the coarse init");
    }

    #[test]
    fn budget_expiry_without_truncation_is_not_a_hit() {
        // The budget fires on the very last row of a capped run (budget
        // == the run's exact total evals): iterate max_iters is already
        // recorded, so nothing was actually cut — the sample matches the
        // unbudgeted run and deadline_hit must stay false (an honest
        // dashboard never counts phantom degradation).
        let be = backend();
        let x0 = prior_sample(64, 19);
        let plain = SamplerSpec::srds(36).with_tol(0.0).with_max_iters(2).with_seed(19);
        let full = drive(&be, &x0, &plain);
        let got = drive(&be, &x0, &plain.clone().with_deadline_evals(full.stats.total_evals));
        assert!(!got.stats.deadline_hit, "no refinement was lost — not a hit");
        assert_eq!(got.sample, full.sample);
        assert_eq!(got.stats.iters, full.stats.iters);
        assert_eq!(got.stats.converged, full.stats.converged);
    }

    #[test]
    fn no_deadline_runs_are_unchanged_and_other_kinds_ignore_it() {
        // deadline_evals: None must be byte-for-byte the pre-QoS
        // behavior, and a generous budget must never fire. Non-SRDS
        // kinds ignore the budget entirely (no anytime anchor).
        let be = backend();
        let x0 = prior_sample(64, 11);
        let spec = SamplerSpec::srds(25).with_tol(1e-5).with_seed(11);
        let want = drive(&be, &x0, &spec);
        let got = drive(&be, &x0, &spec.clone().with_deadline_evals(u64::MAX));
        assert_eq!(got.sample, want.sample);
        assert_eq!(got.stats.iters, want.stats.iters);
        assert!(!got.stats.deadline_hit);
        assert!(!want.stats.deadline_hit);
        for kind in ["sequential", "paradigms", "parataa"] {
            let s = registry().parse(kind).unwrap();
            let spec = SamplerSpec::for_kind(25, s.kind())
                .with_tol(1e-5)
                .with_deadline_evals(1)
                .with_seed(11);
            let got = drive(&be, &x0, &spec);
            let plain = SamplerSpec::for_kind(25, s.kind()).with_tol(1e-5).with_seed(11);
            let want = drive(&be, &x0, &plain);
            assert_eq!(got.sample, want.sample, "{kind}: deadline must be a no-op");
            assert!(!got.stats.deadline_hit, "{kind}: never reports a hit it can't honor");
        }
    }

    #[test]
    fn tasks_honor_kind_specific_knobs() {
        let be = backend();
        let x0 = prior_sample(64, 5);
        // Windowed ParaDiGMS through the task path.
        let spec = SamplerSpec::paradigms(64).with_tol(1e-4).with_window(16).with_seed(5);
        let want = crate::coordinator::paradigms(&be, &x0, &spec);
        let got = drive(&be, &x0, &spec);
        assert_eq!(got.sample, want.sample);
        assert_eq!(got.stats.peak_states, 17);
        // Plain-Picard ParaTAA (history 0) through the task path.
        let spec = SamplerSpec::parataa(32).with_history(0).with_tol(1e-4).with_seed(8);
        let want = crate::coordinator::parataa(&be, &x0, &spec);
        let got = drive(&be, &x0, &spec);
        assert_eq!(got.sample, want.sample);
        assert_eq!(got.stats.iters, want.stats.iters);
    }

    #[test]
    fn guided_tasks_match_guided_vanilla_runs() {
        // Conditioning flows through the task path: mask + guidance are
        // attached per row by the driver exactly as the engine does.
        let gmm = make_gmm("latent_cond");
        let mask = gmm.class_mask(2);
        let be = NativeBackend::new(Arc::new(GmmEps::new(gmm)), Solver::Ddim);
        let x0 = prior_sample(256, 2);
        let cond = Conditioning::class(mask, 7.5);
        for kind in ["sequential", "srds"] {
            let s = registry().parse(kind).unwrap();
            let spec = SamplerSpec::for_kind(25, s.kind())
                .with_tol(1e-6)
                .with_cond(cond.clone())
                .with_seed(2);
            let want = s.run(&be, &x0, &spec);
            let got = drive(&be, &x0, &spec);
            assert_eq!(got.sample, want.sample, "{kind} guided task vs vanilla");
        }
    }

    #[test]
    fn sequential_task_is_a_single_row_chain() {
        let be = backend();
        let x0 = prior_sample(64, 7);
        let pool = BufPool::new();
        let spec = SamplerSpec::sequential(10).with_seed(7);
        let mut task = new_task(&x0, &spec, &pool, 1);
        let rows = task.start();
        assert_eq!(rows.len(), 1, "a chain emits exactly one row at a time");
        let out = drive(&be, &x0, &spec);
        assert_eq!(out.stats.engine_rows, 10, "one engine row per fine step");
        assert_eq!(out.stats.total_evals, 10);
    }

    #[test]
    fn sweep_tasks_emit_whole_sweeps_at_once() {
        // The batched-row shape the adapter used to serialize: ParaDiGMS
        // emits its full window, ParaTAA its full trajectory.
        let x0 = prior_sample(64, 1);
        let pool = BufPool::new();
        let spec = SamplerSpec::paradigms(64).with_window(16).with_seed(1);
        assert_eq!(new_task(&x0, &spec, &pool, 1).start().len(), 16);
        let spec = SamplerSpec::parataa(25).with_seed(1);
        assert_eq!(new_task(&x0, &spec, &pool, 1).start().len(), 25);
        let spec = SamplerSpec::srds(25).with_seed(1);
        // SRDS seeds the coarse chain head plus every iteration's first
        // cells: (max_iters + 1) coarse rows + max_iters fine chains.
        assert_eq!(new_task(&x0, &spec, &pool, 1).start().len(), 11);
    }

    #[test]
    fn warm_spine_task_matches_fresh_bitwise_and_skips_the_spine() {
        // The spine-cache contract at its root: a task warm-started from
        // a previous run's harvested spine executes zero iteration-0
        // coarse rows, drops the skipped sweep from eff_serial_evals,
        // and still produces the bit-identical sample.
        let be = backend();
        let x0 = prior_sample(64, 21);
        let spec = SamplerSpec::srds(25).with_tol(1e-4).with_seed(21);
        let pool = BufPool::new();
        let epc = be.evals_per_step() as u64;

        // `drive`, plus a count of executed coarse-spine rows (decoding
        // the stable `srds_key` packing) and a spine harvest at the end.
        let run = |mut task: Box<dyn SamplerTask>| {
            let mut rows = task.start();
            let mut spine_rows = 0u64;
            while !rows.is_empty() {
                let done: Vec<Completion> = rows
                    .drain(..)
                    .map(|r| {
                        if (r.key >> 33) == 0 && r.key & 1 == 0 {
                            spine_rows += 1;
                        }
                        let mut out = pool.get(r.x.len());
                        be.step_into(
                            &StepRequest {
                                x: &r.x,
                                s_from: &[r.s_from],
                                s_to: &[r.s_to],
                                mask: spec.cond.mask_slice(),
                                guidance: spec.cond.guidance,
                                seeds: &[spec.seed],
                            },
                            out.as_mut_slice(),
                        );
                        Completion { key: r.key, out, batch_rows: 1 }
                    })
                    .collect();
                rows = task.poll(done);
            }
            assert!(task.finished());
            let spine = task.take_spine();
            (task.finalize(), spine, spine_rows)
        };

        let m = spec.partition().num_blocks() as u64;
        let (fresh, spine, fresh_spine_rows) = run(new_task(&x0, &spec, &pool, epc));
        assert_eq!(fresh_spine_rows, m, "a fresh run executes the full serial spine");
        let spine = spine.expect("a finished SRDS task yields its spine");
        assert_eq!(spine.len(), m as usize);

        let (warm, rewarm, warm_spine_rows) =
            run(new_warm_task(&x0, &spec, &pool, epc, spine));
        assert_eq!(warm_spine_rows, 0, "a warm run executes zero spine rows");
        assert_eq!(warm.sample, fresh.sample, "warm vs fresh bit-identity");
        assert_eq!(warm.stats.iters, fresh.stats.iters);
        assert_eq!(warm.stats.converged, fresh.stats.converged);
        assert_eq!(
            warm.stats.eff_serial_evals + m * epc,
            fresh.stats.eff_serial_evals,
            "warm accounting drops exactly the skipped sweep"
        );
        assert!(
            warm.stats.total_evals < fresh.stats.total_evals,
            "warm runs do strictly less engine work"
        );
        // Warm tasks re-yield the spine, so a cache re-stock is a pure
        // recency refresh of the same shared buffers.
        assert!(rewarm.is_some());

        // A mismatched spine (wrong kind / wrong block count) degrades
        // to a correct cold start, never a wrong warm one.
        let seq = SamplerSpec::sequential(25).with_seed(21);
        let (cold, no_spine, _) =
            run(new_warm_task(&x0, &seq, &pool, epc, vec![pool.take(&x0)]));
        assert!(no_spine.is_none(), "sequential tasks have no spine");
        assert_eq!(cold.sample, drive(&be, &x0, &seq).sample);
    }

    /// `drive`, draining [`SamplerTask::take_progress`] after every poll
    /// round — the dispatcher's streaming loop, synchronously.
    fn drive_streaming(
        backend: &dyn StepBackend,
        x0: &[f32],
        spec: &SamplerSpec,
    ) -> (SampleOutput, Vec<IterateEvent>) {
        let pool = BufPool::new();
        let mut task = new_task(x0, spec, &pool, backend.evals_per_step() as u64);
        let mut rows = task.start();
        let mut events = task.take_progress();
        while !rows.is_empty() {
            let done: Vec<Completion> = rows
                .drain(..)
                .map(|r| {
                    let mut out = pool.get(r.x.len());
                    backend.step_into(
                        &StepRequest {
                            x: &r.x,
                            s_from: &[r.s_from],
                            s_to: &[r.s_to],
                            mask: spec.cond.mask_slice(),
                            guidance: spec.cond.guidance,
                            seeds: &[spec.seed],
                        },
                        out.as_mut_slice(),
                    );
                    Completion { key: r.key, out, batch_rows: 1 }
                })
                .collect();
            rows = task.poll(done);
            events.extend(task.take_progress());
        }
        assert!(task.finished());
        (task.finalize(), events)
    }

    #[test]
    fn streaming_task_publishes_every_completed_iterate() {
        // The anytime property as a stream: a τ = 0 run records exactly
        // max_iters iterate events, in order, and each event's sample is
        // bit-identical to the corresponding entry of the keep_iterates
        // trail (events share the same grid cells by refcount).
        let be = backend();
        let x0 = prior_sample(64, 23);
        let full = crate::coordinator::srds(
            &be,
            &x0,
            &SamplerSpec::srds(25).with_tol(0.0).with_max_iters(4).with_iterates().with_seed(23),
        );
        let spec = SamplerSpec::srds(25).with_tol(0.0).with_max_iters(4).with_stream().with_seed(23);
        let (out, events) = drive_streaming(&be, &x0, &spec);
        assert_eq!(events.len(), out.stats.iters, "one event per refinement");
        for (k, ev) in events.iter().enumerate() {
            assert_eq!(ev.iter, k + 1, "events arrive in iteration order");
            assert!(ev.residual.is_finite());
            assert_eq!(ev.residual, out.stats.per_iter[k].residual);
            // iterates[0] is the coarse init; iterate p sits at index p.
            assert_eq!(ev.sample.to_vec(), full.iterates[k + 1]);
        }
        assert_eq!(
            events.last().unwrap().sample.to_vec(),
            out.sample,
            "the final iterate event IS the final sample"
        );
        // Streaming never changes numerics.
        let plain = drive(&be, &x0, &SamplerSpec::srds(25).with_tol(0.0).with_max_iters(4).with_seed(23));
        assert_eq!(out.sample, plain.sample);
        assert_eq!(out.stats.iters, plain.stats.iters);
    }

    #[test]
    fn non_streaming_tasks_record_no_progress() {
        let be = backend();
        let x0 = prior_sample(64, 29);
        let (_, events) =
            drive_streaming(&be, &x0, &SamplerSpec::srds(25).with_tol(0.0).with_max_iters(3).with_seed(29));
        assert!(events.is_empty(), "progress is opt-in via spec.stream");
        for kind in ["sequential", "paradigms", "parataa"] {
            let s = registry().parse(kind).unwrap();
            let spec = SamplerSpec::for_kind(16, s.kind()).with_tol(1e-4).with_stream().with_seed(29);
            let (_, events) = drive_streaming(&be, &x0, &spec);
            assert!(events.is_empty(), "{kind}: no anytime anchor, no progress events");
        }
    }

    #[test]
    fn force_finish_truncates_to_newest_iterate_honestly() {
        // Timeout before any refinement completed: the task converges on
        // the coarse init (iterate 0), reports timed_out + !converged,
        // and the sample is exactly the untruncated run's iterate 0.
        let be = backend();
        let x0 = prior_sample(64, 31);
        let full = crate::coordinator::srds(
            &be,
            &x0,
            &SamplerSpec::srds(25).with_tol(0.0).with_max_iters(4).with_iterates().with_seed(31),
        );
        let spec = SamplerSpec::srds(25).with_tol(0.0).with_max_iters(4).with_seed(31);
        let pool = BufPool::new();
        let mut task = new_task(&x0, &spec, &pool, be.evals_per_step() as u64);
        let mut rows = task.start();
        assert!(task.force_finish(), "SRDS always has an anytime answer");
        // The chosen iterate's remaining rows still run (a target, not a
        // hard wall): drive until the task can finalize.
        while !rows.is_empty() && !task.finished() {
            let done: Vec<Completion> = rows
                .drain(..)
                .map(|r| {
                    let mut out = pool.get(r.x.len());
                    be.step_into(
                        &StepRequest {
                            x: &r.x,
                            s_from: &[r.s_from],
                            s_to: &[r.s_to],
                            mask: None,
                            guidance: 0.0,
                            seeds: &[spec.seed],
                        },
                        out.as_mut_slice(),
                    );
                    Completion { key: r.key, out, batch_rows: 1 }
                })
                .collect();
            rows = task.poll(done);
        }
        assert!(task.finished());
        let out = task.finalize();
        assert!(out.stats.timed_out, "refinement was actually cut short");
        assert!(!out.stats.converged);
        assert_eq!(out.stats.iters, 0);
        assert_eq!(out.sample, full.iterates[0], "iterate 0 is the coarse init");
    }

    #[test]
    fn force_finish_after_convergence_is_not_a_timeout() {
        // Expiry after the convergence test already fired truncates
        // nothing — the honest path reports a plain converged run.
        let be = backend();
        let x0 = prior_sample(64, 37);
        let spec = SamplerSpec::srds(25).with_tol(1e-4).with_seed(37);
        let plain = drive(&be, &x0, &spec);
        let pool = BufPool::new();
        let mut task = new_task(&x0, &spec, &pool, be.evals_per_step() as u64);
        let mut rows = task.start();
        while !rows.is_empty() {
            let done: Vec<Completion> = rows
                .drain(..)
                .map(|r| {
                    let mut out = pool.get(r.x.len());
                    be.step_into(
                        &StepRequest {
                            x: &r.x,
                            s_from: &[r.s_from],
                            s_to: &[r.s_to],
                            mask: None,
                            guidance: 0.0,
                            seeds: &[spec.seed],
                        },
                        out.as_mut_slice(),
                    );
                    Completion { key: r.key, out, batch_rows: 1 }
                })
                .collect();
            rows = task.poll(done);
        }
        assert!(task.finished());
        assert!(task.force_finish());
        let out = task.finalize();
        assert!(!out.stats.timed_out, "no work was lost — not a timeout");
        assert_eq!(out.sample, plain.sample);
        assert_eq!(out.stats.converged, plain.stats.converged);
    }

    #[test]
    fn kinds_without_the_anytime_anchor_refuse_force_finish() {
        let x0 = prior_sample(64, 41);
        let pool = BufPool::new();
        for kind in ["sequential", "paradigms", "parataa"] {
            let s = registry().parse(kind).unwrap();
            let spec = SamplerSpec::for_kind(16, s.kind()).with_seed(41);
            let mut task = new_task(&x0, &spec, &pool, 1);
            let _ = task.start();
            assert!(!task.force_finish(), "{kind}: no valid early answer to truncate onto");
        }
    }
}
