//! `srds` — the L3 coordinator CLI.
//!
//! ```text
//! srds info                          # artifact + model + sampler inventory
//! srds sample [--model gmm_church] [--solver ddim] [--n 1024]
//!             [--sampler <registry name>] [--backend native|pjrt]
//!             [--tol 2.5e-3] [--norm l1_mean|l2_mean|linf] [--seed 0]
//!             [--max-iters K] [--block B] [--window W] [--history H]
//!             [--class C --guidance W] [--out sample.pgm]
//! srds serve  [--addr 127.0.0.1:7878] [--shards S] [--workers 4]
//!             [--model …] [--solver …] [--backend native|pjrt]
//!             [--batch-wait 2] [--buckets 32,16,8,4,2,1]
//!             [--max-inflight 64] [--class-weights 8,3,1]
//!             [--default-deadline EVALS]
//!             [--spine-cache-cap 64] [--no-coalesce]
//! ```
//!
//! `serve` runs every request on a sharded multi-tenant engine fleet
//! (`exec::router` over `exec::engine`) as an engine-native sampler
//! task: `--shards` sets the fleet width (default: one shard per
//! `--workers`-sized core group — each shard is a full engine with its
//! own dispatcher, worker pool, and buffer pool, and idle shards steal
//! queued rows from saturated siblings), `--workers` sizes each shard's
//! pool, `--batch-wait` bounds how long (ms) an under-filled
//! cross-request batch may linger, `--buckets` lists the preferred batch
//! sizes, descending, and `--max-inflight` caps the in-flight requests
//! admitted per connection (past it, requests are shed immediately with
//! the structured `overloaded` error line — `retry_after_ms` included —
//! so clients back off).
//! `--class-weights` sets the weighted-DRR service shares of the
//! `interactive,standard,batch` QoS lanes, and `--default-deadline`
//! applies an anytime eval budget to requests that don't carry their own
//! `"deadline"` field (SRDS then finalizes from its best completed
//! iterate once the budget is spent).
//! `--spine-cache-cap` sizes each shard's coarse-spine cache (entries;
//! 0 disables): repeat SRDS requests warm-start from the retained
//! iteration-0 boundary states and skip the serial coarse sweep,
//! bit-identically. `--no-coalesce` turns off in-flight coalescing of
//! identical concurrent requests (on by default; coalesced duplicates
//! share one run and fan out bit-identical responses).
//!
//! The serving loop speaks wire protocol v1 (DESIGN.md "Wire protocol
//! v1"): requests carrying `"v": 1` get typed response frames, may set
//! `"stream": true` (SRDS only) to receive every completed anytime
//! iterate as an `iterate` frame before the final, and may set
//! `"timeout_ms"` for a per-request wall-clock budget enforced in the
//! engine dispatcher. Requests without `"v"` keep the exact legacy
//! single-frame responses — no client migration required.
//!
//! `--sampler` accepts any name from `coordinator::api::registry()`;
//! `srds info` lists them. (Argument parsing is in-tree: the offline
//! vendored crate set has no clap.)

use srds::batching::BatchPolicy;
use srds::coordinator::{prior_sample, registry, Conditioning, ConvNorm, SamplerSpec};
use srds::data::make_gmm;
use srds::exec::NativeFactory;
use srds::model::{EpsModel, GmmEps, SmallDenoiser};
use srds::runtime::{PjrtBackend, PjrtFactory, PjrtRuntime};
use srds::server::{serve, ServeConfig};
use srds::solvers::{BackendFactory, Solver, StepBackend};
use std::collections::HashMap;
use std::sync::Arc;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    m
}

fn native_model(model: &str) -> Arc<dyn EpsModel> {
    if model == "small_denoiser" {
        Arc::new(SmallDenoiser::new(256))
    } else {
        Arc::new(GmmEps::new(make_gmm(model.trim_start_matches("gmm_"))))
    }
}

fn make_backend(flags: &HashMap<String, String>) -> srds::Result<(Box<dyn StepBackend>, String)> {
    let model = flags.get("model").cloned().unwrap_or_else(|| "gmm_church".into());
    let solver = Solver::parse(flags.get("solver").map(|s| s.as_str()).unwrap_or("ddim"))
        .ok_or_else(|| anyhow::anyhow!("unknown solver"))?;
    let backend = flags.get("backend").map(|s| s.as_str()).unwrap_or("native");
    let be: Box<dyn StepBackend> = match backend {
        "pjrt" => {
            let rt = Box::leak(Box::new(PjrtRuntime::open_default()?));
            Box::new(PjrtBackend::new(rt, &model, solver)?)
        }
        _ => Box::new(srds::solvers::NativeBackend::new(native_model(&model), solver)),
    };
    Ok((be, model))
}

fn cmd_info() -> srds::Result<()> {
    println!("SRDS — Self-Refining Diffusion Samplers (NeurIPS 2024 reproduction)");
    println!("artifacts dir: {}", srds::artifacts_dir().display());
    match PjrtRuntime::open_default() {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("models: {:?}", rt.manifest().models());
            println!("artifacts: {}", rt.manifest().artifacts.len());
            println!("batch buckets: {:?}", rt.manifest().batch_buckets);
        }
        Err(e) => println!("(artifacts unavailable: {e:#}; run `make artifacts`)"),
    }
    println!("native datasets: church bedroom imagenet64 cifar latent_cond toy2d");
    println!("samplers: {}", registry().list().join(" "));
    println!("wire protocol: v0 (legacy single-frame), v1 (framed; streaming + timeout_ms)");
    Ok(())
}

fn cmd_sample(flags: HashMap<String, String>) -> srds::Result<()> {
    let (be, model) = make_backend(&flags)?;
    let n: usize = flags.get("n").map(|s| s.parse()).transpose()?.unwrap_or(1024);
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let tol: f32 = flags.get("tol").map(|s| s.parse()).transpose()?.unwrap_or(2.5e-3);
    let sampler = flags.get("sampler").cloned().unwrap_or_else(|| "srds".into());
    let cond = match flags.get("class") {
        Some(c) if model.contains("latent_cond") => {
            let g = make_gmm("latent_cond");
            let w: f32 = flags.get("guidance").map(|s| s.parse()).transpose()?.unwrap_or(7.5);
            Conditioning::class(g.class_mask(c.parse()?), w)
        }
        _ => Conditioning::none(),
    };
    let reg = registry();
    let entry = reg.parse(&sampler).ok_or_else(|| {
        anyhow::anyhow!("unknown sampler {sampler:?}; available: {}", reg.list().join(", "))
    })?;
    let mut spec = SamplerSpec::for_kind(n, entry.kind())
        .with_tol(tol)
        .with_seed(seed)
        .with_cond(cond);
    if let Some(k) = flags.get("max-iters") {
        spec = spec.with_max_iters(k.parse()?);
    }
    if let Some(b) = flags.get("block") {
        spec = spec.with_block(b.parse()?);
    }
    if let Some(w) = flags.get("window") {
        spec = spec.with_window(w.parse()?);
    }
    if let Some(h) = flags.get("history") {
        spec = spec.with_history(h.parse()?);
    }
    if let Some(nm) = flags.get("norm") {
        spec = spec.with_norm(
            ConvNorm::parse(nm).ok_or_else(|| anyhow::anyhow!("unknown norm {nm:?}"))?,
        );
    }
    spec.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
    let x0 = prior_sample(be.dim(), seed);
    let t0 = std::time::Instant::now();
    let r = entry.run(be.as_ref(), &x0, &spec);
    let sample = r.sample;
    println!(
        "{}: {} iters (converged={}), eff serial evals {} (pipelined {}), total {}; \
         state pool {} hits / {} misses; wall {:.1} ms",
        entry.name(),
        r.stats.iters,
        r.stats.converged,
        r.stats.eff_serial_evals,
        r.stats.eff_serial_evals_pipelined,
        r.stats.total_evals,
        r.stats.pool_hits,
        r.stats.pool_misses,
        t0.elapsed().as_secs_f64() * 1e3
    );
    let d = sample.len();
    let side = (d as f64).sqrt() as usize;
    if side * side == d {
        println!("{}", srds::viz::ascii_image(&sample, side, side));
        if let Some(path) = flags.get("out") {
            srds::viz::write_pgm(std::path::Path::new(path), &sample, side, side)?;
            println!("wrote {path}");
        }
    } else {
        println!("sample[0..8] = {:?}", &sample[..8.min(d)]);
    }
    Ok(())
}

fn cmd_serve(flags: HashMap<String, String>) -> srds::Result<()> {
    let model = flags.get("model").cloned().unwrap_or_else(|| "gmm_church".into());
    let solver = Solver::parse(flags.get("solver").map(|s| s.as_str()).unwrap_or("ddim"))
        .ok_or_else(|| anyhow::anyhow!("unknown solver"))?;
    let workers: usize = flags.get("workers").map(|s| s.parse()).transpose()?.unwrap_or(4);
    // Fleet width: explicit `--shards N`, else one shard per
    // `workers`-sized core group of this machine.
    let shards: usize = match flags.get("shards") {
        Some(v) => {
            let s: usize = v.parse()?;
            if s == 0 {
                return Err(anyhow::anyhow!("--shards must be >= 1, got 0"));
            }
            s
        }
        None => srds::exec::default_shards(workers),
    };
    let addr = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7878".into());
    // Engine batching knobs: `--batch-wait` is the linger bound in
    // milliseconds (0 = flush eagerly, never hold a row), `--buckets`
    // the descending batch-size preference list, e.g. "32,8,1".
    let mut batch = BatchPolicy::default();
    if let Some(w) = flags.get("batch-wait") {
        let ms: f64 = w.parse()?;
        if !(0.0..=60_000.0).contains(&ms) {
            return Err(anyhow::anyhow!("--batch-wait must be in 0..=60000 ms, got {ms}"));
        }
        batch.max_wait = std::time::Duration::from_secs_f64(ms / 1000.0);
    }
    if let Some(b) = flags.get("buckets") {
        let buckets: Vec<usize> = b
            .split(',')
            .map(|t| t.trim().parse::<usize>())
            .collect::<Result<_, _>>()?;
        if buckets.is_empty() || buckets.contains(&0) {
            return Err(anyhow::anyhow!("--buckets needs a comma list of sizes >= 1"));
        }
        batch.buckets = buckets;
    }
    // QoS lane weights, in interactive,standard,batch order. Zero
    // weights are rejected here (the batcher would clamp them to 1
    // anyway — starvation is not configurable).
    if let Some(w) = flags.get("class-weights") {
        let weights: Vec<u64> = w
            .split(',')
            .map(|t| t.trim().parse::<u64>())
            .collect::<Result<_, _>>()?;
        if weights.len() != 3 || weights.contains(&0) {
            return Err(anyhow::anyhow!(
                "--class-weights needs exactly 3 comma-separated weights >= 1 \
                 (interactive,standard,batch), e.g. 8,3,1"
            ));
        }
        batch.class_weights = [weights[0], weights[1], weights[2]];
    }
    let default_deadline: Option<u64> = match flags.get("default-deadline") {
        Some(v) => {
            let evals: u64 = v.parse()?;
            if evals == 0 {
                return Err(anyhow::anyhow!("--default-deadline must be >= 1 model eval"));
            }
            Some(evals)
        }
        None => None,
    };
    let max_inflight: usize = match flags.get("max-inflight") {
        Some(v) => {
            let k: usize = v.parse()?;
            if k == 0 {
                return Err(anyhow::anyhow!("--max-inflight must be >= 1, got 0"));
            }
            k
        }
        None => srds::server::DEFAULT_MAX_INFLIGHT,
    };
    // Shared-work layer: spine-cache capacity (0 = off) and the
    // coalescing kill switch (for A/B runs; see benches/serving.rs).
    let spine_cache_cap: usize = match flags.get("spine-cache-cap") {
        Some(v) => v.parse()?,
        None => srds::server::DEFAULT_SPINE_CACHE_CAP,
    };
    let coalesce = !flags.contains_key("no-coalesce");
    let factory: Arc<dyn BackendFactory> = match flags.get("backend").map(|s| s.as_str()) {
        Some("pjrt") => Arc::new(PjrtFactory::new(srds::artifacts_dir(), &model, solver)?),
        _ => Arc::new(NativeFactory::new(native_model(&model), solver)),
    };
    serve(ServeConfig {
        addr,
        shards,
        workers,
        model_name: model,
        factory,
        batch,
        max_inflight,
        default_deadline,
        spine_cache_cap,
        coalesce,
    })
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("info");
    let flags = parse_flags(&args[1.min(args.len())..]);
    let r = match cmd {
        "sample" => cmd_sample(flags),
        "serve" => cmd_serve(flags),
        "info" => cmd_info(),
        other => {
            eprintln!("unknown command {other:?}; try: info | sample | serve");
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
