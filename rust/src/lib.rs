//! # SRDS — Self-Refining Diffusion Samplers
//!
//! Production-grade reproduction of *"Self-Refining Diffusion Samplers:
//! Enabling Parallelization via Parareal Iterations"* (NeurIPS 2024) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: the SRDS Parareal sampler
//!   ([`coordinator::srds`]), its pipelined variant
//!   ([`coordinator::pipeline`]), the ParaDiGMS/Picard and ParaTAA
//!   baselines — all behind the unified [`coordinator::api`] sampler
//!   trait + registry — plus the multi-tenant step-level execution
//!   engine ([`exec::engine`]: one shared worker pool, cross-request
//!   batched steps via [`batching`]), a discrete-event simulated-clock
//!   executor ([`exec::simclock`]), and the JSON-line serving loop
//!   ([`server`]) that submits every request into the engine as an
//!   engine-native sampler task ([`exec::task`]: each of the four
//!   registered samplers is a dispatcher-resident state machine — no
//!   per-request threads exist anywhere on the serving path). The
//!   serving loop speaks the versioned wire protocol (DESIGN.md "Wire
//!   protocol v1"): the legacy single-frame dialect byte-for-byte at
//!   `v: 0`, and at `v: 1` typed frames — including `"stream": true`
//!   requests that publish every completed Parareal iterate as an
//!   `iterate` frame (the paper's anytime property on the wire) and
//!   per-request `timeout_ms` wall-clock budgets that finalize SRDS
//!   from its newest iterate. The
//!   engine schedules by QoS class
//!   ([`coordinator::QosClass`]: weighted deficit-round-robin lanes in
//!   [`batching`] so no tenant starves another, anytime eval budgets
//!   that truncate SRDS to its best completed Parareal iterate under
//!   load, and immediate structured `overloaded` shedding at the
//!   admission cap — per-class lanes observable in
//!   [`exec::EngineStats`] and on the wire). Deterministic runs make
//!   cross-request *work sharing* legal: identical in-flight
//!   submissions coalesce into one resident task with fanned-out
//!   bit-identical replies, and a per-shard coarse-spine cache lets a
//!   repeat SRDS request warm-start past the serial coarse sweep
//!   (keyed by [`coordinator::SamplerSpec::cache_key`] +
//!   [`coordinator::state_hash`]; `cache_hits`/`coalesced` counters on
//!   the wire; see DESIGN.md "Shared work across requests"). All
//!   state on the hot path lives in the zero-copy buffer layer ([`buf`]:
//!   the pooled refcounted `StateBuf` slab + the reusable `BatchStage`
//!   staging buffer), and solver steps write in place via the
//!   [`solvers::StepBackend::step_into`] contract — steady-state steps
//!   allocate nothing, observable as `pool_hits`/`pool_misses` in
//!   [`coordinator::RunStats`] and over the wire. The math under those
//!   steps runs on the lane-tiled kernel layer ([`kernels`]: stable-Rust
//!   8-lane chunked loops LLVM autovectorizes — fused scale-adds for the
//!   solver updates, softmax/log-sum-exp + scaled distances for the GMM
//!   score, a blocked matmul for the denoiser — with a fixed per-row
//!   reduction order so each row's output is bit-identical regardless
//!   of batch shape or worker chunk split).
//! * **L2/L1 (python/, build-time only)** — JAX solver-step graphs calling
//!   Pallas kernels, AOT-lowered once to HLO-text artifacts that
//!   [`runtime`] loads and executes via the PJRT C API (`xla` crate).
//!
//! Python never runs on the request path: after `make artifacts` the rust
//! binary is self-contained.
//!
//! See `DESIGN.md` at the repository root for the layer inventory, the
//! `Sampler` trait / registry design, and the "Wire protocol v1"
//! section (request/frame schemas, version negotiation, the streaming
//! lifecycle); the benches under `rust/benches/` print the
//! paper-vs-measured tables.
//!
//! The contracts above are not just prose: `tools/srds-lint` (a
//! standalone, dependency-free analyzer run in CI) mechanically checks
//! the zero-copy hot paths, the lock order, the request-path panic
//! policy, and wire-schema/DESIGN.md sync. See the "Checked invariants"
//! section of `DESIGN.md` for the rule list and the in-source marker
//! and waiver syntax.

pub mod batching;
pub mod buf;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod json;
pub mod kernels;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod report;
pub mod runtime;
pub mod schedule;
pub mod server;
pub mod solvers;
pub mod viz;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Root of the artifacts directory (`make artifacts` output).
///
/// Resolution order: `$SRDS_ARTIFACTS`, then `<crate>/artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("SRDS_ARTIFACTS") {
        return p.into();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
