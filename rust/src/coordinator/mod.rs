//! The paper's L3 contribution: trajectory-parallel diffusion samplers.
//!
//! * [`sequential`] — the baseline `N`-step solve (paper §2.1).
//! * [`srds`] — Self-Refining Diffusion Sampler, Algorithm 1: coarse
//!   init sweep, batched parallel fine solves, sequential
//!   predictor-corrector sweep, early convergence check.
//! * [`pipeline_schedule`] — the pipelined execution schedule of Fig. 4
//!   (same iterates as vanilla SRDS; overlaps iteration `p+1`'s fine
//!   solves with iteration `p`'s sweep). Timing realized in
//!   [`crate::exec`].
//! * [`paradigms`] — ParaDiGMS (Shih et al.), the Picard-iteration
//!   baseline with a sliding window.
//! * [`parataa`] — ParaTAA-style baseline (Tang et al.): fixed-point
//!   iteration on the triangular system with Anderson acceleration.
//!
//! All samplers are written against [`crate::solvers::StepBackend`], so
//! they run identically over the native rust models and the AOT-compiled
//! PJRT artifacts.

pub mod convergence;
pub mod paradigms;
pub mod parataa;
pub mod pipeline;
pub mod sequential;
pub mod srds;
pub mod stats;

pub use convergence::ConvNorm;
pub use paradigms::{paradigms, ParadigmsConfig, ParadigmsResult};
pub use parataa::{parataa, ParataaConfig, ParataaResult};
pub use pipeline::{pipeline_schedule, PipelineStats};
pub use sequential::{sequential, sequential_trajectory};
pub use srds::{srds, SrdsResult};
pub use stats::{IterStat, RunStats};

use crate::schedule::Partition;

/// Conditioning information threaded through every sampler.
#[derive(Debug, Clone, Default)]
pub struct Conditioning {
    /// Component mask for guided models (length = model k).
    pub mask: Option<Vec<f32>>,
    /// Classifier-free guidance weight (paper Table 2 uses 7.5).
    pub guidance: f32,
}

impl Conditioning {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn class(mask: Vec<f32>, guidance: f32) -> Self {
        Conditioning { mask: Some(mask), guidance }
    }

    /// Tile the per-sample mask across `rows` batch rows.
    pub(crate) fn tiled_mask(&self, rows: usize) -> Option<Vec<f32>> {
        self.mask.as_ref().map(|m| {
            let mut v = Vec::with_capacity(rows * m.len());
            for _ in 0..rows {
                v.extend_from_slice(m);
            }
            v
        })
    }
}

/// Configuration for one SRDS sampling run.
#[derive(Debug, Clone)]
pub struct SrdsConfig {
    /// Fine-grid steps `N`.
    pub n: usize,
    /// Fine steps per block (`None` → `⌈√N⌉`, the Prop. 4 optimum).
    pub block: Option<usize>,
    /// Convergence tolerance τ on the chosen norm of the *final sample*
    /// change between refinements (Alg. 1 line 13).
    pub tol: f32,
    /// Norm used for the convergence check.
    pub norm: ConvNorm,
    /// Iteration cap (`None` → `num_blocks`, the Prop. 1 worst case).
    pub max_iters: Option<usize>,
    /// Conditioning (guided models).
    pub cond: Conditioning,
    /// Seed for the DDPM noise derivation (ignored by ODE solvers).
    pub seed: u64,
    /// Keep the final-sample iterate after every refinement (Fig. 1/5/7).
    pub keep_iterates: bool,
}

impl SrdsConfig {
    pub fn new(n: usize) -> Self {
        SrdsConfig {
            n,
            block: None,
            tol: 2.5e-3,
            norm: ConvNorm::L1Mean,
            max_iters: None,
            cond: Conditioning::none(),
            seed: 0,
            keep_iterates: false,
        }
    }

    pub fn partition(&self) -> Partition {
        match self.block {
            Some(b) => Partition::with_block(self.n, b),
            None => Partition::sqrt_n(self.n),
        }
    }

    pub fn with_tol(mut self, tol: f32) -> Self {
        self.tol = tol;
        self
    }

    pub fn with_block(mut self, block: usize) -> Self {
        self.block = Some(block);
        self
    }

    pub fn with_max_iters(mut self, k: usize) -> Self {
        self.max_iters = Some(k);
        self
    }

    pub fn with_cond(mut self, cond: Conditioning) -> Self {
        self.cond = cond;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_iterates(mut self) -> Self {
        self.keep_iterates = true;
        self
    }
}

/// Tag xored into chain seeds for the prior draw so the prior stream and
/// the DDPM step-noise stream never collide.
const PRIOR_TAG: u64 = 0x5EED_0000_0000_0F00;

/// Draw the prior sample `x(s=0) ~ N(0, I)` for a chain seed — the same
/// draw every sampler uses, so baselines start from identical noise.
pub fn prior_sample(dim: usize, seed: u64) -> Vec<f32> {
    use crate::data::rng::SplitMix64;
    let mut rng = SplitMix64::new(seed ^ PRIOR_TAG);
    rng.normals_f32(dim)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prior_is_deterministic_per_seed() {
        assert_eq!(prior_sample(8, 1), prior_sample(8, 1));
        assert_ne!(prior_sample(8, 1), prior_sample(8, 2));
    }

    #[test]
    fn config_defaults_follow_paper() {
        let c = SrdsConfig::new(1024);
        let p = c.partition();
        assert_eq!(p.block(), 32);
        assert_eq!(p.num_blocks(), 32);
    }

    #[test]
    fn tiled_mask_repeats() {
        let c = Conditioning::class(vec![1.0, 0.0], 7.5);
        assert_eq!(c.tiled_mask(3).unwrap(), vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0]);
        assert!(Conditioning::none().tiled_mask(3).is_none());
    }
}
