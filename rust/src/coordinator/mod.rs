//! The paper's L3 contribution: trajectory-parallel diffusion samplers.
//!
//! * [`api`] — the unified sampler API: [`SamplerSpec`] (one config for
//!   every sampler), the [`Sampler`] trait returning [`SampleOutput`],
//!   and the [`registry`] the server/CLI/benches dispatch through.
//! * [`sequential`] — the baseline `N`-step solve (paper §2.1).
//! * [`srds`] — Self-Refining Diffusion Sampler, Algorithm 1: coarse
//!   init sweep, batched parallel fine solves, sequential
//!   predictor-corrector sweep, early convergence check.
//! * [`pipeline_schedule`] — the pipelined execution schedule of Fig. 4
//!   (same iterates as vanilla SRDS; overlaps iteration `p+1`'s fine
//!   solves with iteration `p`'s sweep). Timing realized in
//!   [`crate::exec`].
//! * [`paradigms`] — ParaDiGMS (Shih et al.), the Picard-iteration
//!   baseline with a sliding window.
//! * [`parataa`] — ParaTAA-style baseline (Tang et al.): fixed-point
//!   iteration on the triangular system with Anderson acceleration.
//!
//! All samplers are written against [`crate::solvers::StepBackend`], so
//! they run identically over the native rust models and the AOT-compiled
//! PJRT artifacts.

pub mod api;
pub mod convergence;
pub mod paradigms;
pub mod parataa;
pub mod pipeline;
pub mod sequential;
pub mod srds;
pub mod stats;

pub use api::{registry, Registry, SampleOutput, Sampler, SamplerKind, SamplerSpec};
pub use convergence::ConvNorm;
pub use paradigms::paradigms;
pub use parataa::parataa;
pub use pipeline::{pipeline_schedule, PipelineStats};
pub use sequential::{sequential, sequential_trajectory};
pub use srds::srds;
pub use stats::{IterStat, RunStats};

/// Conditioning information threaded through every sampler.
#[derive(Debug, Clone, Default)]
pub struct Conditioning {
    /// Component mask for guided models (length = model k).
    pub mask: Option<Vec<f32>>,
    /// Classifier-free guidance weight (paper Table 2 uses 7.5).
    pub guidance: f32,
}

impl Conditioning {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn class(mask: Vec<f32>, guidance: f32) -> Self {
        Conditioning { mask: Some(mask), guidance }
    }

    /// Tile the per-sample mask across `rows` batch rows.
    pub(crate) fn tiled_mask(&self, rows: usize) -> Option<Vec<f32>> {
        self.mask.as_ref().map(|m| {
            let mut v = Vec::with_capacity(rows * m.len());
            for _ in 0..rows {
                v.extend_from_slice(m);
            }
            v
        })
    }
}

/// Tag xored into chain seeds for the prior draw so the prior stream and
/// the DDPM step-noise stream never collide.
const PRIOR_TAG: u64 = 0x5EED_0000_0000_0F00;

/// Draw the prior sample `x(s=0) ~ N(0, I)` for a chain seed — the same
/// draw every sampler uses, so baselines start from identical noise.
pub fn prior_sample(dim: usize, seed: u64) -> Vec<f32> {
    use crate::data::rng::SplitMix64;
    let mut rng = SplitMix64::new(seed ^ PRIOR_TAG);
    rng.normals_f32(dim)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prior_is_deterministic_per_seed() {
        assert_eq!(prior_sample(8, 1), prior_sample(8, 1));
        assert_ne!(prior_sample(8, 1), prior_sample(8, 2));
    }

    #[test]
    fn tiled_mask_repeats() {
        let c = Conditioning::class(vec![1.0, 0.0], 7.5);
        assert_eq!(c.tiled_mask(3).unwrap(), vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0]);
        assert!(Conditioning::none().tiled_mask(3).is_none());
    }
}
