//! The paper's L3 contribution: trajectory-parallel diffusion samplers.
//!
//! * [`api`] — the unified sampler API: [`SamplerSpec`] (one config for
//!   every sampler), the [`Sampler`] trait returning [`SampleOutput`],
//!   and the [`registry`] the server/CLI/benches dispatch through.
//! * [`sequential`] — the baseline `N`-step solve (paper §2.1).
//! * [`srds`] — Self-Refining Diffusion Sampler, Algorithm 1: coarse
//!   init sweep, batched parallel fine solves, sequential
//!   predictor-corrector sweep, early convergence check.
//! * [`pipeline_schedule`] — the pipelined execution schedule of Fig. 4
//!   (same iterates as vanilla SRDS; overlaps iteration `p+1`'s fine
//!   solves with iteration `p`'s sweep). Timing realized in
//!   [`crate::exec`].
//! * [`paradigms`] — ParaDiGMS (Shih et al.), the Picard-iteration
//!   baseline with a sliding window.
//! * [`parataa`] — ParaTAA-style baseline (Tang et al.): fixed-point
//!   iteration on the triangular system with Anderson acceleration.
//!
//! All samplers are written against [`crate::solvers::StepBackend`], so
//! they run identically over the native rust models and the AOT-compiled
//! PJRT artifacts.

pub mod api;
pub mod convergence;
pub mod paradigms;
pub mod parataa;
pub mod pipeline;
pub mod sequential;
pub mod srds;
pub mod stats;

pub use api::{registry, state_hash, QosClass, Registry, SampleOutput, Sampler, SamplerKind, SamplerSpec};
pub use convergence::ConvNorm;
pub use paradigms::paradigms;
pub use parataa::parataa;
pub use pipeline::{pipeline_schedule, PipelineStats};
pub use sequential::{sequential, sequential_trajectory};
pub use srds::srds;
pub use stats::{IterStat, RunStats};

/// Conditioning information threaded through every sampler.
///
/// The mask is refcounted: the engine attaches it to every step row it
/// emits, and an `Arc` clone per row beats copying `k` floats per row
/// (requests at paper scale emit thousands of rows from one mask).
#[derive(Debug, Clone, Default)]
pub struct Conditioning {
    /// Component mask for guided models (length = model k), shared.
    pub mask: Option<std::sync::Arc<[f32]>>,
    /// Classifier-free guidance weight (paper Table 2 uses 7.5).
    pub guidance: f32,
}

impl Conditioning {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn class(mask: Vec<f32>, guidance: f32) -> Self {
        Conditioning { mask: Some(mask.into()), guidance }
    }

    /// The single-sample mask as a slice (what single-row step requests
    /// take directly — no tiling, no allocation).
    pub fn mask_slice(&self) -> Option<&[f32]> {
        self.mask.as_deref()
    }

    /// Tile the mask across up to `max_rows` batch rows **once per run**;
    /// the returned [`TiledMask`] hands out row-count slices for every
    /// batched step afterwards. Replaces the old per-call `tiled_mask`,
    /// which re-allocated the tiling on every single coarse/fine call.
    pub(crate) fn tiler(&self, max_rows: usize) -> TiledMask {
        match &self.mask {
            None => TiledMask { buf: Vec::new(), k: 0 },
            Some(m) => {
                let mut buf = Vec::with_capacity(max_rows * m.len());
                for _ in 0..max_rows {
                    buf.extend_from_slice(m);
                }
                TiledMask { buf, k: m.len() }
            }
        }
    }
}

/// A mask tiled once per run (see [`Conditioning::tiler`]).
pub(crate) struct TiledMask {
    buf: Vec<f32>,
    k: usize,
}

impl TiledMask {
    /// The `(rows, k)` mask slice, or `None` when unconditioned.
    pub(crate) fn rows(&self, rows: usize) -> Option<&[f32]> {
        (self.k > 0).then(|| &self.buf[..rows * self.k])
    }
}

/// Tag xored into chain seeds for the prior draw so the prior stream and
/// the DDPM step-noise stream never collide.
const PRIOR_TAG: u64 = 0x5EED_0000_0000_0F00;

/// Draw the prior sample `x(s=0) ~ N(0, I)` for a chain seed — the same
/// draw every sampler uses, so baselines start from identical noise.
pub fn prior_sample(dim: usize, seed: u64) -> Vec<f32> {
    use crate::data::rng::SplitMix64;
    let mut rng = SplitMix64::new(seed ^ PRIOR_TAG);
    rng.normals_f32(dim)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prior_is_deterministic_per_seed() {
        assert_eq!(prior_sample(8, 1), prior_sample(8, 1));
        assert_ne!(prior_sample(8, 1), prior_sample(8, 2));
    }

    #[test]
    fn tiler_tiles_once_and_slices_per_row_count() {
        let c = Conditioning::class(vec![1.0, 0.0], 7.5);
        let t = c.tiler(3);
        assert_eq!(t.rows(3).unwrap(), &[1.0, 0.0, 1.0, 0.0, 1.0, 0.0]);
        assert_eq!(t.rows(1).unwrap(), &[1.0, 0.0], "smaller batches slice the same tiling");
        assert_eq!(c.mask_slice().unwrap(), &[1.0, 0.0]);
        let none = Conditioning::none();
        assert!(none.tiler(3).rows(3).is_none());
        assert!(none.mask_slice().is_none());
    }
}
