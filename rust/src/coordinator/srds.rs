//! SRDS — Algorithm 1 of the paper.
//!
//! ```text
//! x^0_0 ~ N(0, I)
//! x^0_i = G(x^0_{i-1})                      # coarse init sweep
//! repeat p = 1, 2, …:
//!   y_i  = F(x^{p-1}_{i-1})   ∀i in parallel  # batched fine solves
//!   cur_i = G(x^p_{i-1})      sequentially    # coarse sweep
//!   x^p_i = y_i + cur_i − prev_i              # predictor-corrector
//!   prev_i = cur_i
//! until |x^p_M − x^{p-1}_M| < τ
//! ```
//!
//! The fine solves for all blocks advance in lockstep through one
//! *batched* step request per fine-step index — this is the paper's
//! batched-inference benefit (§3.4): a single sample generation fills the
//! device batch dimension with its own trajectory blocks.

use super::{Conditioning, IterStat, RunStats, SampleOutput, SamplerSpec};
use crate::buf::{BatchStage, BufPool, StateBuf};
use crate::schedule::Partition;
use crate::solvers::{StepBackend, StepRequest};
use std::time::Instant;

/// One coarse step `G`: a single solver step across a whole block,
/// written into `out`. Single row — the sample mask *is* the row mask,
/// so there is no tiling on the coarse path at all (it used to re-tile
/// on every call).
// lint: hot-path
fn coarse_step(
    backend: &dyn StepBackend,
    x: &[f32],
    s_from: f32,
    s_to: f32,
    cond: &Conditioning,
    seed: u64,
    out: &mut [f32],
) {
    backend.step_into(
        &StepRequest {
            x,
            s_from: &[s_from],
            s_to: &[s_to],
            mask: cond.mask_slice(),
            guidance: cond.guidance,
            seeds: &[seed],
        },
        out,
    );
}

/// Eq. 6's predictor-corrector: `out = y + (G_new − G_old)`. The
/// parenthesization is load-bearing: once the coarse solves agree
/// bitwise the correction is an exact `0.0` and `out` collapses onto the
/// fine solve (Prop. 1's bitwise-equality property). Shared by the
/// vanilla loop below and the engine-resident
/// [`crate::exec::task`] SRDS state machine so the two paths cannot
/// drift apart numerically.
#[inline]
pub(crate) fn corrector(y: &[f32], g_new: &[f32], g_old: &[f32], out: &mut [f32]) {
    for j in 0..out.len() {
        out[j] = y[j] + (g_new[j] - g_old[j]);
    }
}

/// All blocks' fine solves, batched in lockstep, written into the
/// caller's persistent scratch: `stage` is the reused flat staging
/// buffer and `y` the pooled per-block lockstep states (cleared first,
/// so the previous iteration's buffers recycle through `pool`).
///
/// Returns the accounting pair `(serial_fine_steps, total_fine_steps)`;
/// the per-block results are left in `y`.
// lint: hot-path
#[allow(clippy::too_many_arguments)]
fn fine_solves(
    backend: &dyn StepBackend,
    part: &Partition,
    x_prev: &[StateBuf],
    cond: &Conditioning,
    seed: u64,
    pool: &BufPool,
    stage: &mut BatchStage,
    y: &mut Vec<StateBuf>,
) -> (u64, u64) {
    let m = part.num_blocks();
    let d = backend.dim();
    let grid = part.grid();
    let max_len = (0..m).map(|j| part.block_len(j)).max().unwrap_or(0);

    // y[j] starts at the previous iterate of boundary j (block j+1's
    // initial value); rows drop out once their block is fully solved.
    y.clear();
    for xj in x_prev {
        y.push(pool.take(xj));
    }
    let mut serial = 0u64;
    let mut total = 0u64;
    for t in 0..max_len {
        stage.reset(cond.guidance);
        for (j, yj) in y.iter().enumerate() {
            if t >= part.block_len(j) {
                continue;
            }
            let base = part.bound(j) + t;
            stage.push_row(yj, grid.s(base), grid.s(base + 1), seed, cond.mask_slice());
        }
        if stage.is_empty() {
            break;
        }
        let rows = stage.rows();
        let out = stage.execute(backend);
        let mut r = 0usize;
        for (j, yj) in y.iter_mut().enumerate() {
            if t >= part.block_len(j) {
                continue;
            }
            yj.as_mut_slice().copy_from_slice(&out[r * d..(r + 1) * d]);
            r += 1;
        }
        serial += 1;
        total += rows as u64;
    }
    (serial, total)
}

/// Run SRDS from the prior sample `x0`. See module docs for the algorithm.
pub fn srds(backend: &dyn StepBackend, x0: &[f32], spec: &SamplerSpec) -> SampleOutput {
    let t0 = Instant::now();
    let part = spec.partition();
    let m = part.num_blocks();
    let b = part.block();
    let d = backend.dim();
    let epc = backend.evals_per_step() as u64;
    let max_iters = spec.max_iters.unwrap_or(m).max(1);

    // Run-local slab pool + staging: every boundary state, coarse result
    // and fine lockstep state is a pooled StateBuf written in place, so
    // after the first iteration the loop runs entirely on recycled
    // buffers (stats.pool_misses stops growing, stats.pool_hits climbs).
    let pool = BufPool::new();
    let mut stage = BatchStage::new();
    let mut y: Vec<StateBuf> = Vec::new();

    // Coarse init sweep (Alg. 1 lines 2–4).
    let mut x: Vec<StateBuf> = Vec::with_capacity(m + 1);
    x.push(pool.take(x0));
    // prev[0] is never read; an empty placeholder keeps the 1-based
    // block indexing of the paper.
    let mut prev: Vec<StateBuf> = vec![StateBuf::detached(Vec::new())];
    for i in 1..=m {
        let mut g = pool.get(d);
        coarse_step(
            backend,
            &x[i - 1],
            part.s_bound(i - 1),
            part.s_bound(i),
            &spec.cond,
            spec.seed,
            g.as_mut_slice(),
        );
        // Refcount share, not a copy: both are read-only from here and
        // each is replaced (never mutated) by the corrector sweep.
        x.push(g.clone());
        prev.push(g);
    }
    let mut total_evals = m as u64 * epc;
    let mut eff_serial = m as u64 * epc;
    let mut iterates = Vec::new();
    if spec.keep_iterates {
        iterates.push(x[m].to_vec());
    }

    let mut per_iter = Vec::new();
    let mut converged = false;
    let mut iters = 0usize;

    for p in 1..=max_iters {
        let evals_before = total_evals;
        // Parallel fine solves from the previous iterate (line 7–8).
        let (fine_serial, fine_total) = fine_solves(
            backend,
            &part,
            &x[0..m],
            &spec.cond,
            spec.seed,
            &pool,
            &mut stage,
            &mut y,
        );
        total_evals += fine_total * epc;
        eff_serial += fine_serial * epc;

        // Sequential coarse sweep + predictor-corrector (lines 9–12).
        let x_final_prev = x[m].clone();
        for i in 1..=m {
            let mut cur = pool.get(d);
            coarse_step(
                backend,
                &x[i - 1],
                part.s_bound(i - 1),
                part.s_bound(i),
                &spec.cond,
                spec.seed,
                cur.as_mut_slice(),
            );
            let mut xi = pool.get(d);
            corrector(&y[i - 1], &cur, &prev[i], xi.as_mut_slice());
            x[i] = xi; // the replaced buffers return to the pool
            prev[i] = cur;
        }
        total_evals += m as u64 * epc;
        eff_serial += m as u64 * epc;

        iters = p;
        let residual = spec.norm.dist(&x[m], &x_final_prev);
        per_iter.push(IterStat { iter: p, residual, evals: total_evals - evals_before });
        if spec.keep_iterates {
            iterates.push(x[m].to_vec());
        }
        // Line 13: convergence on the final generation; Prop. 1 makes
        // p == m exact regardless of τ.
        if residual < spec.tol || p >= m {
            converged = true;
            break;
        }
    }

    // Pipelined schedule accounting (Prop. 2 proof): iteration p's last
    // fine solve finishes at (M·p + B − p) coarse-equivalent steps.
    let eff_pipelined = if iters == 0 {
        m as u64 * epc
    } else {
        ((m * iters + b).saturating_sub(iters)) as u64 * epc
    };

    let ps = pool.stats();
    let stats = RunStats {
        iters,
        converged,
        // The blocking coordinator path has no scheduler above it to
        // trade refinement against; anytime truncation is the engine
        // task's job (`exec::task::SrdsTask`).
        deadline_hit: false,
        timed_out: false,
        eff_serial_evals: eff_serial,
        eff_serial_evals_pipelined: eff_pipelined,
        total_evals,
        wall: t0.elapsed(),
        // Boundary states x (M+1), previous coarse results (M+1), and
        // the fine solves (M) — 3M+2 states, the O(√N) memory of §3.6.
        peak_states: 3 * m + 2,
        batch_occupancy: 0.0,
        engine_rows: 0,
        pool_hits: ps.hits,
        pool_misses: ps.misses,
        per_iter,
    };
    SampleOutput { sample: x.pop().unwrap().into_vec(), stats, iterates }
}

#[cfg(test)]
mod tests {
    use super::super::{prior_sample, sequential, Conditioning, SamplerSpec};
    use super::*;
    use crate::data::make_gmm;
    use crate::model::{AffineModel, GmmEps};
    use crate::solvers::{NativeBackend, Solver};
    use std::sync::Arc;

    fn gmm_backend(name: &str, solver: Solver) -> NativeBackend {
        NativeBackend::new(Arc::new(GmmEps::new(make_gmm(name))), solver)
    }

    #[test]
    fn converges_to_sequential_solution() {
        let be = gmm_backend("toy2d", Solver::Ddim);
        let x0 = prior_sample(2, 11);
        let (seq, _) = sequential(&be, &x0, 25, &Conditioning::none(), 11);
        let spec = SamplerSpec::srds(25).with_tol(1e-7).with_seed(11);
        let res = srds(&be, &x0, &spec);
        let d = spec.norm.dist(&res.sample, &seq);
        assert!(d < 1e-5, "srds vs sequential {d}");
    }

    #[test]
    fn worst_case_iterations_give_exact_equality() {
        // Prop. 1: after M iterations SRDS equals sequential bit-for-bit
        // (identical float op sequences once the corrector telescopes).
        let be = gmm_backend("toy2d", Solver::Ddim);
        let x0 = prior_sample(2, 3);
        let n = 16;
        let (seq, _) = sequential(&be, &x0, n, &Conditioning::none(), 3);
        let spec = SamplerSpec::srds(n).with_tol(0.0).with_max_iters(4).with_seed(3);
        let res = srds(&be, &x0, &spec);
        assert_eq!(res.sample, seq, "bitwise equality after sqrt(N) iterations");
        assert_eq!(res.stats.iters, 4);
    }

    #[test]
    fn eval_accounting_matches_formulas() {
        let be = gmm_backend("toy2d", Solver::Ddim);
        let x0 = prior_sample(2, 1);
        let spec = SamplerSpec::srds(25).with_tol(0.0).with_max_iters(1);
        let res = srds(&be, &x0, &spec);
        // init M + (fine B + sweep M) = 5 + 5 + 5 = 15 (Table 3, N=25).
        assert_eq!(res.stats.eff_serial_evals, 15);
        // pipelined: M·p + B − p = 5 + 5 − 1 = 9 (Table 3).
        assert_eq!(res.stats.eff_serial_evals_pipelined, 9);
        // total = M + (N + M) = 5 + 30 = 35.
        assert_eq!(res.stats.total_evals, 35);
    }

    #[test]
    fn early_convergence_beats_worst_case() {
        let be = gmm_backend("church", Solver::Ddim);
        let x0 = prior_sample(64, 9);
        let spec = SamplerSpec::srds(256).with_tol(2.5e-3).with_seed(9);
        let res = srds(&be, &x0, &spec);
        assert!(res.stats.converged);
        assert!(
            res.stats.iters < 16,
            "expected early convergence, took {} iterations",
            res.stats.iters
        );
    }

    #[test]
    fn iterates_are_recorded_and_improve() {
        let be = gmm_backend("toy2d", Solver::Ddim);
        let x0 = prior_sample(2, 21);
        let (seq, _) = sequential(&be, &x0, 36, &Conditioning::none(), 21);
        let spec =
            SamplerSpec::srds(36).with_tol(0.0).with_max_iters(6).with_iterates().with_seed(21);
        let res = srds(&be, &x0, &spec);
        assert_eq!(res.iterates.len(), 7); // init + 6 refinements
        let err_first = spec.norm.dist(&res.iterates[0], &seq);
        let err_last = spec.norm.dist(res.iterates.last().unwrap(), &seq);
        assert!(err_last <= err_first, "{err_last} vs {err_first}");
        assert_eq!(err_last, 0.0, "exact after M iterations");
    }

    #[test]
    fn non_square_n_still_converges_exactly() {
        // Paper footnote 2: N need not be a perfect square.
        let be = gmm_backend("toy2d", Solver::Ddim);
        let x0 = prior_sample(2, 5);
        for n in [7usize, 27, 40] {
            let (seq, _) = sequential(&be, &x0, n, &Conditioning::none(), 5);
            let part = SamplerSpec::srds(n).partition();
            let spec = SamplerSpec::srds(n)
                .with_tol(0.0)
                .with_max_iters(part.num_blocks())
                .with_seed(5);
            let res = srds(&be, &x0, &spec);
            assert_eq!(res.sample, seq, "n={n}");
        }
    }

    #[test]
    fn ddpm_solver_converges_with_deterministic_noise() {
        let be = gmm_backend("toy2d", Solver::Ddpm);
        let x0 = prior_sample(2, 13);
        let (seq, _) = sequential(&be, &x0, 16, &Conditioning::none(), 13);
        let spec = SamplerSpec::srds(16).with_tol(0.0).with_max_iters(4).with_seed(13);
        let res = srds(&be, &x0, &spec);
        assert_eq!(res.sample, seq, "Parareal over the DDPM map is exact too");
    }

    #[test]
    fn guided_sampling_runs() {
        let gmm = make_gmm("latent_cond");
        let mask = gmm.class_mask(2);
        let be = NativeBackend::new(Arc::new(GmmEps::new(gmm)), Solver::Ddim);
        let x0 = prior_sample(256, 2);
        let cond = Conditioning::class(mask, 7.5);
        let (seq, _) = sequential(&be, &x0, 25, &cond, 2);
        let spec = SamplerSpec::srds(25).with_tol(1e-6).with_cond(cond).with_seed(2);
        let res = srds(&be, &x0, &spec);
        let d = spec.norm.dist(&res.sample, &seq);
        assert!(d < 1e-4, "guided srds vs sequential {d}");
    }

    #[test]
    fn steady_state_iterations_allocate_no_buffers() {
        // The zero-copy claim, run-local: more refinement iterations must
        // not allocate more state buffers — after the first iteration the
        // pool serves everything from its free lists.
        let be = gmm_backend("church", Solver::Ddim);
        let x0 = prior_sample(64, 9);
        let run = |k: usize| {
            srds(&be, &x0, &SamplerSpec::srds(256).with_tol(0.0).with_max_iters(k).with_seed(9))
        };
        let short = run(2);
        let long = run(8);
        assert!(short.stats.pool_misses > 0, "states do come from the pool");
        assert_eq!(
            short.stats.pool_misses, long.stats.pool_misses,
            "iterations past warm-up allocated fresh buffers"
        );
        assert!(long.stats.pool_hits > short.stats.pool_hits, "recycling is happening");
    }

    #[test]
    fn affine_model_converges_fast() {
        // Linear ODE: parareal converges superlinearly; expect << M iters.
        let be = NativeBackend::new(Arc::new(AffineModel::new(8, 0.4, 0.1)), Solver::Ddim);
        let x0 = prior_sample(8, 4);
        let spec = SamplerSpec::srds(144).with_tol(1e-5).with_seed(4);
        let res = srds(&be, &x0, &spec);
        assert!(res.stats.converged);
        assert!(res.stats.iters <= 8, "iters = {}", res.stats.iters);
    }
}
