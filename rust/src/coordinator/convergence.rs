//! Convergence criteria (Alg. 1 line 13 and the baselines' per-point
//! checks).

/// Norm used to measure the change between consecutive iterates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConvNorm {
    /// Mean absolute change per dimension — the paper's pixel-ℓ1
    /// criterion (§4.1), in native units.
    L1Mean,
    /// Root mean squared change per dimension (ParaDiGMS uses an ℓ2-style
    /// per-point criterion).
    L2Mean,
    /// Max absolute change.
    LInf,
}

impl ConvNorm {
    /// Distance between two equal-length vectors under this norm.
    pub fn dist(self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            ConvNorm::L1Mean => {
                a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32
            }
            ConvNorm::L2Mean => (a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f32>()
                / a.len() as f32)
                .sqrt(),
            ConvNorm::LInf => a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0, f32::max),
        }
    }

    pub const ALL: [ConvNorm; 3] = [ConvNorm::L1Mean, ConvNorm::L2Mean, ConvNorm::LInf];

    pub fn name(self) -> &'static str {
        match self {
            ConvNorm::L1Mean => "l1_mean",
            ConvNorm::L2Mean => "l2_mean",
            ConvNorm::LInf => "linf",
        }
    }

    /// Inverse of [`ConvNorm::name`] (the JSON protocol / CLI spelling).
    pub fn parse(s: &str) -> Option<ConvNorm> {
        ConvNorm::ALL.into_iter().find(|n| n.name() == s)
    }
}

/// Map the paper's pixel-space tolerance (values in `[0, 255]`) to this
/// repo's native data units. The GMM zoo has a data range of roughly
/// `[-3, 3]` (≈ 6 units across), so `τ_native = τ_255 · 6 / 255`.
pub fn tol_from_pixel255(tau_255: f32) -> f32 {
    tau_255 * 6.0 / 255.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms_on_known_vectors() {
        let a = [0.0f32, 0.0, 0.0, 0.0];
        let b = [1.0f32, -1.0, 2.0, 0.0];
        assert_eq!(ConvNorm::L1Mean.dist(&a, &b), 1.0);
        assert!((ConvNorm::L2Mean.dist(&a, &b) - (6.0f32 / 4.0).sqrt()).abs() < 1e-6);
        assert_eq!(ConvNorm::LInf.dist(&a, &b), 2.0);
    }

    #[test]
    fn identical_vectors_have_zero_distance() {
        let a = [1.5f32, -2.0, 3.0];
        for n in [ConvNorm::L1Mean, ConvNorm::L2Mean, ConvNorm::LInf] {
            assert_eq!(n.dist(&a, &a), 0.0);
        }
    }

    #[test]
    fn names_roundtrip_through_parse() {
        for n in ConvNorm::ALL {
            assert_eq!(ConvNorm::parse(n.name()), Some(n));
        }
        assert_eq!(ConvNorm::parse("l3"), None);
    }

    #[test]
    fn pixel_tolerance_mapping() {
        let t = tol_from_pixel255(0.1);
        assert!((t - 0.1 * 6.0 / 255.0).abs() < 1e-9);
    }
}
